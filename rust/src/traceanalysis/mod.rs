//! Across-stack bottleneck attribution — the layer that turns captured
//! spans into the paper's inspection workflow.
//!
//! The paper's signature claim is that leveled tracing "gives a holistic
//! view of model execution and helps pinpoint bottlenecks" across HW/SW
//! stack levels. Capturing spans ([`crate::tracing`]) and assembling
//! timelines ([`crate::traceserver`]) is not attribution, though: to *name*
//! the bottleneck you need, per span, the time it spent itself (not in its
//! children), per level/stage totals, the critical path through concurrent
//! execution, and aggregation across repeated runs so one noisy trace
//! doesn't decide the verdict. This module computes all four:
//!
//! - [`SpanTree`]: a repaired tree from a flat span set — orphans (parent
//!   id absent from the trace) become roots, children extending outside
//!   their parent are clipped for accounting, inverted spans clamp to zero
//!   duration; every repair is counted in [`RepairStats`] instead of
//!   silently absorbed.
//! - **Self time**: `duration − union(child intervals ∩ span)` — what the
//!   span itself cost. Non-negative by construction, and for disjoint
//!   in-parent children `self + Σ children == duration` (pinned by the
//!   property tests).
//! - [`SpanTree::critical_path`]: the backward walk from the latest end —
//!   at every instant the deepest span that determines completion — giving
//!   non-overlapping, time-monotone segments whose total is ≤ wall clock
//!   (equal when one root covers the trace).
//! - [`profile`]: aggregation across ≥ 1 timelines by *span signature*
//!   (name + level + a stable tag subset) into count/mean/p50/p99 self-time
//!   stats, per-level and per-stage attribution, and a
//!   [`TraceProfile::verdict`] naming the dominant stage (queueing vs model
//!   compute vs pre/post-processing) and its top contributor. Aggregation
//!   is order-invariant under span shuffling.
//!
//! Stages come from the `stage` span tag when present (the serving-stack
//! spans emitted by [`crate::server::Server::evaluate_batched`] tag
//! themselves) and fall back to level/name heuristics for model-execution
//! traces.

use crate::benchkit::Table;
use crate::metrics::SummaryStats;
use crate::tracing::{Span, TraceLevel};
use crate::traceserver::Timeline;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One node of the repaired span tree.
#[derive(Debug, Clone)]
pub struct SpanNode {
    pub span: Span,
    /// Indices into [`SpanTree::nodes`], sorted by (start, span id).
    pub children: Vec<usize>,
    /// Self time: duration minus the union of child intervals (clipped to
    /// the span). Computed at build time.
    pub self_ns: u64,
}

/// What had to be repaired while building the tree. Surfaced (not hidden)
/// so a malformed producer shows up in reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RepairStats {
    /// Spans whose `parent_id` named no span in the set — promoted to
    /// roots.
    pub orphans: usize,
    /// Children whose interval extended outside their parent — clipped to
    /// the parent for self-time accounting (the span itself is untouched).
    pub clipped_children: usize,
    /// Spans with `end < start` — duration clamps to zero.
    pub inverted: usize,
    /// Spans sharing a span id with an earlier span — dropped from the
    /// tree (ids are the tree's identity).
    pub duplicate_ids: usize,
}

impl RepairStats {
    pub fn total(&self) -> usize {
        self.orphans + self.clipped_children + self.inverted + self.duplicate_ids
    }

    fn absorb(&mut self, other: &RepairStats) {
        self.orphans += other.orphans;
        self.clipped_children += other.clipped_children;
        self.inverted += other.inverted;
        self.duplicate_ids += other.duplicate_ids;
    }
}

/// A repaired span tree (a forest: multiple roots are normal — concurrent
/// agents, orphans) with per-span self time.
#[derive(Debug, Clone)]
pub struct SpanTree {
    pub nodes: Vec<SpanNode>,
    /// Indices of root nodes, sorted by (start, span id).
    pub roots: Vec<usize>,
    pub repairs: RepairStats,
}

/// One hop of the critical path: during `[start_ns, end_ns)` this span was
/// the deepest work determining completion time.
#[derive(Debug, Clone, PartialEq)]
pub struct CriticalSegment {
    pub span_id: u64,
    pub name: String,
    pub level: TraceLevel,
    pub start_ns: u64,
    pub end_ns: u64,
}

impl CriticalSegment {
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

impl SpanTree {
    pub fn from_timeline(tl: &Timeline) -> SpanTree {
        SpanTree::build(&tl.spans)
    }

    /// Build the tree from a flat span set, in any order.
    pub fn build(spans: &[Span]) -> SpanTree {
        let mut repairs = RepairStats::default();
        // Deterministic node order regardless of input order.
        let mut sorted: Vec<Span> = spans.to_vec();
        sorted.sort_by_key(|s| (s.start_ns, s.span_id, s.end_ns));
        let mut nodes: Vec<SpanNode> = Vec::with_capacity(sorted.len());
        let mut index: BTreeMap<u64, usize> = BTreeMap::new();
        for s in sorted {
            if index.contains_key(&s.span_id) {
                repairs.duplicate_ids += 1;
                continue;
            }
            if s.end_ns < s.start_ns {
                repairs.inverted += 1;
            }
            index.insert(s.span_id, nodes.len());
            nodes.push(SpanNode { span: s, children: Vec::new(), self_ns: 0 });
        }
        let mut roots = Vec::new();
        for i in 0..nodes.len() {
            let parent = nodes[i].span.parent_id;
            let own_id = nodes[i].span.span_id;
            match parent {
                // A self-parented span is an orphan, not a 1-cycle.
                Some(p) if p != own_id && index.contains_key(&p) => {
                    let pi = index[&p];
                    nodes[pi].children.push(i);
                }
                Some(_) => {
                    repairs.orphans += 1;
                    roots.push(i);
                }
                None => roots.push(i),
            }
        }
        // Parent-pointer cycles (a→b→…→a) leave whole components
        // unreachable from any root; promote one member per component so no
        // span silently vanishes from attribution.
        let mut reachable = vec![false; nodes.len()];
        let mark = |nodes: &[SpanNode], reachable: &mut [bool], from: &[usize]| {
            let mut stack: Vec<usize> = from.to_vec();
            while let Some(i) = stack.pop() {
                if reachable[i] {
                    continue;
                }
                reachable[i] = true;
                stack.extend(nodes[i].children.iter().copied());
            }
        };
        mark(&nodes, &mut reachable, &roots);
        while let Some(i) = (0..nodes.len()).find(|&i| !reachable[i]) {
            // Cut the cycle at its deterministically-first member.
            if let Some(p) = nodes[i].span.parent_id {
                if let Some(&pi) = index.get(&p) {
                    nodes[pi].children.retain(|&c| c != i);
                }
            }
            repairs.orphans += 1;
            roots.push(i);
            mark(&nodes, &mut reachable, &[i]);
        }
        roots.sort_by_key(|&i| (nodes[i].span.start_ns, nodes[i].span.span_id));
        // Self time: duration minus the union of child intervals clipped to
        // the span.
        for i in 0..nodes.len() {
            let (s, e) = (nodes[i].span.start_ns, nodes[i].span.end_ns.max(nodes[i].span.start_ns));
            // Detach the child list so sorting it can read sibling spans
            // without aliasing `nodes`.
            let mut kids = std::mem::take(&mut nodes[i].children);
            kids.sort_by_key(|&c| (nodes[c].span.start_ns, nodes[c].span.span_id));
            let mut intervals: Vec<(u64, u64)> = Vec::with_capacity(kids.len());
            for &c in &kids {
                let (cs, ce) = (nodes[c].span.start_ns, nodes[c].span.end_ns);
                if cs < s || ce > e {
                    repairs.clipped_children += 1;
                }
                let (cs, ce) = (cs.max(s), ce.min(e));
                if ce > cs {
                    intervals.push((cs, ce));
                }
            }
            intervals.sort_unstable();
            let mut covered = 0u64;
            let mut cursor = s;
            for (cs, ce) in intervals {
                let cs = cs.max(cursor);
                if ce > cs {
                    covered += ce - cs;
                    cursor = ce;
                }
            }
            nodes[i].self_ns = (e - s).saturating_sub(covered);
            nodes[i].children = kids;
        }
        SpanTree { nodes, roots, repairs }
    }

    /// Wall-clock extent of the forest (first start → last end), ns.
    pub fn total_ns(&self) -> u64 {
        let start = self.nodes.iter().map(|n| n.span.start_ns).min().unwrap_or(0);
        let end = self.nodes.iter().map(|n| n.span.end_ns).max().unwrap_or(0);
        end.saturating_sub(start)
    }

    /// Self time summed per level.
    pub fn level_self_ns(&self) -> BTreeMap<TraceLevel, u64> {
        let mut out = BTreeMap::new();
        for n in &self.nodes {
            *out.entry(n.span.level).or_insert(0) += n.self_ns;
        }
        out
    }

    /// The critical path: walk backward from the latest end, at every
    /// instant descending into the child that determines completion (latest
    /// effective end). Returns chronological, non-overlapping segments;
    /// their total is ≤ the wall-clock extent, with equality when one root
    /// covers the whole trace.
    pub fn critical_path(&self) -> Vec<CriticalSegment> {
        let mut segs: Vec<CriticalSegment> = Vec::new();
        let Some(mut t) = self.nodes.iter().map(|n| n.span.end_ns).max() else {
            return segs;
        };
        loop {
            // The root that determines completion at time t: maximal
            // effective end, ties to the later start (the deeper/later
            // work), then span id for determinism.
            let best = self
                .roots
                .iter()
                .copied()
                .filter_map(|r| {
                    let n = &self.nodes[r].span;
                    let eff_end = n.end_ns.min(t);
                    (eff_end > n.start_ns).then_some((eff_end, n.start_ns, n.span_id, r))
                })
                .max_by_key(|&(eff_end, start, id, _)| (eff_end, start, std::cmp::Reverse(id)));
            let Some((eff_end, _, _, r)) = best else { break };
            self.walk(r, 0, eff_end, &mut segs);
            t = self.nodes[r].span.start_ns;
        }
        segs.reverse();
        segs
    }

    /// Critical-path length, ns.
    pub fn critical_path_ns(&self) -> u64 {
        self.critical_path().iter().map(CriticalSegment::duration_ns).sum()
    }

    /// Cover `[max(node.start, floor), t_end]` with segments. `floor` is the
    /// ancestor window's start: a clipped child (one starting before its
    /// parent) must not walk below it, or its segments would overlap work
    /// already attributed outside the parent and the path could exceed wall
    /// clock.
    fn walk(&self, i: usize, floor: u64, t_end: u64, out: &mut Vec<CriticalSegment>) {
        let node = &self.nodes[i];
        let start = node.span.start_ns.max(floor);
        let mut t = t_end.max(start);
        loop {
            // Child with the latest effective end before the cursor.
            let best = node
                .children
                .iter()
                .copied()
                .filter_map(|c| {
                    let n = &self.nodes[c].span;
                    let eff_end = n.end_ns.min(t);
                    let eff_start = n.start_ns.max(start);
                    (eff_end > eff_start).then_some((eff_end, n.start_ns, n.span_id, c))
                })
                .max_by_key(|&(eff_end, s, id, _)| (eff_end, s, std::cmp::Reverse(id)));
            match best {
                None => {
                    if t > start {
                        out.push(self.segment(i, start, t));
                    }
                    return;
                }
                Some((eff_end, _, _, c)) => {
                    if t > eff_end {
                        out.push(self.segment(i, eff_end, t));
                    }
                    self.walk(c, start, eff_end, out);
                    t = self.nodes[c].span.start_ns.max(start);
                    if t <= start {
                        return;
                    }
                }
            }
        }
    }

    fn segment(&self, i: usize, start_ns: u64, end_ns: u64) -> CriticalSegment {
        let s = &self.nodes[i].span;
        CriticalSegment {
            span_id: s.span_id,
            name: s.name.clone(),
            level: s.level,
            start_ns,
            end_ns,
        }
    }
}

/// Serving-stack stage a span belongs to. The explicit `stage` tag wins
/// (the batched-dispatch path tags its spans); otherwise FRAMEWORK/SYSTEM
/// spans are model compute and well-known MODEL-level names classify
/// themselves. `idle` marks time with no work in flight (the serving root's
/// self time) and is excluded from the bottleneck verdict — absence of load
/// is not a bottleneck.
pub const STAGES: &[&str] =
    &["batching", "queueing", "compute", "preprocessing", "postprocessing", "idle", "other"];

pub fn stage_of(span: &Span) -> &'static str {
    if let Some(tag) = span.tag("stage") {
        return STAGES.iter().find(|s| **s == tag).copied().unwrap_or("other");
    }
    match span.level {
        TraceLevel::Framework | TraceLevel::System => "compute",
        _ => match span.name.as_str() {
            "preprocess" => "preprocessing",
            "postprocess" => "postprocessing",
            "predict" | "batch_predict" | "batch_service" => "compute",
            "batching_wait" => "batching",
            "queue_wait" => "queueing",
            _ => "other",
        },
    }
}

/// Identity used to aggregate spans across repeated runs: name + level + a
/// stable subset of tags. Two spans with the same signature are "the same
/// stage observed again".
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct SpanSignature {
    pub name: String,
    pub level: TraceLevel,
    /// The [`SIGNATURE_TAGS`] the span carries, in that fixed order.
    pub tags: Vec<(String, String)>,
}

/// Tags that distinguish signatures (kind = layer type, tenant = traffic
/// class, stage = serving stage). Everything else — per-request ids, batch
/// indices, timings — is noise that would shatter the aggregation.
pub const SIGNATURE_TAGS: &[&str] = &["stage", "kind", "tenant"];

impl SpanSignature {
    pub fn of(span: &Span) -> SpanSignature {
        SpanSignature {
            name: span.name.clone(),
            level: span.level,
            tags: SIGNATURE_TAGS
                .iter()
                .filter_map(|k| span.tag(k).map(|v| (k.to_string(), v.to_string())))
                .collect(),
        }
    }

    pub fn label(&self) -> String {
        if self.tags.is_empty() {
            format!("{} [{}]", self.name, self.level.as_str())
        } else {
            let tags: Vec<String> =
                self.tags.iter().map(|(k, v)| format!("{k}={v}")).collect();
            format!("{} [{}] {}", self.name, self.level.as_str(), tags.join(","))
        }
    }
}

/// Aggregated stats for one signature across every analyzed run.
#[derive(Debug, Clone)]
pub struct SignatureStats {
    pub sig: SpanSignature,
    pub count: usize,
    pub total_self_ms: f64,
    /// Per-span self time, ms.
    pub self_ms: SummaryStats,
    /// Per-span duration, ms.
    pub duration_ms: SummaryStats,
}

/// Multi-run attribution profile: per-level and per-stage self time, the
/// top self-time signatures, and the wall-clock / critical-path totals
/// (summed across runs so the `critical ≤ wall` invariant survives
/// aggregation).
#[derive(Debug, Clone)]
pub struct TraceProfile {
    pub runs: usize,
    pub spans: usize,
    pub total_ms: f64,
    pub critical_path_ms: f64,
    pub total_self_ms: f64,
    /// Self time per level, descending.
    pub levels: Vec<(TraceLevel, f64)>,
    /// Self time per stage, descending.
    pub stages: Vec<(String, f64)>,
    /// Top signatures by total self time, descending.
    pub top: Vec<SignatureStats>,
    pub repairs: RepairStats,
}

/// Aggregate one or more timelines (repeated runs, or one run's serving +
/// session traces analyzed separately) into a [`TraceProfile`]. The result
/// is a pure function of the span *sets* — shuffling spans within a
/// timeline changes nothing.
pub fn profile(timelines: &[Timeline], top_k: usize) -> TraceProfile {
    let mut by_sig: BTreeMap<SpanSignature, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    let mut levels: BTreeMap<TraceLevel, f64> = BTreeMap::new();
    let mut stages: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut repairs = RepairStats::default();
    let (mut total_ms, mut critical_ms, mut total_self_ms) = (0.0, 0.0, 0.0);
    let mut spans = 0usize;
    for tl in timelines {
        let tree = SpanTree::from_timeline(tl);
        total_ms += tree.total_ns() as f64 / 1e6;
        critical_ms += tree.critical_path_ns() as f64 / 1e6;
        repairs.absorb(&tree.repairs);
        spans += tree.nodes.len();
        for n in &tree.nodes {
            let self_ms = n.self_ns as f64 / 1e6;
            total_self_ms += self_ms;
            *levels.entry(n.span.level).or_insert(0.0) += self_ms;
            *stages.entry(stage_of(&n.span)).or_insert(0.0) += self_ms;
            let entry = by_sig.entry(SpanSignature::of(&n.span)).or_default();
            entry.0.push(self_ms);
            entry.1.push(n.span.duration_ms());
        }
    }
    let mut top: Vec<SignatureStats> = by_sig
        .into_iter()
        .map(|(sig, (self_ms, dur_ms))| SignatureStats {
            sig,
            count: self_ms.len(),
            total_self_ms: self_ms.iter().sum(),
            self_ms: SummaryStats::of(&self_ms),
            duration_ms: SummaryStats::of(&dur_ms),
        })
        .collect();
    // Descending by total self time; signature order breaks exact ties so
    // the ranking stays deterministic.
    top.sort_by(|a, b| {
        b.total_self_ms
            .partial_cmp(&a.total_self_ms)
            .unwrap()
            .then_with(|| a.sig.cmp(&b.sig))
    });
    top.truncate(top_k);
    let mut levels: Vec<(TraceLevel, f64)> = levels.into_iter().collect();
    levels.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut stages: Vec<(String, f64)> =
        stages.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    stages.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    TraceProfile {
        runs: timelines.len(),
        spans,
        total_ms,
        critical_path_ms: critical_ms,
        total_self_ms,
        levels,
        stages,
        top,
        repairs,
    }
}

impl TraceProfile {
    /// The stage the run is bottlenecked on: the largest self-time share,
    /// `idle` excluded (an underloaded system's dominant "stage" is idle
    /// time, which is not a bottleneck).
    pub fn dominant_stage(&self) -> Option<&str> {
        self.stages.iter().find(|(s, _)| s != "idle").map(|(s, _)| s.as_str())
    }

    /// The automated bottleneck verdict: dominant stage, its share of
    /// non-idle self time, and the top contributing signature.
    pub fn verdict(&self) -> String {
        let Some(stage) = self.dominant_stage() else {
            return "no spans to attribute".to_string();
        };
        let stage_ms =
            self.stages.iter().find(|(s, _)| s == stage).map(|(_, ms)| *ms).unwrap_or(0.0);
        let busy_ms: f64 =
            self.stages.iter().filter(|(s, _)| s != "idle").map(|(_, ms)| ms).sum();
        let share = if busy_ms > 0.0 { stage_ms / busy_ms * 100.0 } else { 0.0 };
        match self.top.iter().find(|t| t.count > 0 && stage_for_sig(&t.sig) == stage) {
            Some(t) => format!(
                "{stage} dominates ({share:.0}% of busy self time); top contributor {} — {:.3} ms total self over {} span(s), p99 {:.3} ms",
                t.sig.label(),
                t.total_self_ms,
                t.count,
                t.self_ms.p99,
            ),
            None => format!("{stage} dominates ({share:.0}% of busy self time)"),
        }
    }

    /// Render the profile as the report's bottleneck section.
    pub fn render(&self, context: &str) -> String {
        let mut out = format!(
            "Bottleneck attribution — {context}\n  runs {} · spans {} · wall {:.3} ms · critical path {:.3} ms ({:.0}% of wall) · repairs {}\n",
            self.runs,
            self.spans,
            self.total_ms,
            self.critical_path_ms,
            if self.total_ms > 0.0 { self.critical_path_ms / self.total_ms * 100.0 } else { 0.0 },
            self.repairs.total(),
        );
        let mut stage_table = Table::new(
            "self time by stage / level",
            &["Stage", "Self (ms)", "Share %"],
        );
        for (stage, ms) in &self.stages {
            stage_table.row(&[
                stage.clone(),
                format!("{ms:.3}"),
                format!("{:.1}", pct(*ms, self.total_self_ms)),
            ]);
        }
        for (level, ms) in &self.levels {
            stage_table.row(&[
                format!("level:{}", level.as_str()),
                format!("{ms:.3}"),
                format!("{:.1}", pct(*ms, self.total_self_ms)),
            ]);
        }
        out.push_str(&stage_table.render());
        let mut top_table = Table::new(
            "top self-time contributors (aggregated by span signature)",
            &["Span", "Count", "Self Σ (ms)", "Self p50", "Self p99", "Dur p99"],
        );
        for t in &self.top {
            top_table.row(&[
                t.sig.label(),
                t.count.to_string(),
                format!("{:.3}", t.total_self_ms),
                format!("{:.3}", t.self_ms.p50),
                format!("{:.3}", t.self_ms.p99),
                format!("{:.3}", t.duration_ms.p99),
            ]);
        }
        out.push_str(&top_table.render());
        out.push_str(&format!("  bottleneck verdict: {}\n", self.verdict()));
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("runs", Json::num(self.runs as f64)),
            ("spans", Json::num(self.spans as f64)),
            ("total_ms", Json::num(self.total_ms)),
            ("critical_path_ms", Json::num(self.critical_path_ms)),
            ("total_self_ms", Json::num(self.total_self_ms)),
            (
                "stages",
                Json::Obj(
                    self.stages
                        .iter()
                        .map(|(s, ms)| (s.clone(), Json::num(*ms)))
                        .collect(),
                ),
            ),
            (
                "levels",
                Json::Obj(
                    self.levels
                        .iter()
                        .map(|(l, ms)| (l.as_str().to_string(), Json::num(*ms)))
                        .collect(),
                ),
            ),
            (
                "top",
                Json::arr(
                    self.top
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("span", Json::str(t.sig.label())),
                                ("count", Json::num(t.count as f64)),
                                ("total_self_ms", Json::num(t.total_self_ms)),
                                ("self_p99_ms", Json::num(t.self_ms.p99)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("verdict", Json::str(self.verdict())),
        ])
    }
}

fn pct(part: f64, whole: f64) -> f64 {
    if whole > 0.0 {
        part / whole * 100.0
    } else {
        0.0
    }
}

/// Stage of an aggregated signature (from its `stage` tag or the same
/// heuristics as [`stage_of`]).
fn stage_for_sig(sig: &SpanSignature) -> &'static str {
    stage_of(&Span {
        trace_id: 0,
        span_id: 0,
        parent_id: None,
        name: sig.name.clone(),
        level: sig.level,
        start_ns: 0,
        end_ns: 0,
        tags: sig.tags.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(
        id: u64,
        parent: Option<u64>,
        name: &str,
        level: TraceLevel,
        start_ms: f64,
        end_ms: f64,
    ) -> Span {
        Span {
            trace_id: 1,
            span_id: id,
            parent_id: parent,
            name: name.to_string(),
            level,
            start_ns: (start_ms * 1e6) as u64,
            end_ns: (end_ms * 1e6) as u64,
            tags: Vec::new(),
        }
    }

    /// root [0,10] with children a [1,4] and b [6,9] → self 4ms.
    fn small_tree() -> Vec<Span> {
        vec![
            span(1, None, "root", TraceLevel::Model, 0.0, 10.0),
            span(2, Some(1), "a", TraceLevel::Framework, 1.0, 4.0),
            span(3, Some(1), "b", TraceLevel::Framework, 6.0, 9.0),
        ]
    }

    #[test]
    fn self_time_subtracts_child_union() {
        let tree = SpanTree::build(&small_tree());
        assert_eq!(tree.roots.len(), 1);
        assert_eq!(tree.repairs, RepairStats::default());
        let root = &tree.nodes[tree.roots[0]];
        assert_eq!(root.span.name, "root");
        assert_eq!(root.self_ns, 4_000_000);
        assert_eq!(root.children.len(), 2);
    }

    #[test]
    fn overlapping_children_count_once() {
        // Children [1,5] and [3,7] overlap: union is 6ms, self 4ms.
        let spans = vec![
            span(1, None, "root", TraceLevel::Model, 0.0, 10.0),
            span(2, Some(1), "a", TraceLevel::Framework, 1.0, 5.0),
            span(3, Some(1), "b", TraceLevel::Framework, 3.0, 7.0),
        ];
        let tree = SpanTree::build(&spans);
        assert_eq!(tree.nodes[tree.roots[0]].self_ns, 4_000_000);
    }

    #[test]
    fn orphans_and_clips_and_inversions_are_counted_not_dropped() {
        let spans = vec![
            span(1, None, "root", TraceLevel::Model, 0.0, 10.0),
            // Orphan: parent 99 absent.
            span(2, Some(99), "lost", TraceLevel::System, 2.0, 3.0),
            // Child sticking out past its parent's end: clipped.
            span(3, Some(1), "long", TraceLevel::Framework, 8.0, 12.0),
            // Inverted span.
            span(4, Some(1), "backwards", TraceLevel::Framework, 6.0, 5.0),
        ];
        let tree = SpanTree::build(&spans);
        assert_eq!(tree.nodes.len(), 4, "every span survives");
        assert_eq!(tree.roots.len(), 2, "orphan promoted to root");
        assert_eq!(tree.repairs.orphans, 1);
        assert_eq!(tree.repairs.clipped_children, 1);
        assert_eq!(tree.repairs.inverted, 1);
        // Root self: 10 − clipped child [8,10] = 8ms (inverted child adds 0).
        let root = tree.roots.iter().find(|&&r| tree.nodes[r].span.name == "root").unwrap();
        assert_eq!(tree.nodes[*root].self_ns, 8_000_000);
    }

    #[test]
    fn duplicate_ids_keep_first_and_count() {
        let mut spans = small_tree();
        spans.push(span(2, Some(1), "dupe", TraceLevel::System, 0.5, 0.6));
        let tree = SpanTree::build(&spans);
        assert_eq!(tree.nodes.len(), 3);
        assert_eq!(tree.repairs.duplicate_ids, 1);
    }

    #[test]
    fn parent_cycles_are_cut_not_lost() {
        let spans = vec![
            span(1, Some(2), "a", TraceLevel::Model, 0.0, 4.0),
            span(2, Some(1), "b", TraceLevel::Model, 1.0, 3.0),
        ];
        let tree = SpanTree::build(&spans);
        assert_eq!(tree.nodes.len(), 2);
        assert_eq!(tree.roots.len(), 1, "cycle cut at one member");
        assert_eq!(tree.repairs.orphans, 1);
        // Both spans reachable → both attributed.
        let total: u64 = tree.nodes.iter().map(|n| n.self_ns).sum();
        assert!(total > 0);
    }

    #[test]
    fn critical_path_descends_into_the_determining_child() {
        let tl = Timeline { trace_id: 1, spans: small_tree() };
        let tree = SpanTree::from_timeline(&tl);
        let path = tree.critical_path();
        let names: Vec<&str> = path.iter().map(|s| s.name.as_str()).collect();
        // Backward from 10: root [9,10], b [6,9], root [4,6], a [1,4],
        // root [0,1] — reversed to chronological.
        assert_eq!(names, vec!["root", "a", "root", "b", "root"]);
        assert_eq!(tree.critical_path_ns(), tree.total_ns());
        // Chronological and non-overlapping.
        for w in path.windows(2) {
            assert!(w[0].end_ns <= w[1].start_ns);
        }
    }

    #[test]
    fn critical_path_of_concurrent_roots_never_exceeds_wall() {
        // Two concurrent roots with a gap: [0,4] and [6,9]; path covers
        // 7ms of the 9ms wall (the 2ms gap is nobody's work).
        let spans = vec![
            span(1, None, "agent0", TraceLevel::Model, 0.0, 4.0),
            span(2, None, "agent1", TraceLevel::Model, 6.0, 9.0),
        ];
        let tree = SpanTree::build(&spans);
        assert_eq!(tree.critical_path_ns(), 7_000_000);
        assert!(tree.critical_path_ns() <= tree.total_ns());
    }

    #[test]
    fn clipped_child_cannot_push_critical_path_past_wall() {
        // A child starting before its parent (clipped for accounting) must
        // not walk below the parent window: without the floor, its segment
        // would overlap the earlier root's and the path would sum to 15 ms
        // against a 10 ms wall.
        let spans = vec![
            span(1, None, "early_root", TraceLevel::Model, 0.0, 5.0),
            span(2, None, "late_root", TraceLevel::Model, 5.0, 10.0),
            span(3, Some(2), "clipped", TraceLevel::System, 0.0, 10.0),
        ];
        let tree = SpanTree::build(&spans);
        assert_eq!(tree.repairs.clipped_children, 1);
        let path = tree.critical_path();
        for w in path.windows(2) {
            assert!(w[0].end_ns <= w[1].start_ns, "overlapping segments: {path:?}");
        }
        assert_eq!(tree.critical_path_ns(), 10_000_000);
        assert!(tree.critical_path_ns() <= tree.total_ns());
        // The clipped child is credited only for its in-parent window.
        let clipped: Vec<_> = path.iter().filter(|s| s.name == "clipped").collect();
        assert_eq!(clipped.len(), 1);
        assert_eq!(clipped[0].start_ns, 5_000_000);
        assert_eq!(clipped[0].end_ns, 10_000_000);
    }

    #[test]
    fn zero_duration_spans_terminate_the_walk() {
        let spans = vec![
            span(1, None, "root", TraceLevel::Model, 0.0, 5.0),
            span(2, Some(1), "instant", TraceLevel::Model, 5.0, 5.0),
            span(3, Some(1), "work", TraceLevel::Model, 0.0, 5.0),
        ];
        let tree = SpanTree::build(&spans);
        let path = tree.critical_path();
        assert!(!path.is_empty());
        assert_eq!(tree.critical_path_ns(), 5_000_000);
    }

    #[test]
    fn stage_classification() {
        let mk = |name: &str, level, tags: Vec<(&str, &str)>| Span {
            trace_id: 0,
            span_id: 0,
            parent_id: None,
            name: name.into(),
            level,
            start_ns: 0,
            end_ns: 0,
            tags: tags.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
        };
        assert_eq!(stage_of(&mk("queue_wait", TraceLevel::Model, vec![])), "queueing");
        assert_eq!(stage_of(&mk("preprocess", TraceLevel::Model, vec![])), "preprocessing");
        assert_eq!(stage_of(&mk("conv1", TraceLevel::Framework, vec![])), "compute");
        assert_eq!(stage_of(&mk("sgemm", TraceLevel::System, vec![])), "compute");
        assert_eq!(stage_of(&mk("evaluate", TraceLevel::Model, vec![])), "other");
        // Explicit tag wins over name heuristics.
        assert_eq!(
            stage_of(&mk("anything", TraceLevel::Model, vec![("stage", "queueing")])),
            "queueing"
        );
        assert_eq!(
            stage_of(&mk("anything", TraceLevel::Model, vec![("stage", "bogus")])),
            "other"
        );
    }

    #[test]
    fn profile_aggregates_across_runs_and_names_the_bottleneck() {
        let mut run = small_tree();
        run.push(span(4, Some(1), "queue_wait", TraceLevel::Model, 4.0, 6.0));
        let tl = Timeline { trace_id: 1, spans: run };
        let p1 = profile(&[tl.clone()], 10);
        let p2 = profile(&[tl.clone(), tl], 10);
        assert_eq!(p1.runs, 1);
        assert_eq!(p2.runs, 2);
        assert_eq!(p2.spans, p1.spans * 2);
        assert!((p2.total_self_ms - 2.0 * p1.total_self_ms).abs() < 1e-9);
        // Signature counts double across runs.
        let count = |p: &TraceProfile, name: &str| {
            p.top.iter().find(|t| t.sig.name == name).map(|t| t.count).unwrap_or(0)
        };
        assert_eq!(count(&p2, "queue_wait"), 2 * count(&p1, "queue_wait"));
        // compute (a 3ms + b 3ms = 6ms) > queueing 2ms > other (root self
        // 2ms after the queue_wait child is added).
        assert_eq!(p1.dominant_stage(), Some("compute"));
        assert!(p1.verdict().contains("compute"), "{}", p1.verdict());
        // Render + JSON carry the verdict.
        assert!(p1.render("test").contains("bottleneck verdict"));
        assert_eq!(
            p1.to_json().get("verdict").unwrap().as_str().unwrap(),
            p1.verdict()
        );
    }

    #[test]
    fn idle_excluded_from_verdict() {
        // A serving root whose self time (idle) dwarfs the work.
        let mut spans = vec![span(1, None, "serve", TraceLevel::Model, 0.0, 100.0)];
        spans[0].tags.push(("stage".into(), "idle".into()));
        spans.push(span(2, Some(1), "batch_service", TraceLevel::Model, 0.0, 5.0));
        let tl = Timeline { trace_id: 1, spans };
        let p = profile(&[tl], 5);
        assert_eq!(p.stages[0].0, "idle", "idle is the largest stage");
        assert_eq!(p.dominant_stage(), Some("compute"), "but not the verdict");
    }

    #[test]
    fn empty_profile_is_sane() {
        let p = profile(&[], 5);
        assert_eq!(p.runs, 0);
        assert_eq!(p.dominant_stage(), None);
        assert_eq!(p.verdict(), "no spans to attribute");
        assert!(p.render("empty").contains("no spans"));
    }
}
