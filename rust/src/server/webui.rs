//! The web UI (F10): a single-page dashboard served by the MLModelScope
//! server — the "push-button" interface of §4.2 ("allows users to specify
//! a model evaluation through simple clicks").
//!
//! The page is static HTML + vanilla JS speaking the same REST API the CLI
//! uses (`/api/models`, `/api/agents`, `/api/evaluate`, `/api/analyze`,
//! `/api/trace/:id`), so everything the UI can do is scriptable — the
//! paper's claim that the web UI and command line are views over one API.

/// The dashboard page.
pub const INDEX_HTML: &str = r#"<!doctype html>
<html>
<head>
<meta charset="utf-8">
<title>MLModelScope-RS</title>
<style>
  body { font-family: ui-monospace, Menlo, monospace; margin: 2rem; background: #101418; color: #d7dde4; }
  h1 { font-size: 1.3rem; } h2 { font-size: 1.05rem; margin-top: 1.6rem; }
  select, button, input { background: #1b222b; color: #d7dde4; border: 1px solid #37414d; padding: .35rem .6rem; border-radius: 4px; }
  button { cursor: pointer; } button:hover { border-color: #6ea8fe; }
  table { border-collapse: collapse; margin-top: .6rem; }
  td, th { border: 1px solid #2a333e; padding: .25rem .6rem; text-align: right; }
  th { background: #161c23; }
  td:first-child, th:first-child { text-align: left; }
  pre { background: #0b0e12; padding: .8rem; border-radius: 6px; overflow-x: auto; }
  .muted { color: #7b8794; }
</style>
</head>
<body>
<h1>MLModelScope-RS — scalable DL benchmarking</h1>
<p class="muted">web UI (F10) over the REST API; everything here is also available via <code>mlms</code> and <code>curl</code>.</p>

<h2>Run an evaluation</h2>
<div>
  model <select id="model"></select>
  scenario <select id="scenario">
    <option value="online">online</option>
    <option value="batched">batched</option>
    <option value="poisson">poisson</option>
  </select>
  batch <input id="batch" value="8" size="4">
  trace <select id="level">
    <option>none</option><option selected>model</option>
    <option>framework</option><option>full</option>
  </select>
  <button onclick="evaluate()">evaluate</button>
</div>
<div id="result"></div>

<h2>Agents</h2><div id="agents"></div>
<h2>Analysis (stored runs)</h2>
<button onclick="analyze()">refresh analysis</button>
<div id="analysis"></div>
<h2>Trace</h2>
<div>trace id <input id="traceid" size="8"> <button onclick="trace()">view</button></div>
<pre id="tracebox" class="muted">run an evaluation with trace ≥ model, then enter its trace id.</pre>

<script>
async function j(path, opts) { const r = await fetch(path, opts); return r.json(); }
function table(rows, cols) {
  if (!rows.length) return '<p class="muted">no data</p>';
  let h = '<table><tr>' + cols.map(c => `<th>${c}</th>`).join('') + '</tr>';
  for (const r of rows) h += '<tr>' + cols.map(c => `<td>${r[c] ?? ''}</td>`).join('') + '</tr>';
  return h + '</table>';
}
async function init() {
  const models = await j('/api/models');
  document.getElementById('model').innerHTML =
    models.map(m => `<option>${m.split(':')[0]}</option>`).join('');
  const agents = await j('/api/agents');
  document.getElementById('agents').innerHTML = table(agents,
    ['id','system','framework','architecture','interconnect','devices']);
}
async function evaluate() {
  const scenario = { kind: document.getElementById('scenario').value,
                     count: 8,
                     batch_size: +document.getElementById('batch').value,
                     batches: 3, rate: 20 };
  const body = { model: document.getElementById('model').value,
                 scenario, trace_level: document.getElementById('level').value };
  document.getElementById('result').innerHTML = '<p class="muted">running…</p>';
  const recs = await j('/api/evaluate', { method: 'POST', body: JSON.stringify(body) });
  if (recs.error) { document.getElementById('result').innerHTML = `<p>${recs.error}</p>`; return; }
  const rows = recs.map(r => ({
    system: r.key.system, device: r.key.device, batch: r.key.batch_size,
    'throughput (items/s)': r.throughput.toFixed(1), trace: r.trace_id,
    'requests': r.latencies.length,
  }));
  document.getElementById('result').innerHTML =
    table(rows, ['system','device','batch','requests','throughput (items/s)','trace']);
}
async function analyze() {
  const models = (await j('/api/models')).map(m => m.split(':')[0]);
  const s = await j('/api/analyze?models=' + models.join(','));
  const rows = s.map(r => ({ model: r.model, accuracy: r.accuracy,
    'online TM (ms)': (r.online_trimmed_mean_ms ?? 0).toFixed(2),
    'p90 (ms)': (r.online_p90_ms ?? 0).toFixed(2),
    'max tput': (r.max_throughput ?? 0).toFixed(1), 'opt batch': r.optimal_batch }));
  document.getElementById('analysis').innerHTML =
    table(rows, ['model','accuracy','online TM (ms)','p90 (ms)','max tput','opt batch']);
}
async function trace() {
  const id = document.getElementById('traceid').value;
  const t = await j('/api/trace/' + id);
  if (t.error) { document.getElementById('tracebox').textContent = t.error; return; }
  const origin = Math.min(...t.spans.map(s => s.start_ns));
  document.getElementById('tracebox').textContent = t.spans.map(s =>
    `[${((s.start_ns - origin)/1e6).toFixed(3).padStart(10)} ms +${((s.end_ns - s.start_ns)/1e6).toFixed(3).padStart(9)} ms] ${s.level.padEnd(9)} ${s.name}`
  ).join('\n');
}
init();
</script>
</body>
</html>
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_references_every_api_endpoint() {
        for ep in ["/api/models", "/api/agents", "/api/evaluate", "/api/analyze", "/api/trace/"] {
            assert!(INDEX_HTML.contains(ep), "missing {ep}");
        }
    }

    #[test]
    fn served_at_root() {
        let server = crate::server::Server::sim_platform(crate::tracing::TraceLevel::None);
        let http = crate::httpd::HttpServer::serve("127.0.0.1:0", server.router()).unwrap();
        // Raw request since the helper client assumes JSON.
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(http.addr()).unwrap();
        write!(s, "GET / HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 200"));
        assert!(buf.contains("MLModelScope-RS"));
        assert!(buf.contains("text/html"));
        http.stop();
    }
}
