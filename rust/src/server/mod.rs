//! The MLModelScope server (§4.3): accepts client requests over REST,
//! resolves agents via the registry, dispatches evaluations over the wire
//! protocol (or in-process), and runs the analysis workflow against the
//! evaluation database.
//!
//! Workload generation note: scenarios are materialized deterministically
//! from `(scenario, seed)` by [`crate::scenario::Workload::generate`]; the
//! server chooses the seed and ships `(scenario, seed)` to the agent, which
//! regenerates the identical schedule — the request load is thus
//! server-defined (paper: "the server generates an inference request load
//! based on the benchmarking scenario") without shipping every request
//! over the wire individually.

pub mod webui;

use crate::agent::{Agent, EvalRequest};
use crate::batcher::admission::{filter_workload, AdmissionConfig};
use crate::batcher::{
    batching_series, plan_batches, Batch, BatchExecutor, BatcherConfig, Dispatcher,
    DispatchOutcome, DispatchWatch, QueueSim,
};
use crate::metrics::ShedSeries;
use crate::tracing::{SimClock, Span, Tracer};
use crate::evaldb::{EvalDb, EvalKey, EvalRecord, RunMeta};
use crate::manifest::SystemRequirements;
use crate::metrics::{BatchingSeries, TenantLatencies};
use crate::pipeline::{Envelope, Payload};
use crate::predictor::InputMode;
use crate::preprocess::Tensor;
use crate::registry::{AgentInfo, Registry};
use crate::scenario::{Scenario, Workload};
use crate::traceserver::TraceServer;
use crate::tracing::TraceLevel;
use crate::util::json::Json;
use crate::util::threadpool::parallel_map;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// A fully-specified evaluation job (the paper's "user input": model,
/// SW stack, system requirements, benchmarking scenario).
#[derive(Debug, Clone)]
pub struct EvalJob {
    pub model: String,
    pub model_version: Option<String>,
    pub requirements: SystemRequirements,
    pub scenario: Scenario,
    pub trace_level: TraceLevel,
    pub input_mode: InputMode,
    pub seed: u64,
    /// Evaluate on every resolved agent (the paper's "or, at the user
    /// request, all of" the resolved agents) instead of one.
    pub all_agents: bool,
    /// Run metadata stamped onto the stored record; the label folds into
    /// the spec digest (see [`crate::evaldb::EvalSpec::run_label`]) so
    /// labeled runs memoize per run line.
    pub run_meta: RunMeta,
    /// Priority-aware admission control applied to the generated workload
    /// before batching: per-tenant token buckets shed over-rate traffic
    /// with typed rejections, and the shed accounting lands in the stored
    /// record's `meta["admission"]`. `None` (the default) admits
    /// everything, preserving the classic workload contract bit-for-bit.
    pub admission: Option<AdmissionConfig>,
}

impl EvalJob {
    pub fn new(model: &str, scenario: Scenario) -> EvalJob {
        EvalJob {
            model: model.to_string(),
            model_version: None,
            requirements: SystemRequirements::any(),
            scenario,
            trace_level: TraceLevel::Model,
            input_mode: InputMode::Direct,
            seed: 42,
            all_agents: false,
            run_meta: RunMeta::default(),
            admission: None,
        }
    }
}

/// Result of a batched multi-agent evaluation: the stored record plus the
/// dispatch accounting and batching series behind it.
pub struct BatchedEval {
    pub record: EvalRecord,
    pub series: BatchingSeries,
    pub outcome: DispatchOutcome,
    /// Queueing-aware latencies grouped by tenant (`"all"` for non-`Mix`
    /// scenarios) — the fairness question's raw material.
    pub per_tenant: TenantLatencies,
    /// True when a [`DispatchWatch`] cut the run short (SLO probe abort);
    /// the record is then *not* stored in the evaluation database and
    /// covers only the completed prefix.
    pub aborted: bool,
    /// Trace holding the serving-stack spans (batching_wait / queue_wait /
    /// batch_service per batch, from the virtual-time schedule). `None`
    /// when the job's trace level is `None` or nothing was scheduled.
    pub serving_trace_id: Option<u64>,
    /// Per-agent session traces (the `batch_predict` spans on each agent's
    /// own clock) — the model-execution side of the attribution.
    pub session_trace_ids: Vec<u64>,
}

/// Builds a [`DispatchWatch`] for a batched evaluation, given the batch
/// plan and the number of agents the dispatch will shard across. The SLO
/// probe runner uses this to wire its early-abort judge to the exact plan
/// the server executes.
pub type WatchFactory<'a> = &'a dyn Fn(&[Batch], usize) -> Arc<dyn DispatchWatch>;

/// Planning facts for one batch, captured before the dispatcher consumes
/// the plan; indexed by batch index for serving-span emission.
struct BatchFacts {
    opened_at: f64,
    formed_at: f64,
    occupancy: usize,
    tenant: u32,
}

/// The server.
pub struct Server {
    pub registry: Arc<Registry>,
    pub evaldb: Arc<EvalDb>,
    pub traces: Arc<TraceServer>,
    /// Live progress gauges for the fleet dashboard (`mlms fleet --dash`):
    /// the dispatcher mirrors per-agent in-flight counts here and every
    /// batched evaluation folds its per-tenant latency tails in.
    pub gauges: Arc<crate::dash::FleetGauges>,
    /// In-process agents by id (agents may instead be remote, reached via
    /// their registered endpoint).
    local_agents: Mutex<HashMap<String, Arc<Agent>>>,
}

#[derive(Debug)]
pub enum ServerError {
    UnknownModel(String),
    NoAgent { model: String, req: String },
    AgentFailed(String, String),
    Unsupported(String),
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServerError::UnknownModel(m) => write!(f, "model {m:?} not found in registry"),
            ServerError::NoAgent { model, req } => {
                write!(f, "no agent satisfies the request (model {model}, requirements {req})")
            }
            ServerError::AgentFailed(id, msg) => write!(f, "agent {id} failed: {msg}"),
            ServerError::Unsupported(msg) => write!(f, "unsupported: {msg}"),
        }
    }
}

impl std::error::Error for ServerError {}

impl Server {
    pub fn new(
        registry: Arc<Registry>,
        evaldb: Arc<EvalDb>,
        traces: Arc<TraceServer>,
    ) -> Arc<Server> {
        Arc::new(Server {
            registry,
            evaldb,
            traces,
            gauges: crate::dash::FleetGauges::new(),
            local_agents: Mutex::new(HashMap::new()),
        })
    }

    /// Fresh server with its own registry/db/trace services (common setup).
    pub fn standalone() -> Arc<Server> {
        Server::new(Registry::new(), Arc::new(EvalDb::in_memory()), TraceServer::new())
    }

    /// Attach an in-process agent: registers it (no TTL — it lives exactly
    /// as long as the server) and remembers the handle.
    pub fn attach_local_agent(&self, agent: Arc<Agent>) -> String {
        let id = agent.register_with_ttl(&self.registry, "", None);
        self.local_agents.lock().unwrap().insert(id.clone(), agent);
        id
    }

    /// Detach an in-process agent previously attached with
    /// [`Server::attach_local_agent`]: drops the handle and deregisters it
    /// so it stops resolving. Returns whether the id was attached here.
    /// The autoscaling supervisor uses this to retire replicas it spawned.
    pub fn detach_local_agent(&self, id: &str) -> bool {
        let had = self.local_agents.lock().unwrap().remove(id).is_some();
        if had {
            self.registry.deregister_agent(id);
        }
        had
    }

    /// Register all 37 zoo manifests (bootstrap, §4.7).
    pub fn register_zoo(&self) {
        for m in crate::zoo::all() {
            self.registry.register_manifest(m.manifest());
        }
    }

    /// The evaluation workflow ②–⑨ for one job. Returns one record per
    /// agent evaluated.
    pub fn evaluate(&self, job: &EvalJob) -> Result<Vec<EvalRecord>, ServerError> {
        // ③ resolve the manifest + agents.
        let manifest = self
            .registry
            .manifest(&job.model, job.model_version.as_deref())
            .ok_or_else(|| ServerError::UnknownModel(job.model.clone()))?;
        let candidates = self.registry.resolve(&manifest, &job.requirements);
        if candidates.is_empty() {
            return Err(ServerError::NoAgent {
                model: job.model.clone(),
                req: job.requirements.to_json().to_string(),
            });
        }
        let targets: Vec<AgentInfo> = if job.all_agents {
            candidates
        } else {
            // The pick re-checks liveness: every candidate may have expired
            // between resolution and dispatch.
            match self.registry.pick(&candidates) {
                Some(target) => vec![target],
                None => {
                    return Err(ServerError::NoAgent {
                        model: job.model.clone(),
                        req: job.requirements.to_json().to_string(),
                    })
                }
            }
        };

        // ④ dispatch — remote agents in parallel (F4), local ones inline.
        let req = EvalRequest {
            manifest,
            scenario: job.scenario.clone(),
            trace_level: job.trace_level,
            input_mode: job.input_mode,
            seed: job.seed,
            run_meta: job.run_meta.clone(),
        };
        let mut results = Vec::new();
        let mut remote = Vec::new();
        for target in targets {
            if let Some(agent) = self.local_agents.lock().unwrap().get(&target.id).cloned() {
                let r = agent
                    .evaluate(&req)
                    .map_err(|e| ServerError::AgentFailed(target.id.clone(), e))?;
                results.push(r.record);
            } else {
                remote.push(target);
            }
        }
        if !remote.is_empty() {
            let mut payload_fields = vec![
                ("manifest", req.manifest.to_json()),
                ("scenario", req.scenario.to_json()),
                ("trace_level", Json::str(req.trace_level.as_str())),
                ("input_mode", Json::str(req.input_mode.as_str())),
                ("seed", Json::num(req.seed as f64)),
            ];
            if !req.run_meta.is_empty() {
                payload_fields.push(("run_meta", req.run_meta.to_json()));
            }
            let payload = Json::obj(payload_fields);
            let remote_results = parallel_map(remote, 8, move |target| {
                let client = crate::wire::RpcClient::connect(&target.endpoint)
                    .map_err(|e| (target.id.clone(), e.to_string()))?;
                // A full remote scenario can run long, but not forever: a
                // partitioned agent must fail the dispatch, not hang it.
                client.set_read_timeout(Some(std::time::Duration::from_secs(300)));
                let resp = client
                    .call("Evaluate", payload.clone())
                    .map_err(|e| (target.id.clone(), e.to_string()))?;
                EvalRecord::from_json(resp.get("record").ok_or_else(|| {
                    (target.id.clone(), "missing record".to_string())
                })?)
                .ok_or_else(|| (target.id.clone(), "bad record".to_string()))
            });
            for r in remote_results {
                match r {
                    Ok(rec) => {
                        // Remote agents store into their own DB shard; the
                        // server also records centrally (the paper's
                        // "centralized management of benchmarking results").
                        self.evaldb.put(rec.clone());
                        results.push(rec);
                    }
                    Err((id, e)) => return Err(ServerError::AgentFailed(id, e)),
                }
            }
        }
        Ok(results)
    }

    /// Batched multi-agent evaluation (the scaling path): coalesce the
    /// job's request stream into dynamic batches and shard them across
    /// *every* resolved live in-process agent under the dispatcher's
    /// least-outstanding-requests policy. Stores one evaluation record
    /// whose metadata carries the batching series (occupancy, queue delay,
    /// per-agent sharding, per-tenant tails) for the analysis workflow.
    ///
    /// Per-request latency is computed by the deterministic virtual-time
    /// queueing replay ([`QueueSim`]): batching delay + wait for a free
    /// agent + batch service time. Latency therefore grows with offered
    /// load — the property the SLO search ([`crate::slo`]) depends on.
    pub fn evaluate_batched(
        &self,
        job: &EvalJob,
        cfg: &BatcherConfig,
    ) -> Result<BatchedEval, ServerError> {
        self.evaluate_batched_watched(job, cfg, None)
    }

    /// As [`Server::evaluate_batched`], with an optional [`WatchFactory`]
    /// whose watch observes every executed batch and may abort the run.
    ///
    /// Watched evaluations are *probes*, not benchmark results: they are
    /// never persisted in the evaluation database (a 20-probe SLO search
    /// would otherwise bury the real records under arbitrary-load
    /// `fixed_qps` rows). Only the unwatched path stores.
    pub fn evaluate_batched_watched(
        &self,
        job: &EvalJob,
        cfg: &BatcherConfig,
        watch: Option<WatchFactory<'_>>,
    ) -> Result<BatchedEval, ServerError> {
        // The batcher coalesces *single-item* request streams; a scenario
        // whose requests are already batches (`Batched`) would be silently
        // miscounted here — its batching happens in the classic path.
        if job.scenario.batch_size() > 1 {
            return Err(ServerError::Unsupported(format!(
                "batched dispatch requires per-request batch size 1; scenario {:?} carries {} — use Server::evaluate",
                job.scenario.name(),
                job.scenario.batch_size()
            )));
        }
        let no_agent = || ServerError::NoAgent {
            model: job.model.clone(),
            req: job.requirements.to_json().to_string(),
        };
        let manifest = self
            .registry
            .manifest(&job.model, job.model_version.as_deref())
            .ok_or_else(|| ServerError::UnknownModel(job.model.clone()))?;
        let candidates = self.registry.resolve(&manifest, &job.requirements);
        // Shard across every resolved agent that is still live (TTL
        // re-checked at dispatch time): in-process agents get a local batch
        // session, registry-discovered TCP agents a [`RemoteBatchSession`]
        // over the wire — one fleet, one executor pool.
        let live: Vec<AgentInfo> = candidates
            .into_iter()
            .filter(|c| self.registry.is_live(&c.id))
            .collect();
        if live.is_empty() {
            return Err(no_agent());
        }

        // The server defines the workload (same `(scenario, seed)` contract
        // as the classic path) and the batch plan is a pure function of it.
        let workload = Workload::generate(&job.scenario, job.seed);
        // Admission control (when configured) runs between workload
        // generation and batching: shed requests never reach the planner,
        // and the per-tenant accounting rides along in the record's meta.
        let names = job.scenario.tenant_names();
        let label = |t: u32| -> String {
            names.get(t as usize).cloned().unwrap_or_else(|| format!("t{t}"))
        };
        let (workload, admission_series) = match &job.admission {
            Some(adm) => {
                let (admitted, rejections) = filter_workload(adm, &workload);
                let mut shed = ShedSeries::default();
                for r in &workload.requests {
                    let row = shed.row_mut(&label(r.tenant));
                    row.priority = adm.policy_for(r.tenant).priority.as_str().to_string();
                    row.offered += 1;
                }
                for r in &admitted.requests {
                    shed.row_mut(&label(r.tenant)).admitted += 1;
                }
                for rej in &rejections {
                    shed.row_mut(&label(rej.tenant)).shed_rate_limited += 1;
                }
                (admitted, Some(shed))
            }
            None => (workload, None),
        };
        let batches = plan_batches(&workload, cfg, |r| Envelope {
            seq: r.id,
            trace_id: 0,
            parent_span: None,
            payload: Payload::Tensor(Tensor::random(vec![1, 4, 4, 3], job.seed ^ r.id)),
        });
        let series = batching_series(&batches, cfg);
        // Per-batch planning facts, captured before the dispatcher consumes
        // the plan — the serving-span emission needs them afterwards.
        let batch_facts: Vec<BatchFacts> = batches
            .iter()
            .map(|b| BatchFacts {
                opened_at: b.opened_at_secs,
                formed_at: b.formed_at_secs,
                occupancy: b.len(),
                tenant: b.tenant,
            })
            .collect();

        // Open sessions leniently: a candidate whose session fails to open
        // (agent died between resolution and open, model unsupported) is
        // skipped — failover starts before the first batch. Only an empty
        // pool is an error.
        let locals = self.local_agents.lock().unwrap().clone();
        // Sessions open in parallel, order-preserving: a remote open is a
        // TCP connect plus a model load on the agent, so opening a fleet of
        // N candidates serially costs N round-trips before the first batch
        // moves — in parallel it costs roughly one.
        let registry = self.registry.clone();
        let manifest_for_open = manifest.clone();
        let max_batch = cfg.max_batch_size;
        let remote_deadline_ms = cfg.remote_deadline_ms;
        type OpenedExec = Result<(Arc<dyn BatchExecutor>, Option<u64>, bool), Option<String>>;
        let opened: Vec<OpenedExec> = parallel_map(live.clone(), 8, move |c| {
            if let Some(agent) = locals.get(&c.id) {
                match agent.open_batch_session(&manifest_for_open, max_batch) {
                    Ok(session) => {
                        let trace_id = session.trace_id();
                        let exec: Arc<dyn BatchExecutor> = Arc::new(session);
                        Ok((exec, Some(trace_id), false))
                    }
                    Err(e) => Err(Some(format!("{}: {e}", c.id))),
                }
            } else if !c.endpoint.is_empty() {
                match crate::agent::RemoteBatchSession::open(
                    &c.endpoint,
                    &c.id,
                    &manifest_for_open,
                    max_batch,
                    Some(registry.clone()),
                    remote_deadline_ms,
                ) {
                    Ok(session) => {
                        let exec: Arc<dyn BatchExecutor> = Arc::new(session);
                        Ok((exec, None, true))
                    }
                    Err(e) => Err(Some(format!("{}: {e}", c.id))),
                }
            } else {
                // Neither local nor addressable: not an error, just skipped.
                Err(None)
            }
        });
        let mut executors: Vec<Arc<dyn BatchExecutor>> = Vec::new();
        let mut trace_ids = Vec::new();
        let mut used: Vec<AgentInfo> = Vec::new();
        let mut remote_agents = 0usize;
        let mut open_errors: Vec<String> = Vec::new();
        for (c, result) in live.iter().zip(opened) {
            match result {
                Ok((exec, trace_id, is_remote)) => {
                    if let Some(t) = trace_id {
                        trace_ids.push(t);
                    }
                    if is_remote {
                        remote_agents += 1;
                    }
                    executors.push(exec);
                    used.push(c.clone());
                }
                Err(Some(msg)) => open_errors.push(msg),
                Err(None) => {}
            }
        }
        if executors.is_empty() {
            return Err(if open_errors.is_empty() {
                no_agent()
            } else {
                ServerError::AgentFailed("-".into(), open_errors.join("; "))
            });
        }
        let mut replay = QueueSim::new(&batches, executors.len(), cfg.policy());
        let is_probe = watch.is_some();
        let watch = watch.map(|f| f(&batches, executors.len()));
        let outcome = Dispatcher::new(executors)
            .with_policy(cfg.policy())
            .with_gauges(self.gauges.clone())
            .dispatch_watched(batches, watch)
            .map_err(|e| ServerError::AgentFailed(e.agent.clone(), e.msg))?;

        // Queueing-aware per-request latency: feed the observed per-batch
        // service times through the virtual-time replay in plan order.
        let mut rows = outcome.batch_log.clone();
        rows.sort_by_key(|r| r.index);
        let mut completed = Vec::new();
        for row in &rows {
            completed.extend(replay.offer(row.index, row.latency_s));
        }
        let tenant_names = job.scenario.tenant_names();
        let tenant_name = |t: u32| -> String {
            tenant_names
                .get(t as usize)
                .cloned()
                .unwrap_or_else(|| format!("t{t}"))
        };
        let mut by_seq: HashMap<u64, f64> = HashMap::with_capacity(completed.len());
        let mut per_tenant = TenantLatencies::new();
        for c in &completed {
            by_seq.insert(c.seq, c.latency_s);
            per_tenant.record(&tenant_name(c.tenant), c.latency_s);
        }
        // Feed the dashboard's rolling p50/p99 window — probes included;
        // a live operator wants to see probe traffic too.
        self.gauges.fold_tenants(&per_tenant);
        // Serving-stack spans: the virtual-time schedule, republished as a
        // trace (batching_wait → queue_wait → batch_service per batch) so
        // bottleneck attribution covers queueing and dispatch, not just
        // model internals. Probes emit too — an SLO search's failing probe
        // is exactly the trace worth attributing.
        let serving_trace_id = if job.trace_level >= TraceLevel::Model {
            self.publish_serving_spans(
                job,
                &batch_facts,
                &replay,
                &tenant_name,
                is_probe,
                &outcome.requeue_log,
            )
        } else {
            None
        };
        // One latency per completed output (aborted runs cover a prefix).
        let latencies: Vec<f64> = outcome
            .outputs
            .iter()
            .filter_map(|env| by_seq.get(&env.seq).copied())
            .collect();
        let items = outcome.outputs.len() as f64;
        let throughput = items / outcome.makespan_s().max(1e-12);

        // Key facts come from the registry advertisements of the agents
        // that actually served (identical to the predictor-reported values
        // for local agents; the only source available for remote ones).
        let systems: std::collections::BTreeSet<String> =
            used.iter().map(|a| a.system.clone()).collect();
        let key = EvalKey {
            model: manifest.name.clone(),
            model_version: manifest.version.to_string(),
            framework: used[0].framework.clone(),
            framework_version: used[0].framework_version.to_string(),
            system: if systems.len() == 1 {
                systems.iter().next().unwrap().clone()
            } else {
                "multi".to_string()
            },
            device: used[0]
                .devices
                .first()
                .cloned()
                .unwrap_or_else(|| "cpu".to_string()),
            scenario: job.scenario.name().to_string(),
            batch_size: cfg.max_batch_size.max(1),
        };
        // Content address of the resolved spec, with the dispatch config
        // folded in: a batched run under a different batcher setup is a
        // different experiment and must never memoize into this one. An
        // admission policy changes the admitted workload, so it folds into
        // the digest too — but only when configured, preserving the digests
        // of every pre-admission record.
        let dispatch_fp = match &job.admission {
            Some(adm) => Json::obj(vec![
                ("batcher", cfg.fingerprint_json()),
                ("admission", adm.fingerprint_json()),
            ]),
            None => cfg.fingerprint_json(),
        };
        let mut spec = crate::evaldb::EvalSpec::for_request(
            &manifest,
            &key.system,
            &key.device,
            &job.scenario,
            key.batch_size,
            job.trace_level,
            job.seed,
            dispatch_fp,
        );
        spec.run_label = job.run_meta.label.clone();
        let mut record = EvalRecord::new(key, latencies, throughput);
        record.spec_digest = Some(spec.digest());
        record.run_meta = job.run_meta.clone();
        // The serving trace is the record's primary trace (it carries the
        // queueing attribution); session traces remain reachable through
        // the returned `session_trace_ids`.
        record.trace_id = serving_trace_id.or_else(|| trace_ids.first().copied());
        let mut meta = vec![
            ("batching", series.to_json()),
            (
                "dispatch",
                Json::str(if cfg.fair { "fair_by_tenant" } else { "least_outstanding" }),
            ),
            ("fair", Json::Bool(cfg.fair)),
            ("agents", Json::num(used.len() as f64)),
            ("remote_agents", Json::num(remote_agents as f64)),
            (
                "per_agent_items",
                Json::Obj(
                    outcome
                        .per_agent_items
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
            ("requeued_batches", Json::num(outcome.requeued_batches as f64)),
            (
                "failover",
                Json::arr(
                    outcome
                        .requeue_log
                        .iter()
                        .map(|(idx, agent)| {
                            Json::obj(vec![
                                ("batch_index", Json::num(*idx as f64)),
                                ("from_agent", Json::str(agent)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("makespan_s", Json::num(outcome.makespan_s())),
        ];
        if matches!(job.scenario, Scenario::Mix { .. }) {
            meta.push(("tenants", per_tenant.to_json()));
        }
        if let Some(shed) = &admission_series {
            meta.push(("admission", shed.to_json()));
        }
        if let Some(tid) = serving_trace_id {
            meta.push(("serving_trace_id", Json::num(tid as f64)));
        }
        record.meta = Json::obj(meta);
        let mut record_out = record.clone();
        // Probes (watched runs) and aborted runs are not benchmark
        // results: don't store them.
        if !outcome.aborted && !is_probe {
            record_out.seq = self.evaldb.put(record);
        }
        let aborted = outcome.aborted;
        Ok(BatchedEval {
            record: record_out,
            series,
            outcome,
            per_tenant,
            aborted,
            serving_trace_id,
            session_trace_ids: trace_ids,
        })
    }

    /// Republish the virtual-time queueing schedule as spans in a fresh
    /// trace: one `serve` root (self time = idle), one `batch` span per
    /// scheduled batch with `batching_wait` (open → formed), `queue_wait`
    /// (formed → start) and `batch_service` (start → completion) children,
    /// each tagged with its serving stage and tenant so
    /// [`crate::traceanalysis`] can attribute the serving stack. A batch
    /// that was requeued after an agent death additionally carries a
    /// `failover` child naming the agent that failed it — the trace records
    /// the failover, not just the recovery.
    fn publish_serving_spans(
        &self,
        job: &EvalJob,
        batch_facts: &[BatchFacts],
        replay: &QueueSim,
        tenant_name: &dyn Fn(u32) -> String,
        is_probe: bool,
        requeues: &[(u64, String)],
    ) -> Option<u64> {
        let sched = replay.schedule_log();
        if sched.is_empty() {
            return None;
        }
        // The tracer is used purely as an id allocator + publisher; span
        // intervals come pre-built from the schedule's virtual times
        // (§4.4.4: trace timestamps need not be wall clock).
        let tracer = Tracer::new(
            TraceLevel::Full,
            Arc::new(SimClock::new()),
            self.traces.clone(),
        );
        let trace_id = tracer.new_trace();
        let root_id = tracer.new_trace();
        let ns = |s: f64| (s.max(0.0) * 1e9).round() as u64;
        let requeued_from = |index: u64| -> Option<String> {
            requeues
                .iter()
                .find(|(i, _)| *i == index)
                .map(|(_, agent)| agent.clone())
        };
        let mut t_start = f64::INFINITY;
        let mut t_end = 0.0f64;
        // The whole trace is built up front and published in one batch:
        // one collector lock for the trace instead of one per span (a
        // high-rate scenario emits four spans per scheduled batch).
        let mut spans: Vec<Span> = Vec::with_capacity(sched.len() * 4 + 1);
        for s in sched {
            let b = &batch_facts[s.index as usize];
            let tenant = tenant_name(b.tenant);
            t_start = t_start.min(b.opened_at);
            t_end = t_end.max(s.completion);
            let batch_id = tracer.new_trace();
            spans.push(Span {
                trace_id,
                span_id: batch_id,
                parent_id: Some(root_id),
                name: "batch".into(),
                level: TraceLevel::Model,
                start_ns: ns(b.opened_at),
                end_ns: ns(s.completion),
                tags: vec![
                    ("batch_index".into(), s.index.to_string()),
                    ("occupancy".into(), b.occupancy.to_string()),
                    ("tenant".into(), tenant.clone()),
                    ("agent_slot".into(), s.server.to_string()),
                ],
            });
            let mut child = |name: &str, stage: &str, s0: f64, s1: f64| {
                if s1 > s0 {
                    spans.push(Span {
                        trace_id,
                        span_id: tracer.new_trace(),
                        parent_id: Some(batch_id),
                        name: name.into(),
                        level: TraceLevel::Model,
                        start_ns: ns(s0),
                        end_ns: ns(s1),
                        tags: vec![
                            ("stage".into(), stage.into()),
                            ("tenant".into(), tenant.clone()),
                        ],
                    });
                }
            };
            child("batching_wait", "batching", b.opened_at, b.formed_at);
            child("queue_wait", "queueing", s.formed_at, s.start);
            child("batch_service", "compute", s.start, s.completion);
            // The requeue itself: the virtual-time replay schedules only
            // the successful execution, so the failover is pinned to the
            // batch's pre-service window (minimum 1 ns so it is never
            // dropped as zero-width) and named after the dead agent.
            if let Some(from_agent) = requeued_from(s.index) {
                spans.push(Span {
                    trace_id,
                    span_id: tracer.new_trace(),
                    parent_id: Some(batch_id),
                    name: "failover".into(),
                    level: TraceLevel::Model,
                    start_ns: ns(s.formed_at),
                    end_ns: ns(s.start).max(ns(s.formed_at) + 1),
                    tags: vec![
                        ("stage".into(), "failover".into()),
                        ("tenant".into(), tenant.clone()),
                        ("from_agent".into(), from_agent),
                        ("batch_index".into(), s.index.to_string()),
                    ],
                });
            }
        }
        spans.push(Span {
            trace_id,
            span_id: root_id,
            parent_id: None,
            name: "serve".into(),
            level: TraceLevel::Model,
            start_ns: ns(t_start),
            end_ns: ns(t_end),
            tags: vec![
                ("stage".into(), "idle".into()),
                ("scenario".into(), job.scenario.name().to_string()),
                ("probe".into(), is_probe.to_string()),
            ],
        });
        tracer.publish_all(spans);
        Some(trace_id)
    }

    /// Standard simulation platform: the four Table-1 systems, GPU + CPU
    /// agents each, zoo registered. Shared by benches/examples.
    pub fn sim_platform(trace_level: TraceLevel) -> Arc<Server> {
        let server = Server::standalone();
        server.register_zoo();
        for sys in ["aws_p3", "aws_g3", "aws_p2", "ibm_p8"] {
            for dev in [crate::sysmodel::Device::Gpu, crate::sysmodel::Device::Cpu] {
                let (agent, _sim, _t) = crate::agent::sim_agent(
                    sys,
                    dev,
                    trace_level,
                    server.evaldb.clone(),
                    server.traces.clone(),
                );
                server.attach_local_agent(agent);
            }
        }
        server
    }

    /// The analysis workflow (a–e): summarize models across stored runs.
    pub fn analyze(&self, models: &[String]) -> Json {
        crate::analysis::summaries_json(models, &self.evaldb)
    }

    pub fn report(&self, models: &[String]) -> String {
        crate::analysis::full_report_with_traces(models, &self.evaldb, &self.traces)
    }

    /// Build the REST API router (F10; consumed by web/CLI clients).
    pub fn router(self: &Arc<Self>) -> crate::httpd::Router {
        use crate::httpd::{HttpResponse, Router};
        let s = self.clone();
        let r = Router::new()
            .route("GET", "/api/ping", |_| {
                HttpResponse::json(&Json::obj(vec![("ok", Json::Bool(true))]))
            })
            // The web UI (F10) at the root.
            .route("GET", "/", |_| HttpResponse {
                status: 200,
                content_type: "text/html".into(),
                body: webui::INDEX_HTML.as_bytes().to_vec(),
            });
        let r = {
            let s = s.clone();
            r.route("GET", "/api/models", move |_| {
                HttpResponse::json(&Json::arr(
                    s.registry.manifest_names().iter().map(Json::str).collect(),
                ))
            })
        };
        let r = {
            let s = s.clone();
            r.route("GET", "/api/agents", move |_| {
                HttpResponse::json(&Json::arr(
                    s.registry.agents().iter().map(|a| a.to_json()).collect(),
                ))
            })
        };
        let r = {
            let _s = s.clone();
            r.route("GET", "/api/systems", move |_| {
                HttpResponse::json(&Json::arr(
                    crate::sysmodel::systems().values().map(|p| p.to_json()).collect(),
                ))
            })
        };
        let r = {
            let s = s.clone();
            r.route("POST", "/api/evaluate", move |req| {
                let body = match req.json() {
                    Some(b) => b,
                    None => return HttpResponse::error(400, "invalid JSON body"),
                };
                let scenario = match body.get("scenario").and_then(Scenario::from_json) {
                    Some(sc) => sc,
                    None => return HttpResponse::error(400, "missing/invalid scenario"),
                };
                let model = body.str_or("model", "");
                let mut job = EvalJob::new(model, scenario);
                job.model_version =
                    body.get("version").and_then(|v| v.as_str()).map(String::from);
                job.trace_level =
                    match TraceLevel::parse(body.str_or("trace_level", "model")) {
                        Some(t) => t,
                        None => {
                            return HttpResponse::error(
                                400,
                                "invalid trace_level (none|model|framework|system|full)",
                            )
                        }
                    };
                job.input_mode = InputMode::parse(body.str_or("input_mode", "c"));
                job.seed = body.f64_or("seed", 42.0) as u64;
                job.all_agents = body.get("all_agents").and_then(|v| v.as_bool()).unwrap_or(false);
                if let Some(reqs) = body.get("requirements") {
                    job.requirements = SystemRequirements::from_json(reqs);
                }
                match s.evaluate(&job) {
                    Ok(records) => HttpResponse::json(&Json::arr(
                        records.iter().map(|r| r.to_json()).collect(),
                    )),
                    Err(e @ ServerError::UnknownModel(_)) => HttpResponse::error(404, e.to_string()),
                    Err(e @ ServerError::NoAgent { .. }) => HttpResponse::error(503, e.to_string()),
                    Err(e) => HttpResponse::error(500, e.to_string()),
                }
            })
        };
        let r = {
            let s = s.clone();
            r.route("GET", "/api/analyze", move |req| {
                let q = req.query_map();
                let models: Vec<String> = q
                    .get("models")
                    .map(|m| m.split(',').map(str::to_string).collect())
                    .unwrap_or_default();
                HttpResponse::json(&s.analyze(&models))
            })
        };
        let r = {
            let s = s.clone();
            r.route("GET", "/api/report", move |req| {
                let q = req.query_map();
                let models: Vec<String> = q
                    .get("models")
                    .map(|m| m.split(',').map(str::to_string).collect())
                    .unwrap_or_default();
                HttpResponse::text(200, s.report(&models))
            })
        };
        {
            let s = s.clone();
            r.route("GET", "/api/trace/:id", move |req| {
                match req.param("id").and_then(|i| i.parse::<u64>().ok()) {
                    Some(id) => {
                        let tl = s.traces.timeline(id);
                        if tl.is_empty() {
                            HttpResponse::error(404, format!("trace {id} not found"))
                        } else {
                            HttpResponse::json(&tl.to_json())
                        }
                    }
                    None => HttpResponse::error(400, "bad trace id"),
                }
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::sim_agent;
    use crate::sysmodel::Device;

    /// Server + two simulated GPU agents (P3 + P8) sharing the server's DB
    /// and trace sink — the standard in-proc topology.
    fn testbed() -> Arc<Server> {
        let server = Server::standalone();
        server.register_zoo();
        for sys in ["aws_p3", "ibm_p8"] {
            let (agent, _sim, _tracer) = sim_agent(
                sys,
                Device::Gpu,
                TraceLevel::Full,
                server.evaldb.clone(),
                server.traces.clone(),
            );
            server.attach_local_agent(agent);
        }
        server
    }

    #[test]
    fn evaluation_workflow_end_to_end() {
        let server = testbed();
        let job = EvalJob::new("ResNet_v1_50", Scenario::Online { count: 8 });
        let records = server.evaluate(&job).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].latencies.len(), 8);
        // Result is queryable through the analysis workflow.
        let analysis = server.analyze(&["ResNet_v1_50".to_string()]);
        assert_eq!(analysis.as_arr().unwrap().len(), 1);
        // The trace made it to the trace server.
        let trace_id = records[0].trace_id.unwrap();
        assert!(!server.traces.timeline(trace_id).is_empty());
    }

    #[test]
    fn batched_dispatch_shards_and_records() {
        let server = testbed();
        let mut job = EvalJob::new(
            "ResNet_v1_50",
            Scenario::Poisson { rate: 2000.0, count: 64 },
        );
        job.seed = 7;
        let cfg = BatcherConfig::new(8, 10.0);
        let result = server.evaluate_batched(&job, &cfg).unwrap();
        // Every request came back, in order, exactly once.
        assert_eq!(result.outcome.outputs.len(), 64);
        for (i, env) in result.outcome.outputs.iter().enumerate() {
            assert_eq!(env.seq, i as u64);
        }
        // Real coalescing happened and the series landed in the record.
        assert!(result.series.mean_occupancy() > 1.5, "{}", result.series.mean_occupancy());
        let meta = &result.record.meta;
        assert!(meta.get("batching").is_some());
        assert_eq!(meta.f64_or("agents", 0.0), 2.0);
        assert_eq!(meta.f64_or("requeued_batches", 99.0), 0.0);
        // Per-request latencies: one per request, all positive.
        assert_eq!(result.record.latencies.len(), 64);
        assert!(result.record.latencies.iter().all(|l| *l > 0.0));
        assert!(result.record.throughput > 0.0);
        // Stored centrally for the analysis workflow.
        assert_eq!(server.evaldb.len(), 1);
        let served: usize = result.outcome.per_agent_items.values().sum();
        assert_eq!(served, 64);
        // Pre-batched scenarios are rejected, not miscounted.
        let job = EvalJob::new("ResNet_v1_50", Scenario::Batched { batch_size: 8, batches: 4 });
        assert!(matches!(
            server.evaluate_batched(&job, &cfg),
            Err(ServerError::Unsupported(_))
        ));
    }

    #[test]
    fn batched_results_identical_to_per_request_baseline() {
        // The same job through coalesced multi-agent dispatch and through
        // the degenerate per-request single-agent config must produce
        // element-wise identical outputs (batching never changes results).
        let run = |cfg: &BatcherConfig, single_agent: bool| {
            let server = Server::standalone();
            server.register_zoo();
            let systems: &[&str] = if single_agent { &["aws_p3"] } else { &["aws_p3", "ibm_p8"] };
            for sys in systems {
                let (agent, _sim, _tracer) = sim_agent(
                    sys,
                    Device::Gpu,
                    TraceLevel::None,
                    server.evaldb.clone(),
                    server.traces.clone(),
                );
                server.attach_local_agent(agent);
            }
            let mut job = EvalJob::new(
                "MobileNet_v1_1.0_224",
                Scenario::FixedQps { qps: 5000.0, count: 40 },
            );
            job.seed = 11;
            server.evaluate_batched(&job, cfg).unwrap()
        };
        let batched = run(&BatcherConfig::new(8, 20.0), false);
        let baseline = run(&BatcherConfig::per_request(), true);
        assert_eq!(batched.outcome.outputs.len(), baseline.outcome.outputs.len());
        for (a, b) in batched.outcome.outputs.iter().zip(&baseline.outcome.outputs) {
            assert_eq!(a.seq, b.seq);
            match (&a.payload, &b.payload) {
                (crate::pipeline::Payload::Tensor(x), crate::pipeline::Payload::Tensor(y)) => {
                    assert_eq!(x, y, "request {} diverged under batching", a.seq)
                }
                other => panic!("unexpected payloads {other:?}"),
            }
        }
        // And the batched run actually coalesced.
        assert!(batched.series.mean_occupancy() > 1.5);
        assert_eq!(baseline.series.mean_occupancy(), 1.0);
    }

    #[test]
    fn batched_dispatch_emits_serving_stack_spans() {
        let server = testbed();
        let mut job = EvalJob::new(
            "ResNet_v1_50",
            Scenario::Poisson { rate: 2000.0, count: 64 },
        );
        job.seed = 7;
        let cfg = BatcherConfig::new(8, 10.0);
        let result = server.evaluate_batched(&job, &cfg).unwrap();
        let tid = result.serving_trace_id.expect("serving trace emitted");
        assert_eq!(result.record.trace_id, Some(tid));
        assert_eq!(result.record.meta.f64_or("serving_trace_id", 0.0) as u64, tid);
        assert_eq!(result.session_trace_ids.len(), 2, "one per agent session");
        let tl = server.traces.timeline(tid);
        assert!(!tl.is_empty());
        let names: std::collections::HashSet<&str> =
            tl.spans.iter().map(|s| s.name.as_str()).collect();
        assert!(
            names.contains("serve") && names.contains("batch") && names.contains("batch_service"),
            "{names:?}"
        );
        // Every batch_service span is stage-tagged and parented to a batch.
        for s in tl.spans.iter().filter(|s| s.name == "batch_service") {
            assert_eq!(s.tag("stage"), Some("compute"));
            assert!(s.parent_id.is_some());
        }
        // Attribution over the serving trace: verdict exists and the
        // critical path never exceeds wall clock.
        let p = crate::traceanalysis::profile(&[tl], 5);
        assert!(p.critical_path_ms <= p.total_ms + 1e-9, "{} > {}", p.critical_path_ms, p.total_ms);
        assert!(p.dominant_stage().is_some());
        // A TraceLevel::None job emits no serving trace.
        let mut quiet = job.clone();
        quiet.trace_level = TraceLevel::None;
        quiet.seed = 8;
        let r2 = server.evaluate_batched(&quiet, &cfg).unwrap();
        assert!(r2.serving_trace_id.is_none());
        assert_eq!(r2.record.trace_id, r2.session_trace_ids.first().copied());
    }

    #[test]
    fn all_agents_fanout() {
        let server = testbed();
        let mut job = EvalJob::new("Inception_v3", Scenario::Online { count: 4 });
        job.all_agents = true;
        let records = server.evaluate(&job).unwrap();
        assert_eq!(records.len(), 2, "both P3 and P8 evaluated");
        let systems: std::collections::HashSet<String> =
            records.iter().map(|r| r.key.system.clone()).collect();
        assert!(systems.contains("aws_p3") && systems.contains("ibm_p8"));
    }

    #[test]
    fn requirements_narrow_resolution() {
        let server = testbed();
        let mut job = EvalJob::new("VGG16", Scenario::Online { count: 2 });
        job.requirements = SystemRequirements {
            interconnect: Some("nvlink".into()),
            ..SystemRequirements::any()
        };
        let records = server.evaluate(&job).unwrap();
        assert_eq!(records[0].key.system, "ibm_p8");
        // Impossible requirements → NoAgent.
        job.requirements = SystemRequirements {
            min_memory_gb: Some(10_000.0),
            ..SystemRequirements::any()
        };
        assert!(matches!(server.evaluate(&job), Err(ServerError::NoAgent { .. })));
    }

    #[test]
    fn unknown_model_rejected() {
        let server = testbed();
        let job = EvalJob::new("NotInZoo", Scenario::Online { count: 1 });
        assert!(matches!(server.evaluate(&job), Err(ServerError::UnknownModel(_))));
    }

    #[test]
    fn rest_api_round_trip() {
        let server = testbed();
        let http = crate::httpd::HttpServer::serve("127.0.0.1:0", server.router()).unwrap();
        let addr = http.addr();

        let (status, models) = crate::httpd::http_request(addr, "GET", "/api/models", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(models.as_arr().unwrap().len(), 37);

        let (status, agents) = crate::httpd::http_request(addr, "GET", "/api/agents", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(agents.as_arr().unwrap().len(), 2);

        let payload = Json::obj(vec![
            ("model", Json::str("MobileNet_v1_1.0_224")),
            ("scenario", Scenario::Batched { batch_size: 8, batches: 2 }.to_json()),
            ("trace_level", Json::str("framework")),
        ]);
        let (status, records) =
            crate::httpd::http_request(addr, "POST", "/api/evaluate", Some(&payload)).unwrap();
        assert_eq!(status, 200, "{records}");
        let rec = &records.as_arr().unwrap()[0];
        let trace_id = rec.get_path("trace_id").unwrap().as_u64().unwrap();

        let (status, timeline) = crate::httpd::http_request(
            addr,
            "GET",
            &format!("/api/trace/{trace_id}"),
            None,
        )
        .unwrap();
        assert_eq!(status, 200);
        assert!(!timeline.get("spans").unwrap().as_arr().unwrap().is_empty());

        let (status, analysis) = crate::httpd::http_request(
            addr,
            "GET",
            "/api/analyze?models=MobileNet_v1_1.0_224",
            None,
        )
        .unwrap();
        assert_eq!(status, 200);
        assert_eq!(analysis.as_arr().unwrap().len(), 1);

        let (status, _) =
            crate::httpd::http_request(addr, "GET", "/api/trace/999999", None).unwrap();
        assert_eq!(status, 404);
        http.stop();
    }

    #[test]
    fn remote_agent_dispatch() {
        // A remote agent process: own evaldb shard, served over the wire.
        let agent_db = Arc::new(EvalDb::in_memory());
        let sink = crate::tracing::MemorySink::new();
        let (agent, _sim, _tracer) =
            sim_agent("aws_g3", Device::Gpu, TraceLevel::Model, agent_db.clone(), sink);
        let rpc =
            crate::wire::RpcServer::serve("127.0.0.1:0", crate::agent::agent_service(agent.clone()))
                .unwrap();

        let server = Server::standalone();
        server.register_zoo();
        // Register the remote agent by endpoint (no local handle).
        let mut info = crate::registry::AgentInfo {
            id: String::new(),
            endpoint: rpc.addr().to_string(),
            framework: "SimFramework-Maxwell".into(),
            framework_version: "1.0.0".parse().unwrap(),
            system: "aws_g3".into(),
            architecture: "x86_64".into(),
            devices: vec!["gpu".into()],
            interconnect: "pcie3".into(),
            host_memory_gb: 30.5,
            device_memory_gb: 8.0,
            models: crate::zoo::all().iter().map(|m| m.name.clone()).collect(),
        };
        info.id = String::new();
        server.registry.register_agent(info, None);

        let job = EvalJob::new("BVLC_AlexNet", Scenario::Online { count: 3 });
        let records = server.evaluate(&job).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].key.system, "aws_g3");
        // Stored both agent-side and centrally.
        assert_eq!(agent_db.len(), 1);
        assert_eq!(server.evaldb.len(), 1);
        rpc.stop();
    }
}
