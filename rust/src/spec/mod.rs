//! Declarative evaluation specs: `mlms run spec.yaml`.
//!
//! The paper's platform is driven by *specifications* (§4.1): manifests
//! declare models and frameworks, and evaluations are meant to be
//! reproducible artifacts rather than one-off flag soups. This module adds
//! the missing piece — a YAML evaluation spec that names a whole run
//! (eval, sweep, slo-search, regress, or autoscale) declaratively:
//!
//! ```yaml
//! run: sweep
//! models: [ResNet_v1_50, VGG16]
//! systems: [aws_p3]
//! scenario:
//!   kind: online
//!   count: 16
//! batch_sizes: [1, 8]
//! seed: 42
//! label: nightly
//! ```
//!
//! Design rules:
//!
//! - **Strict schema.** Unknown keys reject with an error naming the key;
//!   typed fields reject wrong types, non-finite numbers, fractional
//!   counts. A spec never half-applies: nothing in an accepted spec is
//!   silently ignored, and nothing absent is silently invented beyond the
//!   documented defaults (which mirror the CLI's).
//! - **Strict front-end.** On top of [`yamlmini`]'s grammar the spec
//!   front-end rejects tab indentation, odd indentation widths, empty
//!   documents, and non-mapping documents — each with a 1-based line
//!   number ([`SpecError`]).
//! - **Digest parity.** [`EvalSpecFile::to_plan`] lowers a spec onto the
//!   exact same [`sweep::Plan`](crate::sweep::Plan) the flag-driven CLI
//!   builds, so a spec-driven cell and its flag-equivalent invocation
//!   produce the *same* content-addressed
//!   [`EvalSpec`](crate::evaldb::EvalSpec) digest and hit the same
//!   memoization line in the evaluation database.
//! - **Reorder invariance.** [`EvalSpecFile::digest`] hashes the resolved
//!   spec's canonical JSON; two specs differing only in key order (or
//!   comments, or formatting) digest identically.

use crate::batcher::admission::{AdmissionConfig, Priority, TenantPolicy};
use crate::batcher::BatcherConfig;
use crate::evaldb::RunMeta;
use crate::manifest::Accelerator;
use crate::scenario::Scenario;
use crate::sweep::Plan;
use crate::tracing::TraceLevel;
use crate::util::json::Json;
use crate::util::sha256::sha256_hex;
use crate::util::yamlmini;

/// A spec parse/validation error with a 1-based source line when the
/// front-end knows one (`line == 0` for schema errors, which concern the
/// resolved document rather than a single line).
#[derive(Debug)]
pub struct SpecError {
    pub line: usize,
    pub msg: String,
}

impl SpecError {
    fn at(line: usize, msg: impl Into<String>) -> SpecError {
        SpecError { line, msg: msg.into() }
    }

    fn schema(msg: impl Into<String>) -> SpecError {
        SpecError { line: 0, msg: msg.into() }
    }
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line == 0 {
            write!(f, "spec error: {}", self.msg)
        } else {
            write!(f, "spec error at line {}: {}", self.line, self.msg)
        }
    }
}

impl std::error::Error for SpecError {}

/// Parse spec YAML with the strict front-end: tabs in indentation and odd
/// indentation widths reject with their line number *before* the grammar
/// runs (yamlmini tolerates both; a spec that silently means something
/// other than what its indentation suggests is worse than a parse error),
/// then empty and non-mapping documents reject.
pub fn parse_spec_yaml(input: &str) -> Result<Json, SpecError> {
    for (i, raw) in input.lines().enumerate() {
        let n = i + 1;
        let trimmed = raw.trim_end();
        let content = trimmed.trim_start();
        if content.is_empty() || content.starts_with('#') || content == "---" {
            continue;
        }
        let indent = &trimmed[..trimmed.len() - content.len()];
        if indent.contains('\t') {
            return Err(SpecError::at(n, "tab indentation is not allowed (use 2-space indents)"));
        }
        if indent.len() % 2 != 0 {
            return Err(SpecError::at(
                n,
                format!("odd indentation of {} space(s) (use 2-space indents)", indent.len()),
            ));
        }
    }
    let v = yamlmini::parse(input).map_err(|e| SpecError::at(e.line, e.msg))?;
    if matches!(v, Json::Null) {
        return Err(SpecError::at(1, "empty spec document"));
    }
    if v.as_obj().is_none() {
        return Err(SpecError::at(1, "top-level of a spec must be a mapping"));
    }
    Ok(v)
}

/// What a spec runs. Mirrors the CLI subcommands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunKind {
    Eval,
    Sweep,
    SloSearch,
    Regress,
    Autoscale,
}

impl RunKind {
    pub fn parse(s: &str) -> Option<RunKind> {
        match s {
            "eval" => Some(RunKind::Eval),
            "sweep" => Some(RunKind::Sweep),
            "slo-search" => Some(RunKind::SloSearch),
            "regress" => Some(RunKind::Regress),
            "autoscale" => Some(RunKind::Autoscale),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            RunKind::Eval => "eval",
            RunKind::Sweep => "sweep",
            RunKind::SloSearch => "slo-search",
            RunKind::Regress => "regress",
            RunKind::Autoscale => "autoscale",
        }
    }
}

/// `slo:` block — SLO-frontier search parameters (defaults mirror
/// `mlms slo-search`).
#[derive(Debug, Clone, PartialEq)]
pub struct SloBlock {
    pub percentile: f64,
    pub bounds_ms: Vec<f64>,
    pub start_qps: f64,
    pub probe_count: usize,
    pub max_probes: usize,
}

impl Default for SloBlock {
    fn default() -> Self {
        SloBlock {
            percentile: 99.0,
            bounds_ms: vec![50.0, 20.0, 10.0, 5.0],
            start_qps: 50.0,
            probe_count: 256,
            max_probes: 24,
        }
    }
}

impl SloBlock {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("percentile", Json::num(self.percentile)),
            ("bounds_ms", Json::arr(self.bounds_ms.iter().map(|b| Json::num(*b)).collect())),
            ("start_qps", Json::num(self.start_qps)),
            ("probe_count", Json::num(self.probe_count as f64)),
            ("max_probes", Json::num(self.max_probes as f64)),
        ])
    }
}

/// `regress:` block — the commit-over-commit gate's two run lines and
/// thresholds (defaults mirror `mlms regress`).
#[derive(Debug, Clone, PartialEq)]
pub struct RegressBlock {
    pub control: String,
    pub treatment: String,
    pub alpha: f64,
    pub min_effect: f64,
}

impl RegressBlock {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("control", Json::str(&self.control)),
            ("treatment", Json::str(&self.treatment)),
            ("alpha", Json::num(self.alpha)),
            ("min_effect", Json::num(self.min_effect)),
        ])
    }
}

/// `autoscale:` block — controller and service-model parameters (defaults
/// mirror `mlms autoscale`).
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleBlock {
    pub min_agents: usize,
    pub max_agents: usize,
    pub interval_s: f64,
    pub cooldown_s: f64,
    pub spawn_delay_s: f64,
    pub bound_ms: f64,
    pub percentile: f64,
    pub service_base_ms: f64,
    pub service_item_ms: f64,
    /// Initial fleet size; `None` starts at `min_agents`.
    pub agents: Option<usize>,
    /// `static: true` — fixed-fleet baseline, controller off.
    pub fixed: bool,
}

impl Default for AutoscaleBlock {
    fn default() -> Self {
        AutoscaleBlock {
            min_agents: 1,
            max_agents: 8,
            interval_s: 0.5,
            cooldown_s: 1.0,
            spawn_delay_s: 0.25,
            bound_ms: 10.0,
            percentile: 99.0,
            service_base_ms: 1.0,
            service_item_ms: 0.4,
            agents: None,
            fixed: false,
        }
    }
}

impl AutoscaleBlock {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("min_agents", Json::num(self.min_agents as f64)),
            ("max_agents", Json::num(self.max_agents as f64)),
            ("interval_s", Json::num(self.interval_s)),
            ("cooldown_s", Json::num(self.cooldown_s)),
            ("spawn_delay_s", Json::num(self.spawn_delay_s)),
            ("bound_ms", Json::num(self.bound_ms)),
            ("percentile", Json::num(self.percentile)),
            ("service_base_ms", Json::num(self.service_base_ms)),
            ("service_item_ms", Json::num(self.service_item_ms)),
            (
                "agents",
                self.agents.map(|a| Json::num(a as f64)).unwrap_or(Json::Null),
            ),
            ("static", Json::Bool(self.fixed)),
        ])
    }
}

/// A fully resolved evaluation spec file.
#[derive(Debug, Clone)]
pub struct EvalSpecFile {
    pub kind: RunKind,
    pub models: Vec<String>,
    pub systems: Vec<String>,
    pub scenario: Scenario,
    pub batch_sizes: Vec<usize>,
    pub trace_level: TraceLevel,
    pub seed: u64,
    pub run_label: String,
    pub accelerator: Accelerator,
    pub parallelism: usize,
    pub dispatch: Option<BatcherConfig>,
    pub admission: Option<AdmissionConfig>,
    pub slo: Option<SloBlock>,
    pub regress: Option<RegressBlock>,
    pub autoscale: Option<AutoscaleBlock>,
}

const TOP_KEYS: &[&str] = &[
    "run",
    "label",
    "model",
    "models",
    "system",
    "systems",
    "scenario",
    "batch_sizes",
    "trace_level",
    "seed",
    "accelerator",
    "parallelism",
    "dispatch",
    "admission",
    "slo",
    "regress",
    "autoscale",
];

impl EvalSpecFile {
    /// Parse spec YAML text into a resolved spec (strict front-end +
    /// strict schema).
    pub fn parse(input: &str) -> Result<EvalSpecFile, SpecError> {
        let j = parse_spec_yaml(input)?;
        EvalSpecFile::from_json(&j)
    }

    /// Validate a parsed document against the spec schema.
    pub fn from_json(j: &Json) -> Result<EvalSpecFile, SpecError> {
        reject_unknown(j, "spec", TOP_KEYS)?;

        let kind_raw = want_str(req(j, "spec", "run")?, "`run`")?;
        let kind = RunKind::parse(&kind_raw).ok_or_else(|| {
            SpecError::schema(format!(
                "unknown run kind {kind_raw:?} (eval|sweep|slo-search|regress|autoscale)"
            ))
        })?;

        let models = name_list(j, "model", "models")?
            .ok_or_else(|| SpecError::schema("a spec must name `model:` or `models:`"))?;
        let systems = name_list(j, "system", "systems")?
            .unwrap_or_else(crate::sysmodel::table1_system_names);

        let scenario = match get(j, "scenario") {
            None => Scenario::Online { count: 16 },
            Some(v) => {
                if v.as_obj().is_none() {
                    return Err(SpecError::schema("`scenario` must be a mapping with a `kind`"));
                }
                Scenario::from_json(v).ok_or_else(|| {
                    SpecError::schema(
                        "invalid `scenario` block (the strict grammar requires `kind` and \
                         every field of that kind, with finite positive values)",
                    )
                })?
            }
        };

        let batch_sizes = match get(j, "batch_sizes") {
            None => vec![1],
            Some(v) => want_count_list(v, "`batch_sizes`")?,
        };

        let trace_level = match get(j, "trace_level") {
            None => TraceLevel::None,
            Some(v) => {
                let s = want_str(v, "`trace_level`")?;
                TraceLevel::parse(&s).ok_or_else(|| {
                    SpecError::schema(format!(
                        "invalid `trace_level` {s:?} (none|model|framework|system|full)"
                    ))
                })?
            }
        };

        let seed = match get(j, "seed") {
            None => 42,
            Some(v) => want_u64(v, "`seed`")?,
        };

        let run_label = match get(j, "label") {
            None => String::new(),
            Some(v) => want_str(v, "`label`")?,
        };

        let accelerator = match get(j, "accelerator") {
            None => Accelerator::Gpu,
            Some(v) => {
                let s = want_str(v, "`accelerator`")?;
                match s.to_ascii_lowercase().as_str() {
                    // Accelerator::parse maps unknown strings to Any; a
                    // declarative spec must not accept typos that way.
                    "cpu" | "gpu" | "fpga" | "any" => Accelerator::parse(&s),
                    _ => {
                        return Err(SpecError::schema(format!(
                            "invalid `accelerator` {s:?} (cpu|gpu|fpga|any)"
                        )))
                    }
                }
            }
        };

        let parallelism = match get(j, "parallelism") {
            None => 4,
            Some(v) => want_count(v, "`parallelism`")?,
        };

        let dispatch = match get(j, "dispatch") {
            None => None,
            Some(v) => Some(parse_dispatch(v)?),
        };

        let admission = match get(j, "admission") {
            None => None,
            Some(v) => Some(parse_admission(v)?),
        };

        let slo = match get(j, "slo") {
            None => None,
            Some(v) => Some(parse_slo(v)?),
        };

        let regress = match get(j, "regress") {
            None => None,
            Some(v) => Some(parse_regress(v)?),
        };

        let autoscale = match get(j, "autoscale") {
            None => None,
            Some(v) => Some(parse_autoscale(v)?),
        };

        // Kind ↔ block consistency: a block that the declared run kind
        // would never read is an error, not dead weight.
        if kind == RunKind::Regress && regress.is_none() {
            return Err(SpecError::schema("run: regress requires a `regress:` block"));
        }
        if regress.is_some() && kind != RunKind::Regress {
            return Err(SpecError::schema("a `regress:` block requires run: regress"));
        }
        if slo.is_some() && kind != RunKind::SloSearch {
            return Err(SpecError::schema("an `slo:` block requires run: slo-search"));
        }
        if autoscale.is_some() && kind != RunKind::Autoscale {
            return Err(SpecError::schema("an `autoscale:` block requires run: autoscale"));
        }
        if admission.is_some() && kind != RunKind::Autoscale {
            return Err(SpecError::schema(
                "an `admission:` block is only used by run: autoscale",
            ));
        }

        Ok(EvalSpecFile {
            kind,
            models,
            systems,
            scenario,
            batch_sizes,
            trace_level,
            seed,
            run_label,
            accelerator,
            parallelism,
            dispatch,
            admission,
            slo,
            regress,
            autoscale,
        })
    }

    /// Lower the spec onto the sweep engine's plan. This is the digest
    /// parity point: the returned plan is field-for-field what the
    /// flag-driven CLI builds, so every cell's content-addressed
    /// [`EvalSpec`](crate::evaldb::EvalSpec) digest — and therefore its
    /// memoization line — is identical between the two front-ends.
    pub fn to_plan(&self) -> Plan {
        let mut plan = Plan::new(self.models.clone(), self.systems.clone());
        plan.scenarios = vec![self.scenario.clone()];
        plan.batch_sizes = self.batch_sizes.clone();
        plan.accelerator = self.accelerator;
        plan.trace_level = self.trace_level;
        plan.seed = self.seed;
        plan.dispatch = self.dispatch.clone();
        plan.parallelism = self.parallelism;
        plan.run_meta = if self.run_label.is_empty() {
            RunMeta::default()
        } else {
            RunMeta::labeled(&self.run_label)
        };
        plan
    }

    /// The resolved spec as canonical JSON. Two spec files that differ
    /// only in key order, comments, or formatting resolve to the same
    /// value (and hence the same [`digest`](EvalSpecFile::digest)).
    pub fn canonical_json(&self) -> Json {
        Json::obj(vec![
            ("run", Json::str(self.kind.as_str())),
            ("models", Json::arr(self.models.iter().map(Json::str).collect())),
            ("systems", Json::arr(self.systems.iter().map(Json::str).collect())),
            ("scenario", self.scenario.to_json()),
            (
                "batch_sizes",
                Json::arr(self.batch_sizes.iter().map(|b| Json::num(*b as f64)).collect()),
            ),
            ("trace_level", Json::str(self.trace_level.as_str())),
            // Seed as a string: u64 survives exactly (same trick as
            // EvalSpec::canonical).
            ("seed", Json::str(self.seed.to_string())),
            ("label", Json::str(&self.run_label)),
            ("accelerator", Json::str(self.accelerator.as_str())),
            ("parallelism", Json::num(self.parallelism as f64)),
            (
                "dispatch",
                self.dispatch.as_ref().map(|d| d.fingerprint_json()).unwrap_or(Json::Null),
            ),
            (
                "admission",
                self.admission.as_ref().map(|a| a.fingerprint_json()).unwrap_or(Json::Null),
            ),
            ("slo", self.slo.as_ref().map(|s| s.to_json()).unwrap_or(Json::Null)),
            ("regress", self.regress.as_ref().map(|r| r.to_json()).unwrap_or(Json::Null)),
            (
                "autoscale",
                self.autoscale.as_ref().map(|a| a.to_json()).unwrap_or(Json::Null),
            ),
        ])
    }

    /// Content digest of the resolved spec.
    pub fn digest(&self) -> String {
        sha256_hex(self.canonical_json().to_string().as_bytes())
    }
}

// ---------------------------------------------------------------------------
// Block parsers.

fn parse_dispatch(v: &Json) -> Result<BatcherConfig, SpecError> {
    reject_unknown(v, "dispatch", &["batch", "wait_ms", "fair"])?;
    let mut cfg = BatcherConfig::new(8, 5.0);
    if let Some(b) = get(v, "batch") {
        cfg.max_batch_size = want_count(b, "`dispatch.batch`")?;
    }
    if let Some(w) = get(v, "wait_ms") {
        cfg.max_wait_ms = want_pos(w, "`dispatch.wait_ms`")?;
    }
    if let Some(f) = get(v, "fair") {
        cfg.fair = want_bool(f, "`dispatch.fair`")?;
    }
    Ok(cfg)
}

fn parse_policy(v: &Json, ctx: &str, allow_tenant: bool) -> Result<TenantPolicy, SpecError> {
    let allowed: &[&str] = if allow_tenant {
        &["tenant", "priority", "rate_per_s", "burst", "deadline_ms"]
    } else {
        &["priority", "rate_per_s", "burst", "deadline_ms"]
    };
    reject_unknown(v, ctx, allowed)?;
    let mut p = TenantPolicy::default();
    if let Some(pr) = get(v, "priority") {
        let s = want_str(pr, &format!("`{ctx}.priority`"))?;
        p.priority = Priority::from_str(&s).ok_or_else(|| {
            SpecError::schema(format!("invalid `{ctx}.priority` {s:?} (high|low)"))
        })?;
    }
    if let Some(r) = get(v, "rate_per_s") {
        p.rate_per_s = Some(want_pos(r, &format!("`{ctx}.rate_per_s`"))?);
    }
    if let Some(b) = get(v, "burst") {
        p.burst = want_pos(b, &format!("`{ctx}.burst`"))?;
    }
    if let Some(d) = get(v, "deadline_ms") {
        p.queue_deadline_ms = Some(want_pos(d, &format!("`{ctx}.deadline_ms`"))?);
    }
    Ok(p)
}

fn parse_admission(v: &Json) -> Result<AdmissionConfig, SpecError> {
    reject_unknown(v, "admission", &["default", "tenants"])?;
    let mut cfg = AdmissionConfig::default();
    if let Some(d) = get(v, "default") {
        cfg.default = parse_policy(d, "admission.default", false)?;
    }
    if let Some(ts) = get(v, "tenants") {
        let arr = ts
            .as_arr()
            .ok_or_else(|| SpecError::schema("`admission.tenants` must be a list"))?;
        for (i, t) in arr.iter().enumerate() {
            let ctx = format!("admission.tenants[{i}]");
            let id = want_u64(
                req(t, &ctx, "tenant")?,
                &format!("`{ctx}.tenant`"),
            )?;
            if id > u32::MAX as u64 {
                return Err(SpecError::schema(format!(
                    "`{ctx}.tenant` {id} exceeds the 32-bit tenant id space"
                )));
            }
            let policy = parse_policy(t, &ctx, true)?;
            cfg = cfg.with_tenant(id as u32, policy);
        }
    }
    Ok(cfg)
}

fn parse_slo(v: &Json) -> Result<SloBlock, SpecError> {
    reject_unknown(
        v,
        "slo",
        &["percentile", "bounds_ms", "start_qps", "probe_count", "max_probes"],
    )?;
    let mut b = SloBlock::default();
    if let Some(p) = get(v, "percentile") {
        b.percentile = want_pos(p, "`slo.percentile`")?;
        if b.percentile >= 100.0 {
            return Err(SpecError::schema("`slo.percentile` must be in (0, 100)"));
        }
    }
    if let Some(bs) = get(v, "bounds_ms") {
        b.bounds_ms = want_pos_list(bs, "`slo.bounds_ms`")?;
    }
    if let Some(q) = get(v, "start_qps") {
        b.start_qps = want_pos(q, "`slo.start_qps`")?;
    }
    if let Some(c) = get(v, "probe_count") {
        b.probe_count = want_count(c, "`slo.probe_count`")?;
    }
    if let Some(m) = get(v, "max_probes") {
        b.max_probes = want_count(m, "`slo.max_probes`")?;
    }
    Ok(b)
}

fn parse_regress(v: &Json) -> Result<RegressBlock, SpecError> {
    reject_unknown(v, "regress", &["control", "treatment", "alpha", "min_effect"])?;
    let control = want_str(req(v, "regress", "control")?, "`regress.control`")?;
    let treatment = want_str(req(v, "regress", "treatment")?, "`regress.treatment`")?;
    if control == treatment {
        return Err(SpecError::schema(
            "`regress.control` and `regress.treatment` must name different run lines",
        ));
    }
    let mut b = RegressBlock { control, treatment, alpha: 0.01, min_effect: 0.05 };
    if let Some(a) = get(v, "alpha") {
        b.alpha = want_pos(a, "`regress.alpha`")?;
        if b.alpha >= 1.0 {
            return Err(SpecError::schema("`regress.alpha` must be in (0, 1)"));
        }
    }
    if let Some(m) = get(v, "min_effect") {
        b.min_effect = want_pos(m, "`regress.min_effect`")?;
    }
    Ok(b)
}

fn parse_autoscale(v: &Json) -> Result<AutoscaleBlock, SpecError> {
    reject_unknown(
        v,
        "autoscale",
        &[
            "min_agents",
            "max_agents",
            "interval_s",
            "cooldown_s",
            "spawn_delay_s",
            "bound_ms",
            "percentile",
            "service_base_ms",
            "service_item_ms",
            "agents",
            "static",
        ],
    )?;
    let mut b = AutoscaleBlock::default();
    if let Some(x) = get(v, "min_agents") {
        b.min_agents = want_count(x, "`autoscale.min_agents`")?;
    }
    if let Some(x) = get(v, "max_agents") {
        b.max_agents = want_count(x, "`autoscale.max_agents`")?;
    }
    if b.max_agents < b.min_agents {
        return Err(SpecError::schema("`autoscale.max_agents` must be >= `min_agents`"));
    }
    if let Some(x) = get(v, "interval_s") {
        b.interval_s = want_pos(x, "`autoscale.interval_s`")?;
    }
    if let Some(x) = get(v, "cooldown_s") {
        b.cooldown_s = want_pos(x, "`autoscale.cooldown_s`")?;
    }
    if let Some(x) = get(v, "spawn_delay_s") {
        b.spawn_delay_s = want_pos(x, "`autoscale.spawn_delay_s`")?;
    }
    if let Some(x) = get(v, "bound_ms") {
        b.bound_ms = want_pos(x, "`autoscale.bound_ms`")?;
    }
    if let Some(x) = get(v, "percentile") {
        b.percentile = want_pos(x, "`autoscale.percentile`")?;
        if b.percentile >= 100.0 {
            return Err(SpecError::schema("`autoscale.percentile` must be in (0, 100)"));
        }
    }
    if let Some(x) = get(v, "service_base_ms") {
        b.service_base_ms = want_pos(x, "`autoscale.service_base_ms`")?;
    }
    if let Some(x) = get(v, "service_item_ms") {
        b.service_item_ms = want_pos(x, "`autoscale.service_item_ms`")?;
    }
    if let Some(x) = get(v, "agents") {
        b.agents = Some(want_count(x, "`autoscale.agents`")?);
    }
    if let Some(x) = get(v, "static") {
        b.fixed = want_bool(x, "`autoscale.static`")?;
    }
    Ok(b)
}

// ---------------------------------------------------------------------------
// Strict typed field helpers. Counts reject non-finite, non-integral, and
// beyond-2^53 values (the same contract as the scenario grammar).

/// Largest f64 that still represents every integer exactly (2^53).
const MAX_EXACT: f64 = 9_007_199_254_740_992.0;

/// A present key; explicit `null` (bare `key:`) counts as absent.
fn get<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    j.get(key).filter(|v| !matches!(v, Json::Null))
}

fn req<'a>(j: &'a Json, ctx: &str, key: &str) -> Result<&'a Json, SpecError> {
    get(j, key).ok_or_else(|| SpecError::schema(format!("`{ctx}` requires `{key}:`")))
}

fn reject_unknown(j: &Json, ctx: &str, allowed: &[&str]) -> Result<(), SpecError> {
    let obj = j
        .as_obj()
        .ok_or_else(|| SpecError::schema(format!("`{ctx}` must be a mapping")))?;
    for k in obj.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(SpecError::schema(format!(
                "unknown key `{k}` in `{ctx}` (allowed: {})",
                allowed.join(", ")
            )));
        }
    }
    Ok(())
}

fn want_str(v: &Json, what: &str) -> Result<String, SpecError> {
    v.as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| SpecError::schema(format!("{what} must be a string")))
}

fn want_bool(v: &Json, what: &str) -> Result<bool, SpecError> {
    v.as_bool().ok_or_else(|| SpecError::schema(format!("{what} must be true or false")))
}

fn want_finite(v: &Json, what: &str) -> Result<f64, SpecError> {
    v.as_f64()
        .filter(|x| x.is_finite())
        .ok_or_else(|| SpecError::schema(format!("{what} must be a finite number")))
}

fn want_pos(v: &Json, what: &str) -> Result<f64, SpecError> {
    let x = want_finite(v, what)?;
    if x > 0.0 {
        Ok(x)
    } else {
        Err(SpecError::schema(format!("{what} must be positive")))
    }
}

fn want_count(v: &Json, what: &str) -> Result<usize, SpecError> {
    let x = want_finite(v, what)?;
    if x >= 1.0 && x <= MAX_EXACT && x.fract() == 0.0 {
        Ok(x as usize)
    } else {
        Err(SpecError::schema(format!("{what} must be a positive integer")))
    }
}

fn want_u64(v: &Json, what: &str) -> Result<u64, SpecError> {
    let x = want_finite(v, what)?;
    if x >= 0.0 && x <= MAX_EXACT && x.fract() == 0.0 {
        Ok(x as u64)
    } else {
        Err(SpecError::schema(format!("{what} must be a non-negative integer")))
    }
}

fn want_count_list(v: &Json, what: &str) -> Result<Vec<usize>, SpecError> {
    let arr = v
        .as_arr()
        .ok_or_else(|| SpecError::schema(format!("{what} must be a list of positive integers")))?;
    if arr.is_empty() {
        return Err(SpecError::schema(format!("{what} must not be empty")));
    }
    arr.iter().map(|x| want_count(x, &format!("{what} entry"))).collect()
}

fn want_pos_list(v: &Json, what: &str) -> Result<Vec<f64>, SpecError> {
    let arr = v
        .as_arr()
        .ok_or_else(|| SpecError::schema(format!("{what} must be a list of positive numbers")))?;
    if arr.is_empty() {
        return Err(SpecError::schema(format!("{what} must not be empty")));
    }
    arr.iter().map(|x| want_pos(x, &format!("{what} entry"))).collect()
}

/// `model:`/`models:` (or `system:`/`systems:`): singular is one string,
/// plural a non-empty string list; naming both is ambiguous and rejects.
fn name_list(j: &Json, singular: &str, plural: &str) -> Result<Option<Vec<String>>, SpecError> {
    match (get(j, singular), get(j, plural)) {
        (Some(_), Some(_)) => Err(SpecError::schema(format!(
            "`{singular}:` and `{plural}:` are mutually exclusive"
        ))),
        (Some(v), None) => Ok(Some(vec![want_str(v, &format!("`{singular}`"))?])),
        (None, Some(v)) => {
            let arr = v.as_arr().ok_or_else(|| {
                SpecError::schema(format!("`{plural}` must be a list of names"))
            })?;
            if arr.is_empty() {
                return Err(SpecError::schema(format!("`{plural}` must not be empty")));
            }
            let names = arr
                .iter()
                .map(|x| want_str(x, &format!("`{plural}` entry")))
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Some(names))
        }
        (None, None) => Ok(None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL_SWEEP: &str = "\
run: sweep
models: [ResNet_v1_50, VGG16]
systems: [aws_p3]
scenario:
  kind: online
  count: 16
batch_sizes: [1, 8]
trace_level: none
seed: 42
label: nightly
accelerator: gpu
parallelism: 2
dispatch:
  batch: 8
  wait_ms: 5
  fair: true
";

    #[test]
    fn full_sweep_spec_resolves() {
        let s = EvalSpecFile::parse(FULL_SWEEP).unwrap();
        assert_eq!(s.kind, RunKind::Sweep);
        assert_eq!(s.models, vec!["ResNet_v1_50", "VGG16"]);
        assert_eq!(s.systems, vec!["aws_p3"]);
        assert_eq!(s.scenario, Scenario::Online { count: 16 });
        assert_eq!(s.batch_sizes, vec![1, 8]);
        assert_eq!(s.seed, 42);
        assert_eq!(s.run_label, "nightly");
        assert_eq!(s.parallelism, 2);
        let d = s.dispatch.as_ref().unwrap();
        assert_eq!(d.max_batch_size, 8);
        assert!(d.fair);
        let plan = s.to_plan();
        assert_eq!(plan.run_meta.label, "nightly");
        assert_eq!(plan.scenarios, vec![Scenario::Online { count: 16 }]);
    }

    #[test]
    fn defaults_mirror_the_flag_path() {
        let s = EvalSpecFile::parse("run: eval\nmodel: ResNet_v1_50\n").unwrap();
        assert_eq!(s.systems, crate::sysmodel::table1_system_names());
        assert_eq!(s.scenario, Scenario::Online { count: 16 });
        assert_eq!(s.batch_sizes, vec![1]);
        assert_eq!(s.trace_level, TraceLevel::None);
        assert_eq!(s.seed, 42);
        assert_eq!(s.parallelism, 4);
        assert!(s.dispatch.is_none());
        assert_eq!(s.run_label, "");
    }

    #[test]
    fn front_end_rejects_tabs_with_line_number() {
        let err = EvalSpecFile::parse("run: eval\nscenario:\n\tkind: online\n").unwrap_err();
        assert_eq!(err.line, 3);
        assert!(err.msg.contains("tab"), "{}", err.msg);
    }

    #[test]
    fn front_end_rejects_odd_indent_with_line_number() {
        let err =
            EvalSpecFile::parse("run: eval\nscenario:\n   kind: online\n").unwrap_err();
        assert_eq!(err.line, 3);
        assert!(err.msg.contains("odd indentation"), "{}", err.msg);
    }

    #[test]
    fn front_end_rejects_empty_and_non_mapping_docs() {
        assert!(EvalSpecFile::parse("").unwrap_err().msg.contains("empty"));
        assert!(EvalSpecFile::parse("# just a comment\n").unwrap_err().msg.contains("empty"));
        assert!(EvalSpecFile::parse("- a\n- b\n").unwrap_err().msg.contains("mapping"));
    }

    #[test]
    fn duplicate_keys_reject_with_line_number() {
        let err = EvalSpecFile::parse("run: eval\nmodel: A\nmodel: B\n").unwrap_err();
        assert_eq!(err.line, 3);
        assert!(err.msg.contains("duplicate"), "{}", err.msg);
    }

    #[test]
    fn unknown_keys_reject_everywhere() {
        let err = EvalSpecFile::parse("run: eval\nmodel: A\nbatchsizes: [1]\n").unwrap_err();
        assert!(err.msg.contains("batchsizes"), "{}", err.msg);
        let err = EvalSpecFile::parse(
            "run: eval\nmodel: A\ndispatch:\n  batch: 8\n  waitms: 5\n",
        )
        .unwrap_err();
        assert!(err.msg.contains("waitms"), "{}", err.msg);
    }

    #[test]
    fn typed_fields_reject_bad_values() {
        for (spec, needle) in [
            ("run: warp\nmodel: A\n", "unknown run kind"),
            ("run: eval\n", "`model:` or `models:`"),
            ("run: eval\nmodel: A\nmodels: [B]\n", "mutually exclusive"),
            ("run: eval\nmodels: []\n", "must not be empty"),
            ("run: eval\nmodel: A\nseed: 1.5\n", "non-negative integer"),
            ("run: eval\nmodel: A\nseed: -1\n", "non-negative integer"),
            ("run: eval\nmodel: A\nbatch_sizes: [0]\n", "positive integer"),
            ("run: eval\nmodel: A\nbatch_sizes: 8\n", "must be a list"),
            ("run: eval\nmodel: A\ntrace_level: ful\n", "trace_level"),
            ("run: eval\nmodel: A\naccelerator: gup\n", "accelerator"),
            ("run: eval\nmodel: A\nparallelism: 0\n", "positive integer"),
            ("run: eval\nmodel: A\nscenario: online\n", "must be a mapping"),
            ("run: eval\nmodel: A\nscenario:\n  kind: online\n", "scenario"),
            ("run: eval\nmodel: A\ndispatch:\n  wait_ms: 0\n", "positive"),
        ] {
            let err = EvalSpecFile::parse(spec).unwrap_err();
            assert!(err.msg.contains(needle), "spec {spec:?}: got {:?}", err.msg);
        }
    }

    #[test]
    fn kind_block_consistency_is_enforced() {
        let err = EvalSpecFile::parse("run: regress\nmodel: A\n").unwrap_err();
        assert!(err.msg.contains("requires a `regress:` block"), "{}", err.msg);
        let err = EvalSpecFile::parse(
            "run: eval\nmodel: A\nregress:\n  control: a\n  treatment: b\n",
        )
        .unwrap_err();
        assert!(err.msg.contains("requires run: regress"), "{}", err.msg);
        let err =
            EvalSpecFile::parse("run: eval\nmodel: A\nslo:\n  percentile: 99\n").unwrap_err();
        assert!(err.msg.contains("run: slo-search"), "{}", err.msg);
        let err = EvalSpecFile::parse(
            "run: regress\nmodel: A\nregress:\n  control: x\n  treatment: x\n",
        )
        .unwrap_err();
        assert!(err.msg.contains("different run lines"), "{}", err.msg);
    }

    #[test]
    fn admission_block_parses_tenant_policies() {
        let s = EvalSpecFile::parse(
            "run: autoscale\nmodel: A\nadmission:\n  tenants:\n    - tenant: 1\n      \
             priority: low\n      rate_per_s: 500\n      burst: 64\n      deadline_ms: 50\n",
        )
        .unwrap();
        let adm = s.admission.unwrap();
        let p = adm.policy_for(1);
        assert_eq!(p.priority, Priority::Low);
        assert_eq!(p.rate_per_s, Some(500.0));
        assert_eq!(p.burst, 64.0);
        assert_eq!(p.queue_deadline_ms, Some(50.0));
        assert_eq!(adm.policy_for(0).priority, Priority::High);
    }

    #[test]
    fn digest_is_invariant_under_key_reordering() {
        let reordered = "\
label: nightly
dispatch:
  fair: true
  wait_ms: 5
  batch: 8
parallelism: 2
accelerator: gpu
seed: 42
trace_level: none
batch_sizes: [1, 8]
scenario:
  count: 16
  kind: online
systems: [aws_p3]
models: [ResNet_v1_50, VGG16]
run: sweep
";
        let a = EvalSpecFile::parse(FULL_SWEEP).unwrap();
        let b = EvalSpecFile::parse(reordered).unwrap();
        assert_eq!(a.canonical_json().to_string(), b.canonical_json().to_string());
        assert_eq!(a.digest(), b.digest());
        // And a one-field change does move the digest.
        let c = EvalSpecFile::parse(&FULL_SWEEP.replace("seed: 42", "seed: 43")).unwrap();
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn plan_digests_match_the_flag_built_plan() {
        let s = EvalSpecFile::parse(FULL_SWEEP).unwrap();
        let from_spec = s.to_plan();
        // What build_sweep_plan in main.rs would produce for the
        // flag-equivalent invocation.
        let mut by_hand = Plan::new(
            vec!["ResNet_v1_50".into(), "VGG16".into()],
            vec!["aws_p3".into()],
        );
        by_hand.scenarios = vec![Scenario::Online { count: 16 }];
        by_hand.batch_sizes = vec![1, 8];
        by_hand.seed = 42;
        by_hand.parallelism = 2;
        by_hand.dispatch = Some(BatcherConfig::new(8, 5.0).with_fairness());
        by_hand.run_meta = RunMeta::labeled("nightly");
        let registry = crate::registry::Registry::new();
        for m in crate::zoo::all() {
            registry.register_manifest(m.manifest());
        }
        for (a, b) in from_spec.cells().iter().zip(by_hand.cells().iter()) {
            assert_eq!(
                from_spec.digest(&registry, a),
                by_hand.digest(&registry, b),
                "cell {} digests diverge between spec and flag front-ends",
                a.label()
            );
        }
    }
}
