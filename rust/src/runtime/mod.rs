//! PJRT runtime boundary: load AOT-compiled HLO artifacts and execute them
//! (the paper's "framework C library" binding — §4.4.3's "MLModelScope
//! binds to the frameworks' C API to avoid the overhead of scripting
//! languages").
//!
//! ## Offline stub
//!
//! The dependency-free build has no `xla`/PJRT bindings, so this module
//! keeps the runtime's *interface* — artifact paths, the executable cache
//! contract, `Runtime::cpu()` / `run()` — while the execution entry points
//! return a typed [`RuntimeError`]. Everything above this boundary
//! (predictor, agent, server, CLI) is written against the interface and
//! degrades cleanly: the platform falls back to the Table-1 simulator
//! agents (§4.4.4 explicitly supports simulator-published trace times), and
//! artifact-dependent tests skip when [`available_families`] is empty.
//!
//! Re-enabling real execution means implementing [`Runtime::run_multi`]
//! over a PJRT binding; the artifact format (HLO text produced by
//! `python/compile/aot.py`) and the cache semantics are unchanged.

use crate::preprocess::Tensor;
use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Runtime-layer error (compile, execute, or missing-binding failures).
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

fn err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

/// Shared runtime handle: tracks the loaded-artifact cache so the
/// predictor's load/unload lifecycle is exercised even without bindings.
pub struct Runtime {
    cache: Mutex<HashSet<PathBuf>>,
}

impl Runtime {
    /// Create a CPU runtime handle. Succeeds so platform assembly (server,
    /// CLI) works uniformly; execution reports the missing binding.
    pub fn cpu() -> Result<std::sync::Arc<Runtime>> {
        Ok(std::sync::Arc::new(Runtime { cache: Mutex::new(HashSet::new()) }))
    }

    /// Backing platform name (`"stub"` until real PJRT bindings are wired).
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Register an artifact in the cache (idempotent). Fails when the
    /// artifact file does not exist — same contract as the compiling
    /// implementation, minus the compile.
    pub fn load(&self, path: &Path) -> Result<()> {
        if !path.exists() {
            return Err(err(format!("parse HLO text {}: file not found", path.display())));
        }
        self.cache.lock().unwrap().insert(path.to_path_buf());
        Ok(())
    }

    /// Drop a cached executable (the predictor interface's `ModelUnload`).
    pub fn unload(&self, path: &Path) {
        self.cache.lock().unwrap().remove(path);
    }

    /// Number of artifacts currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute an artifact on one input tensor.
    pub fn run(&self, path: &Path, input: &Tensor) -> Result<Tensor> {
        self.run_multi(path, std::slice::from_ref(input))
    }

    /// Execute with multiple input tensors. Always an error in the stub:
    /// there is no PJRT binding to run the HLO on.
    pub fn run_multi(&self, path: &Path, _inputs: &[Tensor]) -> Result<Tensor> {
        Err(err(format!(
            "execute {}: PJRT bindings not available in this build (simulator agents remain fully functional)",
            path.display()
        )))
    }
}

/// Resolve the artifacts directory: `$MLMS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("MLMS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Path of a model-family artifact at a batch size.
pub fn artifact_path(family: &str, batch: usize) -> PathBuf {
    artifacts_dir().join(format!("{family}_b{batch}.hlo.txt"))
}

/// Batch sizes the AOT pipeline compiles per family (must match aot.py).
pub const ARTIFACT_BATCHES: &[usize] = &[1, 2, 4, 8, 16, 32];

/// List the families with at least the batch-1 artifact present.
pub fn available_families() -> Vec<String> {
    let dir = artifacts_dir();
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&dir) {
        for e in entries.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if let Some(family) = name.strip_suffix("_b1.hlo.txt") {
                out.push(family.to_string());
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_file() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mlms_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("smoke.hlo.txt");
        std::fs::write(&path, "HloModule stub\n").unwrap();
        path
    }

    #[test]
    fn cache_load_unload() {
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.cached(), 0);
        rt.load(&artifact_file()).unwrap();
        rt.load(&artifact_file()).unwrap();
        assert_eq!(rt.cached(), 1);
        rt.unload(&artifact_file());
        assert_eq!(rt.cached(), 0);
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load(Path::new("/nonexistent/model.hlo.txt")).is_err());
    }

    #[test]
    fn stub_execution_reports_missing_binding() {
        let rt = Runtime::cpu().unwrap();
        let path = artifact_file();
        rt.load(&path).unwrap();
        let input = Tensor::zeros(vec![1, 4]);
        let e = rt.run(&path, &input).unwrap_err();
        assert!(e.to_string().contains("PJRT bindings"), "{e}");
    }

    #[test]
    fn artifact_paths() {
        assert!(artifact_path("tiny_resnet", 8)
            .to_string_lossy()
            .ends_with("tiny_resnet_b8.hlo.txt"));
    }

    #[test]
    fn platform_is_stub() {
        assert_eq!(Runtime::cpu().unwrap().platform(), "stub");
    }
}
