//! PJRT runtime: load AOT-compiled HLO text and execute it (the paper's
//! "framework C library" binding — §4.4.3's "MLModelScope binds to the
//! frameworks' C API to avoid the overhead of scripting languages").
//!
//! The compile path (`python/compile/aot.py`) lowers each JAX/Pallas model
//! to **HLO text** (not a serialized `HloModuleProto`: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids — see /opt/xla-example/README.md). This module loads that
//! text with `HloModuleProto::from_text_file`, compiles it on the PJRT CPU
//! client, and executes it with zero Python on the request path.
//!
//! ## Thread safety
//!
//! The `xla` crate's `PjRtClient` is an `Rc`-based handle (not `Send`), and
//! executables/buffers clone it internally. [`Runtime`] therefore keeps the
//! client and the executable cache behind a single `Mutex` and performs
//! *every* PJRT interaction — compile, execute, buffer fetch — while holding
//! it. All `Rc` refcount traffic is serialized by that lock, which is what
//! makes the `unsafe impl Send + Sync` below sound. The underlying XLA CPU
//! runtime parallelizes internally, so one-at-a-time dispatch does not
//! serialize the math, only the FFI boundary.
//!
//! Executables are cached per artifact path: XLA compilation is expensive
//! and the agent reuses one compiled executable per (model, batch) variant.

use crate::preprocess::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

struct Inner {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
}

/// Shared PJRT CPU client + executable cache. Cheap to clone via `Arc`.
pub struct Runtime {
    inner: Mutex<Inner>,
}

// SAFETY: every access to the Rc-based xla handles goes through `inner`'s
// Mutex (see module docs); no Rc clone/drop can race.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create a CPU PJRT runtime.
    pub fn cpu() -> Result<Arc<Runtime>> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Arc::new(Runtime { inner: Mutex::new(Inner { client, cache: HashMap::new() }) }))
    }

    pub fn platform(&self) -> String {
        self.inner.lock().unwrap().client.platform_name()
    }

    /// Load + compile an HLO-text artifact into the cache (idempotent).
    pub fn load(&self, path: &Path) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.cache.contains_key(path) {
            return Ok(());
        }
        let exe = compile_at(&inner.client, path)?;
        inner.cache.insert(path.to_path_buf(), exe);
        Ok(())
    }

    /// Drop a cached executable (the predictor interface's `ModelUnload`).
    pub fn unload(&self, path: &Path) {
        self.inner.lock().unwrap().cache.remove(path);
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.inner.lock().unwrap().cache.len()
    }

    /// Execute an artifact on one input tensor; compiles on first use.
    /// Returns the first output tensor (artifacts are lowered with
    /// `return_tuple=True`, so the single output is a 1-tuple).
    pub fn run(&self, path: &Path, input: &Tensor) -> Result<Tensor> {
        self.run_multi(path, std::slice::from_ref(input))
    }

    /// Execute with multiple input tensors.
    pub fn run_multi(&self, path: &Path, inputs: &[Tensor]) -> Result<Tensor> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.cache.contains_key(path) {
            let exe = compile_at(&inner.client, path)?;
            inner.cache.insert(path.to_path_buf(), exe);
        }
        let exe = inner.cache.get(path).unwrap();
        let lits: Vec<xla::Literal> =
            inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e}", path.display()))?;
        let out = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("no output buffer"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e}"))?;
        let out = out.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        literal_to_tensor(&out)
    }
}

fn compile_at(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parse HLO text {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {}: {e}", path.display()))
}

/// Tensor → XLA literal (f32, reshaped to the tensor's dims).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e}"))
}

/// XLA literal → Tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
    Ok(Tensor::new(dims, data))
}

/// Resolve the artifacts directory: `$MLMS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("MLMS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Path of a model-family artifact at a batch size.
pub fn artifact_path(family: &str, batch: usize) -> PathBuf {
    artifacts_dir().join(format!("{family}_b{batch}.hlo.txt"))
}

/// Batch sizes the AOT pipeline compiles per family (must match aot.py).
pub const ARTIFACT_BATCHES: &[usize] = &[1, 2, 4, 8, 16, 32];

/// List the families with at least the batch-1 artifact present.
pub fn available_families() -> Vec<String> {
    let dir = artifacts_dir();
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&dir) {
        for e in entries.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if let Some(family) = name.strip_suffix("_b1.hlo.txt") {
                out.push(family.to_string());
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny hand-written HLO module (x·y + 2 over f32[2,2]) so the bridge
    /// is tested without depending on `make artifacts`.
    const SMOKE_HLO: &str = r#"
HloModule jit_fn, entry_computation_layout={(f32[2,2]{1,0}, f32[2,2]{1,0})->(f32[2,2]{1,0})}

ENTRY main.8 {
  Arg_0.1 = f32[2,2]{1,0} parameter(0)
  Arg_1.2 = f32[2,2]{1,0} parameter(1)
  dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  constant.4 = f32[] constant(2)
  broadcast.5 = f32[2,2]{1,0} broadcast(constant.4), dimensions={}
  add.6 = f32[2,2]{1,0} add(dot.3, broadcast.5)
  ROOT tuple.7 = (f32[2,2]{1,0}) tuple(add.6)
}
"#;

    fn smoke_path() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mlms_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("smoke.hlo.txt");
        std::fs::write(&path, SMOKE_HLO).unwrap();
        path
    }

    #[test]
    fn smoke_hlo_two_arg_execution() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        let x = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let y = Tensor::new(vec![2, 2], vec![1., 1., 1., 1.]);
        let out = rt.run_multi(&smoke_path(), &[x, y]).unwrap();
        assert_eq!(out.shape, vec![2, 2]);
        assert_eq!(out.data, vec![5., 5., 9., 9.]);
    }

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::random(vec![2, 3, 4], 1);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back.shape, t.shape);
        assert_eq!(back.data, t.data);
    }

    #[test]
    fn cache_load_unload() {
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.cached(), 0);
        rt.load(&smoke_path()).unwrap();
        rt.load(&smoke_path()).unwrap();
        assert_eq!(rt.cached(), 1);
        rt.unload(&smoke_path());
        assert_eq!(rt.cached(), 0);
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load(Path::new("/nonexistent/model.hlo.txt")).is_err());
    }

    #[test]
    fn concurrent_execution_is_safe() {
        let rt = Runtime::cpu().unwrap();
        rt.load(&smoke_path()).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let rt = rt.clone();
                std::thread::spawn(move || {
                    for _ in 0..10 {
                        let x = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
                        let y = Tensor::new(vec![2, 2], vec![1., 1., 1., 1.]);
                        let out = rt.run_multi(&smoke_path(), &[x, y]).unwrap();
                        assert_eq!(out.data, vec![5., 5., 9., 9.]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn artifact_paths() {
        assert!(artifact_path("tiny_resnet", 8)
            .to_string_lossy()
            .ends_with("tiny_resnet_b8.hlo.txt"));
    }

    /// Real-artifact integration: only runs after `make artifacts`.
    #[test]
    fn real_artifact_executes_if_present() {
        let path = artifact_path("tiny_resnet", 1);
        if !path.exists() {
            eprintln!("skipping: {} missing (run `make artifacts`)", path.display());
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let input = Tensor::random(vec![1, 32, 32, 3], 7);
        let out = rt.run(&path, &input).unwrap();
        assert_eq!(out.shape, vec![1, 10]);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }
}
