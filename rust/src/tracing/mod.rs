//! Across-stack tracing hooks (paper §4.4.4, F9).
//!
//! A *tracing hook* is a start/end pair capturing an interval of time plus
//! context and metadata — a *trace event* (span). Spans carry an
//! OpenTracing-style identity (trace id, span id, parent span id) so the
//! tracing server can assemble events from different levels — and even
//! different processes — into a single end-to-end timeline.
//!
//! Levels follow the paper's `TraceLevel` enum (Listing 4):
//! `NONE < MODEL < FRAMEWORK < SYSTEM ≤ FULL`. A span is recorded only when
//! its level is enabled, so tracing can be switched off entirely on the hot
//! path (the ablation bench `ablation_tracing` measures exactly this).
//!
//! Timestamps are *logical nanoseconds* supplied by a [`Clock`]: wall-clock
//! by default, simulator-driven for the Table-1 system models (§4.4.4: "the
//! timestamps of trace events do not need to reflect the actual wall clock
//! time").

use crate::util::json::Json;
use crate::util::sync::lock_recover;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Trace verbosity — mirrors the paper's protobuf enum.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    None = 0,
    /// Steps in the evaluation pipeline (pre-process, predict, post-process).
    Model = 1,
    /// Layers within the framework.
    Framework = 2,
    /// System profilers: device kernels, memory copies, counters.
    System = 3,
    /// Everything.
    Full = 4,
}

impl TraceLevel {
    /// Parse a level name, case-insensitively. Returns `None` for unknown
    /// strings — callers decide whether that is a usage error (CLI / REST)
    /// or falls back to a default. (Unknown strings used to map silently to
    /// `Full`, which turned typos like `--trace-level ful` into the most
    /// expensive tracing mode.)
    pub fn parse(s: &str) -> Option<TraceLevel> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Some(TraceLevel::None),
            "model" => Some(TraceLevel::Model),
            "framework" => Some(TraceLevel::Framework),
            "system" => Some(TraceLevel::System),
            "full" => Some(TraceLevel::Full),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TraceLevel::None => "none",
            TraceLevel::Model => "model",
            TraceLevel::Framework => "framework",
            TraceLevel::System => "system",
            TraceLevel::Full => "full",
        }
    }
}

/// A completed trace event.
#[derive(Debug, Clone)]
pub struct Span {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent_id: Option<u64>,
    pub name: String,
    pub level: TraceLevel,
    /// Start timestamp, logical nanoseconds.
    pub start_ns: u64,
    /// End timestamp, logical nanoseconds.
    pub end_ns: u64,
    /// Free-form key/value metadata (layer shape, kernel name, bytes, ...).
    pub tags: Vec<(String, String)>,
}

impl Span {
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    pub fn duration_ms(&self) -> f64 {
        self.duration_ns() as f64 / 1e6
    }

    pub fn tag(&self, key: &str) -> Option<&str> {
        self.tags.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Serialize. Tags are an **array of `[key, value]` pairs**, not an
    /// object: tag order is meaningful (it mirrors emission order) and
    /// duplicate keys are legal — a JSON object (backed by a sorted map)
    /// silently reordered and deduplicated them, which is exactly the kind
    /// of drift the golden-trace tests pin against.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace_id", Json::num(self.trace_id as f64)),
            ("span_id", Json::num(self.span_id as f64)),
            (
                "parent_id",
                self.parent_id.map(|p| Json::num(p as f64)).unwrap_or(Json::Null),
            ),
            ("name", Json::str(&self.name)),
            ("level", Json::str(self.level.as_str())),
            ("start_ns", Json::num(self.start_ns as f64)),
            ("end_ns", Json::num(self.end_ns as f64)),
            (
                "tags",
                Json::arr(
                    self.tags
                        .iter()
                        .map(|(k, v)| Json::arr(vec![Json::str(k), Json::str(v)]))
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a span. Malformed identity is a rejection, not a silent
    /// default: a present-but-unparsable `parent_id` or an unknown `level`
    /// string returns `None` (a span reparented to the root or promoted to
    /// `Full` would corrupt attribution invisibly). An *absent* `level`
    /// still defaults to `Full` for spans stored before levels existed, and
    /// the legacy object form of `tags` is still accepted.
    pub fn from_json(j: &Json) -> Option<Span> {
        let parent_id = match j.get("parent_id") {
            None => None,
            Some(Json::Null) => None,
            Some(v) => Some(v.as_u64()?),
        };
        let level = match j.get("level") {
            None => TraceLevel::Full,
            Some(v) => TraceLevel::parse(v.as_str()?)?,
        };
        let tags = match j.get("tags") {
            None => Vec::new(),
            Some(Json::Arr(pairs)) => pairs
                .iter()
                .map(|p| {
                    let pair = p.as_arr()?;
                    Some((pair.first()?.as_str()?.to_string(), pair.get(1)?.as_str()?.to_string()))
                })
                .collect::<Option<Vec<_>>>()?,
            Some(Json::Obj(m)) => m
                .iter()
                .map(|(k, v)| Some((k.clone(), v.as_str()?.to_string())))
                .collect::<Option<Vec<_>>>()?,
            Some(_) => return None,
        };
        Some(Span {
            trace_id: j.get("trace_id")?.as_u64()?,
            span_id: j.get("span_id")?.as_u64()?,
            parent_id,
            name: j.get("name")?.as_str()?.to_string(),
            level,
            start_ns: j.get("start_ns")?.as_u64()?,
            end_ns: j.get("end_ns")?.as_u64()?,
            tags,
        })
    }
}

/// Time source. Wall-clock for real executions; simulators advance their own
/// logical clock and stamp spans with simulated time.
pub trait Clock: Send + Sync {
    fn now_ns(&self) -> u64;
}

/// Monotonic wall-clock.
pub struct WallClock {
    origin: std::time::Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { origin: std::time::Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// A manually-advanced clock for simulators and tests.
#[derive(Default)]
pub struct SimClock {
    ns: AtomicU64,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance_ns(&self, ns: u64) {
        self.ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn advance_secs(&self, s: f64) {
        self.advance_ns((s * 1e9) as u64);
    }

    pub fn set_ns(&self, ns: u64) {
        self.ns.store(ns, Ordering::Relaxed);
    }
}

impl Clock for SimClock {
    fn now_ns(&self) -> u64 {
        self.ns.load(Ordering::Relaxed)
    }
}

/// Destination for completed spans. The in-process collector and the remote
/// trace-server client both implement this.
pub trait SpanSink: Send + Sync {
    fn publish(&self, span: Span);

    /// Publish a batch of completed spans. The default forwards one at a
    /// time; collectors with internal locking override this to take their
    /// lock once per batch instead of once per span — the serving path
    /// republishes whole per-trace span sets through here.
    fn publish_all(&self, spans: Vec<Span>) {
        for s in spans {
            self.publish(s);
        }
    }
}

/// Number of independently-locked shards in a [`MemorySink`]. Small and
/// fixed: the goal is to stop N pipeline workers serializing on one mutex,
/// not to scale with core count.
const SINK_SHARDS: usize = 8;

/// The shard a publishing thread writes to: assigned round-robin on first
/// publish and cached in a thread-local, so the per-span cost is one TLS
/// read — no hashing, no contention on the assignment counter after the
/// first span.
fn publisher_shard(n: usize) -> usize {
    static NEXT_PUBLISHER: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SLOT: std::cell::Cell<usize> = std::cell::Cell::new(usize::MAX);
    }
    SLOT.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            v = NEXT_PUBLISHER.fetch_add(1, Ordering::Relaxed);
            s.set(v);
        }
        v % n
    })
}

/// Collects spans in memory — the default sink, also used by benches/tests.
///
/// Sharded: each publishing thread appends to its own mutex-guarded shard
/// (round-robin thread→shard assignment), so concurrent pipeline workers no
/// longer serialize every span behind a single `Mutex<Vec<Span>>`. Spans
/// are visible to [`MemorySink::drain`]/[`MemorySink::snapshot`] the moment
/// `publish` returns — there is no deferred thread-local buffer to flush.
/// Drain order is per-shard FIFO (intra-thread publication order is
/// preserved); consumers that need a global order sort by timestamp, as
/// [`crate::traceserver::Timeline`] already does. Locks are poison-tolerant:
/// a panicking instrumented thread loses at most its own in-flight span,
/// never the sink.
pub struct MemorySink {
    shards: Vec<Mutex<Vec<Span>>>,
}

impl Default for MemorySink {
    fn default() -> Self {
        MemorySink { shards: (0..SINK_SHARDS).map(|_| Mutex::new(Vec::new())).collect() }
    }
}

impl MemorySink {
    pub fn new() -> Arc<MemorySink> {
        Arc::new(MemorySink::default())
    }

    pub fn drain(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.append(&mut lock_recover(shard));
        }
        out
    }

    pub fn snapshot(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.extend_from_slice(&lock_recover(shard));
        }
        out
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_recover(s).len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl SpanSink for MemorySink {
    fn publish(&self, span: Span) {
        lock_recover(&self.shards[publisher_shard(self.shards.len())]).push(span);
    }

    fn publish_all(&self, mut spans: Vec<Span>) {
        if spans.is_empty() {
            return;
        }
        // One lock for the whole batch, on this thread's own shard.
        lock_recover(&self.shards[publisher_shard(self.shards.len())]).append(&mut spans);
    }
}

/// Sink that drops everything (trace level NONE fast path).
pub struct NullSink;

impl SpanSink for NullSink {
    fn publish(&self, _span: Span) {}
}

/// The tracer handed to agents/pipelines: filters by level, assigns ids,
/// stamps times, forwards to the sink.
pub struct Tracer {
    level: TraceLevel,
    clock: Arc<dyn Clock>,
    sink: Arc<dyn SpanSink>,
    next_id: AtomicU64,
}

impl Tracer {
    pub fn new(level: TraceLevel, clock: Arc<dyn Clock>, sink: Arc<dyn SpanSink>) -> Arc<Tracer> {
        // Ids draw from a process-global counter so spans from different
        // tracers (one per agent) can never collide when aggregated by a
        // shared trace server — the distributed-tracing requirement.
        static NEXT: AtomicU64 = AtomicU64::new(1);
        let base = NEXT.fetch_add(1 << 20, Ordering::Relaxed);
        Arc::new(Tracer { level, clock, sink, next_id: AtomicU64::new(base) })
    }

    /// Wall-clock tracer into a fresh memory sink (common setup).
    pub fn in_memory(level: TraceLevel) -> (Arc<Tracer>, Arc<MemorySink>) {
        let sink = MemorySink::new();
        let tracer = Tracer::new(level, Arc::new(WallClock::new()), sink.clone());
        (tracer, sink)
    }

    /// Disabled tracer — no allocation, no publication.
    pub fn disabled() -> Arc<Tracer> {
        Tracer::new(TraceLevel::None, Arc::new(WallClock::new()), Arc::new(NullSink))
    }

    pub fn level(&self) -> TraceLevel {
        self.level
    }

    pub fn enabled(&self, level: TraceLevel) -> bool {
        level != TraceLevel::None && self.level >= level
    }

    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// Allocate a fresh trace id for a new end-to-end evaluation.
    pub fn new_trace(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Start a span; returns a guard that publishes on [`ActiveSpan::finish`]
    /// (or drop). Returns `None` when the level is filtered out — callers
    /// pay only the enabled-check. `name` is taken by `Into<String>` so a
    /// caller that already owns its name moves it in instead of paying a
    /// fresh allocation per span (the filtered-out path allocates nothing
    /// either way — the conversion happens after the level check).
    pub fn start(
        self: &Arc<Self>,
        trace_id: u64,
        parent_id: Option<u64>,
        level: TraceLevel,
        name: impl Into<String>,
    ) -> Option<ActiveSpan> {
        if !self.enabled(level) {
            return None;
        }
        let span_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Some(ActiveSpan {
            tracer: self.clone(),
            span: Some(Span {
                trace_id,
                span_id,
                parent_id,
                name: name.into(),
                level,
                start_ns: self.clock.now_ns(),
                end_ns: 0,
                tags: Vec::new(),
            }),
        })
    }

    /// Publish a pre-built span (used by simulators that compute intervals
    /// analytically rather than measuring them).
    pub fn publish(&self, span: Span) {
        if self.enabled(span.level) {
            self.sink.publish(span);
        }
    }

    /// Publish a batch of pre-built spans in one sink call: level-filtered
    /// in place, then handed to [`SpanSink::publish_all`] so the collector
    /// takes its lock once per batch instead of once per span. The serving
    /// path republishes each trace's whole span set through here.
    pub fn publish_all(&self, mut spans: Vec<Span>) {
        spans.retain(|s| self.enabled(s.level));
        if !spans.is_empty() {
            self.sink.publish_all(spans);
        }
    }
}

/// Live span guard.
pub struct ActiveSpan {
    tracer: Arc<Tracer>,
    span: Option<Span>,
}

impl ActiveSpan {
    pub fn id(&self) -> u64 {
        self.span.as_ref().unwrap().span_id
    }

    pub fn tag(&mut self, key: &str, value: impl Into<String>) {
        if let Some(s) = self.span.as_mut() {
            s.tags.push((key.to_string(), value.into()));
        }
    }

    /// Close and publish the span now.
    pub fn finish(mut self) {
        self.finish_inner();
    }

    fn finish_inner(&mut self) {
        if let Some(mut s) = self.span.take() {
            s.end_ns = self.tracer.clock.now_ns();
            self.tracer.sink.publish(s);
        }
    }
}

impl Drop for ActiveSpan {
    fn drop(&mut self) {
        self.finish_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_listing4() {
        assert!(TraceLevel::None < TraceLevel::Model);
        assert!(TraceLevel::Model < TraceLevel::Framework);
        assert!(TraceLevel::Framework < TraceLevel::System);
        assert!(TraceLevel::System < TraceLevel::Full);
    }

    #[test]
    fn parse_accepts_known_levels_case_insensitively() {
        for (name, level) in [
            ("none", TraceLevel::None),
            ("model", TraceLevel::Model),
            ("framework", TraceLevel::Framework),
            ("system", TraceLevel::System),
            ("full", TraceLevel::Full),
        ] {
            assert_eq!(TraceLevel::parse(name), Some(level));
            assert_eq!(TraceLevel::parse(&name.to_ascii_uppercase()), Some(level));
            // Mixed case too: "Model", "Framework", ...
            let mut mixed = name.to_string();
            mixed[..1].make_ascii_uppercase();
            assert_eq!(TraceLevel::parse(&mixed), Some(level));
            // as_str round-trips.
            assert_eq!(TraceLevel::parse(level.as_str()), Some(level));
        }
    }

    #[test]
    fn parse_rejects_unknown_levels() {
        for bad in ["", "ful", "verbose", "FULL2", "model ", "all", "3"] {
            assert_eq!(TraceLevel::parse(bad), None, "{bad:?} must be rejected");
        }
    }

    #[test]
    fn level_filtering() {
        let (tracer, sink) = Tracer::in_memory(TraceLevel::Model);
        let t = tracer.new_trace();
        assert!(tracer.start(t, None, TraceLevel::Model, "predict").is_some());
        assert!(tracer.start(t, None, TraceLevel::Framework, "conv").is_none());
        assert!(tracer.start(t, None, TraceLevel::System, "kernel").is_none());
        drop(tracer);
        assert_eq!(sink.len(), 1);
    }

    #[test]
    fn full_level_records_everything() {
        let (tracer, sink) = Tracer::in_memory(TraceLevel::Full);
        let t = tracer.new_trace();
        for level in [TraceLevel::Model, TraceLevel::Framework, TraceLevel::System] {
            tracer.start(t, None, level, "x").unwrap().finish();
        }
        assert_eq!(sink.len(), 3);
    }

    #[test]
    fn parent_child_identity() {
        let (tracer, sink) = Tracer::in_memory(TraceLevel::Full);
        let t = tracer.new_trace();
        let parent = tracer.start(t, None, TraceLevel::Model, "predict").unwrap();
        let pid = parent.id();
        let child = tracer.start(t, Some(pid), TraceLevel::Framework, "conv2d/Conv2D").unwrap();
        child.finish();
        parent.finish();
        let spans = sink.drain();
        assert_eq!(spans.len(), 2);
        let conv = spans.iter().find(|s| s.name == "conv2d/Conv2D").unwrap();
        assert_eq!(conv.parent_id, Some(pid));
        assert_eq!(conv.trace_id, t);
    }

    #[test]
    fn sim_clock_stamps_logical_time() {
        let clock = Arc::new(SimClock::new());
        let sink = MemorySink::new();
        let tracer = Tracer::new(TraceLevel::Full, clock.clone(), sink.clone());
        let t = tracer.new_trace();
        let span = tracer.start(t, None, TraceLevel::System, "volta_cgemm").unwrap();
        clock.advance_secs(0.00603); // the paper's K1: 6.03 ms
        span.finish();
        let s = &sink.drain()[0];
        assert!((s.duration_ms() - 6.03).abs() < 1e-6);
    }

    #[test]
    fn tags_and_json_roundtrip() {
        let (tracer, sink) = Tracer::in_memory(TraceLevel::Full);
        let t = tracer.new_trace();
        let mut span = tracer.start(t, None, TraceLevel::Framework, "fc6").unwrap();
        span.tag("shape", "(64, 4096)");
        span.tag("kind", "Dense");
        span.finish();
        let s = &sink.drain()[0];
        assert_eq!(s.tag("shape"), Some("(64, 4096)"));
        let j = s.to_json();
        let back = Span::from_json(&j).unwrap();
        assert_eq!(back.name, "fc6");
        assert_eq!(back.tag("kind"), Some("Dense"));
        assert_eq!(back.span_id, s.span_id);
    }

    #[test]
    fn json_roundtrip_preserves_every_field() {
        // Duplicate tag keys and emission order must survive — the old
        // object serialization silently deduplicated and re-sorted them.
        let span = Span {
            trace_id: 77,
            span_id: (1u64 << 53) - 1, // largest id exact in a JSON double
            parent_id: Some(41),
            name: "fc6".into(),
            level: TraceLevel::Framework,
            start_ns: 123_456_789,
            end_ns: 987_654_321,
            tags: vec![
                ("zeta".into(), "first".into()),
                ("alpha".into(), "second".into()),
                ("zeta".into(), "third".into()),
            ],
        };
        let back = Span::from_json(&span.to_json()).unwrap();
        assert_eq!(back.trace_id, span.trace_id);
        assert_eq!(back.span_id, span.span_id);
        assert_eq!(back.parent_id, span.parent_id);
        assert_eq!(back.name, span.name);
        assert_eq!(back.level, span.level);
        assert_eq!(back.start_ns, span.start_ns);
        assert_eq!(back.end_ns, span.end_ns);
        assert_eq!(back.tags, span.tags, "tag order and duplicates preserved");
        // And through a full serialize→parse of the textual form.
        let text = span.to_json().to_string();
        let reparsed = Span::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(reparsed.tags, span.tags);
        assert_eq!(reparsed.parent_id, span.parent_id);
    }

    #[test]
    fn from_json_rejects_malformed_identity_instead_of_defaulting() {
        let (tracer, sink) = Tracer::in_memory(TraceLevel::Full);
        let t = tracer.new_trace();
        tracer.start(t, None, TraceLevel::Model, "x").unwrap().finish();
        let good = sink.drain()[0].to_json();
        // Unknown level string: used to coerce silently to Full.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            m.insert("level".into(), Json::str("ful"));
        }
        assert!(Span::from_json(&bad).is_none());
        // parent_id present but not a number: used to become None silently.
        let mut bad = good.clone();
        if let Json::Obj(m) = &mut bad {
            m.insert("parent_id".into(), Json::str("41"));
        }
        assert!(Span::from_json(&bad).is_none());
        // Absent level stays the legacy Full default; null parent is root.
        let mut legacy = good.clone();
        if let Json::Obj(m) = &mut legacy {
            m.remove("level");
            m.insert("parent_id".into(), Json::Null);
        }
        let back = Span::from_json(&legacy).unwrap();
        assert_eq!(back.level, TraceLevel::Full);
        assert_eq!(back.parent_id, None);
    }

    #[test]
    fn from_json_accepts_legacy_object_tags() {
        let legacy = Json::obj(vec![
            ("trace_id", Json::num(1.0)),
            ("span_id", Json::num(2.0)),
            ("parent_id", Json::Null),
            ("name", Json::str("conv1")),
            ("level", Json::str("framework")),
            ("start_ns", Json::num(0.0)),
            ("end_ns", Json::num(10.0)),
            (
                "tags",
                Json::obj(vec![("kind", Json::str("Conv2D")), ("shape", Json::str("(1, 3)"))]),
            ),
        ]);
        let span = Span::from_json(&legacy).unwrap();
        assert_eq!(span.tag("kind"), Some("Conv2D"));
        assert_eq!(span.tag("shape"), Some("(1, 3)"));
    }

    #[test]
    fn disabled_tracer_is_silent() {
        let tracer = Tracer::disabled();
        let t = tracer.new_trace();
        assert!(tracer.start(t, None, TraceLevel::Model, "x").is_none());
        assert!(!tracer.enabled(TraceLevel::Model));
    }

    #[test]
    fn drop_publishes_span() {
        let (tracer, sink) = Tracer::in_memory(TraceLevel::Full);
        let t = tracer.new_trace();
        {
            let _span = tracer.start(t, None, TraceLevel::Model, "scoped");
        }
        assert_eq!(sink.len(), 1);
    }
}
