//! Benchmarking scenarios + workload generation (§4.1.3, F7).
//!
//! Scenarios mimic real-world DL usage: *online* inference (single requests
//! arriving over time — latency matters), *batched* inference (offline
//! throughput), plus a *fixed-QPS* server scenario and a *burst* scenario
//! for interactive workloads. The server turns a scenario into a concrete
//! request schedule via [`Workload::generate`]; generators are pluggable —
//! implementing [`ArrivalProcess`] adds a custom scenario (the paper's
//! "flexible to support custom or emerging workloads").

use crate::util::json::Json;
use crate::util::rng::Xorshift;

/// A benchmarking scenario — part of the user input (§4.1).
#[derive(Debug, Clone, PartialEq)]
pub enum Scenario {
    /// Latency-oriented: requests of batch size 1, measured one at a time.
    /// `count` requests total.
    Online { count: usize },
    /// Poisson arrivals at `rate` req/s for `count` requests — the paper's
    /// "configurable distribution of time of request".
    Poisson { rate: f64, count: usize },
    /// Throughput-oriented: `batches` consecutive batches of `batch_size`.
    Batched { batch_size: usize, batches: usize },
    /// Closed-loop fixed QPS (uniform gaps).
    FixedQps { qps: f64, count: usize },
    /// Bursts of `burst_size` every `period_s` (interactive applications).
    Burst { burst_size: usize, period_s: f64, bursts: usize },
    /// Replay a recorded arrival log: one request per timestamp (seconds
    /// from workload start). Generation sanitizes the log — negatives clamp
    /// to zero and timestamps are sorted — so the non-decreasing-arrivals
    /// invariant holds for captured production traces too.
    TraceReplay { timestamps: Vec<f64> },
    /// Poisson arrivals whose rate swings sinusoidally between `trough_qps`
    /// and `peak_qps` over `period_s` — the daily traffic curve the
    /// cross-request batcher is designed for.
    Diurnal { peak_qps: f64, trough_qps: f64, period_s: f64, count: usize },
    /// MLPerf *SingleStream* mode (MLHarness, arXiv:2111.05231): a closed
    /// loop issuing one query at a time, the next only after the previous
    /// completes — the latency-bound edge scenario. Schedule-equivalent to
    /// [`Scenario::Online`] but kept as its own variant so MLPerf mode
    /// names survive into evaluation keys and reports.
    SingleStream { count: usize },
    /// MLPerf *MultiStream*: `streams` queries arrive together every
    /// `period_s` for `intervals` periods — the fixed-camera-count video
    /// analytics scenario. All `streams` queries of an interval share one
    /// arrival instant, so they are natural batch candidates.
    MultiStream { streams: usize, period_s: f64, intervals: usize },
    /// MLPerf *Server*: open-loop Poisson arrivals at `qps` — the
    /// interactive datacenter scenario the SLO machinery probes. Unlike
    /// [`Scenario::FixedQps`] (uniform gaps) the gaps are exponential, as
    /// the MLPerf load generator specifies.
    Server { qps: f64, count: usize },
    /// MLPerf *Offline*: the whole query set is available at `t = 0` and
    /// throughput is the only metric — the batch-processing scenario.
    Offline { count: usize },
    /// Multi-tenant composition: several tenants (name + leaf scenario)
    /// sharing one agent fleet. Generation merges the tenants' schedules by
    /// arrival time while tagging every request with its tenant index, so
    /// per-tenant identity survives through [`crate::pipeline::Envelope`]
    /// (the request id carried as `seq` maps back to a tenant via the
    /// workload) and per-tenant latency tails can be reported separately.
    /// Tenants should be single-item scenarios (batch size 1); nesting a
    /// `Mix` inside a `Mix` is not supported.
    Mix { tenants: Vec<(String, Scenario)> },
}

impl Scenario {
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Online { .. } => "online",
            Scenario::Poisson { .. } => "poisson",
            Scenario::Batched { .. } => "batched",
            Scenario::FixedQps { .. } => "fixed_qps",
            Scenario::Burst { .. } => "burst",
            Scenario::TraceReplay { .. } => "trace_replay",
            Scenario::Diurnal { .. } => "diurnal",
            Scenario::SingleStream { .. } => "single_stream",
            Scenario::MultiStream { .. } => "multi_stream",
            Scenario::Server { .. } => "server",
            Scenario::Offline { .. } => "offline",
            Scenario::Mix { .. } => "mix",
        }
    }

    /// Batch size each request carries. For a `Mix` this is the largest
    /// tenant batch size, so composing pre-batched scenarios is visible to
    /// callers that require single-item request streams.
    pub fn batch_size(&self) -> usize {
        match self {
            Scenario::Batched { batch_size, .. } => *batch_size,
            Scenario::Mix { tenants } => {
                tenants.iter().map(|(_, s)| s.batch_size()).max().unwrap_or(1)
            }
            _ => 1,
        }
    }

    /// Total number of *inputs* (items) the scenario evaluates.
    pub fn total_items(&self) -> usize {
        match self {
            Scenario::Online { count } => *count,
            Scenario::Poisson { count, .. } => *count,
            Scenario::Batched { batch_size, batches } => batch_size * batches,
            Scenario::FixedQps { count, .. } => *count,
            Scenario::Burst { burst_size, bursts, .. } => burst_size * bursts,
            Scenario::TraceReplay { timestamps } => timestamps.len(),
            Scenario::Diurnal { count, .. } => *count,
            Scenario::SingleStream { count } => *count,
            Scenario::MultiStream { streams, intervals, .. } => streams * intervals,
            Scenario::Server { count, .. } => *count,
            Scenario::Offline { count } => *count,
            Scenario::Mix { tenants } => tenants.iter().map(|(_, s)| s.total_items()).sum(),
        }
    }

    /// Tenant names, in tenant-index order (single implicit tenant for
    /// non-`Mix` scenarios).
    pub fn tenant_names(&self) -> Vec<String> {
        match self {
            Scenario::Mix { tenants } => tenants.iter().map(|(n, _)| n.clone()).collect(),
            _ => vec!["all".to_string()],
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Scenario::Online { count } => Json::obj(vec![
                ("kind", Json::str("online")),
                ("count", Json::num(*count as f64)),
            ]),
            Scenario::Poisson { rate, count } => Json::obj(vec![
                ("kind", Json::str("poisson")),
                ("rate", Json::num(*rate)),
                ("count", Json::num(*count as f64)),
            ]),
            Scenario::Batched { batch_size, batches } => Json::obj(vec![
                ("kind", Json::str("batched")),
                ("batch_size", Json::num(*batch_size as f64)),
                ("batches", Json::num(*batches as f64)),
            ]),
            Scenario::FixedQps { qps, count } => Json::obj(vec![
                ("kind", Json::str("fixed_qps")),
                ("qps", Json::num(*qps)),
                ("count", Json::num(*count as f64)),
            ]),
            Scenario::Burst { burst_size, period_s, bursts } => Json::obj(vec![
                ("kind", Json::str("burst")),
                ("burst_size", Json::num(*burst_size as f64)),
                ("period_s", Json::num(*period_s)),
                ("bursts", Json::num(*bursts as f64)),
            ]),
            Scenario::TraceReplay { timestamps } => Json::obj(vec![
                ("kind", Json::str("trace_replay")),
                (
                    "timestamps",
                    Json::arr(timestamps.iter().map(|t| Json::num(*t)).collect()),
                ),
            ]),
            Scenario::Diurnal { peak_qps, trough_qps, period_s, count } => Json::obj(vec![
                ("kind", Json::str("diurnal")),
                ("peak_qps", Json::num(*peak_qps)),
                ("trough_qps", Json::num(*trough_qps)),
                ("period_s", Json::num(*period_s)),
                ("count", Json::num(*count as f64)),
            ]),
            Scenario::SingleStream { count } => Json::obj(vec![
                ("kind", Json::str("single_stream")),
                ("count", Json::num(*count as f64)),
            ]),
            Scenario::MultiStream { streams, period_s, intervals } => Json::obj(vec![
                ("kind", Json::str("multi_stream")),
                ("streams", Json::num(*streams as f64)),
                ("period_s", Json::num(*period_s)),
                ("intervals", Json::num(*intervals as f64)),
            ]),
            Scenario::Server { qps, count } => Json::obj(vec![
                ("kind", Json::str("server")),
                ("qps", Json::num(*qps)),
                ("count", Json::num(*count as f64)),
            ]),
            Scenario::Offline { count } => Json::obj(vec![
                ("kind", Json::str("offline")),
                ("count", Json::num(*count as f64)),
            ]),
            Scenario::Mix { tenants } => Json::obj(vec![
                ("kind", Json::str("mix")),
                (
                    "tenants",
                    Json::arr(
                        tenants
                            .iter()
                            .map(|(name, s)| {
                                Json::obj(vec![
                                    ("name", Json::str(name)),
                                    ("scenario", s.to_json()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        }
    }

    /// Strict grammar: every variant requires its `kind` and every field to
    /// be present, well-typed, finite, and in range. A malformed shape
    /// returns `None` — it never silently defaults into a different
    /// experiment than the one the spec digest claims. `to_json` emits
    /// every field, so round-trips are unaffected.
    pub fn from_json(j: &Json) -> Option<Scenario> {
        match j.get("kind")?.as_str()? {
            "online" => Some(Scenario::Online { count: strict_count(j, "count")? }),
            "poisson" => Some(Scenario::Poisson {
                rate: strict_positive(j, "rate")?,
                count: strict_count(j, "count")?,
            }),
            "batched" => Some(Scenario::Batched {
                batch_size: strict_count(j, "batch_size")?,
                batches: strict_count(j, "batches")?,
            }),
            "fixed_qps" => Some(Scenario::FixedQps {
                qps: strict_positive(j, "qps")?,
                count: strict_count(j, "count")?,
            }),
            "burst" => Some(Scenario::Burst {
                burst_size: strict_count(j, "burst_size")?,
                period_s: strict_positive(j, "period_s")?,
                bursts: strict_count(j, "bursts")?,
            }),
            "trace_replay" => Some(Scenario::TraceReplay {
                // Every entry must be a finite number — a mistyped or
                // non-finite timestamp rejects the whole log rather than
                // silently shrinking it.
                timestamps: j
                    .get("timestamps")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_f64().filter(|t| t.is_finite()))
                    .collect::<Option<Vec<_>>>()?,
            }),
            "diurnal" => Some(Scenario::Diurnal {
                peak_qps: strict_positive(j, "peak_qps")?,
                trough_qps: strict_nonneg(j, "trough_qps")?,
                period_s: strict_positive(j, "period_s")?,
                count: strict_count(j, "count")?,
            }),
            "single_stream" => Some(Scenario::SingleStream { count: strict_count(j, "count")? }),
            "multi_stream" => Some(Scenario::MultiStream {
                streams: strict_count(j, "streams")?,
                period_s: strict_positive(j, "period_s")?,
                intervals: strict_count(j, "intervals")?,
            }),
            "server" => Some(Scenario::Server {
                qps: strict_positive(j, "qps")?,
                count: strict_count(j, "count")?,
            }),
            "offline" => Some(Scenario::Offline { count: strict_count(j, "count")? }),
            "mix" => Some(Scenario::Mix {
                tenants: j
                    .get("tenants")?
                    .as_arr()?
                    .iter()
                    .map(|t| {
                        Some((
                            t.get("name")?.as_str()?.to_string(),
                            Scenario::from_json(t.get("scenario")?)?,
                        ))
                    })
                    .collect::<Option<Vec<_>>>()?,
            }),
            _ => None,
        }
    }
}

/// Largest count accepted from the wire: 2^53, the last integer `f64`
/// represents exactly. Anything above has already lost precision in JSON,
/// so the cast to `usize` could not be faithful.
const MAX_EXACT_COUNT: f64 = 9_007_199_254_740_992.0;

/// Strict count parse: present, finite, integral, in `1..=2^53`. Guarding
/// integrality and range *before* the cast means `v as usize` can never
/// truncate, saturate, or smuggle a NaN/negative through as 0.
fn strict_count(j: &Json, key: &str) -> Option<usize> {
    let v = j.get(key)?.as_f64()?;
    if v.is_finite() && v >= 1.0 && v <= MAX_EXACT_COUNT && v.fract() == 0.0 {
        Some(v as usize)
    } else {
        None
    }
}

/// Strict rate/period parse: present, finite, > 0.
fn strict_positive(j: &Json, key: &str) -> Option<f64> {
    let v = j.get(key)?.as_f64()?;
    if v.is_finite() && v > 0.0 {
        Some(v)
    } else {
        None
    }
}

/// Strict non-negative parse (diurnal troughs may rest at zero QPS).
fn strict_nonneg(j: &Json, key: &str) -> Option<f64> {
    let v = j.get(key)?.as_f64()?;
    if v.is_finite() && v >= 0.0 {
        Some(v)
    } else {
        None
    }
}

/// One scheduled request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival offset from workload start, seconds.
    pub at_secs: f64,
    pub batch_size: usize,
    /// Tenant index within a [`Scenario::Mix`] (0 for single-tenant
    /// scenarios). Carried so batching/dispatch can keep tenants separate
    /// and metrics can report per-tenant latency tails.
    pub tenant: u32,
}

/// An arrival process produces request offsets — implement to plug in a
/// custom scenario (the paper's "flexible to support custom or emerging
/// workloads"). [`Workload::from_process`] turns any implementation into a
/// schedulable workload.
pub trait ArrivalProcess {
    fn arrivals(&self, rng: &mut Xorshift) -> Vec<Request>;

    /// Name recorded in the evaluation key.
    fn name(&self) -> &str {
        "custom"
    }
}

/// A diurnal sinusoidal-rate process — an "emerging workload" example:
/// Poisson arrivals whose rate swings between `base_rate·(1±amplitude)`
/// over `period_s`, as in daily traffic curves.
pub struct DiurnalProcess {
    pub base_rate: f64,
    pub amplitude: f64,
    pub period_s: f64,
    pub count: usize,
}

impl ArrivalProcess for DiurnalProcess {
    fn arrivals(&self, rng: &mut Xorshift) -> Vec<Request> {
        let mut t = 0.0;
        (0..self.count)
            .map(|id| {
                let phase = (2.0 * std::f64::consts::PI * t / self.period_s).sin();
                let rate = (self.base_rate * (1.0 + self.amplitude * phase)).max(1e-6);
                t += rng.exponential(rate);
                Request { id: id as u64, at_secs: t, batch_size: 1, tenant: 0 }
            })
            .collect()
    }

    fn name(&self) -> &str {
        "diurnal"
    }
}

/// A concrete workload: the scenario's request schedule.
#[derive(Debug, Clone)]
pub struct Workload {
    pub scenario: Scenario,
    pub requests: Vec<Request>,
}

impl Workload {
    /// Generate the request schedule for a scenario, deterministically from
    /// `seed` (reproducible evaluation, F1: the same seed yields the same
    /// workload everywhere).
    pub fn generate(scenario: &Scenario, seed: u64) -> Workload {
        let mut rng = Xorshift::new(seed);
        let mut requests = Vec::new();
        match scenario {
            Scenario::Online { count } => {
                // Closed loop: next request issues when the previous answer
                // returns, so arrival offsets are all zero.
                for id in 0..*count {
                    requests.push(Request { id: id as u64, at_secs: 0.0, batch_size: 1, tenant: 0 });
                }
            }
            Scenario::Poisson { rate, count } => {
                let mut t = 0.0;
                for id in 0..*count {
                    t += rng.exponential(*rate);
                    requests.push(Request { id: id as u64, at_secs: t, batch_size: 1, tenant: 0 });
                }
            }
            Scenario::Batched { batch_size, batches } => {
                for id in 0..*batches {
                    requests.push(Request { id: id as u64, at_secs: 0.0, batch_size: *batch_size, tenant: 0 });
                }
            }
            Scenario::FixedQps { qps, count } => {
                let gap = 1.0 / qps.max(1e-9);
                for id in 0..*count {
                    requests.push(Request { id: id as u64, at_secs: id as f64 * gap, batch_size: 1, tenant: 0 });
                }
            }
            Scenario::Burst { burst_size, period_s, bursts } => {
                let mut id = 0u64;
                for b in 0..*bursts {
                    for _ in 0..*burst_size {
                        requests.push(Request { id, at_secs: b as f64 * period_s, batch_size: 1, tenant: 0 });
                        id += 1;
                    }
                }
            }
            Scenario::TraceReplay { timestamps } => {
                // Sanitize the recorded log: clamp negatives, sort, so the
                // non-decreasing invariant holds regardless of capture noise.
                let mut ts: Vec<f64> = timestamps
                    .iter()
                    .map(|t| if t.is_finite() && *t > 0.0 { *t } else { 0.0 })
                    .collect();
                ts.sort_by(f64::total_cmp);
                for (id, t) in ts.into_iter().enumerate() {
                    requests.push(Request { id: id as u64, at_secs: t, batch_size: 1, tenant: 0 });
                }
            }
            Scenario::Diurnal { peak_qps, trough_qps, period_s, count } => {
                let (hi, lo) = (peak_qps.max(*trough_qps), peak_qps.min(*trough_qps));
                let period = period_s.max(1e-9);
                let mut t = 0.0;
                for id in 0..*count {
                    let phase = (2.0 * std::f64::consts::PI * t / period).sin();
                    // phase = +1 → peak, -1 → trough.
                    let rate = (lo + (hi - lo) * (1.0 + phase) / 2.0).max(1e-6);
                    t += rng.exponential(rate);
                    requests.push(Request { id: id as u64, at_secs: t, batch_size: 1, tenant: 0 });
                }
            }
            Scenario::SingleStream { count } => {
                // Closed loop, exactly like Online: the next query issues
                // only when the previous one completes.
                for id in 0..*count {
                    requests.push(Request { id: id as u64, at_secs: 0.0, batch_size: 1, tenant: 0 });
                }
            }
            Scenario::MultiStream { streams, period_s, intervals } => {
                let period = period_s.max(0.0);
                let mut id = 0u64;
                for k in 0..*intervals {
                    for _ in 0..*streams {
                        requests.push(Request {
                            id,
                            at_secs: k as f64 * period,
                            batch_size: 1,
                            tenant: 0,
                        });
                        id += 1;
                    }
                }
            }
            Scenario::Server { qps, count } => {
                // Open-loop Poisson at the target QPS, per the MLPerf load
                // generator's server mode.
                let mut t = 0.0;
                for id in 0..*count {
                    t += rng.exponential(qps.max(1e-9));
                    requests.push(Request { id: id as u64, at_secs: t, batch_size: 1, tenant: 0 });
                }
            }
            Scenario::Offline { count } => {
                // The entire query set is available at t = 0 (open loop).
                for id in 0..*count {
                    requests.push(Request { id: id as u64, at_secs: 0.0, batch_size: 1, tenant: 0 });
                }
            }
            Scenario::Mix { tenants } => {
                // Each tenant generates from its own derived seed, then the
                // schedules merge by arrival time. Ids are reassigned to be
                // globally unique; the tenant index preserves identity.
                for (ti, (_, sub)) in tenants.iter().enumerate() {
                    let sub_seed =
                        seed ^ (ti as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
                    for r in Workload::generate(sub, sub_seed).requests {
                        requests.push(Request { tenant: ti as u32, ..r });
                    }
                }
                // Stable sort: ties keep tenant-major generation order, so
                // the merge is deterministic (F1). `total_cmp` so a NaN
                // arrival (corrupt trace tenant) sorts last, never panics.
                requests.sort_by(|a, b| a.at_secs.total_cmp(&b.at_secs));
                for (i, r) in requests.iter_mut().enumerate() {
                    r.id = i as u64;
                }
            }
        }
        Workload { scenario: scenario.clone(), requests }
    }

    /// Build a workload from any custom [`ArrivalProcess`].
    pub fn from_process(process: &dyn ArrivalProcess, seed: u64) -> Workload {
        let mut rng = Xorshift::new(seed);
        let requests = process.arrivals(&mut rng);
        // Custom workloads are carried as online-shaped scenarios with the
        // generated request count (batch size per request stays explicit).
        Workload {
            scenario: Scenario::Online { count: requests.len() },
            requests,
        }
    }

    /// Mean arrival rate over the schedule (req/s); infinite for batch-at-0.
    pub fn offered_rate(&self) -> f64 {
        let span = self.requests.last().map(|r| r.at_secs).unwrap_or(0.0);
        if span <= 0.0 {
            f64::INFINITY
        } else {
            self.requests.len() as f64 / span
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_is_closed_loop() {
        let w = Workload::generate(&Scenario::Online { count: 10 }, 1);
        assert_eq!(w.requests.len(), 10);
        assert!(w.requests.iter().all(|r| r.at_secs == 0.0 && r.batch_size == 1));
    }

    #[test]
    fn poisson_mean_rate_close() {
        let rate = 100.0;
        let w = Workload::generate(&Scenario::Poisson { rate, count: 20_000 }, 2);
        let measured = w.offered_rate();
        assert!((measured - rate).abs() / rate < 0.05, "rate {measured}");
        // Arrival times strictly increasing.
        for pair in w.requests.windows(2) {
            assert!(pair[1].at_secs > pair[0].at_secs);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let s = Scenario::Poisson { rate: 50.0, count: 100 };
        let a = Workload::generate(&s, 42);
        let b = Workload::generate(&s, 42);
        assert_eq!(a.requests, b.requests);
        let c = Workload::generate(&s, 43);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn batched_counts() {
        let s = Scenario::Batched { batch_size: 64, batches: 5 };
        let w = Workload::generate(&s, 3);
        assert_eq!(w.requests.len(), 5);
        assert_eq!(s.total_items(), 320);
        assert!(w.requests.iter().all(|r| r.batch_size == 64));
    }

    #[test]
    fn fixed_qps_uniform_gaps() {
        let w = Workload::generate(&Scenario::FixedQps { qps: 20.0, count: 5 }, 4);
        for (i, r) in w.requests.iter().enumerate() {
            assert!((r.at_secs - i as f64 * 0.05).abs() < 1e-12);
        }
    }

    #[test]
    fn burst_schedule() {
        let s = Scenario::Burst { burst_size: 3, period_s: 2.0, bursts: 2 };
        let w = Workload::generate(&s, 5);
        assert_eq!(w.requests.len(), 6);
        assert_eq!(w.requests[0].at_secs, 0.0);
        assert_eq!(w.requests[3].at_secs, 2.0);
    }

    #[test]
    fn custom_arrival_process_plugs_in() {
        // The F7 extension point: a user-defined diurnal workload.
        let proc_ = DiurnalProcess { base_rate: 100.0, amplitude: 0.8, period_s: 2.0, count: 4000 };
        let w = Workload::from_process(&proc_, 7);
        assert_eq!(w.requests.len(), 4000);
        assert_eq!(proc_.name(), "diurnal");
        // Monotone arrivals, unique ids.
        for pair in w.requests.windows(2) {
            assert!(pair[1].at_secs >= pair[0].at_secs);
        }
        // Rate actually swings: compare request density in the first vs
        // second quarter-period (peak vs trough of the sine).
        let count_in = |lo: f64, hi: f64| {
            w.requests.iter().filter(|r| r.at_secs >= lo && r.at_secs < hi).count()
        };
        let peak = count_in(0.0, 0.5);
        let trough = count_in(1.0, 1.5);
        assert!(peak as f64 > trough as f64 * 1.5, "peak {peak} vs trough {trough}");
        // Deterministic per seed.
        let w2 = Workload::from_process(&proc_, 7);
        assert_eq!(w.requests, w2.requests);
    }

    #[test]
    fn json_roundtrip_all_variants() {
        let scenarios = [
            Scenario::Online { count: 7 },
            Scenario::Poisson { rate: 5.0, count: 9 },
            Scenario::Batched { batch_size: 8, batches: 2 },
            Scenario::FixedQps { qps: 3.0, count: 4 },
            Scenario::Burst { burst_size: 2, period_s: 0.5, bursts: 3 },
            Scenario::TraceReplay { timestamps: vec![0.0, 0.125, 0.5, 2.0] },
            Scenario::Diurnal { peak_qps: 200.0, trough_qps: 25.0, period_s: 10.0, count: 6 },
            Scenario::Mix {
                tenants: vec![
                    ("steady".into(), Scenario::FixedQps { qps: 40.0, count: 12 }),
                    ("bursty".into(), Scenario::Burst { burst_size: 4, period_s: 0.5, bursts: 2 }),
                ],
            },
        ];
        for s in scenarios {
            let j = s.to_json();
            let back = Scenario::from_json(&j).unwrap();
            assert_eq!(back, s);
        }
    }

    #[test]
    fn legacy_variants_parse_strictly_field_by_field() {
        // The legacy grammar follows the same no-default contract as the
        // MLPerf modes: a missing, mistyped, non-finite, negative, or
        // fractional field rejects the spec — it never silently becomes a
        // different experiment. One malformed case per field.
        let cases = [
            // kind itself must be present and a string.
            r#"{"count":8}"#,
            r#"{"kind":7,"count":8}"#,
            // online.count: missing, wrong type, zero, negative, fractional.
            r#"{"kind":"online"}"#,
            r#"{"kind":"online","count":"many"}"#,
            r#"{"kind":"online","count":0}"#,
            r#"{"kind":"online","count":-3}"#,
            r#"{"kind":"online","count":2.5}"#,
            // poisson.rate / poisson.count.
            r#"{"kind":"poisson","count":8}"#,
            r#"{"kind":"poisson","rate":0,"count":8}"#,
            r#"{"kind":"poisson","rate":-1.5,"count":8}"#,
            r#"{"kind":"poisson","rate":10}"#,
            // batched.batch_size / batched.batches.
            r#"{"kind":"batched","batches":4}"#,
            r#"{"kind":"batched","batch_size":0,"batches":4}"#,
            r#"{"kind":"batched","batch_size":8}"#,
            r#"{"kind":"batched","batch_size":8,"batches":1.5}"#,
            // fixed_qps.qps / fixed_qps.count.
            r#"{"kind":"fixed_qps","count":8}"#,
            r#"{"kind":"fixed_qps","qps":0,"count":8}"#,
            r#"{"kind":"fixed_qps","qps":5}"#,
            // burst: all three fields required and in range.
            r#"{"kind":"burst","period_s":1,"bursts":2}"#,
            r#"{"kind":"burst","burst_size":4,"bursts":2}"#,
            r#"{"kind":"burst","burst_size":4,"period_s":0,"bursts":2}"#,
            r#"{"kind":"burst","burst_size":4,"period_s":1}"#,
            // trace_replay: list required, every entry a number.
            r#"{"kind":"trace_replay"}"#,
            r#"{"kind":"trace_replay","timestamps":0.5}"#,
            r#"{"kind":"trace_replay","timestamps":[0.1,"oops",0.3]}"#,
            // diurnal: every field required; peak/period positive; trough ≥ 0.
            r#"{"kind":"diurnal","trough_qps":1,"period_s":60,"count":8}"#,
            r#"{"kind":"diurnal","peak_qps":0,"trough_qps":1,"period_s":60,"count":8}"#,
            r#"{"kind":"diurnal","peak_qps":100,"trough_qps":-1,"period_s":60,"count":8}"#,
            r#"{"kind":"diurnal","peak_qps":100,"trough_qps":1,"count":8}"#,
            r#"{"kind":"diurnal","peak_qps":100,"trough_qps":1,"period_s":60}"#,
            // mix: tenant name must be present and a string.
            r#"{"kind":"mix","tenants":[{"scenario":{"kind":"online","count":4}}]}"#,
        ];
        for (i, text) in cases.iter().enumerate() {
            let j = Json::parse(text).unwrap();
            assert_eq!(Scenario::from_json(&j), None, "case {i} must be rejected: {text}");
        }
        // Non-finite numbers cannot be written in JSON text; build in-memory.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let j = Json::obj(vec![("kind", Json::str("online")), ("count", Json::num(bad))]);
            assert_eq!(Scenario::from_json(&j), None, "non-finite count {bad} must be rejected");
            let j = Json::obj(vec![
                ("kind", Json::str("trace_replay")),
                ("timestamps", Json::arr(vec![Json::num(0.1), Json::num(bad)])),
            ]);
            assert_eq!(Scenario::from_json(&j), None, "non-finite timestamp must be rejected");
        }
        // Counts above 2^53 lost integer precision in transit — rejected.
        let j = Json::obj(vec![("kind", Json::str("online")), ("count", Json::num(1e16))]);
        assert_eq!(Scenario::from_json(&j), None, "count beyond exact-f64 range must be rejected");
    }

    #[test]
    fn mix_merges_tenants_preserving_identity() {
        let s = Scenario::Mix {
            tenants: vec![
                ("a".into(), Scenario::FixedQps { qps: 100.0, count: 20 }),
                ("b".into(), Scenario::Poisson { rate: 200.0, count: 30 }),
            ],
        };
        assert_eq!(s.name(), "mix");
        assert_eq!(s.batch_size(), 1);
        assert_eq!(s.total_items(), 50);
        assert_eq!(s.tenant_names(), vec!["a".to_string(), "b".to_string()]);
        let w = Workload::generate(&s, 9);
        assert_eq!(w.requests.len(), 50);
        // Globally unique sequential ids, non-decreasing arrivals.
        for (i, pair) in w.requests.windows(2).enumerate() {
            assert_eq!(pair[0].id, i as u64);
            assert!(pair[1].at_secs >= pair[0].at_secs);
        }
        // Per-tenant counts survive the merge.
        let count_of = |t: u32| w.requests.iter().filter(|r| r.tenant == t).count();
        assert_eq!(count_of(0), 20);
        assert_eq!(count_of(1), 30);
        // Deterministic per seed (F1); different seeds move the Poisson
        // tenant.
        assert_eq!(w.requests, Workload::generate(&s, 9).requests);
        assert_ne!(w.requests, Workload::generate(&s, 10).requests);
        // Non-mix scenarios are single-tenant.
        let online = Workload::generate(&Scenario::Online { count: 4 }, 1);
        assert!(online.requests.iter().all(|r| r.tenant == 0));
        assert_eq!(Scenario::Online { count: 4 }.tenant_names(), vec!["all".to_string()]);
    }

    #[test]
    fn trace_replay_sanitizes_recorded_log() {
        // Out-of-order + negative timestamps from a noisy capture.
        let s = Scenario::TraceReplay { timestamps: vec![0.5, -0.1, 0.2, 0.2, 1.5] };
        let w = Workload::generate(&s, 1);
        assert_eq!(w.requests.len(), 5);
        assert_eq!(s.total_items(), 5);
        let times: Vec<f64> = w.requests.iter().map(|r| r.at_secs).collect();
        assert_eq!(times, vec![0.0, 0.2, 0.2, 0.5, 1.5]);
        // Replay ignores the seed: the log IS the schedule.
        assert_eq!(w.requests, Workload::generate(&s, 2).requests);
    }

    #[test]
    fn diurnal_rate_swings_between_peak_and_trough() {
        let s = Scenario::Diurnal {
            peak_qps: 400.0,
            trough_qps: 40.0,
            period_s: 4.0,
            count: 2000,
        };
        let w = Workload::generate(&s, 11);
        assert_eq!(w.requests.len(), 2000);
        for pair in w.requests.windows(2) {
            assert!(pair[1].at_secs >= pair[0].at_secs);
        }
        // First quarter-period sits at the sine peak, the third at the
        // trough: request density must differ markedly.
        let count_in = |lo: f64, hi: f64| {
            w.requests.iter().filter(|r| r.at_secs >= lo && r.at_secs < hi).count()
        };
        let peak = count_in(0.0, 1.0);
        let trough = count_in(2.0, 3.0);
        assert!(peak as f64 > trough as f64 * 2.0, "peak {peak} vs trough {trough}");
        // Deterministic per seed (F1).
        assert_eq!(w.requests, Workload::generate(&s, 11).requests);
        assert_ne!(w.requests, Workload::generate(&s, 12).requests);
    }
}
