//! The evaluation database (§4.5.2).
//!
//! After an evaluation, the agent stores the benchmarking result (and a
//! pointer to its profiling trace) keyed by the full user input — model,
//! framework, system, scenario — so the analysis workflow can query across
//! historical runs ("MLModelScope allows one to track which model version
//! produced the best result"). The store is an embedded append-only JSONL
//! segment log with in-memory secondary indexes — the offline substitute
//! for the paper's hosted document database.

use crate::metrics::LatencySamples;

use crate::util::json::Json;
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::Mutex;

/// The key identifying one evaluation configuration (the "user input").
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct EvalKey {
    pub model: String,
    pub model_version: String,
    pub framework: String,
    pub framework_version: String,
    pub system: String,
    pub device: String,
    pub scenario: String,
    pub batch_size: usize,
}

impl EvalKey {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("model_version", Json::str(&self.model_version)),
            ("framework", Json::str(&self.framework)),
            ("framework_version", Json::str(&self.framework_version)),
            ("system", Json::str(&self.system)),
            ("device", Json::str(&self.device)),
            ("scenario", Json::str(&self.scenario)),
            ("batch_size", Json::num(self.batch_size as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> EvalKey {
        EvalKey {
            model: j.str_or("model", "").into(),
            model_version: j.str_or("model_version", "1.0.0").into(),
            framework: j.str_or("framework", "").into(),
            framework_version: j.str_or("framework_version", "0.0.0").into(),
            system: j.str_or("system", "local").into(),
            device: j.str_or("device", "cpu").into(),
            scenario: j.str_or("scenario", "online").into(),
            batch_size: j.f64_or("batch_size", 1.0) as usize,
        }
    }
}

/// One stored evaluation record.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub key: EvalKey,
    /// Monotonic sequence number assigned by the database.
    pub seq: u64,
    /// Latency samples (seconds per request).
    pub latencies: Vec<f64>,
    /// Achieved throughput, items/sec.
    pub throughput: f64,
    /// Trace id in the tracing server, if profiling was enabled.
    pub trace_id: Option<u64>,
    /// Free-form metadata (accuracy, graph size, agent id, ...).
    pub meta: Json,
}

impl EvalRecord {
    pub fn new(key: EvalKey, latencies: Vec<f64>, throughput: f64) -> EvalRecord {
        EvalRecord { key, seq: 0, latencies, throughput, trace_id: None, meta: Json::Null }
    }

    pub fn samples(&self) -> LatencySamples {
        LatencySamples::from_secs(self.latencies.clone())
    }

    pub fn trimmed_mean_ms(&self) -> f64 {
        self.samples().trimmed_mean() * 1e3
    }

    pub fn p90_ms(&self) -> f64 {
        self.samples().p90() * 1e3
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("key", self.key.to_json()),
            ("seq", Json::num(self.seq as f64)),
            (
                "latencies",
                Json::arr(self.latencies.iter().map(|l| Json::num(*l)).collect()),
            ),
            ("throughput", Json::num(self.throughput)),
            (
                "trace_id",
                self.trace_id.map(|t| Json::num(t as f64)).unwrap_or(Json::Null),
            ),
            ("meta", self.meta.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Option<EvalRecord> {
        Some(EvalRecord {
            key: EvalKey::from_json(j.get("key")?),
            seq: j.f64_or("seq", 0.0) as u64,
            latencies: j
                .get("latencies")?
                .as_arr()?
                .iter()
                .filter_map(|v| v.as_f64())
                .collect(),
            throughput: j.f64_or("throughput", f64::NAN),
            trace_id: j.get("trace_id").and_then(|v| v.as_u64()),
            meta: j.get("meta").cloned().unwrap_or(Json::Null),
        })
    }
}

/// Query filter: all `Some` fields must match.
#[derive(Debug, Clone, Default)]
pub struct EvalQuery {
    pub model: Option<String>,
    pub framework: Option<String>,
    pub system: Option<String>,
    pub device: Option<String>,
    pub scenario: Option<String>,
    pub batch_size: Option<usize>,
}

impl EvalQuery {
    pub fn model(name: &str) -> EvalQuery {
        EvalQuery { model: Some(name.to_string()), ..Default::default() }
    }

    fn matches(&self, k: &EvalKey) -> bool {
        self.model.as_deref().map_or(true, |m| m == k.model)
            && self.framework.as_deref().map_or(true, |f| f == k.framework)
            && self.system.as_deref().map_or(true, |s| s == k.system)
            && self.device.as_deref().map_or(true, |d| d == k.device)
            && self.scenario.as_deref().map_or(true, |s| s == k.scenario)
            && self.batch_size.map_or(true, |b| b == k.batch_size)
    }
}

/// The embedded evaluation database.
pub struct EvalDb {
    inner: Mutex<Inner>,
}

struct Inner {
    records: Vec<EvalRecord>,
    next_seq: u64,
    /// Append log path; `None` → memory-only (tests, benches).
    log_path: Option<PathBuf>,
}

impl EvalDb {
    /// Memory-only database.
    pub fn in_memory() -> EvalDb {
        EvalDb { inner: Mutex::new(Inner { records: Vec::new(), next_seq: 1, log_path: None }) }
    }

    /// Open (or create) a file-backed database, replaying the existing log.
    pub fn open(path: impl Into<PathBuf>) -> std::io::Result<EvalDb> {
        let path = path.into();
        let mut records = Vec::new();
        let mut next_seq = 1;
        if path.exists() {
            let file = std::fs::File::open(&path)?;
            for line in std::io::BufReader::new(file).lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                if let Ok(j) = Json::parse(&line) {
                    if let Some(r) = EvalRecord::from_json(&j) {
                        next_seq = next_seq.max(r.seq + 1);
                        records.push(r);
                    }
                }
            }
        } else if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(EvalDb { inner: Mutex::new(Inner { records, next_seq, log_path: Some(path) }) })
    }

    /// Store a record; assigns and returns its sequence number.
    pub fn put(&self, mut record: EvalRecord) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        record.seq = inner.next_seq;
        inner.next_seq += 1;
        if let Some(path) = inner.log_path.clone() {
            if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
                let _ = writeln!(f, "{}", record.to_json().to_string());
            }
        }
        let seq = record.seq;
        inner.records.push(record);
        seq
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All records matching the query, in insertion order.
    pub fn query(&self, q: &EvalQuery) -> Vec<EvalRecord> {
        self.inner
            .lock()
            .unwrap()
            .records
            .iter()
            .filter(|r| q.matches(&r.key))
            .cloned()
            .collect()
    }

    /// The latest record per distinct key matching the query (history keeps
    /// every run; comparisons usually want the newest).
    pub fn latest(&self, q: &EvalQuery) -> Vec<EvalRecord> {
        let mut by_key: std::collections::HashMap<String, EvalRecord> =
            std::collections::HashMap::new();
        for r in self.query(q) {
            let k = r.key.to_json().to_string();
            match by_key.get(&k) {
                Some(prev) if prev.seq >= r.seq => {}
                _ => {
                    by_key.insert(k, r);
                }
            }
        }
        let mut out: Vec<EvalRecord> = by_key.into_values().collect();
        out.sort_by_key(|r| r.seq);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    fn key(model: &str, system: &str, batch: usize) -> EvalKey {
        EvalKey {
            model: model.into(),
            model_version: "1.0.0".into(),
            framework: "TensorFlow".into(),
            framework_version: "1.15.0".into(),
            system: system.into(),
            device: "gpu".into(),
            scenario: Scenario::Online { count: 10 }.name().into(),
            batch_size: batch,
        }
    }

    #[test]
    fn put_query_roundtrip() {
        let db = EvalDb::in_memory();
        db.put(EvalRecord::new(key("resnet50", "aws_p3", 1), vec![0.006, 0.0063], 158.0));
        db.put(EvalRecord::new(key("vgg16", "aws_p3", 1), vec![0.022], 45.0));
        db.put(EvalRecord::new(key("resnet50", "ibm_p8", 1), vec![0.008], 125.0));
        assert_eq!(db.len(), 3);
        let r = db.query(&EvalQuery::model("resnet50"));
        assert_eq!(r.len(), 2);
        let q = EvalQuery { system: Some("aws_p3".into()), ..Default::default() };
        assert_eq!(db.query(&q).len(), 2);
    }

    #[test]
    fn latest_deduplicates_by_key() {
        let db = EvalDb::in_memory();
        db.put(EvalRecord::new(key("m", "s", 1), vec![0.010], 100.0));
        db.put(EvalRecord::new(key("m", "s", 1), vec![0.005], 200.0));
        db.put(EvalRecord::new(key("m", "s", 8), vec![0.020], 400.0));
        let latest = db.latest(&EvalQuery::model("m"));
        assert_eq!(latest.len(), 2);
        let b1 = latest.iter().find(|r| r.key.batch_size == 1).unwrap();
        assert_eq!(b1.throughput, 200.0, "latest run wins");
    }

    #[test]
    fn persistence_across_reopen() {
        let dir = std::env::temp_dir().join(format!("evaldb_test_{}", std::process::id()));
        let path = dir.join("eval.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let db = EvalDb::open(&path).unwrap();
            let mut r = EvalRecord::new(key("resnet50", "aws_p3", 256), vec![0.275], 930.7);
            r.trace_id = Some(42);
            r.meta = Json::obj(vec![("accuracy", Json::num(76.46))]);
            db.put(r);
        }
        let db = EvalDb::open(&path).unwrap();
        assert_eq!(db.len(), 1);
        let r = &db.query(&EvalQuery::model("resnet50"))[0];
        assert_eq!(r.trace_id, Some(42));
        assert_eq!(r.key.batch_size, 256);
        assert_eq!(r.meta.get("accuracy").unwrap().as_f64(), Some(76.46));
        // Appending after reopen continues the sequence.
        let seq = db.put(EvalRecord::new(key("x", "s", 1), vec![0.1], 10.0));
        assert_eq!(seq, 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_stats_use_paper_metrics() {
        let lat: Vec<f64> = (1..=10).map(|i| i as f64 / 1e3).collect();
        let r = EvalRecord::new(key("m", "s", 1), lat, 0.0);
        // trimmed mean over 3..8 ms = 5.5ms
        assert!((r.trimmed_mean_ms() - 5.5).abs() < 1e-9);
        assert!(r.p90_ms() >= 9.0);
    }

    #[test]
    fn corrupt_log_lines_skipped() {
        let dir = std::env::temp_dir().join(format!("evaldb_corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("eval.jsonl");
        let mut good = EvalRecord::new(key("m", "s", 1), vec![0.1], 1.0);
        good.seq = 1;
        std::fs::write(
            &path,
            format!("{}\nnot json at all\n{{\"half\": true}}\n", good.to_json().to_string()),
        )
        .unwrap();
        let db = EvalDb::open(&path).unwrap();
        // Good line kept; garbage skipped; half-record (no key) skipped.
        assert_eq!(db.len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
