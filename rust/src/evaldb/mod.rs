//! The evaluation database (§4.5.2).
//!
//! After an evaluation, the agent stores the benchmarking result (and a
//! pointer to its profiling trace) keyed by the full user input — model,
//! framework, system, scenario — so the analysis workflow can query across
//! historical runs ("MLModelScope allows one to track which model version
//! produced the best result"). The store is an embedded append-only store
//! — the offline substitute for the paper's hosted document database —
//! organized as **N independent JSONL segment logs** with per-shard locks:
//!
//! - **Spec digests.** Every record may carry a content-addressed
//!   [`EvalSpec`] digest — SHA-256 over the canonical JSON of the resolved
//!   model manifest + system/device + scenario + batch size + trace level +
//!   seed (+ dispatch config). Identical evaluation configurations are
//!   identical by construction, which is what `sweep` memoization and
//!   crash-safe resume key on ([`EvalDb::get_by_digest`]).
//! - **Sharding.** Records route to a segment by a hash of their identity
//!   (spec digest when present, canonical key JSON otherwise). `put` takes
//!   one atomic sequence fetch plus a single per-shard lock — there is no
//!   global mutex on the hot path — so concurrent writers on different
//!   shards never contend. Reads fan out across all shards and merge by
//!   sequence number, so shard-count changes between runs are harmless
//!   (a record loaded from an "off-route" segment is still found).
//! - **Compaction.** [`EvalDb::compact`] applies *latest-record-wins* per
//!   identity within each shard: for every spec digest (or, for digest-less
//!   records, every canonical key) only the highest-sequence record
//!   survives; each segment log is rewritten atomically (temp file +
//!   rename) and the in-memory indexes are rebuilt. History is therefore
//!   bounded by the number of *distinct* specs, not the number of runs.
//!   Compaction holds one shard lock at a time — writers to other shards
//!   proceed concurrently.
//! - **Crash recovery.** Segment replay is line-oriented and lenient: a
//!   torn tail (a record cut mid-line by a crash) or a corrupt line is
//!   dropped and every complete record is recovered.

use crate::metrics::LatencySamples;

use crate::util::json::Json;
use crate::util::sha256::sha256_hex;
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default segment-log count for sharded databases.
pub const DEFAULT_SHARDS: usize = 8;

/// The key identifying one evaluation configuration (the "user input").
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct EvalKey {
    pub model: String,
    pub model_version: String,
    pub framework: String,
    pub framework_version: String,
    pub system: String,
    pub device: String,
    pub scenario: String,
    pub batch_size: usize,
}

impl EvalKey {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("model_version", Json::str(&self.model_version)),
            ("framework", Json::str(&self.framework)),
            ("framework_version", Json::str(&self.framework_version)),
            ("system", Json::str(&self.system)),
            ("device", Json::str(&self.device)),
            ("scenario", Json::str(&self.scenario)),
            ("batch_size", Json::num(self.batch_size as f64)),
        ])
    }

    /// Canonical JSON string — the key's identity for latest-wins dedup and
    /// for shard routing of digest-less records (object keys serialize in
    /// sorted order, so equal keys always canonicalize identically).
    pub fn canonical(&self) -> String {
        self.to_json().to_string()
    }

    /// Strict parse: every field must be present with the right type.
    /// Returns `None` for missing or malformed fields instead of silently
    /// defaulting them — a half-parsed key would corrupt query results and
    /// latest-wins dedup.
    pub fn from_json(j: &Json) -> Option<EvalKey> {
        let s = |field: &str| -> Option<String> {
            j.get(field).and_then(|v| v.as_str()).map(str::to_string)
        };
        let batch = j.get("batch_size")?.as_f64()?;
        // A real batch size is a positive integer; 8.9 or 0 would merge
        // the record into a wrong or meaningless key, so reject outright.
        if !(batch >= 1.0) || batch.fract() != 0.0 || batch > usize::MAX as f64 {
            return None;
        }
        Some(EvalKey {
            model: s("model")?,
            model_version: s("model_version")?,
            framework: s("framework")?,
            framework_version: s("framework_version")?,
            system: s("system")?,
            device: s("device")?,
            scenario: s("scenario")?,
            batch_size: batch as usize,
        })
    }
}

/// Run metadata: the longitudinal axis for commit-over-commit regression
/// tracking. `label` names the run line being measured (a branch, a commit
/// ref, or just "control"/"treatment"); `commit` and `timestamp` are
/// free-form provenance carried alongside. Only the label participates in
/// spec identity (see [`EvalSpec::run_label`]) — provenance fields never
/// change what experiment a record belongs to.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RunMeta {
    pub label: String,
    pub commit: String,
    pub timestamp: String,
}

impl RunMeta {
    pub fn labeled(label: &str) -> RunMeta {
        RunMeta { label: label.to_string(), ..Default::default() }
    }

    /// True when every field is empty — the legacy "no run metadata" state.
    pub fn is_empty(&self) -> bool {
        self.label.is_empty() && self.commit.is_empty() && self.timestamp.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(&self.label)),
            ("commit", Json::str(&self.commit)),
            ("timestamp", Json::str(&self.timestamp)),
        ])
    }

    /// Strict parse mirroring [`EvalKey::from_json`]: absent or `null`
    /// means "no metadata" (legacy records), but a present `run_meta` must
    /// be an object whose present fields are strings. A number where the
    /// label should be, or a bare string, rejects the record rather than
    /// silently erasing its run line — a record quietly dropped into the
    /// unlabeled pool would corrupt every A/B comparison that touches it.
    pub fn from_json(j: Option<&Json>) -> Option<RunMeta> {
        let j = match j {
            None | Some(Json::Null) => return Some(RunMeta::default()),
            Some(j) => j,
        };
        let obj = j.as_obj()?;
        let field = |name: &str| -> Option<String> {
            match obj.get(name) {
                None => Some(String::new()),
                Some(v) => v.as_str().map(str::to_string),
            }
        };
        Some(RunMeta {
            label: field("label")?,
            commit: field("commit")?,
            timestamp: field("timestamp")?,
        })
    }
}

/// The fully-resolved evaluation specification: everything that determines
/// a benchmark result. Two evaluations whose canonical spec JSON is equal
/// are the same experiment *by construction* (the model-spec
/// reproducibility argument), so the SHA-256 digest of that JSON is the
/// memoization key for [`crate::sweep`] and the content address stored on
/// [`EvalRecord::spec_digest`].
#[derive(Debug, Clone)]
pub struct EvalSpec {
    /// The resolved model manifest, as JSON.
    pub manifest: Json,
    /// System profile name the evaluation targets (e.g. `aws_p3`).
    pub system: String,
    /// Device class (`gpu` / `cpu`).
    pub device: String,
    /// The benchmarking scenario, as JSON.
    pub scenario: Json,
    /// Per-request batch size (or dispatch batch capacity).
    pub batch_size: usize,
    /// Trace level string (`none` … `full`) — tracing perturbs timing, so
    /// runs at different levels are different experiments.
    pub trace_level: String,
    /// Workload seed.
    pub seed: u64,
    /// Cross-request dispatch fingerprint
    /// ([`crate::batcher::BatcherConfig::fingerprint_json`]) or `Null` for
    /// the classic per-request path.
    pub dispatch: Json,
    /// Run label ([`RunMeta::label`]) — the longitudinal identity axis.
    /// Folded into the canonical form *only when non-empty*, so every
    /// pre-existing digest (and with it sweep memoization and crash-safe
    /// resume over stores written before labels existed) is unchanged.
    /// Two sweeps under different labels are different experiments; a
    /// re-run under the same label memoizes.
    pub run_label: String,
}

impl EvalSpec {
    /// The one constructor every execution path and the sweep planner use.
    /// Memoization and crash-safe resume depend on plan-time digests being
    /// byte-identical to stored digests; a single definition makes drift
    /// between the sites impossible.
    #[allow(clippy::too_many_arguments)]
    pub fn for_request(
        manifest: &crate::manifest::ModelManifest,
        system: &str,
        device: &str,
        scenario: &crate::scenario::Scenario,
        batch_size: usize,
        trace_level: crate::tracing::TraceLevel,
        seed: u64,
        dispatch: Json,
    ) -> EvalSpec {
        EvalSpec {
            manifest: manifest.to_json(),
            system: system.to_string(),
            device: device.to_string(),
            scenario: scenario.to_json(),
            batch_size,
            trace_level: trace_level.as_str().to_string(),
            seed,
            dispatch,
            run_label: String::new(),
        }
    }

    /// Canonical JSON form. Objects serialize with sorted keys, so any
    /// reordering of the input fields produces the identical string.
    pub fn canonical(&self) -> Json {
        let mut fields = vec![
            ("batch_size", Json::num(self.batch_size as f64)),
            ("device", Json::str(&self.device)),
            ("dispatch", self.dispatch.clone()),
            ("manifest", self.manifest.clone()),
            ("scenario", self.scenario.clone()),
            // The seed is a full u64; encode as a string so values beyond
            // 2^53 stay exact.
            ("seed", Json::str(self.seed.to_string())),
            ("system", Json::str(&self.system)),
            ("trace_level", Json::str(&self.trace_level)),
        ];
        // Only labeled runs carry the field: unlabeled specs canonicalize
        // exactly as they did before run metadata existed, so historical
        // digests stay valid.
        if !self.run_label.is_empty() {
            fields.push(("run_label", Json::str(&self.run_label)));
        }
        Json::obj(fields)
    }

    /// Content-addressed digest: SHA-256 hex of the canonical JSON.
    pub fn digest(&self) -> String {
        sha256_hex(self.canonical().to_string().as_bytes())
    }
}

/// One stored evaluation record.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub key: EvalKey,
    /// Monotonic sequence number assigned by the database.
    pub seq: u64,
    /// Latency samples (seconds per request).
    pub latencies: Vec<f64>,
    /// Achieved throughput, items/sec.
    pub throughput: f64,
    /// Trace id in the tracing server, if profiling was enabled.
    pub trace_id: Option<u64>,
    /// Content-addressed [`EvalSpec`] digest of the resolved configuration
    /// that produced this record (`None` for legacy or hand-built records).
    pub spec_digest: Option<String>,
    /// Run metadata (label/commit/timestamp) — empty for legacy records
    /// and unlabeled runs. See [`RunMeta`].
    pub run_meta: RunMeta,
    /// Free-form metadata (accuracy, graph size, agent id, ...).
    pub meta: Json,
}

impl EvalRecord {
    pub fn new(key: EvalKey, latencies: Vec<f64>, throughput: f64) -> EvalRecord {
        EvalRecord {
            key,
            seq: 0,
            latencies,
            throughput,
            trace_id: None,
            spec_digest: None,
            run_meta: RunMeta::default(),
            meta: Json::Null,
        }
    }

    pub fn samples(&self) -> LatencySamples {
        LatencySamples::from_secs(self.latencies.clone())
    }

    pub fn trimmed_mean_ms(&self) -> f64 {
        self.samples().trimmed_mean() * 1e3
    }

    pub fn p90_ms(&self) -> f64 {
        self.samples().p90() * 1e3
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("key", self.key.to_json()),
            ("seq", Json::num(self.seq as f64)),
            (
                "latencies",
                Json::arr(self.latencies.iter().map(|l| Json::num(*l)).collect()),
            ),
            ("throughput", Json::num(self.throughput)),
            (
                "trace_id",
                self.trace_id.map(|t| Json::num(t as f64)).unwrap_or(Json::Null),
            ),
            (
                "spec_digest",
                self.spec_digest.clone().map(Json::Str).unwrap_or(Json::Null),
            ),
            ("meta", self.meta.clone()),
        ];
        // Empty run metadata is omitted entirely, so pre-label stores and
        // fixtures serialize byte-identically to before.
        if !self.run_meta.is_empty() {
            fields.push(("run_meta", self.run_meta.to_json()));
        }
        Json::obj(fields)
    }

    /// Strict parse: a present field with the wrong type rejects the whole
    /// record instead of being silently defaulted. Missing `seq` /
    /// `throughput` / `trace_id` / `spec_digest` / `run_meta` keep their
    /// legacy defaults (old stores must replay), but a malformed latency
    /// entry, a string `seq`, or a numeric `run_meta.label` means the line
    /// is corrupt — and a half-parsed record would silently skew every
    /// statistical gate computed over its samples.
    pub fn from_json(j: &Json) -> Option<EvalRecord> {
        let seq = match j.get("seq") {
            None => 0,
            Some(v) => {
                let f = v.as_f64()?;
                if !(f >= 0.0) || f.fract() != 0.0 {
                    return None;
                }
                f as u64
            }
        };
        let mut latencies = Vec::new();
        for v in j.get("latencies")?.as_arr()? {
            // Every sample must be numeric: dropping bad entries (the old
            // behavior) changes sample counts and with them gate verdicts.
            latencies.push(v.as_f64()?);
        }
        let throughput = match j.get("throughput") {
            // NaN serializes as JSON null, so null round-trips back to NaN.
            None | Some(Json::Null) => f64::NAN,
            Some(v) => v.as_f64()?,
        };
        let trace_id = match j.get("trace_id") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_u64()?),
        };
        let spec_digest = match j.get("spec_digest") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_str()?.to_string()),
        };
        Some(EvalRecord {
            key: EvalKey::from_json(j.get("key")?)?,
            seq,
            latencies,
            throughput,
            trace_id,
            spec_digest,
            run_meta: RunMeta::from_json(j.get("run_meta"))?,
            meta: j.get("meta").cloned().unwrap_or(Json::Null),
        })
    }
}

/// Query filter: all `Some` fields must match.
#[derive(Debug, Clone, Default)]
pub struct EvalQuery {
    pub model: Option<String>,
    pub framework: Option<String>,
    pub system: Option<String>,
    pub device: Option<String>,
    pub scenario: Option<String>,
    pub batch_size: Option<usize>,
    /// Match records whose [`RunMeta::label`] equals this (an empty string
    /// selects unlabeled/legacy records).
    pub label: Option<String>,
}

impl EvalQuery {
    pub fn model(name: &str) -> EvalQuery {
        EvalQuery { model: Some(name.to_string()), ..Default::default() }
    }

    /// All records from one labeled run line.
    pub fn label(label: &str) -> EvalQuery {
        EvalQuery { label: Some(label.to_string()), ..Default::default() }
    }

    fn matches(&self, r: &EvalRecord) -> bool {
        let k = &r.key;
        self.model.as_deref().map_or(true, |m| m == k.model)
            && self.framework.as_deref().map_or(true, |f| f == k.framework)
            && self.system.as_deref().map_or(true, |s| s == k.system)
            && self.device.as_deref().map_or(true, |d| d == k.device)
            && self.scenario.as_deref().map_or(true, |s| s == k.scenario)
            && self.batch_size.map_or(true, |b| b == k.batch_size)
            && self.label.as_deref().map_or(true, |l| l == r.run_meta.label)
    }
}

/// Outcome of a [`EvalDb::compact`] pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CompactionStats {
    /// Records examined across all shards.
    pub scanned: usize,
    /// Records surviving (the latest per identity).
    pub retained: usize,
    /// Superseded records removed.
    pub dropped: usize,
}

/// A record's identity for routing and latest-wins compaction: the spec
/// digest when present, the canonical key JSON otherwise.
fn record_identity(r: &EvalRecord) -> String {
    r.spec_digest.clone().unwrap_or_else(|| r.key.canonical())
}

/// Deterministic shard routing (FNV-1a over the identity string). Only
/// write *distribution* depends on this — reads fan out over every shard.
fn shard_index(identity: &str, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in identity.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % shards.max(1) as u64) as usize
}

/// Replay one segment log leniently: complete lines parse into records;
/// torn tails and corrupt lines are dropped.
fn read_segment(path: &Path) -> std::io::Result<Vec<EvalRecord>> {
    let bytes = std::fs::read(path)?;
    let text = String::from_utf8_lossy(&bytes);
    let mut out = Vec::new();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        if let Ok(j) = Json::parse(line) {
            if let Some(r) = EvalRecord::from_json(&j) {
                out.push(r);
            }
        }
    }
    // A file not ending in a newline was torn mid-append by a crash. Left
    // as-is, the next append would concatenate onto the corrupt partial
    // line and that record would vanish on the following replay — so
    // rewrite the segment down to its recovered prefix before the store
    // goes live.
    if !bytes.is_empty() && bytes.last() != Some(&b'\n') {
        let mut log = String::new();
        for r in &out {
            log.push_str(&r.to_json().to_string());
            log.push('\n');
        }
        crate::util::fs::write_atomic(path, log.as_bytes())?;
    }
    Ok(out)
}

/// The embedded evaluation database (sharded; see the module docs).
pub struct EvalDb {
    shards: Vec<Mutex<Shard>>,
    next_seq: AtomicU64,
    /// Records whose segment-log append failed (full disk, vanished
    /// directory, revoked permissions). The records stay queryable in
    /// memory; this counter is the queryable evidence that durability was
    /// lost — [`EvalDb::put`] must not silently swallow I/O errors.
    dropped_writes: AtomicU64,
}

struct Shard {
    records: Vec<EvalRecord>,
    /// Spec digest → position in `records` of the highest-seq record
    /// carrying it (the memoization index).
    by_digest: HashMap<String, usize>,
    /// Segment log path; `None` → memory-only (tests, benches).
    log_path: Option<PathBuf>,
    /// Kept-open appender for `log_path`, opened lazily on the first write.
    /// Replaces a per-record `OpenOptions::open` (a full open/close syscall
    /// pair per put). Invalidated whenever the segment file is replaced on
    /// disk ([`EvalDb::compact`]'s atomic rename would otherwise leave this
    /// fd appending to the unlinked old inode) and on any write error (so
    /// the next put retries with a fresh descriptor).
    writer: Option<std::fs::File>,
    /// Reused serialization buffer: records append via one `write_all` of
    /// this buffer instead of allocating a fresh `String` per record.
    buf: String,
}

impl Shard {
    /// Serialize `records` as JSONL into the reused buffer and append it
    /// with a single `write_all` through the kept-open writer. Memory-only
    /// shards (`log_path == None`) succeed trivially.
    fn append_records(&mut self, records: &[EvalRecord]) -> std::io::Result<()> {
        if self.log_path.is_none() || records.is_empty() {
            return Ok(());
        }
        if self.writer.is_none() {
            let path = self.log_path.as_ref().unwrap();
            let f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
            self.writer = Some(f);
        }
        self.buf.clear();
        for r in records {
            r.to_json().write_into(&mut self.buf);
            self.buf.push('\n');
        }
        let res = self.writer.as_mut().unwrap().write_all(self.buf.as_bytes());
        if res.is_err() {
            // A failed descriptor is not retried: drop it so the next
            // append reopens the segment from scratch.
            self.writer = None;
        }
        res
    }

    /// Insert one sequence-stamped record into the in-memory state
    /// (latest-wins digest index + record list). The caller has already
    /// assigned `record.seq`.
    fn insert(&mut self, record: EvalRecord) {
        let pos = self.records.len();
        if let Some(d) = record.spec_digest.clone() {
            // Latest-wins index: a slower thread holding an older sequence
            // number must not displace a newer record.
            let newer = match self.by_digest.get(&d) {
                Some(&p) => self.records[p].seq <= record.seq,
                None => true,
            };
            if newer {
                self.by_digest.insert(d, pos);
            }
        }
        self.records.push(record);
    }
}

impl EvalDb {
    /// Memory-only database with [`DEFAULT_SHARDS`] shards.
    pub fn in_memory() -> EvalDb {
        EvalDb::in_memory_sharded(DEFAULT_SHARDS)
    }

    /// Memory-only database with an explicit shard count.
    pub fn in_memory_sharded(shards: usize) -> EvalDb {
        EvalDb::assemble((0..shards.max(1)).map(|_| (None, Vec::new())).collect())
    }

    /// Open (or create) a file-backed database, replaying existing logs.
    ///
    /// A path ending in `.jsonl` (or naming an existing regular file) opens
    /// in legacy single-segment mode backed by exactly that file; any other
    /// path is treated as a directory of [`DEFAULT_SHARDS`] segment logs.
    pub fn open(path: impl Into<PathBuf>) -> std::io::Result<EvalDb> {
        let path = path.into();
        let legacy =
            path.extension().and_then(|e| e.to_str()) == Some("jsonl") || path.is_file();
        if !legacy {
            return EvalDb::open_sharded(&path, DEFAULT_SHARDS);
        }
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() && !dir.exists() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let records = if path.exists() { read_segment(&path)? } else { Vec::new() };
        Ok(EvalDb::assemble(vec![(Some(path), records)]))
    }

    /// Open (or create) a sharded database under `dir` with at least
    /// `shards` segment logs. Existing segments beyond the requested count
    /// are still loaded — the shard count only controls write distribution.
    pub fn open_sharded(dir: &Path, shards: usize) -> std::io::Result<EvalDb> {
        std::fs::create_dir_all(dir)?;
        let mut n = shards.max(1);
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().into_owned();
                if let Some(idx) = name
                    .strip_prefix("segment-")
                    .and_then(|s| s.strip_suffix(".jsonl"))
                    .and_then(|s| s.parse::<usize>().ok())
                {
                    n = n.max(idx + 1);
                }
            }
        }
        let mut segments = Vec::with_capacity(n);
        for i in 0..n {
            let p = dir.join(format!("segment-{i:02}.jsonl"));
            let records = if p.exists() { read_segment(&p)? } else { Vec::new() };
            segments.push((Some(p), records));
        }
        Ok(EvalDb::assemble(segments))
    }

    fn assemble(segments: Vec<(Option<PathBuf>, Vec<EvalRecord>)>) -> EvalDb {
        let mut next_seq: u64 = 1;
        let mut shards = Vec::with_capacity(segments.len());
        for (log_path, records) in segments {
            let mut by_digest: HashMap<String, usize> = HashMap::new();
            for (pos, r) in records.iter().enumerate() {
                next_seq = next_seq.max(r.seq + 1);
                if let Some(d) = &r.spec_digest {
                    let newer = match by_digest.get(d) {
                        Some(&p) => records[p].seq <= r.seq,
                        None => true,
                    };
                    if newer {
                        by_digest.insert(d.clone(), pos);
                    }
                }
            }
            shards.push(Mutex::new(Shard {
                records,
                by_digest,
                log_path,
                writer: None,
                buf: String::new(),
            }));
        }
        EvalDb { shards, next_seq: AtomicU64::new(next_seq), dropped_writes: AtomicU64::new(0) }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard an identity (spec digest or canonical key) routes to.
    pub fn shard_of(&self, identity: &str) -> usize {
        shard_index(identity, self.shards.len())
    }

    /// Store a record; assigns and returns its sequence number. Takes one
    /// atomic fetch plus the routed shard's lock — writers to different
    /// shards never contend. The segment append goes through the shard's
    /// kept-open writer with a reused serialization buffer (no per-record
    /// file open, no per-record `String` allocation).
    ///
    /// A failed append no longer vanishes silently: the record stays
    /// queryable in memory and [`EvalDb::dropped_writes`] increments — use
    /// [`EvalDb::try_put`] to get the typed I/O error instead.
    pub fn put(&self, record: EvalRecord) -> u64 {
        let (seq, res) = self.put_inner(record);
        if res.is_err() {
            self.dropped_writes.fetch_add(1, Ordering::Relaxed);
        }
        seq
    }

    /// As [`EvalDb::put`], but surfaces the segment-append error. Even on
    /// `Err` the record was inserted in memory with its assigned sequence
    /// number (and counted in [`EvalDb::dropped_writes`]) — the error
    /// reports lost *durability*, not a lost record.
    pub fn try_put(&self, record: EvalRecord) -> std::io::Result<u64> {
        let (seq, res) = self.put_inner(record);
        match res {
            Ok(()) => Ok(seq),
            Err(e) => {
                self.dropped_writes.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn put_inner(&self, mut record: EvalRecord) -> (u64, std::io::Result<()>) {
        record.seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let seq = record.seq;
        let idx = shard_index(&record_identity(&record), self.shards.len());
        let mut shard = self.shards[idx].lock().unwrap();
        let res = shard.append_records(std::slice::from_ref(&record));
        shard.insert(record);
        (seq, res)
    }

    /// Store a batch of records: sequence numbers are assigned in input
    /// order and returned in input order, records are grouped by shard, and
    /// each touched shard takes its lock **once** and appends the whole
    /// group with a single buffered write. Observationally identical to
    /// calling [`EvalDb::put`] sequentially (pinned by property test) —
    /// just one lock + one syscall per shard instead of one per record.
    ///
    /// On `Err`, every record was still inserted in memory; each record in
    /// a failed group counts toward [`EvalDb::dropped_writes`] and the
    /// first error is returned.
    pub fn put_all(&self, records: Vec<EvalRecord>) -> std::io::Result<Vec<u64>> {
        let mut seqs = Vec::with_capacity(records.len());
        let mut by_shard: Vec<Vec<EvalRecord>> =
            (0..self.shards.len()).map(|_| Vec::new()).collect();
        for mut record in records {
            record.seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
            seqs.push(record.seq);
            let idx = shard_index(&record_identity(&record), self.shards.len());
            by_shard[idx].push(record);
        }
        let mut first_err: Option<std::io::Error> = None;
        for (idx, group) in by_shard.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let mut shard = self.shards[idx].lock().unwrap();
            if let Err(e) = shard.append_records(&group) {
                self.dropped_writes.fetch_add(group.len() as u64, Ordering::Relaxed);
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            for record in group {
                shard.insert(record);
            }
        }
        match first_err {
            None => Ok(seqs),
            Some(e) => Err(e),
        }
    }

    /// Records whose segment-log append failed since open. Non-zero means
    /// the on-disk log is missing records that are still queryable in
    /// memory — an operator signal to check the disk before trusting a
    /// replay.
    pub fn dropped_writes(&self) -> u64 {
        self.dropped_writes.load(Ordering::Relaxed)
    }

    /// The highest-sequence record carrying this spec digest, if any — the
    /// memoization lookup: a hit means the exact configuration was already
    /// measured.
    pub fn get_by_digest(&self, digest: &str) -> Option<EvalRecord> {
        let mut best: Option<EvalRecord> = None;
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            if let Some(&p) = shard.by_digest.get(digest) {
                let r = &shard.records[p];
                if best.as_ref().map_or(true, |b| b.seq < r.seq) {
                    best = Some(r.clone());
                }
            }
        }
        best
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().records.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All records matching the query, in sequence (insertion) order.
    pub fn query(&self, q: &EvalQuery) -> Vec<EvalRecord> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            out.extend(shard.records.iter().filter(|r| q.matches(r)).cloned());
        }
        out.sort_by_key(|r| r.seq);
        out
    }

    /// The latest record per distinct key matching the query (history keeps
    /// every run; comparisons usually want the newest).
    pub fn latest(&self, q: &EvalQuery) -> Vec<EvalRecord> {
        let mut by_key: HashMap<String, EvalRecord> = HashMap::new();
        for r in self.query(q) {
            let k = r.key.canonical();
            match by_key.get(&k) {
                Some(prev) if prev.seq >= r.seq => {}
                _ => {
                    by_key.insert(k, r);
                }
            }
        }
        let mut out: Vec<EvalRecord> = by_key.into_values().collect();
        out.sort_by_key(|r| r.seq);
        out
    }

    /// Latest-record-wins compaction (see the module docs): within each
    /// shard, keep only the highest-sequence record per identity, rewrite
    /// the segment log atomically, and rebuild the digest index. One shard
    /// is locked at a time, so writers to other shards proceed.
    pub fn compact(&self) -> std::io::Result<CompactionStats> {
        let mut stats = CompactionStats::default();
        // Pass 1: the globally-highest sequence per identity. Duplicates of
        // one identity can sit in *different* shards after a shard-count
        // change (routing only governs writes), so per-shard dedup alone
        // would let superseded records survive forever.
        let mut winners: HashMap<String, u64> = HashMap::new();
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            for r in &shard.records {
                let entry = winners.entry(record_identity(r)).or_insert(r.seq);
                if *entry < r.seq {
                    *entry = r.seq;
                }
            }
        }
        // Pass 2: keep only each identity's winner. A record put between
        // the passes has a sequence above its recorded winner and is kept.
        for shard in &self.shards {
            let mut shard = shard.lock().unwrap();
            stats.scanned += shard.records.len();
            let old = std::mem::take(&mut shard.records);
            let mut records = Vec::new();
            for r in old {
                let keep = winners
                    .get(&record_identity(&r))
                    .map_or(true, |&w| r.seq >= w);
                if keep {
                    records.push(r);
                }
            }
            stats.retained += records.len();
            if let Some(path) = shard.log_path.clone() {
                let mut log = String::new();
                for r in &records {
                    r.to_json().write_into(&mut log);
                    log.push('\n');
                }
                crate::util::fs::write_atomic(&path, log.as_bytes())?;
                // The atomic rewrite renamed a fresh file over the segment:
                // a kept-open appender would now write to the unlinked old
                // inode and those appends would vanish. Force the next put
                // to reopen the new file.
                shard.writer = None;
            }
            let mut by_digest: HashMap<String, usize> = HashMap::new();
            for (pos, r) in records.iter().enumerate() {
                if let Some(d) = &r.spec_digest {
                    by_digest.insert(d.clone(), pos);
                }
            }
            shard.records = records;
            shard.by_digest = by_digest;
        }
        stats.dropped = stats.scanned - stats.retained;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    fn key(model: &str, system: &str, batch: usize) -> EvalKey {
        EvalKey {
            model: model.into(),
            model_version: "1.0.0".into(),
            framework: "TensorFlow".into(),
            framework_version: "1.15.0".into(),
            system: system.into(),
            device: "gpu".into(),
            scenario: Scenario::Online { count: 10 }.name().into(),
            batch_size: batch,
        }
    }

    #[test]
    fn put_query_roundtrip() {
        let db = EvalDb::in_memory();
        db.put(EvalRecord::new(key("resnet50", "aws_p3", 1), vec![0.006, 0.0063], 158.0));
        db.put(EvalRecord::new(key("vgg16", "aws_p3", 1), vec![0.022], 45.0));
        db.put(EvalRecord::new(key("resnet50", "ibm_p8", 1), vec![0.008], 125.0));
        assert_eq!(db.len(), 3);
        let r = db.query(&EvalQuery::model("resnet50"));
        assert_eq!(r.len(), 2);
        let q = EvalQuery { system: Some("aws_p3".into()), ..Default::default() };
        assert_eq!(db.query(&q).len(), 2);
    }

    #[test]
    fn latest_deduplicates_by_key() {
        let db = EvalDb::in_memory();
        db.put(EvalRecord::new(key("m", "s", 1), vec![0.010], 100.0));
        db.put(EvalRecord::new(key("m", "s", 1), vec![0.005], 200.0));
        db.put(EvalRecord::new(key("m", "s", 8), vec![0.020], 400.0));
        let latest = db.latest(&EvalQuery::model("m"));
        assert_eq!(latest.len(), 2);
        let b1 = latest.iter().find(|r| r.key.batch_size == 1).unwrap();
        assert_eq!(b1.throughput, 200.0, "latest run wins");
    }

    #[test]
    fn persistence_across_reopen() {
        let dir = std::env::temp_dir().join(format!("evaldb_test_{}", std::process::id()));
        let path = dir.join("eval.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let db = EvalDb::open(&path).unwrap();
            assert_eq!(db.shard_count(), 1, "legacy .jsonl path is single-segment");
            let mut r = EvalRecord::new(key("resnet50", "aws_p3", 256), vec![0.275], 930.7);
            r.trace_id = Some(42);
            r.meta = Json::obj(vec![("accuracy", Json::num(76.46))]);
            db.put(r);
        }
        let db = EvalDb::open(&path).unwrap();
        assert_eq!(db.len(), 1);
        let r = &db.query(&EvalQuery::model("resnet50"))[0];
        assert_eq!(r.trace_id, Some(42));
        assert_eq!(r.key.batch_size, 256);
        assert_eq!(r.meta.get("accuracy").unwrap().as_f64(), Some(76.46));
        // Appending after reopen continues the sequence.
        let seq = db.put(EvalRecord::new(key("x", "s", 1), vec![0.1], 10.0));
        assert_eq!(seq, 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_stats_use_paper_metrics() {
        let lat: Vec<f64> = (1..=10).map(|i| i as f64 / 1e3).collect();
        let r = EvalRecord::new(key("m", "s", 1), lat, 0.0);
        // trimmed mean over 3..8 ms = 5.5ms
        assert!((r.trimmed_mean_ms() - 5.5).abs() < 1e-9);
        assert!(r.p90_ms() >= 9.0);
    }

    #[test]
    fn corrupt_log_lines_skipped() {
        let dir = std::env::temp_dir().join(format!("evaldb_corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("eval.jsonl");
        let mut good = EvalRecord::new(key("m", "s", 1), vec![0.1], 1.0);
        good.seq = 1;
        std::fs::write(
            &path,
            format!("{}\nnot json at all\n{{\"half\": true}}\n", good.to_json().to_string()),
        )
        .unwrap();
        let db = EvalDb::open(&path).unwrap();
        // Good line kept; garbage skipped; half-record (no key) skipped.
        assert_eq!(db.len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn eval_key_from_json_rejects_each_malformed_shape() {
        let valid = key("m", "s", 4).to_json();
        assert!(EvalKey::from_json(&valid).is_some(), "control: valid key parses");
        // Each field missing → reject (no silent defaulting).
        for field in [
            "model",
            "model_version",
            "framework",
            "framework_version",
            "system",
            "device",
            "scenario",
            "batch_size",
        ] {
            if let Json::Obj(mut m) = valid.clone() {
                m.remove(field);
                assert!(
                    EvalKey::from_json(&Json::Obj(m)).is_none(),
                    "missing {field} must reject"
                );
            }
        }
        // Wrong types → reject.
        if let Json::Obj(mut m) = valid.clone() {
            m.insert("batch_size".into(), Json::str("eight"));
            assert!(EvalKey::from_json(&Json::Obj(m)).is_none(), "string batch_size");
        }
        if let Json::Obj(mut m) = valid.clone() {
            m.insert("model".into(), Json::num(7.0));
            assert!(EvalKey::from_json(&Json::Obj(m)).is_none(), "numeric model");
        }
        if let Json::Obj(mut m) = valid.clone() {
            m.insert("batch_size".into(), Json::num(-3.0));
            assert!(EvalKey::from_json(&Json::Obj(m)).is_none(), "negative batch_size");
        }
        if let Json::Obj(mut m) = valid.clone() {
            m.insert("batch_size".into(), Json::num(0.0));
            assert!(EvalKey::from_json(&Json::Obj(m)).is_none(), "zero batch_size");
        }
        if let Json::Obj(mut m) = valid.clone() {
            m.insert("batch_size".into(), Json::num(8.9));
            assert!(EvalKey::from_json(&Json::Obj(m)).is_none(), "fractional batch_size");
        }
        // Non-object inputs → reject.
        assert!(EvalKey::from_json(&Json::Null).is_none());
        assert!(EvalKey::from_json(&Json::str("key")).is_none());
        // And a record with a malformed key is rejected as a whole.
        let mut rec = EvalRecord::new(key("m", "s", 1), vec![0.1], 1.0);
        rec.seq = 3;
        if let Json::Obj(mut m) = rec.to_json() {
            if let Some(Json::Obj(k)) = m.get_mut("key") {
                k.remove("device");
            }
            assert!(EvalRecord::from_json(&Json::Obj(m)).is_none());
        }
    }

    #[test]
    fn spec_digest_is_deterministic_and_field_sensitive() {
        let spec = EvalSpec {
            manifest: Json::obj(vec![("name", Json::str("m")), ("version", Json::str("1.0.0"))]),
            system: "aws_p3".into(),
            device: "gpu".into(),
            scenario: Scenario::Online { count: 8 }.to_json(),
            batch_size: 1,
            trace_level: "none".into(),
            seed: 42,
            dispatch: Json::Null,
            run_label: String::new(),
        };
        assert_eq!(spec.digest(), spec.clone().digest(), "deterministic");
        let mut other = spec.clone();
        other.seed = 43;
        assert_ne!(spec.digest(), other.digest(), "seed is part of the spec");
        let mut other = spec.clone();
        other.trace_level = "full".into();
        assert_ne!(spec.digest(), other.digest(), "trace level is part of the spec");
        // An empty run label is *not* part of the canonical form — digests
        // computed before run metadata existed stay valid — but a non-empty
        // label is a different experiment.
        assert!(!spec.canonical().to_string().contains("run_label"));
        let mut labeled = spec.clone();
        labeled.run_label = "v2".into();
        assert_ne!(spec.digest(), labeled.digest(), "label is part of the spec");
        let mut other_label = spec.clone();
        other_label.run_label = "v3".into();
        assert_ne!(labeled.digest(), other_label.digest());
    }

    #[test]
    fn digest_memoization_index_returns_latest() {
        let db = EvalDb::in_memory_sharded(4);
        let digest = "d".repeat(64);
        let mut a = EvalRecord::new(key("m", "s", 1), vec![0.010], 100.0);
        a.spec_digest = Some(digest.clone());
        let mut b = a.clone();
        b.throughput = 200.0;
        db.put(a);
        db.put(b);
        let hit = db.get_by_digest(&digest).expect("digest hit");
        assert_eq!(hit.throughput, 200.0, "latest record wins");
        assert!(db.get_by_digest(&"e".repeat(64)).is_none());
        // Routing is deterministic.
        assert_eq!(db.shard_of(&digest), db.shard_of(&digest));
        assert!(db.shard_of(&digest) < db.shard_count());
    }

    #[test]
    fn compaction_keeps_latest_per_identity() {
        let db = EvalDb::in_memory_sharded(2);
        let digest = "a".repeat(64);
        for tput in [1.0, 2.0, 3.0] {
            let mut r = EvalRecord::new(key("m", "s", 1), vec![0.01], tput);
            r.spec_digest = Some(digest.clone());
            db.put(r);
        }
        // Digest-less records compact by canonical key.
        db.put(EvalRecord::new(key("n", "s", 1), vec![0.02], 10.0));
        db.put(EvalRecord::new(key("n", "s", 1), vec![0.02], 20.0));
        let stats = db.compact().unwrap();
        assert_eq!(stats, CompactionStats { scanned: 5, retained: 2, dropped: 3 });
        assert_eq!(db.len(), 2);
        assert_eq!(db.get_by_digest(&digest).unwrap().throughput, 3.0);
        assert_eq!(db.latest(&EvalQuery::model("n"))[0].throughput, 20.0);
        // Compacting an already-compact db is a no-op.
        let again = db.compact().unwrap();
        assert_eq!(again, CompactionStats { scanned: 2, retained: 2, dropped: 0 });
    }

    #[test]
    fn run_meta_roundtrips_exactly_and_legacy_parses_empty() {
        let mut r = EvalRecord::new(key("m", "s", 1), vec![0.004, 0.005], 500.0);
        r.run_meta = RunMeta {
            label: "treatment".into(),
            commit: "abc123".into(),
            timestamp: "2026-08-08T00:00:00Z".into(),
        };
        let j = r.to_json();
        let back = EvalRecord::from_json(&j).unwrap();
        assert_eq!(back.run_meta, r.run_meta, "metadata-bearing record round-trips");
        // And the serialized forms are byte-identical (exact round-trip).
        assert_eq!(back.to_json().to_string(), j.to_string());

        // Legacy records (no run_meta field at all) parse with empty
        // metadata, and empty metadata is omitted on write — so a legacy
        // line replays byte-identically too.
        let legacy = EvalRecord::new(key("m", "s", 1), vec![0.004], 1.0);
        assert!(!legacy.to_json().to_string().contains("run_meta"));
        let back = EvalRecord::from_json(&legacy.to_json()).unwrap();
        assert!(back.run_meta.is_empty());

        // Explicit null is treated as absent.
        if let Json::Obj(mut m) = legacy.to_json() {
            m.insert("run_meta".into(), Json::Null);
            let back = EvalRecord::from_json(&Json::Obj(m)).unwrap();
            assert!(back.run_meta.is_empty());
        }
    }

    #[test]
    fn malformed_run_meta_shapes_reject_the_record() {
        let base = EvalRecord::new(key("m", "s", 1), vec![0.01], 100.0);
        let with_run_meta = |v: Json| -> Option<EvalRecord> {
            if let Json::Obj(mut m) = base.to_json() {
                m.insert("run_meta".into(), v);
                EvalRecord::from_json(&Json::Obj(m))
            } else {
                unreachable!()
            }
        };
        // Control: a proper object parses.
        let ok = with_run_meta(Json::obj(vec![("label", Json::str("v1"))])).unwrap();
        assert_eq!(ok.run_meta.label, "v1");
        assert_eq!(ok.run_meta.commit, "");
        // A bare string, number, or array is not a RunMeta.
        assert!(with_run_meta(Json::str("v1")).is_none(), "string run_meta");
        assert!(with_run_meta(Json::num(7.0)).is_none(), "numeric run_meta");
        assert!(with_run_meta(Json::arr(vec![])).is_none(), "array run_meta");
        // Present fields with wrong types reject.
        assert!(
            with_run_meta(Json::obj(vec![("label", Json::num(3.0))])).is_none(),
            "numeric label"
        );
        assert!(
            with_run_meta(Json::obj(vec![
                ("label", Json::str("v1")),
                ("commit", Json::Bool(true)),
            ]))
            .is_none(),
            "bool commit"
        );
        assert!(
            with_run_meta(Json::obj(vec![
                ("label", Json::str("v1")),
                ("timestamp", Json::Null),
            ]))
            .is_none(),
            "null timestamp"
        );
    }

    #[test]
    fn strict_record_parse_rejects_malformed_fields() {
        let base = EvalRecord::new(key("m", "s", 1), vec![0.01, 0.02], 100.0);
        let mutate = |f: &str, v: Json| -> Option<EvalRecord> {
            if let Json::Obj(mut m) = base.to_json() {
                m.insert(f.into(), v);
                EvalRecord::from_json(&Json::Obj(m))
            } else {
                unreachable!()
            }
        };
        assert!(EvalRecord::from_json(&base.to_json()).is_some(), "control parses");
        // A non-numeric latency entry used to be silently dropped, which
        // changed the sample count; now it rejects the record.
        assert!(
            mutate("latencies", Json::arr(vec![Json::num(0.01), Json::str("x")])).is_none(),
            "string latency entry"
        );
        assert!(mutate("latencies", Json::str("fast")).is_none(), "non-array latencies");
        assert!(mutate("seq", Json::str("9")).is_none(), "string seq");
        assert!(mutate("seq", Json::num(-1.0)).is_none(), "negative seq");
        assert!(mutate("seq", Json::num(1.5)).is_none(), "fractional seq");
        assert!(mutate("throughput", Json::str("slow")).is_none(), "string throughput");
        assert!(mutate("trace_id", Json::str("7")).is_none(), "string trace_id");
        assert!(mutate("trace_id", Json::num(-7.0)).is_none(), "negative trace_id");
        assert!(mutate("spec_digest", Json::num(1.0)).is_none(), "numeric spec_digest");
        // Missing optionals keep their legacy defaults.
        if let Json::Obj(mut m) = base.to_json() {
            m.remove("seq");
            m.remove("throughput");
            m.remove("trace_id");
            m.remove("spec_digest");
            let r = EvalRecord::from_json(&Json::Obj(m)).unwrap();
            assert_eq!(r.seq, 0);
            assert!(r.throughput.is_nan());
            assert_eq!(r.trace_id, None);
            assert_eq!(r.spec_digest, None);
        }
    }

    #[test]
    fn label_query_filters_run_lines() {
        let db = EvalDb::in_memory();
        let mut a = EvalRecord::new(key("m", "s", 1), vec![0.010], 100.0);
        a.run_meta = RunMeta::labeled("control");
        let mut b = EvalRecord::new(key("m", "s", 1), vec![0.015], 66.0);
        b.run_meta = RunMeta::labeled("treatment");
        let c = EvalRecord::new(key("m", "s", 1), vec![0.012], 83.0);
        db.put(a);
        db.put(b);
        db.put(c);
        assert_eq!(db.query(&EvalQuery::label("control")).len(), 1);
        assert_eq!(db.query(&EvalQuery::label("treatment")).len(), 1);
        // Empty label selects exactly the unlabeled record.
        assert_eq!(db.query(&EvalQuery::label("")).len(), 1);
        // No label filter sees everything.
        assert_eq!(db.query(&EvalQuery::model("m")).len(), 3);
        // Compound: label + model.
        let q = EvalQuery { label: Some("control".into()), ..EvalQuery::model("m") };
        assert_eq!(db.query(&q).len(), 1);
        assert_eq!(db.query(&q)[0].throughput, 100.0);
    }

    #[test]
    fn record_json_roundtrip_carries_spec_digest() {
        let mut r = EvalRecord::new(key("m", "s", 2), vec![0.004, 0.005], 500.0);
        r.spec_digest = Some("f".repeat(64));
        r.seq = 9;
        let back = EvalRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(back.spec_digest, r.spec_digest);
        assert_eq!(back.seq, 9);
        // Legacy records without the field parse with `None`.
        let mut legacy = EvalRecord::new(key("m", "s", 2), vec![0.004], 1.0);
        legacy.spec_digest = None;
        let back = EvalRecord::from_json(&legacy.to_json()).unwrap();
        assert_eq!(back.spec_digest, None);
    }
}
