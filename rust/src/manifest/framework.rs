//! The framework manifest (paper §4.1.2, Listing 2).

use super::{opt_str, req_str, ManifestError};
use crate::util::json::Json;
use crate::util::semver::Version;
use crate::util::yamlmini;
use std::collections::BTreeMap;

/// A parsed framework manifest: the software stack an evaluation runs on.
///
/// `containers` maps architecture (`amd64`, `ppc64le`, ...) → device class
/// (`cpu`/`gpu`) → container image. In the paper these are Docker images
/// guaranteeing SW-stack isolation; here they are recorded verbatim and
/// folded into the agent's software-stack fingerprint used during agent
/// resolution (container launch itself is environment-gated — see
/// DESIGN.md substitutions).
#[derive(Debug, Clone)]
pub struct FrameworkManifest {
    pub name: String,
    pub version: Version,
    pub description: String,
    pub containers: BTreeMap<String, BTreeMap<String, String>>,
}

impl FrameworkManifest {
    pub fn from_yaml(text: &str) -> Result<FrameworkManifest, ManifestError> {
        let doc = yamlmini::parse(text)?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<FrameworkManifest, ManifestError> {
        let name = req_str(doc, "name")?;
        let version: Version = req_str(doc, "version")?
            .parse()
            .map_err(|e: crate::util::semver::SemverError| ManifestError::field("version", e.to_string()))?;
        let mut containers = BTreeMap::new();
        if let Some(obj) = doc.get("containers").and_then(|v| v.as_obj()) {
            for (arch, devices) in obj {
                let mut per_device = BTreeMap::new();
                if let Some(dmap) = devices.as_obj() {
                    for (device, image) in dmap {
                        let image = image.as_str().ok_or_else(|| {
                            ManifestError::field(
                                &format!("containers.{arch}.{device}"),
                                "container image must be a string",
                            )
                        })?;
                        per_device.insert(device.clone(), image.to_string());
                    }
                }
                containers.insert(arch.clone(), per_device);
            }
        }
        Ok(FrameworkManifest {
            name,
            version,
            description: opt_str(doc, "description").unwrap_or_default(),
            containers,
        })
    }

    /// Stable registry key: `name:version` (F5).
    pub fn key(&self) -> String {
        format!("{}:{}", self.name, self.version)
    }

    /// Container image for an (architecture, device-class) pair.
    pub fn container(&self, arch: &str, device: &str) -> Option<&str> {
        self.containers.get(arch)?.get(device).map(|s| s.as_str())
    }

    pub fn to_json(&self) -> Json {
        let containers = Json::Obj(
            self.containers
                .iter()
                .map(|(arch, devices)| {
                    (
                        arch.clone(),
                        Json::Obj(
                            devices
                                .iter()
                                .map(|(d, img)| (d.clone(), Json::str(img)))
                                .collect(),
                        ),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("version", Json::str(self.version.to_string())),
            ("description", Json::str(&self.description)),
            ("containers", containers),
        ])
    }
}

/// The paper's Listing 2 framework manifest, kept as test vector + example.
pub const LISTING2_EXAMPLE: &str = r#"
name: TensorFlow # framework name
version: 1.15.0 # semantic version of the framework
description: TensorFlow framework manifest
containers: # containers
  amd64:
    cpu: carml/tensorflow:1-15-0_amd64-cpu
    gpu: carml/tensorflow:1-15-0_amd64-gpu
  ppc64le:
    cpu: carml/tensorflow:1-15-0_ppc64le-cpu
    gpu: carml/tensorflow:1-15-0_ppc64le-gpu
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_listing2() {
        let f = FrameworkManifest::from_yaml(LISTING2_EXAMPLE).unwrap();
        assert_eq!(f.key(), "TensorFlow:1.15.0");
        assert_eq!(f.containers.len(), 2);
        assert_eq!(f.container("amd64", "cpu"), Some("carml/tensorflow:1-15-0_amd64-cpu"));
        assert_eq!(f.container("riscv", "cpu"), None);
    }

    #[test]
    fn no_containers_ok() {
        // FPGA-style agents don't use containers (§4.1.2).
        let f = FrameworkManifest::from_yaml("name: FpgaRuntime\nversion: 0.1.0\n").unwrap();
        assert!(f.containers.is_empty());
    }

    #[test]
    fn bad_version_rejected() {
        assert!(FrameworkManifest::from_yaml("name: X\nversion: not-a-version\n").is_err());
    }
}
