//! The model manifest (paper §4.1.1, Listing 1).

use super::{opt_str, req_str, ManifestError};
use crate::util::json::Json;
use crate::util::semver::{Constraint, Version};
use crate::util::yamlmini;

/// A parsed, validated model manifest.
///
/// Field-for-field this mirrors Listing 1: identity + semantic version,
/// framework constraint, typed inputs with pre-processing pipelines, typed
/// outputs with post-processing pipelines, optional custom processing code,
/// model assets with checksum, and free-form attributes.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub version: Version,
    pub description: String,
    pub framework_name: String,
    pub framework_constraint: Constraint,
    pub inputs: Vec<ModelInput>,
    pub outputs: Vec<ModelOutput>,
    /// Custom pre-processing code (Listing 1 line 29). In the paper this is
    /// Python run in a sub-interpreter; here the built-in step pipeline is
    /// the supported path and custom code is carried as opaque text for
    /// forward compatibility (mutually exclusive with `inputs[].steps`).
    pub preprocess_code: Option<String>,
    pub postprocess_code: Option<String>,
    pub assets: ModelAssets,
    /// `attributes:` metadata (training dataset, published accuracy, ...).
    pub attributes: Json,
}

/// One input modality + its pre-processing pipeline.
#[derive(Debug, Clone)]
pub struct ModelInput {
    pub ty: String,
    pub layer_name: String,
    pub element_type: String,
    pub steps: Vec<PreprocessStep>,
}

/// One output modality + its post-processing pipeline.
#[derive(Debug, Clone)]
pub struct ModelOutput {
    pub ty: String,
    pub layer_name: String,
    pub element_type: String,
    pub steps: Vec<PostprocessStep>,
}

/// Built-in pre-processing pipeline operators (§4.1.1 "Built-in Pre- and
/// Post-Processing"). Executed in manifest order by the pipeline executor.
#[derive(Debug, Clone, PartialEq)]
pub enum PreprocessStep {
    Decode { data_layout: String, color_mode: String },
    Resize { dimensions: [usize; 3], method: String, keep_aspect_ratio: bool },
    Normalize { mean: [f64; 3], rescale: f64 },
    CenterCrop { height: usize, width: usize },
    CastTo { element_type: String },
}

/// Built-in post-processing operators.
#[derive(Debug, Clone, PartialEq)]
pub enum PostprocessStep {
    /// Sort class probabilities descending; `labels_url` names the synset.
    Argsort { labels_url: String },
    TopK { k: usize },
    Softmax,
    /// Detection-style intersection-over-union filter.
    Iou { threshold: f64 },
}

/// Model asset locations (graph/weights) + integrity checksum.
#[derive(Debug, Clone, Default)]
pub struct ModelAssets {
    pub base_url: String,
    pub graph_path: String,
    /// Omitted for frameworks that deploy a single file (§4.1.1).
    pub weights_path: Option<String>,
    pub checksum: Option<String>,
}

impl ModelManifest {
    pub fn from_yaml(text: &str) -> Result<ModelManifest, ManifestError> {
        let doc = yamlmini::parse(text)?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<ModelManifest, ManifestError> {
        let name = req_str(doc, "name")?;
        let version: Version = req_str(doc, "version")?
            .parse()
            .map_err(|e: crate::util::semver::SemverError| ManifestError::field("version", e.to_string()))?;
        let framework_name = req_str(doc, "framework.name")?;
        let framework_constraint: Constraint = opt_str(doc, "framework.version")
            .unwrap_or_default()
            .parse()
            .map_err(|e: crate::util::semver::SemverError| {
                ManifestError::field("framework.version", e.to_string())
            })?;

        let mut inputs = Vec::new();
        if let Some(arr) = doc.get("inputs").and_then(|v| v.as_arr()) {
            for (i, inp) in arr.iter().enumerate() {
                inputs.push(parse_input(inp, i)?);
            }
        }
        let mut outputs = Vec::new();
        if let Some(arr) = doc.get("outputs").and_then(|v| v.as_arr()) {
            for (i, out) in arr.iter().enumerate() {
                outputs.push(parse_output(out, i)?);
            }
        }
        if inputs.is_empty() {
            return Err(ManifestError::field("inputs", "at least one input required"));
        }
        if outputs.is_empty() {
            return Err(ManifestError::field("outputs", "at least one output required"));
        }

        let preprocess_code = opt_str(doc, "preprocess");
        let postprocess_code = opt_str(doc, "postprocess");
        // §4.1.1: built-in steps and custom functions are mutually exclusive.
        if preprocess_code.is_some() && inputs.iter().any(|i| !i.steps.is_empty()) {
            return Err(ManifestError::field(
                "preprocess",
                "custom preprocess code and built-in steps are mutually exclusive",
            ));
        }

        let assets = ModelAssets {
            base_url: opt_str(doc, "model.base_url").unwrap_or_default(),
            graph_path: req_str(doc, "model.graph_path")?,
            weights_path: opt_str(doc, "model.weights_path"),
            checksum: opt_str(doc, "model.checksum"),
        };

        let attributes = doc.get("attributes").cloned().unwrap_or(Json::Null);

        Ok(ModelManifest {
            name,
            version,
            description: opt_str(doc, "description").unwrap_or_default(),
            framework_name,
            framework_constraint,
            inputs,
            outputs,
            preprocess_code,
            postprocess_code,
            assets,
            attributes,
        })
    }

    /// Stable registry key: `name:version` (F5 artifact versioning).
    pub fn key(&self) -> String {
        format!("{}:{}", self.name, self.version)
    }

    /// Published accuracy if carried in `attributes` (Table 2 column).
    pub fn accuracy(&self) -> Option<f64> {
        self.attributes.get("top1_accuracy").and_then(|v| v.as_f64())
    }

    /// Graph size in MB if carried in `attributes` (Table 2 column).
    pub fn graph_size_mb(&self) -> Option<f64> {
        self.attributes.get("graph_size_mb").and_then(|v| v.as_f64())
    }

    pub fn to_json(&self) -> Json {
        let input_json = |inp: &ModelInput| {
            Json::obj(vec![
                ("type", Json::str(&inp.ty)),
                ("layer_name", Json::str(&inp.layer_name)),
                ("element_type", Json::str(&inp.element_type)),
                ("steps", Json::arr(inp.steps.iter().map(pre_step_json).collect())),
            ])
        };
        let output_json = |out: &ModelOutput| {
            Json::obj(vec![
                ("type", Json::str(&out.ty)),
                ("layer_name", Json::str(&out.layer_name)),
                ("element_type", Json::str(&out.element_type)),
                ("steps", Json::arr(out.steps.iter().map(post_step_json).collect())),
            ])
        };
        let mut model = vec![
            ("base_url", Json::str(&self.assets.base_url)),
            ("graph_path", Json::str(&self.assets.graph_path)),
        ];
        if let Some(w) = &self.assets.weights_path {
            model.push(("weights_path", Json::str(w)));
        }
        if let Some(c) = &self.assets.checksum {
            model.push(("checksum", Json::str(c)));
        }
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("version", Json::str(self.version.to_string())),
            ("description", Json::str(&self.description)),
            (
                "framework",
                Json::obj(vec![
                    ("name", Json::str(&self.framework_name)),
                    ("version", Json::str(self.framework_constraint.source())),
                ]),
            ),
            ("inputs", Json::arr(self.inputs.iter().map(input_json).collect())),
            ("outputs", Json::arr(self.outputs.iter().map(output_json).collect())),
            ("model", Json::obj(model)),
            ("attributes", self.attributes.clone()),
        ];
        if let Some(p) = &self.preprocess_code {
            fields.push(("preprocess", Json::str(p)));
        }
        if let Some(p) = &self.postprocess_code {
            fields.push(("postprocess", Json::str(p)));
        }
        Json::obj(fields)
    }
}

fn parse_input(inp: &Json, idx: usize) -> Result<ModelInput, ManifestError> {
    let field = format!("inputs[{idx}]");
    let ty = inp
        .get("type")
        .and_then(|v| v.as_str())
        .ok_or_else(|| ManifestError::field(&field, "missing type"))?;
    let mut steps = Vec::new();
    if let Some(arr) = inp.get("steps").and_then(|v| v.as_arr()) {
        for s in arr {
            steps.push(parse_pre_step(s, &field)?);
        }
    }
    Ok(ModelInput {
        ty: ty.to_string(),
        layer_name: inp.str_or("layer_name", "input").to_string(),
        element_type: inp.str_or("element_type", "float32").to_string(),
        steps,
    })
}

fn parse_output(out: &Json, idx: usize) -> Result<ModelOutput, ManifestError> {
    let field = format!("outputs[{idx}]");
    let ty = out
        .get("type")
        .and_then(|v| v.as_str())
        .ok_or_else(|| ManifestError::field(&field, "missing type"))?;
    let mut steps = Vec::new();
    if let Some(arr) = out.get("steps").and_then(|v| v.as_arr()) {
        for s in arr {
            steps.push(parse_post_step(s, &field)?);
        }
    }
    Ok(ModelOutput {
        ty: ty.to_string(),
        layer_name: out.str_or("layer_name", "output").to_string(),
        element_type: out.str_or("element_type", "float32").to_string(),
        steps,
    })
}

fn triple_f64(v: &Json) -> Option<[f64; 3]> {
    let a = v.as_arr()?;
    if a.len() != 3 {
        return None;
    }
    Some([a[0].as_f64()?, a[1].as_f64()?, a[2].as_f64()?])
}

fn parse_pre_step(step: &Json, ctx: &str) -> Result<PreprocessStep, ManifestError> {
    let obj = step
        .as_obj()
        .filter(|m| m.len() == 1)
        .ok_or_else(|| ManifestError::field(ctx, "step must be a single-key mapping"))?;
    let (op, body) = obj.iter().next().unwrap();
    match op.as_str() {
        "decode" => Ok(PreprocessStep::Decode {
            data_layout: body.str_or("data_layout", "NHWC").to_string(),
            color_mode: body.str_or("color_mode", "RGB").to_string(),
        }),
        "resize" => {
            let dims = body
                .get("dimensions")
                .and_then(triple_f64)
                .ok_or_else(|| ManifestError::field(ctx, "resize.dimensions must be [c,h,w]"))?;
            Ok(PreprocessStep::Resize {
                dimensions: [dims[0] as usize, dims[1] as usize, dims[2] as usize],
                method: body.str_or("method", "bilinear").to_string(),
                keep_aspect_ratio: body
                    .get("keep_aspect_ratio")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false),
            })
        }
        "normalize" => {
            let mean = body
                .get("mean")
                .and_then(triple_f64)
                .ok_or_else(|| ManifestError::field(ctx, "normalize.mean must be [r,g,b]"))?;
            Ok(PreprocessStep::Normalize { mean, rescale: body.f64_or("rescale", 1.0) })
        }
        "center_crop" => Ok(PreprocessStep::CenterCrop {
            height: body.f64_or("height", 224.0) as usize,
            width: body.f64_or("width", 224.0) as usize,
        }),
        "cast" => Ok(PreprocessStep::CastTo {
            element_type: body.str_or("element_type", "float32").to_string(),
        }),
        other => Err(ManifestError::field(ctx, format!("unknown preprocess op {other:?}"))),
    }
}

fn parse_post_step(step: &Json, ctx: &str) -> Result<PostprocessStep, ManifestError> {
    let obj = step
        .as_obj()
        .filter(|m| m.len() == 1)
        .ok_or_else(|| ManifestError::field(ctx, "step must be a single-key mapping"))?;
    let (op, body) = obj.iter().next().unwrap();
    match op.as_str() {
        "argsort" => Ok(PostprocessStep::Argsort {
            labels_url: body.str_or("labels_url", "").to_string(),
        }),
        "top_k" => Ok(PostprocessStep::TopK { k: body.f64_or("k", 5.0) as usize }),
        "softmax" => Ok(PostprocessStep::Softmax),
        "iou" => Ok(PostprocessStep::Iou { threshold: body.f64_or("threshold", 0.5) }),
        other => Err(ManifestError::field(ctx, format!("unknown postprocess op {other:?}"))),
    }
}

fn pre_step_json(s: &PreprocessStep) -> Json {
    match s {
        PreprocessStep::Decode { data_layout, color_mode } => Json::obj(vec![(
            "decode",
            Json::obj(vec![
                ("data_layout", Json::str(data_layout)),
                ("color_mode", Json::str(color_mode)),
            ]),
        )]),
        PreprocessStep::Resize { dimensions, method, keep_aspect_ratio } => Json::obj(vec![(
            "resize",
            Json::obj(vec![
                (
                    "dimensions",
                    Json::arr(dimensions.iter().map(|d| Json::num(*d as f64)).collect()),
                ),
                ("method", Json::str(method)),
                ("keep_aspect_ratio", Json::Bool(*keep_aspect_ratio)),
            ]),
        )]),
        PreprocessStep::Normalize { mean, rescale } => Json::obj(vec![(
            "normalize",
            Json::obj(vec![
                ("mean", Json::arr(mean.iter().map(|m| Json::num(*m)).collect())),
                ("rescale", Json::num(*rescale)),
            ]),
        )]),
        PreprocessStep::CenterCrop { height, width } => Json::obj(vec![(
            "center_crop",
            Json::obj(vec![
                ("height", Json::num(*height as f64)),
                ("width", Json::num(*width as f64)),
            ]),
        )]),
        PreprocessStep::CastTo { element_type } => Json::obj(vec![(
            "cast",
            Json::obj(vec![("element_type", Json::str(element_type))]),
        )]),
    }
}

fn post_step_json(s: &PostprocessStep) -> Json {
    match s {
        PostprocessStep::Argsort { labels_url } => Json::obj(vec![(
            "argsort",
            Json::obj(vec![("labels_url", Json::str(labels_url))]),
        )]),
        PostprocessStep::TopK { k } => {
            Json::obj(vec![("top_k", Json::obj(vec![("k", Json::num(*k as f64))]))])
        }
        PostprocessStep::Softmax => Json::obj(vec![("softmax", Json::obj(vec![]))]),
        PostprocessStep::Iou { threshold } => {
            Json::obj(vec![("iou", Json::obj(vec![("threshold", Json::num(*threshold))]))])
        }
    }
}

/// The paper's Listing 1 manifest, kept verbatim-equivalent as a test
/// vector and documentation example.
pub const LISTING1_EXAMPLE: &str = r#"
name: MLPerf_ResNet50_v1.5 # model name
version: 1.0.0 # semantic version of the model
description: MLPerf ResNet50 v1.5 image classification model
framework: # framework information
  name: TensorFlow
  version: '>=1.12.0 <2.0' # framework ver constraint
inputs: # model inputs
  - type: image # first input modality
    layer_name: 'input_tensor'
    element_type: float32
    steps: # pre-processing steps
      - decode:
          data_layout: NHWC
          color_mode: RGB
      - resize:
          dimensions: [3, 224, 224]
          method: bilinear
          keep_aspect_ratio: true
      - normalize:
          mean: [123.68, 116.78, 103.94]
          rescale: 1.0
outputs: # model outputs
  - type: probability # first output modality
    layer_name: prob
    element_type: float32
    steps: # post-processing steps
      - argsort:
          labels_url: https://mlmodelscope.example/synset.txt
model: # model sources
  base_url: https://zenodo.org/record/2535873/files/
  graph_path: resnet50_v1.pb
  checksum: 7b94a2da05d23a46bc08886
attributes: # extra model attributes
  training_dataset: ImageNet
  top1_accuracy: 76.46
  graph_size_mb: 103
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_listing1() {
        let m = ModelManifest::from_yaml(LISTING1_EXAMPLE).unwrap();
        assert_eq!(m.inputs[0].layer_name, "input_tensor");
        assert_eq!(
            m.inputs[0].steps[1],
            PreprocessStep::Resize {
                dimensions: [3, 224, 224],
                method: "bilinear".into(),
                keep_aspect_ratio: true
            }
        );
        assert_eq!(m.assets.checksum.as_deref(), Some("7b94a2da05d23a46bc08886"));
        assert_eq!(m.accuracy(), Some(76.46));
        assert_eq!(m.graph_size_mb(), Some(103.0));
        assert_eq!(m.key(), "MLPerf_ResNet50_v1.5:1.0.0");
    }

    #[test]
    fn missing_required_fields() {
        assert!(ModelManifest::from_yaml("name: x\n").is_err());
        let no_inputs = r#"
name: x
version: 1.0.0
framework:
  name: TF
outputs:
  - type: probability
model:
  graph_path: g.pb
"#;
        let err = ModelManifest::from_yaml(no_inputs).unwrap_err().to_string();
        assert!(err.contains("inputs"), "{err}");
    }

    #[test]
    fn unknown_op_rejected() {
        let y = r#"
name: x
version: 1.0.0
framework:
  name: TF
inputs:
  - type: image
    steps:
      - frobnicate:
          a: 1
outputs:
  - type: probability
model:
  graph_path: g.pb
"#;
        let err = ModelManifest::from_yaml(y).unwrap_err().to_string();
        assert!(err.contains("frobnicate"), "{err}");
    }

    #[test]
    fn custom_code_exclusive_with_steps() {
        let y = r#"
name: x
version: 1.0.0
framework:
  name: TF
preprocess: |
  def fun(env, data):
      return data
inputs:
  - type: image
    steps:
      - decode:
          data_layout: NHWC
outputs:
  - type: probability
model:
  graph_path: g.pb
"#;
        let err = ModelManifest::from_yaml(y).unwrap_err().to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn custom_code_alone_ok() {
        let y = r#"
name: x
version: 1.0.0
framework:
  name: TF
preprocess: |
  def fun(env, data):
      return data
inputs:
  - type: image
outputs:
  - type: probability
model:
  graph_path: g.pb
"#;
        let m = ModelManifest::from_yaml(y).unwrap();
        assert!(m.preprocess_code.unwrap().contains("def fun"));
    }

    #[test]
    fn no_framework_constraint_means_any() {
        let y = r#"
name: onnx_model
version: 1.0.0
framework:
  name: ONNX
inputs:
  - type: image
outputs:
  - type: probability
model:
  graph_path: m.onnx
"#;
        let m = ModelManifest::from_yaml(y).unwrap();
        assert!(m.framework_constraint.is_any());
        assert!(m.framework_constraint.matches_str("0.1.0"));
    }
}
