//! User-specified system requirements (§4.1, §4.7).
//!
//! "During the evaluation, users can specify hardware constraints such as:
//! whether to run on CPU/GPU/FPGA, type of architecture, type of
//! interconnect, and minimum memory requirements — which MLModelScope uses
//! for agent resolution."

use crate::util::json::Json;

/// Accelerator class requested for the evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Accelerator {
    Cpu,
    Gpu,
    Fpga,
    /// Don't care — any device class the agent offers.
    Any,
}

impl Accelerator {
    pub fn parse(s: &str) -> Accelerator {
        match s.to_ascii_lowercase().as_str() {
            "cpu" => Accelerator::Cpu,
            "gpu" => Accelerator::Gpu,
            "fpga" => Accelerator::Fpga,
            _ => Accelerator::Any,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Accelerator::Cpu => "cpu",
            Accelerator::Gpu => "gpu",
            Accelerator::Fpga => "fpga",
            Accelerator::Any => "any",
        }
    }
}

/// Hardware constraints the server matches against registered agents
/// during agent resolution (§4.3 step 3).
#[derive(Debug, Clone)]
pub struct SystemRequirements {
    pub accelerator: Accelerator,
    /// CPU architecture constraint, e.g. `x86_64`, `ppc64le`, `aarch64`.
    pub architecture: Option<String>,
    /// Interconnect requirement, e.g. `nvlink`, `pcie3`.
    pub interconnect: Option<String>,
    /// Minimum host memory in GB.
    pub min_memory_gb: Option<f64>,
    /// Minimum accelerator memory in GB.
    pub min_device_memory_gb: Option<f64>,
    /// Exact system name pin (e.g. `aws_p3`), used by benches to target one
    /// of the Table-1 systems deterministically.
    pub system_name: Option<String>,
}

impl Default for SystemRequirements {
    fn default() -> Self {
        SystemRequirements {
            accelerator: Accelerator::Any,
            architecture: None,
            interconnect: None,
            min_memory_gb: None,
            min_device_memory_gb: None,
            system_name: None,
        }
    }
}

impl SystemRequirements {
    pub fn any() -> Self {
        Self::default()
    }

    pub fn on_system(name: &str) -> Self {
        SystemRequirements { system_name: Some(name.to_string()), ..Self::default() }
    }

    pub fn gpu() -> Self {
        SystemRequirements { accelerator: Accelerator::Gpu, ..Self::default() }
    }

    pub fn cpu() -> Self {
        SystemRequirements { accelerator: Accelerator::Cpu, ..Self::default() }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![("accelerator", Json::str(self.accelerator.as_str()))];
        if let Some(a) = &self.architecture {
            fields.push(("architecture", Json::str(a)));
        }
        if let Some(i) = &self.interconnect {
            fields.push(("interconnect", Json::str(i)));
        }
        if let Some(m) = self.min_memory_gb {
            fields.push(("min_memory_gb", Json::num(m)));
        }
        if let Some(m) = self.min_device_memory_gb {
            fields.push(("min_device_memory_gb", Json::num(m)));
        }
        if let Some(s) = &self.system_name {
            fields.push(("system_name", Json::str(s)));
        }
        Json::obj(fields)
    }

    pub fn from_json(doc: &Json) -> SystemRequirements {
        SystemRequirements {
            accelerator: Accelerator::parse(doc.str_or("accelerator", "any")),
            architecture: doc.get("architecture").and_then(|v| v.as_str()).map(String::from),
            interconnect: doc.get("interconnect").and_then(|v| v.as_str()).map(String::from),
            min_memory_gb: doc.get("min_memory_gb").and_then(|v| v.as_f64()),
            min_device_memory_gb: doc.get("min_device_memory_gb").and_then(|v| v.as_f64()),
            system_name: doc.get("system_name").and_then(|v| v.as_str()).map(String::from),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accelerator_parse_roundtrip() {
        for a in [Accelerator::Cpu, Accelerator::Gpu, Accelerator::Fpga, Accelerator::Any] {
            assert_eq!(Accelerator::parse(a.as_str()), a);
        }
        assert_eq!(Accelerator::parse("GPU"), Accelerator::Gpu);
        assert_eq!(Accelerator::parse("tpu"), Accelerator::Any);
    }

    #[test]
    fn json_roundtrip() {
        let req = SystemRequirements {
            accelerator: Accelerator::Gpu,
            architecture: Some("ppc64le".into()),
            interconnect: Some("nvlink".into()),
            min_memory_gb: Some(32.0),
            min_device_memory_gb: Some(16.0),
            system_name: Some("ibm_p8".into()),
        };
        let j = req.to_json();
        let back = SystemRequirements::from_json(&j);
        assert_eq!(back.accelerator, Accelerator::Gpu);
        assert_eq!(back.architecture.as_deref(), Some("ppc64le"));
        assert_eq!(back.min_memory_gb, Some(32.0));
        assert_eq!(back.system_name.as_deref(), Some("ibm_p8"));
    }

    #[test]
    fn default_is_unconstrained() {
        let req = SystemRequirements::any();
        assert_eq!(req.accelerator, Accelerator::Any);
        assert!(req.architecture.is_none());
    }
}
