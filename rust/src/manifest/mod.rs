//! Benchmarking specification: model and framework manifests (§4.1).
//!
//! The paper's central reproducibility mechanism (F1/F2) is that *all*
//! aspects of an evaluation are specified declaratively: the model manifest
//! (Listing 1: assets, pre/post-processing, framework constraints,
//! metadata) and the framework manifest (Listing 2: software stack +
//! containers). This module defines those data types and their YAML
//! parsing/validation, plus the user's system requirements and the JSON
//! round-trip used when manifests travel over the wire or into the
//! evaluation database.

mod framework;
mod model;
mod system;

pub use framework::FrameworkManifest;
pub use model::{
    ModelAssets, ModelInput, ModelManifest, ModelOutput, PostprocessStep, PreprocessStep,
};
pub use system::{Accelerator, SystemRequirements};

use crate::util::json::Json;

/// The paper's Listing-1 example manifest (test vector + documentation).
pub fn model_listing1() -> &'static str {
    model::LISTING1_EXAMPLE
}

/// The paper's Listing-2 example framework manifest.
pub fn framework_listing2() -> &'static str {
    framework::LISTING2_EXAMPLE
}

/// Shared manifest error type.
#[derive(Debug)]
pub enum ManifestError {
    Yaml(crate::util::yamlmini::YamlError),
    Semver(crate::util::semver::SemverError),
    Field { field: String, msg: String },
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Yaml(e) => write!(f, "yaml: {e}"),
            ManifestError::Semver(e) => write!(f, "semver: {e}"),
            ManifestError::Field { field, msg } => {
                write!(f, "manifest field {field:?}: {msg}")
            }
        }
    }
}

impl std::error::Error for ManifestError {}

impl From<crate::util::yamlmini::YamlError> for ManifestError {
    fn from(e: crate::util::yamlmini::YamlError) -> Self {
        ManifestError::Yaml(e)
    }
}

impl From<crate::util::semver::SemverError> for ManifestError {
    fn from(e: crate::util::semver::SemverError) -> Self {
        ManifestError::Semver(e)
    }
}

impl ManifestError {
    pub fn field(field: &str, msg: impl Into<String>) -> Self {
        ManifestError::Field { field: field.to_string(), msg: msg.into() }
    }
}

pub(crate) fn req_str(doc: &Json, field: &str) -> Result<String, ManifestError> {
    doc.get_path(field)
        .and_then(|v| v.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| ManifestError::field(field, "missing or not a string"))
}

pub(crate) fn opt_str(doc: &Json, field: &str) -> Option<String> {
    doc.get_path(field).and_then(|v| v.as_str()).map(|s| s.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The full Listing-1 manifest parses end-to-end.
    #[test]
    fn listing1_roundtrip() {
        let m = ModelManifest::from_yaml(model::LISTING1_EXAMPLE).unwrap();
        assert_eq!(m.name, "MLPerf_ResNet50_v1.5");
        assert_eq!(m.version.to_string(), "1.0.0");
        assert_eq!(m.framework_name, "TensorFlow");
        assert!(m.framework_constraint.matches_str("1.15.0"));
        assert!(!m.framework_constraint.matches_str("2.0.0"));
        assert_eq!(m.inputs.len(), 1);
        assert_eq!(m.inputs[0].steps.len(), 3);
        assert_eq!(m.outputs.len(), 1);
        // JSON round-trip preserves identity.
        let j = m.to_json();
        let m2 = ModelManifest::from_json(&j).unwrap();
        assert_eq!(m2.name, m.name);
        assert_eq!(m2.inputs[0].steps.len(), 3);
    }

    #[test]
    fn listing2_roundtrip() {
        let f = FrameworkManifest::from_yaml(framework::LISTING2_EXAMPLE).unwrap();
        assert_eq!(f.name, "TensorFlow");
        assert_eq!(f.version.to_string(), "1.15.0");
        assert_eq!(
            f.container("amd64", "gpu"),
            Some("carml/tensorflow:1-15-0_amd64-gpu")
        );
        let j = f.to_json();
        let f2 = FrameworkManifest::from_json(&j).unwrap();
        assert_eq!(f2.container("ppc64le", "cpu"), f.container("ppc64le", "cpu"));
    }
}
