//! The data manager (§4.4.1): downloads and caches evaluation assets
//! (models, datasets) on demand, validating checksums.
//!
//! Offline substitution: "remote" assets materialize from builtin
//! generators (`builtin://` URLs — zoo datasets are synthesized
//! deterministically), while `file://` and bare paths read the local
//! filesystem, exactly the three asset locations the paper lists (artifact
//! repository / web / local file system). Checksums use SHA-256; a cached
//! asset is re-validated before reuse, as in the paper.

// Cache files publish via `write_atomic`: concurrent materializations of
// the same asset (e.g. a sweep evaluating one model on several systems at
// once) produce identical deterministic bytes, so last-rename-wins is safe
// and no reader can ever observe a half-written file.
use crate::util::fs::write_atomic;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum DataError {
    Io(std::io::Error),
    BadUrl(String),
    Checksum { path: String, expected: String, got: String },
}

impl std::fmt::Display for DataError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DataError::Io(e) => write!(f, "io: {e}"),
            DataError::BadUrl(u) => write!(f, "unsupported asset url {u:?}"),
            DataError::Checksum { path, expected, got } => {
                write!(f, "checksum mismatch for {path}: expected {expected}, got {got}")
            }
        }
    }
}

impl std::error::Error for DataError {}

impl From<std::io::Error> for DataError {
    fn from(e: std::io::Error) -> Self {
        DataError::Io(e)
    }
}

/// Hex SHA-256 of a byte slice.
pub fn sha256_hex(bytes: &[u8]) -> String {
    crate::util::sha256::sha256_hex(bytes)
}


/// Asset cache rooted at a directory.
pub struct DataManager {
    cache_dir: PathBuf,
}

impl DataManager {
    pub fn new(cache_dir: impl Into<PathBuf>) -> DataManager {
        DataManager { cache_dir: cache_dir.into() }
    }

    /// Default cache under the target dir (kept out of the source tree).
    pub fn default_cache() -> DataManager {
        DataManager::new(
            std::env::var("MLMS_CACHE")
                .map(PathBuf::from)
                .unwrap_or_else(|_| std::env::temp_dir().join("mlms_cache")),
        )
    }

    pub fn cache_dir(&self) -> &Path {
        &self.cache_dir
    }

    /// Fetch an asset by URL + relative path, returning the local path.
    /// Downloads (materializes) on miss; validates `checksum` when given.
    pub fn fetch(
        &self,
        base_url: &str,
        rel_path: &str,
        checksum: Option<&str>,
    ) -> Result<PathBuf, DataError> {
        let local = self.cache_dir.join(sanitize(base_url)).join(rel_path);
        if !local.exists() {
            let bytes = self.materialize(base_url, rel_path)?;
            if let Some(dir) = local.parent() {
                std::fs::create_dir_all(dir)?;
            }
            write_atomic(&local, &bytes)?;
        }
        if let Some(expected) = checksum {
            // Zoo checksums (`zoo-<id>`) are identity markers, not hashes;
            // only hex-looking checksums are verified byte-wise.
            if expected.len() == 64 && expected.chars().all(|c| c.is_ascii_hexdigit()) {
                let got = sha256_hex(&std::fs::read(&local)?);
                if got != expected {
                    return Err(DataError::Checksum {
                        path: local.display().to_string(),
                        expected: expected.to_string(),
                        got,
                    });
                }
            }
        }
        Ok(local)
    }

    fn materialize(&self, base_url: &str, rel_path: &str) -> Result<Vec<u8>, DataError> {
        if let Some(rest) = base_url.strip_prefix("builtin://") {
            // Builtin generators: zoo model stubs and synthetic datasets.
            return Ok(builtin_asset(rest, rel_path));
        }
        if let Some(path) = base_url.strip_prefix("file://") {
            return Ok(std::fs::read(Path::new(path).join(rel_path))?);
        }
        if base_url.is_empty() || base_url.starts_with('/') || base_url.starts_with("./") {
            return Ok(std::fs::read(Path::new(base_url).join(rel_path))?);
        }
        // http(s) URLs are unreachable in the offline environment.
        Err(DataError::BadUrl(base_url.to_string()))
    }

    /// Synthesize (and cache) a dataset of `n` encoded images at `res`².
    /// Stand-in for TFRecord/RecordIO dataset files: one contiguous binary
    /// file, read back via offsets (same sequential-read profile).
    pub fn synthetic_dataset(&self, name: &str, n: usize, res: usize) -> Result<Vec<Vec<u8>>, DataError> {
        let path = self.cache_dir.join("datasets").join(format!("{name}_{n}x{res}.bin"));
        if !path.exists() {
            if let Some(dir) = path.parent() {
                std::fs::create_dir_all(dir)?;
            }
            let mut blob = Vec::new();
            for i in 0..n {
                let img = crate::preprocess::RawImage::synthetic(res, res, i as u64 + 1);
                let enc = img.encode();
                blob.extend_from_slice(&(enc.len() as u32).to_be_bytes());
                blob.extend_from_slice(&enc);
            }
            write_atomic(&path, &blob)?;
        }
        // Read back as records.
        let blob = std::fs::read(&path)?;
        let mut out = Vec::with_capacity(n);
        let mut off = 0usize;
        while off + 4 <= blob.len() {
            let len = u32::from_be_bytes(blob[off..off + 4].try_into().unwrap()) as usize;
            off += 4;
            out.push(blob[off..off + len].to_vec());
            off += len;
        }
        Ok(out)
    }
}

fn sanitize(url: &str) -> String {
    url.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
        .collect()
}

/// Builtin asset generator: deterministic bytes per (namespace, path).
fn builtin_asset(namespace: &str, rel_path: &str) -> Vec<u8> {
    let tag = format!("builtin asset {namespace}/{rel_path}");
    // A model "graph" stub: header + deterministic filler proportional to a
    // plausible graph size (capped so tests stay fast).
    let mut out = tag.clone().into_bytes();
    let mut rng = crate::util::rng::Xorshift::new(
        tag.bytes().fold(7u64, |acc, b| acc.wrapping_mul(31).wrapping_add(b as u64)),
    );
    for _ in 0..4096 {
        out.push(rng.below(256) as u8);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dm() -> DataManager {
        DataManager::new(
            std::env::temp_dir().join(format!("mlms_dm_{}_{}", std::process::id(), rand_tag())),
        )
    }

    fn rand_tag() -> u64 {
        use std::time::{SystemTime, UNIX_EPOCH};
        SystemTime::now().duration_since(UNIX_EPOCH).unwrap().subsec_nanos() as u64
    }

    #[test]
    fn builtin_fetch_and_cache() {
        let dm = dm();
        let p1 = dm.fetch("builtin://zoo/", "ResNet_v1_50.pb", None).unwrap();
        assert!(p1.exists());
        let bytes1 = std::fs::read(&p1).unwrap();
        // Second fetch hits the cache (same contents).
        let p2 = dm.fetch("builtin://zoo/", "ResNet_v1_50.pb", None).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(bytes1, std::fs::read(&p2).unwrap());
    }

    #[test]
    fn checksum_validation() {
        let dm = dm();
        let p = dm.fetch("builtin://zoo/", "m.pb", None).unwrap();
        let good = sha256_hex(&std::fs::read(&p).unwrap());
        // Correct checksum passes.
        dm.fetch("builtin://zoo/", "m.pb", Some(&good)).unwrap();
        // Wrong (hex) checksum fails.
        let bad = "0".repeat(64);
        assert!(matches!(
            dm.fetch("builtin://zoo/", "m.pb", Some(&bad)),
            Err(DataError::Checksum { .. })
        ));
        // Non-hex marker checksums (zoo-7) are identity tags, not verified.
        dm.fetch("builtin://zoo/", "m.pb", Some("zoo-7")).unwrap();
    }

    #[test]
    fn file_url_fetch() {
        let dir = std::env::temp_dir().join(format!("mlms_src_{}", rand_tag()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("weights.bin"), b"weights!").unwrap();
        let dm = dm();
        let p = dm
            .fetch(&format!("file://{}", dir.display()), "weights.bin", None)
            .unwrap();
        assert_eq!(std::fs::read(p).unwrap(), b"weights!");
    }

    #[test]
    fn http_url_rejected_offline() {
        let dm = dm();
        assert!(matches!(
            dm.fetch("https://zenodo.org/record/1/files/", "m.pb", None),
            Err(DataError::BadUrl(_))
        ));
    }

    #[test]
    fn synthetic_dataset_roundtrip() {
        let dm = dm();
        let records = dm.synthetic_dataset("imagenet_val", 10, 64).unwrap();
        assert_eq!(records.len(), 10);
        for rec in &records {
            let img = crate::preprocess::RawImage::decode(rec).unwrap();
            assert_eq!((img.height, img.width), (64, 64));
        }
        // Deterministic: same dataset on re-read.
        let again = dm.synthetic_dataset("imagenet_val", 10, 64).unwrap();
        assert_eq!(records[3], again[3]);
    }

    #[test]
    fn sha256_known_vector() {
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }
}
