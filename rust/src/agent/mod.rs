//! The MLModelScope agent (§4.4): a model-serving process running on a
//! system of interest.
//!
//! An agent self-registers its HW/SW stack + built-in models into the
//! registry (initialization workflow ①), then serves evaluation requests:
//! it ⑤ downloads the evaluation assets via the data manager, runs the
//! model-evaluation pipeline (pre-process → predict → post-process) under
//! the requested benchmarking scenario, ⑥ publishes trace events, and ⑦
//! stores the benchmarking result in the evaluation database.
//!
//! Aside from the predictor, all agent code is framework-agnostic — the
//! paper's "all code within an agent is common across frameworks".

pub mod data;

pub use data::{sha256_hex, DataManager};

use crate::evaldb::{EvalDb, EvalKey, EvalRecord};
use crate::manifest::ModelManifest;
use crate::predictor::{InputMode, PredictOptions, Predictor};
use crate::preprocess::Tensor;
use crate::registry::{AgentInfo, Registry};
use crate::scenario::{Scenario, Workload};
use crate::tracing::{TraceLevel, Tracer};
use crate::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

/// Agent configuration.
pub struct AgentConfig {
    /// System profile name advertised to the registry.
    pub system: String,
    pub architecture: String,
    pub devices: Vec<String>,
    pub interconnect: String,
    pub host_memory_gb: f64,
    pub device_memory_gb: f64,
    /// Models this agent serves (empty = any the predictor can load).
    pub models: Vec<String>,
    /// Registration TTL; heartbeats must arrive within it.
    pub ttl: Duration,
    /// Inputs are synthesized at this resolution when the manifest's
    /// pre-processing pipeline doesn't dictate one.
    pub input_resolution: usize,
    /// Wall-clock measurement (real predictors) vs simulated-clock
    /// measurement (simulator predictors, §4.4.4).
    pub simulated_time: bool,
}

impl Default for AgentConfig {
    fn default() -> Self {
        AgentConfig {
            system: "local".into(),
            architecture: std::env::consts::ARCH.to_string(),
            devices: vec!["cpu".into()],
            interconnect: "none".into(),
            host_memory_gb: 4.0,
            device_memory_gb: 0.0,
            models: Vec::new(),
            ttl: Duration::from_secs(30),
            input_resolution: 32,
            simulated_time: false,
        }
    }
}

/// One evaluation request, as dispatched by the server (④).
#[derive(Debug, Clone)]
pub struct EvalRequest {
    pub manifest: ModelManifest,
    pub scenario: Scenario,
    pub trace_level: TraceLevel,
    pub input_mode: InputMode,
    /// Workload seed (reproducible evaluation, F1).
    pub seed: u64,
    /// Run metadata stamped on the stored record; the label folds into the
    /// spec digest so labeled runs form their own memoization line.
    pub run_meta: crate::evaldb::RunMeta,
}

/// The result returned to the server (⑧).
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub record: EvalRecord,
    pub trace_id: u64,
}

/// The agent.
pub struct Agent {
    pub config: AgentConfig,
    predictor: Arc<dyn Predictor>,
    /// Concrete handle when the predictor is the simulator (needed to attach
    /// per-evaluation trace context; `dyn Predictor` has no downcast).
    sim: Option<Arc<crate::predictor::SimPredictor>>,
    data: DataManager,
    tracer: Arc<Tracer>,
    evaldb: Arc<EvalDb>,
    id: std::sync::Mutex<String>,
}

impl Agent {
    pub fn new(
        config: AgentConfig,
        predictor: Arc<dyn Predictor>,
        tracer: Arc<Tracer>,
        evaldb: Arc<EvalDb>,
    ) -> Arc<Agent> {
        Arc::new(Agent {
            config,
            predictor,
            sim: None,
            data: DataManager::default_cache(),
            tracer,
            evaldb,
            id: std::sync::Mutex::new(String::new()),
        })
    }

    /// As [`Agent::new`], keeping the concrete simulator handle for trace
    /// context attachment.
    pub fn new_sim(
        config: AgentConfig,
        sim: Arc<crate::predictor::SimPredictor>,
        tracer: Arc<Tracer>,
        evaldb: Arc<EvalDb>,
    ) -> Arc<Agent> {
        Arc::new(Agent {
            config,
            predictor: sim.clone(),
            sim: Some(sim),
            data: DataManager::default_cache(),
            tracer,
            evaldb,
            id: std::sync::Mutex::new(String::new()),
        })
    }

    pub fn predictor(&self) -> &Arc<dyn Predictor> {
        &self.predictor
    }

    pub fn id(&self) -> String {
        self.id.lock().unwrap().clone()
    }

    /// Initialization workflow ①: publish HW/SW stack + models, with the
    /// config's TTL (remote agents must heartbeat within it).
    pub fn register(&self, registry: &Registry, endpoint: &str) -> String {
        self.register_with_ttl(registry, endpoint, Some(self.config.ttl))
    }

    /// The agent's registry advertisement (HW/SW stack + models) for a
    /// given serving endpoint — what `register_agent` publishes, whether
    /// in-process or over the wire (`mlms agent serve --registry`). The id
    /// is left empty: the registry assigns one at registration.
    pub fn info(&self, endpoint: &str) -> AgentInfo {
        let (fw, fw_ver) = self.predictor.framework();
        AgentInfo {
            id: String::new(),
            endpoint: endpoint.to_string(),
            framework: fw,
            framework_version: fw_ver.parse().unwrap_or(crate::util::semver::Version::new(0, 0, 0)),
            system: self.config.system.clone(),
            architecture: self.config.architecture.clone(),
            devices: self.config.devices.clone(),
            interconnect: self.config.interconnect.clone(),
            host_memory_gb: self.config.host_memory_gb,
            device_memory_gb: self.config.device_memory_gb,
            models: self.config.models.clone(),
        }
    }

    /// Adopt a registry-assigned id (remote agents register over the wire,
    /// where the id comes back in the response — and a re-registration
    /// after lease expiry issues a fresh one).
    pub fn adopt_id(&self, id: &str) {
        *self.id.lock().unwrap() = id.to_string();
    }

    /// As [`Agent::register`] with an explicit TTL. In-process agents pass
    /// `None`: they live exactly as long as the server and must not expire
    /// mid-evaluation.
    pub fn register_with_ttl(
        &self,
        registry: &Registry,
        endpoint: &str,
        ttl: Option<Duration>,
    ) -> String {
        let id = registry.register_agent(self.info(endpoint), ttl);
        *self.id.lock().unwrap() = id.clone();
        id
    }

    /// Run one evaluation request end to end; stores the record (⑦) and
    /// returns it (⑧).
    pub fn evaluate(&self, req: &EvalRequest) -> Result<EvalResult, String> {
        let trace_id = self.tracer.new_trace();
        let root = self.tracer.start(trace_id, None, TraceLevel::Model, "evaluate");
        let root_id = root.as_ref().map(|s| s.id());

        // ⑤ Fetch model assets (graph + optional weights), checksum-verified.
        let assets = &req.manifest.assets;
        self.data
            .fetch(&assets.base_url, &assets.graph_path, assets.checksum.as_deref())
            .map_err(|e| format!("asset fetch: {e}"))?;
        if let Some(w) = &assets.weights_path {
            self.data
                .fetch(&assets.base_url, w, None)
                .map_err(|e| format!("asset fetch: {e}"))?;
        }

        // Load the model through the predictor interface.
        let batch = req.scenario.batch_size();
        let handle = self
            .predictor
            .model_load(&self.model_key(&req.manifest), batch)
            .map_err(|e| e.to_string())?;

        // Attach trace context for simulator predictors.
        if let Some(sim) = self.as_sim() {
            sim.attach_tracer(self.tracer.clone(), trace_id, root_id);
        }

        // Build the input: decode+preprocess once per distinct item, then
        // batch. (Dataset read path exercises the data manager.)
        let res = self.input_resolution(&req.manifest);
        let records = self
            .data
            .synthetic_dataset(&req.manifest.name, 4.min(batch.max(1)), res)
            .map_err(|e| format!("dataset: {e}"))?;
        let mut pre_span = self.tracer.start(trace_id, root_id, TraceLevel::Model, "preprocess");
        if let Some(s) = pre_span.as_mut() {
            s.tag("stage", "preprocessing");
        }
        // Real (non-simulated) agents serve artifacts compiled for a fixed
        // input size; retarget the manifest's resize step to it so the
        // preprocessing path is still exercised end to end.
        let steps: Vec<crate::manifest::PreprocessStep> = req.manifest.inputs[0]
            .steps
            .iter()
            .cloned()
            .map(|s| match s {
                crate::manifest::PreprocessStep::Resize { method, keep_aspect_ratio, .. }
                    if !self.config.simulated_time =>
                {
                    crate::manifest::PreprocessStep::Resize {
                        dimensions: [3, res, res],
                        method,
                        keep_aspect_ratio,
                    }
                }
                other => other,
            })
            .collect();
        let one = if steps.is_empty() {
            Tensor::random(vec![1, res, res, 3], req.seed)
        } else {
            crate::preprocess::run_pipeline(&steps, &records[0])
                .map_err(|e| format!("preprocess: {e}"))?
        };
        drop(pre_span);
        let refs: Vec<&Tensor> = std::iter::repeat(&one).take(batch.max(1)).collect();
        let batched = Tensor::stack(&refs).ok_or("batching failed")?;

        // Generate the workload and run it.
        let workload = Workload::generate(&req.scenario, req.seed);
        let opts = PredictOptions { batch_size: batch, input_mode: req.input_mode };
        let clock = self.tracer.clock().clone();
        let mut latencies = Vec::with_capacity(workload.requests.len());
        let run_start = clock.now_ns();
        for r in &workload.requests {
            let span = self.tracer.start(trace_id, root_id, TraceLevel::Model, "predict");
            let span_id = span.as_ref().map(|s| s.id());
            let t0 = clock.now_ns();
            let out = self
                .predictor
                .predict(handle, &batched, &opts)
                .map_err(|e| e.to_string())?;
            // Post-process (top-K) — part of the measured request, with its
            // own span so pre/post-processing attributes separately from
            // model compute.
            let post_span = self.tracer.start(
                trace_id,
                span_id.or(root_id),
                TraceLevel::Model,
                "postprocess",
            );
            let _preds = crate::postprocess::run_pipeline(&req.manifest.outputs[0].steps, &out);
            if let Some(mut p) = post_span {
                p.tag("stage", "postprocessing");
                p.finish();
            }
            let dt = (clock.now_ns() - t0) as f64 / 1e9;
            if let Some(mut s) = span {
                s.tag("request", r.id.to_string());
                s.tag("batch", r.batch_size.to_string());
                s.finish();
            }
            latencies.push(dt);
        }
        let total_secs = ((clock.now_ns() - run_start) as f64 / 1e9).max(1e-12);
        let items = (workload.requests.len() * batch.max(1)) as f64;
        let throughput = items / total_secs;
        self.predictor.model_unload(handle).map_err(|e| e.to_string())?;
        drop(root);

        // ⑦ Store the result.
        let (fw, fw_ver) = self.predictor.framework();
        let device = self
            .config
            .devices
            .first()
            .cloned()
            .unwrap_or_else(|| "cpu".to_string());
        // Content address of the resolved spec (F1): identical configs
        // store identical digests, which is what sweep memoization keys on.
        let mut spec = crate::evaldb::EvalSpec::for_request(
            &req.manifest,
            &self.config.system,
            &device,
            &req.scenario,
            batch,
            req.trace_level,
            req.seed,
            Json::Null,
        );
        spec.run_label = req.run_meta.label.clone();
        let key = EvalKey {
            model: req.manifest.name.clone(),
            model_version: req.manifest.version.to_string(),
            framework: fw,
            framework_version: fw_ver,
            system: self.config.system.clone(),
            device,
            scenario: req.scenario.name().to_string(),
            batch_size: batch,
        };
        let mut record = EvalRecord::new(key, latencies, throughput);
        record.spec_digest = Some(spec.digest());
        record.run_meta = req.run_meta.clone();
        record.trace_id = Some(trace_id);
        record.meta = Json::obj(vec![
            (
                "accuracy",
                req.manifest.accuracy().map(Json::num).unwrap_or(Json::Null),
            ),
            (
                "graph_size_mb",
                req.manifest.graph_size_mb().map(Json::num).unwrap_or(Json::Null),
            ),
            ("agent", Json::str(self.id())),
            ("input_mode", Json::str(req.input_mode.as_str())),
            ("trace_level", Json::str(req.trace_level.as_str())),
        ]);
        let mut record_out = record.clone();
        record_out.seq = self.evaldb.put(record);
        Ok(EvalResult { record: record_out, trace_id })
    }

    /// Map a manifest onto the predictor's model namespace: real XLA agents
    /// serve artifact families; simulator agents serve zoo names directly.
    fn model_key(&self, manifest: &ModelManifest) -> String {
        if self.config.simulated_time {
            manifest.name.clone()
        } else {
            crate::zoo::by_name(&manifest.name)
                .and_then(|z| z.hlo_family().map(str::to_string))
                .unwrap_or_else(|| manifest.name.clone())
        }
    }

    fn input_resolution(&self, manifest: &ModelManifest) -> usize {
        for s in &manifest.inputs[0].steps {
            if let crate::manifest::PreprocessStep::Resize { dimensions, .. } = s {
                // Real XLA artifacts are compiled for the agent's fixed
                // input size; simulators honour the manifest.
                if self.config.simulated_time {
                    return dimensions[1];
                }
            }
        }
        self.config.input_resolution
    }

    fn as_sim(&self) -> Option<&crate::predictor::SimPredictor> {
        self.sim.as_deref()
    }
}

/// Construct a simulator-backed agent for a Table-1 system. Returns the
/// agent plus the concrete simulator handle (for tracer attachment).
///
/// Panics on an unknown system name; callers acting on runtime input (the
/// autoscaling supervisor, CLI flags) should use [`try_sim_agent`].
pub fn sim_agent(
    system: &str,
    device: crate::sysmodel::Device,
    trace_level: TraceLevel,
    evaldb: Arc<EvalDb>,
    sink: Arc<dyn crate::tracing::SpanSink>,
) -> (Arc<Agent>, Arc<crate::predictor::SimPredictor>, Arc<Tracer>) {
    try_sim_agent(system, device, trace_level, evaldb, sink)
        .unwrap_or_else(|| panic!("unknown system profile {system:?}"))
}

/// As [`sim_agent`], but an unknown system name is a `None` instead of a
/// panic — a typo'd profile in a scaling decision must surface as a failed
/// spawn, not a crashed control loop.
pub fn try_sim_agent(
    system: &str,
    device: crate::sysmodel::Device,
    trace_level: TraceLevel,
    evaldb: Arc<EvalDb>,
    sink: Arc<dyn crate::tracing::SpanSink>,
) -> Option<(Arc<Agent>, Arc<crate::predictor::SimPredictor>, Arc<Tracer>)> {
    let profile = crate::sysmodel::systems().get(system)?.clone();
    let sim = Arc::new(crate::predictor::SimPredictor::new(crate::sysmodel::Simulator::new(
        profile.clone(),
        device,
    )));
    let tracer = Tracer::new(trace_level, sim.clock(), sink);
    let config = AgentConfig {
        system: system.to_string(),
        architecture: profile.architecture.clone(),
        devices: vec![match device {
            crate::sysmodel::Device::Cpu => "cpu".to_string(),
            crate::sysmodel::Device::Gpu => "gpu".to_string(),
        }],
        interconnect: profile.interconnect.clone(),
        host_memory_gb: profile.host_mem_gb,
        device_memory_gb: profile.gpu_mem_gb,
        models: crate::zoo::all().iter().map(|m| m.name.clone()).collect(),
        ttl: Duration::from_secs(30),
        input_resolution: 224,
        simulated_time: true,
    };
    let agent = Agent::new_sim(config, sim.clone(), tracer.clone(), evaldb);
    Some((agent, sim, tracer))
}

/// Construct a real XLA/PJRT agent serving the AOT artifact families.
pub fn xla_agent(
    runtime: Arc<crate::runtime::Runtime>,
    trace_level: TraceLevel,
    evaldb: Arc<EvalDb>,
    sink: Arc<dyn crate::tracing::SpanSink>,
) -> (Arc<Agent>, Arc<Tracer>) {
    let tracer = Tracer::new(trace_level, Arc::new(crate::tracing::WallClock::new()), sink);
    let families = crate::runtime::available_families();
    let config = AgentConfig {
        system: "local".into(),
        devices: vec!["cpu".into()],
        models: families,
        input_resolution: 32,
        simulated_time: false,
        ..AgentConfig::default()
    };
    let predictor = Arc::new(crate::predictor::XlaPredictor::new(runtime));
    let agent = Agent::new(config, predictor, tracer.clone(), evaldb);
    (agent, tracer)
}

/// A model opened on one agent for cross-request batched dispatch: the
/// per-agent execution endpoint of the [`crate::batcher`] subsystem. Holds
/// the loaded model handle for the whole dispatch so batches pay no
/// per-call load cost, and publishes one MODEL-level `batch_predict` span
/// per executed batch (tagged with occupancy and batch index) so batching
/// behaviour shows up in the trace output.
pub struct BatchSession {
    agent: Arc<Agent>,
    handle: crate::predictor::ModelHandle,
    trace_id: u64,
}

impl Agent {
    /// Open a batched-dispatch session: load the model once at the
    /// session's batch capacity and allocate a trace id for its spans.
    ///
    /// The session serves server-mode traffic, so the model is warmed with
    /// one throwaway predict: steady-state latency is what SLO probes and
    /// batch service times must measure (MLPerf server-mode methodology),
    /// and the one-time cold-start copy would otherwise land on whichever
    /// batch happened to run first — a thread-scheduling artifact. Cold
    /// starts stay measurable through the classic [`Agent::evaluate`] path
    /// and the `fig8_coldstart` bench.
    pub fn open_batch_session(
        self: &Arc<Self>,
        manifest: &ModelManifest,
        max_batch: usize,
    ) -> Result<BatchSession, String> {
        let handle = self
            .predictor
            .model_load(&self.model_key(manifest), max_batch.max(1))
            .map_err(|e| e.to_string())?;
        let warm = Tensor::random(vec![1, 4, 4, 3], 0);
        let opts = PredictOptions { batch_size: 1, input_mode: InputMode::Direct };
        // Best-effort: a predictor that can't serve this input (e.g. the
        // stubbed XLA runtime) will surface its error on the real batches.
        let _ = self.predictor.predict(handle, &warm, &opts);
        Ok(BatchSession { agent: self.clone(), handle, trace_id: self.tracer.new_trace() })
    }
}

impl BatchSession {
    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    /// Deterministic per-item logits row. Simulator predictors synthesize
    /// logits, so the row is derived purely from the item (input content +
    /// sequence number) — making results batching-invariant by
    /// construction, which is the invariant real frameworks provide
    /// mathematically.
    fn item_logits(env: &crate::pipeline::Envelope, input: &Tensor) -> Tensor {
        let bits = input.data.first().map(|v| v.to_bits() as u64).unwrap_or(0);
        let seed = env.seq.wrapping_mul(0x9E3779B97F4A7C15) ^ bits;
        Tensor::random(vec![1, 1000], seed)
    }
}

impl crate::batcher::BatchExecutor for BatchSession {
    fn id(&self) -> String {
        let id = self.agent.id();
        if id.is_empty() {
            self.agent.config.system.clone()
        } else {
            id
        }
    }

    fn execute(
        &self,
        batch: &crate::batcher::Batch,
    ) -> Result<crate::batcher::BatchResult, String> {
        use crate::pipeline::Payload;
        let inputs: Vec<&Tensor> = batch
            .envelopes
            .iter()
            .map(|e| match &e.payload {
                Payload::Tensor(t) => Ok(t),
                other => Err(format!("batch item {} is not a tensor: {other:?}", e.seq)),
            })
            .collect::<Result<_, String>>()?;
        let stacked = Tensor::stack(&inputs).ok_or("batch items have mismatched shapes")?;
        let opts = PredictOptions {
            batch_size: batch.envelopes.len(),
            input_mode: InputMode::Direct,
        };
        let clock = self.agent.tracer.clock().clone();
        let span =
            self.agent
                .tracer
                .start(self.trace_id, None, TraceLevel::Model, "batch_predict");
        // At FRAMEWORK+ levels, nest the simulator's layer/kernel spans
        // under this batch's span so batched serving traces carry the same
        // model-execution depth as the classic path (attribution can then
        // descend from queueing into the dominant layer). Below that level
        // the attach is skipped — publish_layer does per-layer tag work
        // whenever a tracer is attached, which the hot path shouldn't pay
        // for spans that would be filtered anyway.
        if self.agent.tracer.enabled(TraceLevel::Framework) {
            if let Some(sim) = self.agent.as_sim() {
                sim.attach_tracer(
                    self.agent.tracer.clone(),
                    self.trace_id,
                    span.as_ref().map(|s| s.id()),
                );
            }
        }
        let t0 = clock.now_ns();
        let out = self
            .agent
            .predictor
            .predict(self.handle, &stacked, &opts)
            .map_err(|e| e.to_string())?;
        let latency_s = (clock.now_ns() - t0) as f64 / 1e9;
        if let Some(mut s) = span {
            s.tag("stage", "compute");
            s.tag("tenant", batch.tenant.to_string());
            s.tag("batch_index", batch.index.to_string());
            s.tag("occupancy", batch.envelopes.len().to_string());
            s.tag("queue_delay_ms_max", {
                let max = batch
                    .queue_delays_secs()
                    .into_iter()
                    .fold(0.0f64, f64::max);
                format!("{:.3}", max * 1e3)
            });
            s.finish();
        }
        // Build outputs field-by-field: `..e.clone()` would deep-copy each
        // input tensor payload only to overwrite it — a per-request
        // allocation on the dispatch hot path.
        let reply = |e: &crate::pipeline::Envelope, row: Tensor| crate::pipeline::Envelope {
            seq: e.seq,
            trace_id: e.trace_id,
            parent_span: e.parent_span,
            payload: Payload::Tensor(row),
        };
        let outputs = if self.agent.config.simulated_time {
            batch
                .envelopes
                .iter()
                .zip(&inputs)
                .map(|(e, input)| reply(e, Self::item_logits(e, input)))
                .collect()
        } else {
            // Real frameworks: a batched run's rows are the per-item runs.
            batch
                .envelopes
                .iter()
                .zip(out.unstack())
                .map(|(e, row)| reply(e, row))
                .collect()
        };
        Ok(crate::batcher::BatchResult { outputs, latency_s })
    }
}

impl Drop for BatchSession {
    fn drop(&mut self) {
        let _ = self.agent.predictor.model_unload(self.handle);
    }
}

/// A batch session on a **remote** agent process — the same
/// [`crate::batcher::BatchExecutor`] trait the dispatcher drives locally,
/// but every batch rides the wire: `OpenBatch` loads the model once on the
/// agent, `PredictBatch` ships each coalesced batch (deadline + tenant tags
/// in the frame, stacked tensor as the binary attachment) and streams the
/// result rows back, `CloseBatch` releases the handle.
///
/// Failure semantics are what make the fleet safe:
/// - before each batch the agent's **registry lease** is re-checked — a
///   lapsed heartbeat fails the batch immediately instead of burning a
///   connect/read timeout on a process that is probably gone;
/// - a dropped connection, a deadline, or a remote error all surface as
///   `Err` from [`crate::batcher::BatchExecutor::execute`], which the
///   dispatcher answers by marking this executor dead and requeueing the
///   in-flight batch **exactly once** to a survivor.
pub struct RemoteBatchSession {
    agent_id: String,
    endpoint: String,
    client: crate::wire::RpcClient,
    session: u64,
    registry: Option<Arc<Registry>>,
    deadline_ms: Option<f64>,
}

impl RemoteBatchSession {
    /// Connect to a remote agent and open a batch session for `manifest` at
    /// `max_batch` capacity. `registry` (when given) supplies the liveness
    /// re-check per batch; `deadline_ms` bounds every RPC on this
    /// connection.
    pub fn open(
        endpoint: &str,
        agent_id: &str,
        manifest: &ModelManifest,
        max_batch: usize,
        registry: Option<Arc<Registry>>,
        deadline_ms: Option<f64>,
    ) -> Result<RemoteBatchSession, String> {
        // Two pooled connections: batches multiplex over both, so one
        // slow batch (or one broken member) never serializes the rest of
        // the data plane behind it.
        let client = crate::wire::RpcClient::connect_pooled(endpoint, 2)
            .map_err(|e| format!("connect {endpoint}: {e}"))?;
        if let Some(ms) = deadline_ms {
            client.set_read_timeout(Some(std::time::Duration::from_secs_f64(
                (ms / 1e3).max(1e-3),
            )));
        }
        let resp = client
            .call(
                "OpenBatch",
                Json::obj(vec![
                    ("manifest", manifest.to_json()),
                    ("max_batch", Json::num(max_batch as f64)),
                ]),
            )
            .map_err(|e| format!("OpenBatch on {agent_id} ({endpoint}): {e}"))?;
        let session = resp.f64_or("session", -1.0);
        if session < 0.0 {
            return Err(format!("OpenBatch on {agent_id}: no session id in reply"));
        }
        Ok(RemoteBatchSession {
            agent_id: agent_id.to_string(),
            endpoint: endpoint.to_string(),
            client,
            session: session as u64,
            registry,
            deadline_ms,
        })
    }

    pub fn endpoint(&self) -> &str {
        &self.endpoint
    }
}

impl crate::batcher::BatchExecutor for RemoteBatchSession {
    fn id(&self) -> String {
        self.agent_id.clone()
    }

    fn execute(
        &self,
        batch: &crate::batcher::Batch,
    ) -> Result<crate::batcher::BatchResult, String> {
        use crate::pipeline::{Envelope, Payload};
        // Membership gate: a TTL that lapsed since the last batch means the
        // agent stopped heartbeating — treat it as dead now.
        if let Some(reg) = &self.registry {
            if !reg.is_live(&self.agent_id) {
                return Err(format!(
                    "agent {} lease lapsed (missed heartbeats)",
                    self.agent_id
                ));
            }
        }
        let inputs: Vec<&Tensor> = batch
            .envelopes
            .iter()
            .map(|e| match &e.payload {
                Payload::Tensor(t) => Ok(t),
                other => Err(format!("batch item {} is not a tensor: {other:?}", e.seq)),
            })
            .collect::<Result<_, String>>()?;
        let stacked = Tensor::stack(&inputs).ok_or("batch items have mismatched shapes")?;
        let params = Json::obj(vec![
            ("session", Json::num(self.session as f64)),
            (
                "seqs",
                Json::arr(batch.envelopes.iter().map(|e| Json::num(e.seq as f64)).collect()),
            ),
            (
                "arrivals",
                Json::arr(batch.arrivals.iter().map(|a| Json::num(*a)).collect()),
            ),
            ("tenant", Json::num(batch.tenant as f64)),
            ("batch_index", Json::num(batch.index as f64)),
            ("opened_at", Json::num(batch.opened_at_secs)),
            ("formed_at", Json::num(batch.formed_at_secs)),
            (
                "deadline_ms",
                self.deadline_ms.map(Json::num).unwrap_or(Json::Null),
            ),
        ]);
        let mut chunks: Vec<(usize, Vec<Tensor>)> = Vec::new();
        let (result, _blob) = self
            .client
            .call_streamed("PredictBatch", params, Some(&stacked.to_bytes()), |chunk, blob| {
                if let Some(t) = blob.and_then(Tensor::from_bytes) {
                    chunks.push((chunk.f64_or("offset", 0.0) as usize, t.unstack()));
                }
            })
            .map_err(|e| format!("PredictBatch on {}: {e}", self.agent_id))?;
        chunks.sort_by_key(|(offset, _)| *offset);
        let rows: Vec<Tensor> = chunks.into_iter().flat_map(|(_, ts)| ts).collect();
        if rows.len() != batch.envelopes.len() {
            return Err(format!(
                "PredictBatch on {} returned {} rows for {} requests",
                self.agent_id,
                rows.len(),
                batch.envelopes.len()
            ));
        }
        let outputs = batch
            .envelopes
            .iter()
            .zip(rows)
            .map(|(e, t)| Envelope {
                seq: e.seq,
                trace_id: e.trace_id,
                parent_span: e.parent_span,
                payload: Payload::Tensor(t),
            })
            .collect();
        Ok(crate::batcher::BatchResult {
            outputs,
            latency_s: result.f64_or("latency_s", 0.0),
        })
    }
}

impl Drop for RemoteBatchSession {
    fn drop(&mut self) {
        // Best-effort release; never block shutdown on a dead peer. When
        // the main connection is poisoned (deadline, transport error) the
        // agent may well still be alive — close over a fresh connection so
        // a long-lived agent daemon doesn't accumulate orphaned sessions
        // (loaded models) across controller failures.
        let close = Json::obj(vec![("session", Json::num(self.session as f64))]);
        if !self.client.is_broken() {
            self.client
                .set_read_timeout(Some(std::time::Duration::from_secs(1)));
            let _ = self.client.call("CloseBatch", close);
        } else if let Ok(fresh) = crate::wire::RpcClient::connect(self.endpoint.as_str()) {
            fresh.set_read_timeout(Some(std::time::Duration::from_secs(1)));
            let _ = fresh.call("CloseBatch", close);
        }
    }
}

/// Rows per streamed `PredictBatch` response frame: large batched results
/// leave the agent as a sequence of bounded frames instead of one frame
/// that could brush `MAX_FRAME`.
const PREDICT_BATCH_CHUNK_ROWS: usize = 8;

/// Wire service wrapper with the binary-tensor fast path (§Perf) and the
/// remote batch-session state (`OpenBatch`/`PredictBatch`/`CloseBatch`).
struct AgentService {
    agent: Arc<Agent>,
    sessions: std::sync::Mutex<std::collections::HashMap<u64, Arc<BatchSession>>>,
    next_session: std::sync::atomic::AtomicU64,
}

impl AgentService {
    /// Lock the session table, mapping a poisoned lock (a request worker
    /// panicked while holding it) to a typed RPC error instead of
    /// propagating the panic — on the multiplexed server one poisoned
    /// request must not take down every later session RPC.
    fn sessions_lock(
        &self,
    ) -> Result<
        std::sync::MutexGuard<'_, std::collections::HashMap<u64, Arc<BatchSession>>>,
        String,
    > {
        self.sessions
            .lock()
            .map_err(|_| "agent session table poisoned by a panicked request".to_string())
    }

    /// The streamed `PredictBatch` RPC: the frame carries the coalesced
    /// batch (seqs + arrivals + tenant + deadline tags in the JSON
    /// envelope, the stacked input tensor as the binary attachment); the
    /// reply streams the result rows back in bounded chunks, then a final
    /// frame with the batch's service time on the agent's clock. The
    /// `deadline_ms` tag is advisory on this side — the *caller* enforces
    /// it as a read timeout — but it is recorded on the batch span so a
    /// trace shows what budget the batch ran under.
    fn predict_batch(
        &self,
        params: &Json,
        blob: Option<&[u8]>,
        emit: &mut dyn FnMut(Json, Option<Vec<u8>>) -> Result<(), crate::wire::WireError>,
    ) -> Result<(Json, Option<Vec<u8>>), String> {
        use crate::pipeline::{Envelope, Payload};
        let sid = params.f64_or("session", -1.0);
        if sid < 0.0 {
            return Err("PredictBatch requires a session id from OpenBatch".into());
        }
        let session = self
            .sessions_lock()?
            .get(&(sid as u64))
            .cloned()
            .ok_or_else(|| format!("unknown batch session {sid}"))?;
        let input = blob
            .and_then(Tensor::from_bytes)
            .ok_or("PredictBatch requires a binary tensor attachment")?;
        let seqs: Vec<u64> = params
            .get("seqs")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|s| s.as_u64()).collect())
            .unwrap_or_default();
        if seqs.is_empty() || seqs.len() != input.batch() {
            return Err(format!(
                "PredictBatch seqs/tensor mismatch: {} seqs for batch {}",
                seqs.len(),
                input.batch()
            ));
        }
        let arrivals: Vec<f64> = params
            .get("arrivals")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
            .filter(|a: &Vec<f64>| a.len() == seqs.len())
            .unwrap_or_else(|| vec![0.0; seqs.len()]);
        let batch = crate::batcher::Batch {
            index: params.f64_or("batch_index", 0.0) as u64,
            opened_at_secs: params.f64_or("opened_at", 0.0),
            formed_at_secs: params.f64_or("formed_at", 0.0),
            envelopes: input
                .unstack()
                .into_iter()
                .zip(&seqs)
                .map(|(t, s)| Envelope {
                    seq: *s,
                    trace_id: 0,
                    parent_span: None,
                    payload: Payload::Tensor(t),
                })
                .collect(),
            arrivals,
            tenant: params.f64_or("tenant", 0.0) as u32,
        };
        let result = crate::batcher::BatchExecutor::execute(&*session, &batch)?;
        let rows: Vec<Tensor> = result
            .outputs
            .iter()
            .map(|e| match &e.payload {
                Payload::Tensor(t) => Ok(t.clone()),
                other => Err(format!("non-tensor batch output: {other:?}")),
            })
            .collect::<Result<_, String>>()?;
        for (ci, chunk) in rows.chunks(PREDICT_BATCH_CHUNK_ROWS).enumerate() {
            let refs: Vec<&Tensor> = chunk.iter().collect();
            let stacked = Tensor::stack(&refs).ok_or("result rows have mismatched shapes")?;
            emit(
                Json::obj(vec![
                    ("offset", Json::num((ci * PREDICT_BATCH_CHUNK_ROWS) as f64)),
                    ("rows", Json::num(chunk.len() as f64)),
                ]),
                Some(stacked.to_bytes()),
            )
            .map_err(|e| format!("streaming result chunk: {e}"))?;
        }
        Ok((
            Json::obj(vec![
                ("latency_s", Json::num(result.latency_s)),
                ("rows", Json::num(rows.len() as f64)),
                ("tenant", Json::num(batch.tenant as f64)),
            ]),
            None,
        ))
    }
}

impl crate::wire::Service for AgentService {
    fn call(&self, method: &str, params: &Json) -> Result<Json, String> {
        match method {
            // Open a cross-request batch session: load the model once at
            // session batch capacity, keep the handle server-side, return a
            // session id the remote dispatcher cites per batch.
            "OpenBatch" => {
                let manifest = crate::manifest::ModelManifest::from_json(
                    params.get("manifest").ok_or("missing manifest")?,
                )
                .map_err(|e| e.to_string())?;
                let max_batch = params.f64_or("max_batch", 1.0) as usize;
                let session = self.agent.open_batch_session(&manifest, max_batch)?;
                let id = self
                    .next_session
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let trace_id = session.trace_id();
                self.sessions_lock()?.insert(id, Arc::new(session));
                Ok(Json::obj(vec![
                    ("session", Json::num(id as f64)),
                    ("trace_id", Json::num(trace_id as f64)),
                    ("agent", Json::str(self.agent.id())),
                ]))
            }
            "CloseBatch" => {
                let sid = params.f64_or("session", -1.0);
                self.sessions_lock()?.remove(&(sid as u64));
                Ok(Json::Null)
            }
            _ => agent_call(&self.agent, method, params),
        }
    }

    /// `PredictBin`: input tensor as a raw binary attachment instead of
    /// JSON — the tensor-payload bottleneck fix measured in
    /// `ablation_platform` / EXPERIMENTS.md §Perf.
    fn call_binary(
        &self,
        method: &str,
        params: &Json,
        blob: Option<&[u8]>,
    ) -> Result<(Json, Option<Vec<u8>>), String> {
        if method == "PredictBin" {
            let input = blob
                .and_then(Tensor::from_bytes)
                .ok_or("PredictBin requires a binary tensor attachment")?;
            let h = crate::predictor::ModelHandle(params.f64_or("handle", 0.0) as u64);
            let opts = PredictOptions {
                batch_size: input.batch(),
                input_mode: InputMode::parse(params.str_or("input_mode", "c")),
            };
            let out = self
                .agent
                .predictor
                .predict(h, &input, &opts)
                .map_err(|e| e.to_string())?;
            return Ok((Json::Null, Some(out.to_bytes())));
        }
        self.call(method, params).map(|j| (j, None))
    }

    fn call_stream(
        &self,
        method: &str,
        params: &Json,
        blob: Option<&[u8]>,
        emit: &mut dyn FnMut(Json, Option<Vec<u8>>) -> Result<(), crate::wire::WireError>,
    ) -> Result<(Json, Option<Vec<u8>>), String> {
        if method == "PredictBatch" {
            return self.predict_batch(params, blob, emit);
        }
        self.call_binary(method, params, blob)
    }
}

/// Expose an agent over the wire protocol — the paper's Listing-4 service:
/// `Open`, `Predict` (runs a full scenario), `Close`, plus `Evaluate` which
/// bundles the three for the server's dispatch path, `PredictBin` (binary
/// tensor attachment fast path), and the batched-serving session RPCs
/// `OpenBatch` / `PredictBatch` (streamed) / `CloseBatch`.
pub fn agent_service(agent: Arc<Agent>) -> Arc<dyn crate::wire::Service> {
    Arc::new(AgentService {
        agent,
        sessions: std::sync::Mutex::new(std::collections::HashMap::new()),
        next_session: std::sync::atomic::AtomicU64::new(1),
    })
}

fn agent_call(agent: &Arc<Agent>, method: &str, params: &Json) -> Result<Json, String> {
    {
        match method {
            "Evaluate" => {
                let manifest = ModelManifest::from_json(
                    params.get("manifest").ok_or("missing manifest")?,
                )
                .map_err(|e| e.to_string())?;
                let scenario = Scenario::from_json(
                    params.get("scenario").ok_or("missing scenario")?,
                )
                .ok_or("bad scenario")?;
                let trace_level = TraceLevel::parse(params.str_or("trace_level", "model"))
                    .ok_or_else(|| {
                        format!(
                            "invalid trace_level {:?} (none|model|framework|system|full)",
                            params.str_or("trace_level", "")
                        )
                    })?;
                // Absent run_meta is a legacy/unlabeled dispatch; a present
                // but malformed one is a protocol error, not "no label".
                let run_meta = crate::evaldb::RunMeta::from_json(params.get("run_meta"))
                    .ok_or("malformed run_meta")?;
                let req = EvalRequest {
                    manifest,
                    scenario,
                    trace_level,
                    input_mode: InputMode::parse(params.str_or("input_mode", "c")),
                    seed: params.f64_or("seed", 42.0) as u64,
                    run_meta,
                };
                let result = agent.evaluate(&req)?;
                Ok(Json::obj(vec![
                    ("record", result.record.to_json()),
                    ("trace_id", Json::num(result.trace_id as f64)),
                ]))
            }
            "Open" => {
                let model = params.str_or("model_name", "");
                let batch = params.f64_or("batch_size", 1.0) as usize;
                let h = agent
                    .predictor
                    .model_load(model, batch)
                    .map_err(|e| e.to_string())?;
                Ok(Json::obj(vec![("handle", Json::num(h.0 as f64))]))
            }
            "Predict" => {
                let h = crate::predictor::ModelHandle(params.f64_or("handle", 0.0) as u64);
                let input = Tensor::from_json(params.get("input").ok_or("missing input")?)
                    .ok_or("bad input tensor")?;
                let opts = PredictOptions {
                    batch_size: input.batch(),
                    input_mode: InputMode::parse(params.str_or("input_mode", "c")),
                };
                let out = agent.predictor.predict(h, &input, &opts).map_err(|e| e.to_string())?;
                Ok(out.to_json())
            }
            "Close" => {
                let h = crate::predictor::ModelHandle(params.f64_or("handle", 0.0) as u64);
                agent.predictor.model_unload(h).map_err(|e| e.to_string())?;
                Ok(Json::Null)
            }
            other => Err(format!("unknown agent method {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracing::MemorySink;

    fn sim_setup(system: &str) -> (Arc<Agent>, Arc<crate::predictor::SimPredictor>, Arc<Tracer>, Arc<EvalDb>, Arc<MemorySink>) {
        let db = Arc::new(EvalDb::in_memory());
        let sink = MemorySink::new();
        let (agent, sim, tracer) =
            sim_agent(system, crate::sysmodel::Device::Gpu, TraceLevel::Full, db.clone(), sink.clone());
        (agent, sim, tracer, db, sink)
    }

    #[test]
    fn sim_agent_online_evaluation() {
        let (agent, _sim, _tracer, db, _sink) = sim_setup("aws_p3");
        let manifest = crate::zoo::by_name("ResNet_v1_50").unwrap().manifest();
        let req = EvalRequest {
            manifest,
            scenario: Scenario::Online { count: 12 },
            trace_level: TraceLevel::Model,
            input_mode: InputMode::Direct,
            seed: 1,
            run_meta: Default::default(),
        };
        let result = agent.evaluate(&req).unwrap();
        assert_eq!(result.record.latencies.len(), 12);
        assert!(result.record.throughput > 0.0);
        assert_eq!(db.len(), 1);
        assert_eq!(result.record.key.system, "aws_p3");
        assert_eq!(result.record.meta.get("accuracy").unwrap().as_f64(), Some(75.2));
    }

    #[test]
    fn sim_agent_batched_evaluation_scales() {
        let (agent, _sim, _t, db, _s) = sim_setup("aws_p3");
        let manifest = crate::zoo::by_name("MobileNet_v1_1.0_224").unwrap().manifest();
        for batch in [1usize, 32] {
            let req = EvalRequest {
                manifest: manifest.clone(),
                scenario: Scenario::Batched { batch_size: batch, batches: 4 },
                trace_level: TraceLevel::None,
                input_mode: InputMode::Direct,
                seed: 2,
                run_meta: Default::default(),
            };
            agent.evaluate(&req).unwrap();
        }
        let recs = db.query(&crate::evaldb::EvalQuery::model("MobileNet_v1_1.0_224"));
        assert_eq!(recs.len(), 2);
        let tp1 = recs.iter().find(|r| r.key.batch_size == 1).unwrap().throughput;
        let tp32 = recs.iter().find(|r| r.key.batch_size == 32).unwrap().throughput;
        assert!(tp32 > tp1 * 2.0, "batching must raise throughput: {tp1} → {tp32}");
    }

    #[test]
    fn registration_publishes_stack() {
        let (agent, _sim, _t, _db, _s) = sim_setup("ibm_p8");
        let registry = Registry::new();
        let id = agent.register(&registry, "127.0.0.1:9999");
        assert!(!id.is_empty());
        let agents = registry.agents();
        assert_eq!(agents.len(), 1);
        assert_eq!(agents[0].system, "ibm_p8");
        assert_eq!(agents[0].architecture, "ppc64le");
        assert_eq!(agents[0].interconnect, "nvlink");
        assert_eq!(agents[0].models.len(), 37);
    }

    #[test]
    fn agent_service_evaluate_over_wire() {
        let (agent, _sim, _t, db, _s) = sim_setup("aws_g3");
        let server =
            crate::wire::RpcServer::serve("127.0.0.1:0", agent_service(agent)).unwrap();
        let client = crate::wire::RpcClient::connect(server.addr()).unwrap();
        let manifest = crate::zoo::by_name("BVLC_AlexNet").unwrap().manifest();
        let resp = client
            .call(
                "Evaluate",
                Json::obj(vec![
                    ("manifest", manifest.to_json()),
                    ("scenario", Scenario::Online { count: 5 }.to_json()),
                    ("trace_level", Json::str("framework")),
                    ("seed", Json::num(7.0)),
                ]),
            )
            .unwrap();
        let record = crate::evaldb::EvalRecord::from_json(resp.get("record").unwrap()).unwrap();
        assert_eq!(record.latencies.len(), 5);
        assert_eq!(db.len(), 1);
        server.stop();
    }

    #[test]
    fn open_predict_close_over_wire() {
        let (agent, _sim, _t, _db, _s) = sim_setup("aws_p3");
        let server =
            crate::wire::RpcServer::serve("127.0.0.1:0", agent_service(agent)).unwrap();
        let client = crate::wire::RpcClient::connect(server.addr()).unwrap();
        let h = client
            .call(
                "Open",
                Json::obj(vec![
                    ("model_name", Json::str("Inception_v3")),
                    ("batch_size", Json::num(2.0)),
                ]),
            )
            .unwrap()
            .f64_or("handle", 0.0);
        assert!(h > 0.0);
        let input = Tensor::zeros(vec![2, 8, 8, 3]);
        let out = client
            .call(
                "Predict",
                Json::obj(vec![("handle", Json::num(h)), ("input", input.to_json())]),
            )
            .unwrap();
        let out = Tensor::from_json(&out).unwrap();
        assert_eq!(out.shape, vec![2, 1000]);
        client.call("Close", Json::obj(vec![("handle", Json::num(h))])).unwrap();
        let err = client
            .call("Close", Json::obj(vec![("handle", Json::num(h))]))
            .unwrap_err();
        assert!(err.to_string().contains("handle"), "{err}");
        server.stop();
    }

    #[test]
    fn predict_bin_binary_fast_path() {
        let (agent, _sim, _t, _db, _s) = sim_setup("aws_p3");
        let server =
            crate::wire::RpcServer::serve("127.0.0.1:0", agent_service(agent)).unwrap();
        let client = crate::wire::RpcClient::connect(server.addr()).unwrap();
        let h = client
            .call(
                "Open",
                Json::obj(vec![
                    ("model_name", Json::str("ResNet_v1_50")),
                    ("batch_size", Json::num(2.0)),
                ]),
            )
            .unwrap()
            .f64_or("handle", 0.0);
        let input = Tensor::random(vec![2, 16, 16, 3], 3);
        let (_j, blob) = client
            .call_binary(
                "PredictBin",
                Json::obj(vec![("handle", Json::num(h))]),
                Some(&input.to_bytes()),
            )
            .unwrap();
        let out = Tensor::from_bytes(&blob.expect("binary response")).unwrap();
        assert_eq!(out.shape, vec![2, 1000]);
        // Missing attachment is a clean remote error.
        let err = client
            .call_binary("PredictBin", Json::obj(vec![("handle", Json::num(h))]), None)
            .unwrap_err();
        assert!(err.to_string().contains("binary tensor"), "{err}");
        server.stop();
    }

    #[test]
    fn batch_session_executes_and_traces_batches() {
        use crate::batcher::{Batch, BatchExecutor};
        use crate::pipeline::{Envelope, Payload};
        let db = Arc::new(EvalDb::in_memory());
        let sink = MemorySink::new();
        let (agent, _sim, _tracer) = sim_agent(
            "aws_p3",
            crate::sysmodel::Device::Gpu,
            TraceLevel::Model,
            db,
            sink.clone(),
        );
        let manifest = crate::zoo::by_name("ResNet_v1_50").unwrap().manifest();
        let session = agent.open_batch_session(&manifest, 4).unwrap();
        let mk_batch = |index: u64, seqs: &[u64]| Batch {
            index,
            opened_at_secs: 0.0,
            formed_at_secs: 0.0,
            envelopes: seqs
                .iter()
                .map(|s| Envelope {
                    seq: *s,
                    trace_id: 0,
                    parent_span: None,
                    payload: Payload::Tensor(Tensor::random(vec![1, 4, 4, 3], *s)),
                })
                .collect(),
            arrivals: vec![0.0; seqs.len()],
            tenant: 0,
        };
        let r1 = session.execute(&mk_batch(0, &[0, 1, 2, 3])).unwrap();
        assert_eq!(r1.outputs.len(), 4);
        assert!(r1.latency_s > 0.0, "simulated batch time advances the clock");
        // Identity: the same item in a different batch yields the same row.
        let r2 = session.execute(&mk_batch(1, &[2])).unwrap();
        let row_of = |r: &crate::batcher::BatchResult, seq: u64| match &r
            .outputs
            .iter()
            .find(|e| e.seq == seq)
            .unwrap()
            .payload
        {
            Payload::Tensor(t) => t.clone(),
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(row_of(&r1, 2), row_of(&r2, 2), "results are batching-invariant");
        // Trace output carries batch spans tagged with occupancy.
        let spans = sink.snapshot();
        let batch_spans: Vec<_> =
            spans.iter().filter(|s| s.name == "batch_predict").collect();
        assert_eq!(batch_spans.len(), 2);
        assert_eq!(batch_spans[0].tag("occupancy"), Some("4"));
        assert_eq!(batch_spans[1].tag("occupancy"), Some("1"));
    }

    #[test]
    fn remote_batch_session_over_wire_matches_local() {
        use crate::batcher::{Batch, BatchExecutor};
        use crate::pipeline::{Envelope, Payload};
        let (local_agent, _s, _t, _db, _sink) = sim_setup("aws_p3");
        let (remote_agent, _s2, _t2, _db2, _sink2) = sim_setup("aws_p3");
        let manifest = crate::zoo::by_name("ResNet_v1_50").unwrap().manifest();
        let local = local_agent.open_batch_session(&manifest, 32).unwrap();
        let rpc =
            crate::wire::RpcServer::serve("127.0.0.1:0", agent_service(remote_agent)).unwrap();
        let remote = RemoteBatchSession::open(
            &rpc.addr().to_string(),
            "remote-1",
            &manifest,
            32,
            None,
            Some(10_000.0),
        )
        .unwrap();
        assert_eq!(remote.id(), "remote-1");
        // 20 rows → the 8-row chunking streams the reply as 3 frames.
        let seqs: Vec<u64> = (0..20).collect();
        let mk = |index: u64| Batch {
            index,
            opened_at_secs: 0.0,
            formed_at_secs: 0.001,
            envelopes: seqs
                .iter()
                .map(|s| Envelope {
                    seq: *s,
                    trace_id: 0,
                    parent_span: None,
                    payload: Payload::Tensor(Tensor::random(vec![1, 4, 4, 3], *s)),
                })
                .collect(),
            arrivals: vec![0.0; seqs.len()],
            tenant: 1,
        };
        let rl = local.execute(&mk(0)).unwrap();
        let rr = remote.execute(&mk(0)).unwrap();
        assert_eq!(rr.outputs.len(), 20);
        assert!(rr.latency_s > 0.0, "service time rides back in the final frame");
        // Identity: the remote rows are exactly the local rows, per seq —
        // where a batch executes must never change its results.
        for (a, b) in rl.outputs.iter().zip(&rr.outputs) {
            assert_eq!(a.seq, b.seq);
            match (&a.payload, &b.payload) {
                (Payload::Tensor(x), Payload::Tensor(y)) => {
                    assert_eq!(x, y, "request {} diverged over the wire", a.seq)
                }
                other => panic!("unexpected payloads {other:?}"),
            }
        }
        rpc.stop();
    }

    #[test]
    fn predict_batch_rejects_malformed_requests_cleanly() {
        let (agent, _s, _t, _db, _sink) = sim_setup("aws_g3");
        let server =
            crate::wire::RpcServer::serve("127.0.0.1:0", agent_service(agent)).unwrap();
        let client = crate::wire::RpcClient::connect(server.addr()).unwrap();
        // Unknown session.
        let input = Tensor::random(vec![2, 4, 4, 3], 1);
        let err = client
            .call_streamed(
                "PredictBatch",
                Json::obj(vec![
                    ("session", Json::num(99.0)),
                    ("seqs", Json::arr(vec![Json::num(0.0), Json::num(1.0)])),
                ]),
                Some(&input.to_bytes()),
                |_, _| {},
            )
            .unwrap_err();
        assert!(err.to_string().contains("unknown batch session"), "{err}");
        // Open a real session, then ship a seq/tensor mismatch.
        let manifest = crate::zoo::by_name("BVLC_AlexNet").unwrap().manifest();
        let resp = client
            .call(
                "OpenBatch",
                Json::obj(vec![
                    ("manifest", manifest.to_json()),
                    ("max_batch", Json::num(4.0)),
                ]),
            )
            .unwrap();
        let session = resp.f64_or("session", -1.0);
        assert!(session >= 0.0);
        let err = client
            .call_streamed(
                "PredictBatch",
                Json::obj(vec![
                    ("session", Json::num(session)),
                    ("seqs", Json::arr(vec![Json::num(0.0)])),
                ]),
                Some(&input.to_bytes()),
                |_, _| {},
            )
            .unwrap_err();
        assert!(err.to_string().contains("mismatch"), "{err}");
        // The connection survives both errors; CloseBatch still works.
        client
            .call("CloseBatch", Json::obj(vec![("session", Json::num(session))]))
            .unwrap();
        server.stop();
    }

    /// Real PJRT agent end-to-end (skipped without artifacts or bindings).
    #[test]
    fn xla_agent_runs_artifacts_if_present() {
        if crate::runtime::available_families().is_empty() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let db = Arc::new(EvalDb::in_memory());
        let sink = MemorySink::new();
        let rt = crate::runtime::Runtime::cpu().unwrap();
        let (agent, _tracer) = xla_agent(rt, TraceLevel::Model, db.clone(), sink);
        let manifest = crate::zoo::by_name("ResNet_v1_50").unwrap().manifest();
        let req = EvalRequest {
            manifest,
            scenario: Scenario::Online { count: 3 },
            trace_level: TraceLevel::Model,
            input_mode: InputMode::Direct,
            seed: 3,
            run_meta: Default::default(),
        };
        match agent.evaluate(&req) {
            Ok(result) => {
                assert_eq!(result.record.latencies.len(), 3);
                assert!(result.record.latencies.iter().all(|l| *l > 0.0));
            }
            Err(e) if e.contains("PJRT") => {
                eprintln!("skipping: stub runtime ({e})");
            }
            Err(e) => panic!("{e}"),
        }
    }
}
