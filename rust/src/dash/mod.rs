//! Live fleet dashboard (`mlms fleet --dash`).
//!
//! The distributed MLModelScope deployment (arXiv:2002.08295) argues a
//! fleet you cannot *watch* is a fleet you cannot operate. This module is
//! the operable view: a [`FleetGauges`] sink the dispatcher, the sweep
//! engine, and the server feed while work runs, plus a plain-ANSI renderer
//! that redraws one frame in place — per-agent lease remaining / standby
//! state from the registry, outstanding and in-flight counts from the
//! dispatcher, sweep cell progress, and rolling p50/p99 latency tails from
//! [`crate::metrics::TenantLatencies`]. No terminal library: just `\x1b[H`
//! / `\x1b[2J` escapes, so the same frame renders headlessly in CI
//! (`mlms fleet --dash --once`).

use crate::metrics::{percentile, TenantLatencies};
use crate::registry::Registry;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Rolling latency window: enough for stable tails, bounded so a week-long
/// fleet run cannot grow the dashboard's memory.
const LATENCY_RING: usize = 4096;

/// Per-agent dispatch counters, keyed by executor id.
#[derive(Debug, Clone, Copy, Default)]
pub struct AgentGauge {
    /// Items currently handed to this executor (queued batches it owns).
    pub outstanding_items: usize,
    /// Batches currently executing on this executor.
    pub in_flight_batches: usize,
}

/// Shared, lock-light progress counters the execution paths update while
/// the dashboard samples them. All methods take `&self`; cloning the
/// `Arc<FleetGauges>` into the dispatcher / sweep / server is the wiring.
#[derive(Default)]
pub struct FleetGauges {
    outstanding_items: AtomicUsize,
    in_flight_batches: AtomicUsize,
    completed_batches: AtomicU64,
    completed_items: AtomicU64,
    cells_total: AtomicUsize,
    cells_done: AtomicUsize,
    cells_memoized: AtomicUsize,
    cells_failed: AtomicUsize,
    per_agent: Mutex<BTreeMap<String, AgentGauge>>,
    latencies: Mutex<VecDeque<(String, f64)>>,
}

impl FleetGauges {
    pub fn new() -> Arc<FleetGauges> {
        Arc::new(FleetGauges::default())
    }

    /// A batch was handed to `agent` for execution.
    pub fn batch_started(&self, agent: &str, items: usize) {
        self.outstanding_items.fetch_add(items, Ordering::Relaxed);
        self.in_flight_batches.fetch_add(1, Ordering::Relaxed);
        let mut map = self.per_agent.lock().unwrap();
        let g = map.entry(agent.to_string()).or_default();
        g.outstanding_items += items;
        g.in_flight_batches += 1;
    }

    /// The batch came back (success or failure): undo the in-flight counts.
    pub fn batch_finished(&self, agent: &str, items: usize) {
        self.outstanding_items.fetch_sub(items, Ordering::Relaxed);
        self.in_flight_batches.fetch_sub(1, Ordering::Relaxed);
        let mut map = self.per_agent.lock().unwrap();
        let g = map.entry(agent.to_string()).or_default();
        g.outstanding_items = g.outstanding_items.saturating_sub(items);
        g.in_flight_batches = g.in_flight_batches.saturating_sub(1);
    }

    /// The batch executed successfully.
    pub fn batch_completed(&self, items: usize) {
        self.completed_batches.fetch_add(1, Ordering::Relaxed);
        self.completed_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// A sweep pass begins: `total` cells in the cross-product. Counters
    /// accumulate across passes, so a controller running several sweeps
    /// shows fleet-lifetime progress.
    pub fn sweep_started(&self, total: usize) {
        self.cells_total.fetch_add(total, Ordering::Relaxed);
    }

    pub fn cells_memoized(&self, n: usize) {
        self.cells_memoized.fetch_add(n, Ordering::Relaxed);
        self.cells_done.fetch_add(n, Ordering::Relaxed);
    }

    pub fn cell_executed(&self) {
        self.cells_done.fetch_add(1, Ordering::Relaxed);
    }

    pub fn cells_failed(&self, n: usize) {
        self.cells_failed.fetch_add(n, Ordering::Relaxed);
        self.cells_done.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one served request's latency into the rolling window.
    pub fn record_latency(&self, tenant: &str, secs: f64) {
        let mut ring = self.latencies.lock().unwrap();
        if ring.len() == LATENCY_RING {
            ring.pop_front();
        }
        ring.push_back((tenant.to_string(), secs));
    }

    /// Fold a completed evaluation's per-tenant tails into the window.
    pub fn fold_tenants(&self, tails: &TenantLatencies) {
        let mut ring = self.latencies.lock().unwrap();
        for (tenant, samples) in tails.iter() {
            for s in samples.samples() {
                if ring.len() == LATENCY_RING {
                    ring.pop_front();
                }
                ring.push_back((tenant.clone(), *s));
            }
        }
    }

    /// A consistent point-in-time copy for rendering or assertions.
    pub fn snapshot(&self) -> GaugesSnapshot {
        let per_agent = self.per_agent.lock().unwrap().clone();
        let ring = self.latencies.lock().unwrap();
        let mut by_tenant: BTreeMap<String, Vec<f64>> = BTreeMap::new();
        for (tenant, secs) in ring.iter() {
            by_tenant.entry(tenant.clone()).or_default().push(*secs);
        }
        let tenant_tails = by_tenant
            .into_iter()
            .map(|(tenant, samples)| TenantTail {
                tenant,
                count: samples.len(),
                p50_ms: percentile(&samples, 50.0) * 1e3,
                p99_ms: percentile(&samples, 99.0) * 1e3,
            })
            .collect();
        GaugesSnapshot {
            outstanding_items: self.outstanding_items.load(Ordering::Relaxed),
            in_flight_batches: self.in_flight_batches.load(Ordering::Relaxed),
            completed_batches: self.completed_batches.load(Ordering::Relaxed),
            completed_items: self.completed_items.load(Ordering::Relaxed),
            cells_total: self.cells_total.load(Ordering::Relaxed),
            cells_done: self.cells_done.load(Ordering::Relaxed),
            cells_memoized: self.cells_memoized.load(Ordering::Relaxed),
            cells_failed: self.cells_failed.load(Ordering::Relaxed),
            per_agent,
            tenant_tails,
        }
    }
}

/// Point-in-time dashboard state.
#[derive(Debug, Clone)]
pub struct GaugesSnapshot {
    pub outstanding_items: usize,
    pub in_flight_batches: usize,
    pub completed_batches: u64,
    pub completed_items: u64,
    pub cells_total: usize,
    pub cells_done: usize,
    pub cells_memoized: usize,
    pub cells_failed: usize,
    pub per_agent: BTreeMap<String, AgentGauge>,
    pub tenant_tails: Vec<TenantTail>,
}

/// Rolling latency tail for one tenant.
#[derive(Debug, Clone)]
pub struct TenantTail {
    pub tenant: String,
    pub count: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

fn lease_cell(remaining: Option<Duration>) -> String {
    match remaining {
        None => "gone".to_string(),
        Some(d) if d == Duration::MAX => "static".to_string(),
        Some(d) => format!("{:.1}s", d.as_secs_f64()),
    }
}

fn progress_bar(done: usize, total: usize, width: usize) -> String {
    if total == 0 {
        return format!("[{}]", " ".repeat(width));
    }
    let filled = (done * width / total).min(width);
    format!("[{}{}]", "#".repeat(filled), ".".repeat(width - filled))
}

/// Render one dashboard frame as plain text (no cursor movement — the
/// caller decides whether to wrap it in an in-place redraw).
pub fn render(registry: &Registry, gauges: &FleetGauges) -> String {
    let snap = gauges.snapshot();
    let mut out = String::new();
    out.push_str("mlms fleet dashboard\n");
    out.push_str("====================\n\n");

    // --- agents: identity, lease, standby, dispatch load ---------------
    let members = registry.lease_table();
    let standby_count = members.iter().filter(|(_, _, s)| *s).count();
    out.push_str(&format!(
        "agents ({} live, {} standby)\n",
        members.len() - standby_count,
        standby_count
    ));
    out.push_str("  id                        system        lease    state    outst  in-flight\n");
    for (a, lease, standby) in &members {
        let g = snap.per_agent.get(&a.id).copied().unwrap_or_default();
        out.push_str(&format!(
            "  {:<25} {:<13} {:<8} {:<8} {:>5}  {:>9}\n",
            truncate(&a.id, 25),
            truncate(&a.system, 13),
            lease_cell(Some(*lease)),
            if *standby { "standby" } else { "active" },
            g.outstanding_items,
            g.in_flight_batches,
        ));
    }
    if members.is_empty() {
        out.push_str("  (none joined)\n");
    }

    // --- dispatcher ----------------------------------------------------
    out.push_str(&format!(
        "\ndispatch   outstanding {} item(s), {} batch(es) in flight — {} batch(es) / {} item(s) completed\n",
        snap.outstanding_items,
        snap.in_flight_batches,
        snap.completed_batches,
        snap.completed_items,
    ));

    // --- sweep progress ------------------------------------------------
    if snap.cells_total > 0 {
        out.push_str(&format!(
            "sweep      {} {}/{} cell(s) — {} memoized, {} failed\n",
            progress_bar(snap.cells_done, snap.cells_total, 24),
            snap.cells_done,
            snap.cells_total,
            snap.cells_memoized,
            snap.cells_failed,
        ));
    } else {
        out.push_str("sweep      (no sweep running)\n");
    }

    // --- rolling latency tails ------------------------------------------
    if snap.tenant_tails.is_empty() {
        out.push_str("latency    (no samples yet)\n");
    } else {
        out.push_str(&format!(
            "latency    rolling window, last {} sample(s) max\n",
            LATENCY_RING
        ));
        out.push_str("  tenant            n      p50 ms     p99 ms\n");
        for t in &snap.tenant_tails {
            out.push_str(&format!(
                "  {:<15} {:>5}  {:>9.3}  {:>9.3}\n",
                truncate(&t.tenant, 15),
                t.count,
                t.p50_ms,
                t.p99_ms,
            ));
        }
    }
    out
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        return s.to_string();
    }
    let end = s.char_indices().take(n - 1).last().map_or(0, |(i, c)| i + c.len_utf8());
    format!("{}…", &s[..end])
}

/// Background renderer: redraws [`render`] output in place every
/// `interval` until stopped. Plain escape codes only — clear screen, home
/// the cursor, hide it while live.
pub struct LiveDash {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl LiveDash {
    pub fn spawn(
        registry: Arc<Registry>,
        gauges: Arc<FleetGauges>,
        interval: Duration,
    ) -> LiveDash {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::spawn(move || {
            use std::io::Write;
            print!("\x1b[?25l");
            while !stop2.load(Ordering::Relaxed) {
                // Home + clear-to-end redraws in place without the flash a
                // full-screen clear causes.
                print!("\x1b[H\x1b[2J{}", render(&registry, &gauges));
                let _ = std::io::stdout().flush();
                std::thread::sleep(interval);
            }
            print!("\x1b[?25h");
            let _ = std::io::stdout().flush();
        });
        LiveDash { stop, thread: Some(thread) }
    }

    /// Stop redrawing and restore the cursor.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for LiveDash {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauges_account_batches_and_cells() {
        let g = FleetGauges::new();
        g.batch_started("a1", 8);
        g.batch_started("a2", 4);
        let s = g.snapshot();
        assert_eq!(s.outstanding_items, 12);
        assert_eq!(s.in_flight_batches, 2);
        assert_eq!(s.per_agent["a1"].outstanding_items, 8);
        g.batch_finished("a1", 8);
        g.batch_completed(8);
        let s = g.snapshot();
        assert_eq!(s.outstanding_items, 4);
        assert_eq!(s.in_flight_batches, 1);
        assert_eq!(s.completed_batches, 1);
        assert_eq!(s.completed_items, 8);
        assert_eq!(s.per_agent["a1"].in_flight_batches, 0);

        g.sweep_started(10);
        g.cells_memoized(3);
        g.cell_executed();
        g.cells_failed(1);
        let s = g.snapshot();
        assert_eq!((s.cells_total, s.cells_done), (10, 5));
        assert_eq!((s.cells_memoized, s.cells_failed), (3, 1));
    }

    #[test]
    fn rolling_window_is_bounded_and_computes_tails() {
        let g = FleetGauges::new();
        for i in 0..(LATENCY_RING + 100) {
            g.record_latency("all", 0.001 * (i % 100) as f64);
        }
        let s = g.snapshot();
        assert_eq!(s.tenant_tails.len(), 1);
        assert_eq!(s.tenant_tails[0].count, LATENCY_RING);
        assert!(s.tenant_tails[0].p99_ms >= s.tenant_tails[0].p50_ms);
    }

    #[test]
    fn render_smokes_without_agents_or_samples() {
        let registry = Registry::new();
        let g = FleetGauges::new();
        let frame = render(&registry, &g);
        assert!(frame.contains("mlms fleet dashboard"));
        assert!(frame.contains("(none joined)"));
        assert!(frame.contains("(no samples yet)"));
        // Plain text — the frame itself carries no escape codes; the live
        // loop adds cursor control, the `--once` path prints it verbatim.
        assert!(!frame.contains('\x1b'));
    }

    #[test]
    fn progress_bar_shapes() {
        assert_eq!(progress_bar(0, 0, 4), "[    ]");
        assert_eq!(progress_bar(2, 4, 4), "[##..]");
        assert_eq!(progress_bar(4, 4, 4), "[####]");
    }
}
