//! System profiles — the paper's Table 1, plus the host itself.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Static description of one benchmarking system (a Table-1 row).
#[derive(Debug, Clone)]
pub struct SystemProfile {
    /// Registry name, e.g. `aws_p3`.
    pub name: String,
    pub cpu_name: String,
    pub gpu_name: String,
    pub gpu_architecture: String,
    /// Theoretical FP32 throughput (TFLOPs) — Table 1 column.
    pub gpu_tflops: f64,
    /// GPU memory bandwidth (GB/s) — Table 1 column.
    pub gpu_mem_bw_gbs: f64,
    pub gpu_mem_gb: f64,
    /// Host CPU sustained GFLOPs (estimated; used for CPU-side runs).
    pub cpu_gflops: f64,
    pub cpu_mem_bw_gbs: f64,
    pub host_mem_gb: f64,
    /// CPU architecture string for agent resolution (`x86_64`, `ppc64le`).
    pub architecture: String,
    /// Host↔device interconnect (`pcie3` or `nvlink`).
    pub interconnect: String,
    /// Measured interconnect bandwidth GB/s (paper §5.2: PCIe-3 12,
    /// NVLink 33).
    pub interconnect_measured_gbs: f64,
    /// On-demand cost — Table 1 column; 0 for on-prem (IBM P8).
    pub cost_per_hr: f64,
}

impl SystemProfile {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("cpu", Json::str(&self.cpu_name)),
            ("gpu", Json::str(&self.gpu_name)),
            ("gpu_architecture", Json::str(&self.gpu_architecture)),
            ("gpu_tflops", Json::num(self.gpu_tflops)),
            ("gpu_mem_bw_gbs", Json::num(self.gpu_mem_bw_gbs)),
            ("gpu_mem_gb", Json::num(self.gpu_mem_gb)),
            ("architecture", Json::str(&self.architecture)),
            ("interconnect", Json::str(&self.interconnect)),
            ("interconnect_measured_gbs", Json::num(self.interconnect_measured_gbs)),
            ("cost_per_hr", Json::num(self.cost_per_hr)),
        ])
    }
}

/// Known interconnects with (theoretical, measured) GB/s — paper §5.2.
pub const INTERCONNECTS: &[(&str, f64, f64)] =
    &[("pcie3", 16.0, 12.0), ("nvlink", 40.0, 33.0)];

/// The paper's four Table-1 systems, in table order — the simulated-agent
/// fleet the standard platform attaches and the default sweep targets
/// (`local` is excluded: it is the real host, not a simulated profile).
pub fn table1_system_names() -> Vec<String> {
    ["aws_p3", "aws_g3", "aws_p2", "ibm_p8"].iter().map(|s| s.to_string()).collect()
}

/// The paper's Table 1 systems (plus `local` — the actual host, used when
/// agents run real PJRT executions rather than simulations).
pub fn systems() -> BTreeMap<String, SystemProfile> {
    let mut m = BTreeMap::new();
    m.insert(
        "aws_p3".to_string(),
        SystemProfile {
            name: "aws_p3".into(),
            cpu_name: "Intel Xeon E5-2686 v4 @ 2.30GHz".into(),
            gpu_name: "Tesla V100-SXM2-16GB".into(),
            gpu_architecture: "Volta".into(),
            gpu_tflops: 15.7,
            gpu_mem_bw_gbs: 900.0,
            gpu_mem_gb: 16.0,
            cpu_gflops: 590.0,
            cpu_mem_bw_gbs: 60.0,
            host_mem_gb: 61.0,
            architecture: "x86_64".into(),
            interconnect: "pcie3".into(),
            interconnect_measured_gbs: 12.0,
            cost_per_hr: 3.06,
        },
    );
    m.insert(
        "aws_g3".to_string(),
        SystemProfile {
            name: "aws_g3".into(),
            cpu_name: "Intel Xeon E5-2686 v4 @ 2.30GHz".into(),
            gpu_name: "Tesla M60".into(),
            gpu_architecture: "Maxwell".into(),
            gpu_tflops: 9.6,
            gpu_mem_bw_gbs: 320.0,
            gpu_mem_gb: 8.0,
            cpu_gflops: 295.0,
            cpu_mem_bw_gbs: 40.0,
            host_mem_gb: 30.5,
            architecture: "x86_64".into(),
            interconnect: "pcie3".into(),
            interconnect_measured_gbs: 12.0,
            cost_per_hr: 0.90,
        },
    );
    m.insert(
        "aws_p2".to_string(),
        SystemProfile {
            name: "aws_p2".into(),
            cpu_name: "Intel Xeon E5-2686 v4 @ 2.30GHz".into(),
            gpu_name: "Tesla K80".into(),
            gpu_architecture: "Kepler".into(),
            // K80 per-die FP32: 5.6 TFLOPs (Table 1) but Kepler sustains a
            // far lower fraction on DL kernels; the lower memory clock of
            // the K80 (480 GB/s shared across two dies → ~240 effective)
            // is folded into the bandwidth figure.
            gpu_tflops: 5.6,
            gpu_mem_bw_gbs: 240.0,
            gpu_mem_gb: 12.0,
            cpu_gflops: 295.0,
            cpu_mem_bw_gbs: 40.0,
            host_mem_gb: 61.0,
            architecture: "x86_64".into(),
            interconnect: "pcie3".into(),
            interconnect_measured_gbs: 12.0,
            cost_per_hr: 0.75,
        },
    );
    m.insert(
        "ibm_p8".to_string(),
        SystemProfile {
            name: "ibm_p8".into(),
            cpu_name: "IBM S822LC Power8 @ 3.5GHz".into(),
            gpu_name: "Tesla P100-SXM2".into(),
            gpu_architecture: "Pascal".into(),
            gpu_tflops: 10.6,
            gpu_mem_bw_gbs: 732.0,
            gpu_mem_gb: 16.0,
            // Paper §5.1: P8 1.7×–4.1× over the Xeon (10 cores × 80 SMT).
            cpu_gflops: 1475.0,
            cpu_mem_bw_gbs: 115.0,
            host_mem_gb: 128.0,
            architecture: "ppc64le".into(),
            interconnect: "nvlink".into(),
            interconnect_measured_gbs: 33.0,
            cost_per_hr: 0.0,
        },
    );
    m.insert(
        "local".to_string(),
        SystemProfile {
            name: "local".into(),
            cpu_name: "host CPU (PJRT CPU client)".into(),
            gpu_name: "none".into(),
            gpu_architecture: "none".into(),
            gpu_tflops: 0.0,
            gpu_mem_bw_gbs: 0.0,
            gpu_mem_gb: 0.0,
            cpu_gflops: 50.0,
            cpu_mem_bw_gbs: 10.0,
            host_mem_gb: 4.0,
            architecture: std::env::consts::ARCH.to_string(),
            interconnect: "none".into(),
            interconnect_measured_gbs: f64::INFINITY,
            cost_per_hr: 0.0,
        },
    );
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_rows_present() {
        let s = systems();
        for name in ["aws_p3", "aws_g3", "aws_p2", "ibm_p8", "local"] {
            assert!(s.contains_key(name), "missing {name}");
        }
        // Spot-check Table 1 numbers.
        assert_eq!(s["aws_p3"].gpu_tflops, 15.7);
        assert_eq!(s["aws_p3"].gpu_mem_bw_gbs, 900.0);
        assert_eq!(s["aws_p3"].cost_per_hr, 3.06);
        assert_eq!(s["ibm_p8"].gpu_architecture, "Pascal");
        assert_eq!(s["ibm_p8"].interconnect, "nvlink");
        assert_eq!(s["aws_g3"].cost_per_hr, 0.90);
        assert_eq!(s["aws_p2"].cost_per_hr, 0.75);
    }

    #[test]
    fn json_has_core_fields() {
        let j = systems()["aws_p3"].to_json();
        assert_eq!(j.get("gpu_architecture").unwrap().as_str(), Some("Volta"));
        assert_eq!(j.get("interconnect").unwrap().as_str(), Some("pcie3"));
    }

    #[test]
    fn interconnect_constants() {
        let nv = INTERCONNECTS.iter().find(|(n, _, _)| *n == "nvlink").unwrap();
        assert_eq!(nv.1, 40.0);
        assert_eq!(nv.2, 33.0);
    }
}
