//! System models: roofline simulation of the paper's Table-1 hardware.
//!
//! The paper evaluates on four systems (AWS P3/V100, AWS G3/M60, AWS P2/K80,
//! IBM P8/P100) that are not available here. Paper §4.4.4 explicitly allows
//! the trace pipeline to consume *simulated* time ("users may integrate a
//! system simulator and publish simulated time rather than wall-clock time
//! to the tracing server"); this module is that simulator.
//!
//! The model is an analytic roofline:
//!
//! ```text
//! t_kernel = t_launch + max(flops / (peak_flops · eff), bytes / mem_bw)
//! t_copy   = bytes / interconnect_bw          (host→device, cold start)
//! ```
//!
//! with per-batch weight amortization: weights are read once per kernel
//! regardless of batch size, activations scale with batch. This single
//! mechanism reproduces the paper's qualitative results: small models are
//! launch-bound at batch 1 (good throughput scalability, Fig 6), VGG's huge
//! FC weights amortize across the batch (the paper's "VGG exception"),
//! cold-start AlexNet is bound by the fc6 weight copy where NVLink beats
//! PCIe (Fig 8), and V100 < P100 < M60 < K80 latency ordering (Fig 7).

mod kernels;
mod profile;

pub use kernels::{dominant_kernels, KernelSim};
pub use profile::{systems, table1_system_names, SystemProfile, INTERCONNECTS};
pub use profile::systems as profile_map;

use crate::util::json::Json;

/// The device class a simulated execution runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Device {
    Cpu,
    Gpu,
}

/// An abstract unit of device work — one framework-level layer's worth.
///
/// Produced by [`crate::zoo`] layer generators, consumed by the simulator.
#[derive(Debug, Clone)]
pub struct WorkUnit {
    /// Layer kind, e.g. `Conv2D`, `MatMul`, `Pool`, `BatchNorm`, `Relu`.
    pub kind: String,
    /// FLOPs per *single* input (batch of 1).
    pub flops_per_item: f64,
    /// Activation bytes (read + written) per single input.
    pub act_bytes_per_item: f64,
    /// Weight bytes — read once per kernel, *not* scaled by batch.
    pub weight_bytes: f64,
}

impl WorkUnit {
    pub fn new(kind: &str, flops_per_item: f64, act_bytes_per_item: f64, weight_bytes: f64) -> Self {
        WorkUnit {
            kind: kind.to_string(),
            flops_per_item,
            act_bytes_per_item,
            weight_bytes,
        }
    }
}

/// Simulated timing breakdown for one work unit at a given batch size.
#[derive(Debug, Clone)]
pub struct SimTiming {
    /// Total kernel time (seconds) including launch overhead.
    pub total: f64,
    /// Compute-limited component.
    pub compute: f64,
    /// Memory-bandwidth-limited component.
    pub memory: f64,
    /// Kernel launch / framework dispatch overhead.
    pub launch: f64,
    /// True when `memory > compute` (the kernel is bandwidth-bound).
    pub memory_bound: bool,
}

/// Simulated host→device copy (cold-start weight upload, Fig 8).
#[derive(Debug, Clone)]
pub struct SimCopy {
    pub bytes: f64,
    pub seconds: f64,
}

/// Per-(system, device) simulator.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub profile: SystemProfile,
    pub device: Device,
}

impl Simulator {
    pub fn new(profile: SystemProfile, device: Device) -> Simulator {
        Simulator { profile, device }
    }

    fn peak_flops(&self) -> f64 {
        match self.device {
            Device::Gpu => self.profile.gpu_tflops * 1e12,
            Device::Cpu => self.profile.cpu_gflops * 1e9,
        }
    }

    fn mem_bw(&self) -> f64 {
        match self.device {
            Device::Gpu => self.profile.gpu_mem_bw_gbs * 1e9,
            Device::Cpu => self.profile.cpu_mem_bw_gbs * 1e9,
        }
    }

    fn launch_overhead(&self) -> f64 {
        match self.device {
            // CUDA kernel launch + framework dispatch.
            Device::Gpu => 8e-6,
            // Framework op dispatch on CPU.
            Device::Cpu => 2e-6,
        }
    }

    /// Sustained-efficiency model: large regular kernels (conv/matmul)
    /// approach a high fraction of peak; small/elementwise ops are far from
    /// it. Efficiency ramps with *batch* (device occupancy): the paper's own
    /// batch-1 Table-2 data implies ~9% of peak for every model at batch 1
    /// (ResNet50 7.7 GFLOPs / 6.33 ms, VGG16 31 GFLOPs / 22.4 ms,
    /// Inception-v3 11.5 GFLOPs / 9.2 ms all sit on the same effective-
    /// throughput line), saturating as batching fills the SMs — which is
    /// what makes throughput scale with batch until saturation (Fig 6).
    fn efficiency(&self, kind: &str, batch: f64) -> f64 {
        let eff_max: f64 = match kind {
            "Conv2D" | "MatMul" | "Dense" => match self.device {
                Device::Gpu => 0.62,
                Device::Cpu => 0.45,
            },
            "DepthwiseConv2D" => 0.18, // bandwidth-starved on every arch
            "Pool" | "BatchNorm" | "Relu" | "Add" | "Concat" => 0.08,
            "Softmax" | "LRN" => 0.05,
            _ => 0.10,
        };
        // Occupancy half-point: GPUs need ~6 concurrent items to fill the
        // SMs; CPUs saturate almost immediately.
        let b_half = match self.device {
            Device::Gpu => 6.0,
            Device::Cpu => 1.0,
        };
        let ramp = batch / (batch + b_half);
        eff_max * ramp.max(0.02)
    }

    /// Simulate one work unit at `batch`.
    pub fn layer_time(&self, w: &WorkUnit, batch: usize) -> SimTiming {
        let b = batch.max(1) as f64;
        let flops = w.flops_per_item * b;
        let eff = self.efficiency(&w.kind, b);
        let compute = flops / (self.peak_flops() * eff);
        // Activations scale with batch; weights stream once per kernel.
        let bytes = w.act_bytes_per_item * b + w.weight_bytes;
        let memory = bytes / self.mem_bw();
        let launch = self.launch_overhead();
        let total = launch + compute.max(memory);
        SimTiming { total, compute, memory, launch, memory_bound: memory > compute }
    }

    /// Simulate an entire model (list of work units) at `batch`; returns
    /// (total seconds, per-layer timings).
    pub fn model_time(&self, layers: &[WorkUnit], batch: usize) -> (f64, Vec<SimTiming>) {
        let timings: Vec<SimTiming> = layers.iter().map(|l| self.layer_time(l, batch)).collect();
        let total = timings.iter().map(|t| t.total).sum();
        (total, timings)
    }

    /// Host→device copy over the system interconnect (measured bandwidth).
    pub fn host_to_device(&self, bytes: f64) -> SimCopy {
        let bw = self.profile.interconnect_measured_gbs * 1e9;
        SimCopy { bytes, seconds: bytes / bw }
    }

    /// Largest batch that fits device memory given per-item activation
    /// footprint + weights (used to bound the Table-2 batch sweeps).
    pub fn max_batch(&self, layers: &[WorkUnit]) -> usize {
        let mem = match self.device {
            Device::Gpu => self.profile.gpu_mem_gb * 1e9,
            Device::Cpu => self.profile.host_mem_gb * 1e9,
        };
        let weights: f64 = layers.iter().map(|l| l.weight_bytes).sum();
        // Peak live activations ≈ the largest single layer's activations ×2
        // (in + out), a standard serving approximation.
        let peak_act: f64 = layers
            .iter()
            .map(|l| l.act_bytes_per_item)
            .fold(0.0, f64::max)
            * 2.0;
        if peak_act <= 0.0 {
            return 1;
        }
        (((mem * 0.9 - weights) / peak_act).max(1.0)) as usize
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("system", Json::str(&self.profile.name)),
            (
                "device",
                Json::str(match self.device {
                    Device::Cpu => "cpu",
                    Device::Gpu => "gpu",
                }),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysmodel::profile::systems;

    fn conv() -> WorkUnit {
        // A mid-size ResNet conv: ~200 MFLOPs/item, 3 MB activations, 2 MB weights.
        WorkUnit::new("Conv2D", 2e8, 3e6, 2e6)
    }

    #[test]
    fn v100_beats_k80() {
        let p3 = Simulator::new(systems()["aws_p3"].clone(), Device::Gpu);
        let p2 = Simulator::new(systems()["aws_p2"].clone(), Device::Gpu);
        let w = conv();
        assert!(p3.layer_time(&w, 32).total < p2.layer_time(&w, 32).total);
    }

    #[test]
    fn latency_ordering_matches_paper_fig7() {
        // V100 < P100 < M60 < K80 at moderate batch.
        let order = ["aws_p3", "ibm_p8", "aws_g3", "aws_p2"];
        let w = conv();
        let times: Vec<f64> = order
            .iter()
            .map(|s| Simulator::new(systems()[*s].clone(), Device::Gpu).layer_time(&w, 64).total)
            .collect();
        for i in 1..times.len() {
            assert!(times[i - 1] < times[i], "{order:?} → {times:?}");
        }
    }

    #[test]
    fn batch_amortizes_launch_and_weights() {
        let sim = Simulator::new(systems()["aws_p3"].clone(), Device::Gpu);
        let w = conv();
        let t1 = sim.layer_time(&w, 1).total;
        let t64 = sim.layer_time(&w, 64).total;
        // Throughput at batch 64 must exceed batch 1 (Fig 6 speedup > 1).
        assert!(64.0 / t64 > 1.0 / t1);
    }

    #[test]
    fn weight_heavy_layer_is_memory_bound_at_batch1() {
        // VGG/AlexNet fc6-style layer: moderate flops, huge weights.
        let fc6 = WorkUnit::new("Dense", 7.5e7, 8e4, 150e6);
        let sim = Simulator::new(systems()["aws_p3"].clone(), Device::Gpu);
        let t = sim.layer_time(&fc6, 1);
        assert!(t.memory_bound, "fc6 at batch 1 must be bandwidth-bound: {t:?}");
        // …and becomes compute-bound only at large batch.
        let t256 = sim.layer_time(&fc6, 256);
        assert!(t256.compute > t.compute);
    }

    #[test]
    fn nvlink_copy_faster_than_pcie_fig8() {
        let p3 = Simulator::new(systems()["aws_p3"].clone(), Device::Gpu);
        let p8 = Simulator::new(systems()["ibm_p8"].clone(), Device::Gpu);
        let fc6_weights = 37_748_736.0 * 4.0; // AlexNet fc6 9216×4096 f32
        let c_p3 = p3.host_to_device(fc6_weights);
        let c_p8 = p8.host_to_device(fc6_weights);
        assert!(c_p8.seconds < c_p3.seconds, "NVLink must beat PCIe");
        // Ratio close to 33/12 measured bandwidth ratio.
        let ratio = c_p3.seconds / c_p8.seconds;
        assert!((2.0..4.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn p8_cpu_faster_than_xeon() {
        let p8 = Simulator::new(systems()["ibm_p8"].clone(), Device::Cpu);
        let p3 = Simulator::new(systems()["aws_p3"].clone(), Device::Cpu);
        let w = conv();
        let s = p3.layer_time(&w, 16).total / p8.layer_time(&w, 16).total;
        // Paper: 1.7×–4.1× speedup of P8 over Xeon E5-2686.
        assert!((1.3..5.0).contains(&s), "speedup {s}");
    }

    #[test]
    fn max_batch_is_positive_and_memory_scaled() {
        let sim_big = Simulator::new(systems()["aws_p3"].clone(), Device::Gpu);
        let sim_small = Simulator::new(systems()["aws_g3"].clone(), Device::Gpu);
        let layers = vec![conv(); 20];
        assert!(sim_big.max_batch(&layers) >= sim_small.max_batch(&layers));
        assert!(sim_small.max_batch(&layers) >= 1);
    }

    #[test]
    fn property_more_work_never_faster() {
        crate::util::rng::forall(31, 100, |rng| {
            let sim = Simulator::new(systems()["aws_p3"].clone(), Device::Gpu);
            let f = rng.range_f64(1e6, 1e10);
            let a = rng.range_f64(1e4, 1e8);
            let wt = rng.range_f64(0.0, 1e8);
            let w1 = WorkUnit::new("Conv2D", f, a, wt);
            let w2 = WorkUnit::new("Conv2D", f * 2.0, a, wt);
            let b = 1 + rng.below(256) as usize;
            assert!(sim.layer_time(&w2, b).total >= sim.layer_time(&w1, b).total);
            // Larger batch never reduces total time either.
            assert!(sim.layer_time(&w1, b + 1).total >= sim.layer_time(&w1, b).total * 0.999);
        });
    }
}
