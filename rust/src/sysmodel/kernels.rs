//! GPU-kernel-level simulation: which device kernels a framework layer
//! launches, with per-kernel time splits.
//!
//! This backs the paper's Table 3 / §5.3 analysis ("layer index 208 launches
//! 7 GPU kernels: volta_cgemm_32x32_tn …") — the SYSTEM-level trace events.
//! Kernel naming follows cuDNN/TensorFlow conventions keyed by the GPU
//! architecture, and the kernel *mix* depends on the convolution algorithm
//! the layer would select (FFT for large late-stage convs, implicit-GEMM
//! otherwise), mirroring the paper's observed ResNet_50 breakdown.

use super::{SimTiming, Simulator, WorkUnit};

/// One simulated device kernel launched by a framework layer.
#[derive(Debug, Clone)]
pub struct KernelSim {
    pub name: String,
    pub seconds: f64,
    /// Device memory allocated by / attributed to this kernel (bytes).
    pub alloc_bytes: f64,
}

fn arch_prefix(arch: &str) -> &'static str {
    match arch {
        "Volta" => "volta",
        "Pascal" => "pascal",
        "Maxwell" => "maxwell",
        "Kepler" => "kepler",
        _ => "generic",
    }
}

/// Decide the conv algorithm the way cuDNN heuristics roughly do: FFT wins
/// for small spatial dims with large channel counts (late ResNet stages —
/// exactly the paper's layer 208 case), implicit GEMM otherwise.
fn conv_uses_fft(w: &WorkUnit) -> bool {
    // Encode the heuristic on the analytic signature: weight-heavy relative
    // to activations ⇒ late-stage conv with ≥512 channels and 7×7 maps.
    w.weight_bytes > 2.0 * w.act_bytes_per_item && w.weight_bytes > 4e6
}

/// Expand a framework layer into its simulated GPU kernels.
///
/// The per-layer total time (from [`Simulator::layer_time`]) is split across
/// kernels with fixed proportions measured from the paper's own Table-3 /
/// §5.3 narration (e.g. the FFT path: cgemm 80%, flip_filter 6%, r2c 6%,
/// c2r 3%, r2c 3%, shuffle 1%, pointer setup ~0).
pub fn dominant_kernels(
    sim: &Simulator,
    w: &WorkUnit,
    timing: &SimTiming,
    batch: usize,
) -> Vec<KernelSim> {
    let arch = arch_prefix(&sim.profile.gpu_architecture);
    let t = timing.total;
    let alloc = w.act_bytes_per_item * batch as f64 + w.weight_bytes;
    let mk = |name: String, frac: f64| KernelSim {
        name,
        seconds: t * frac,
        alloc_bytes: alloc * frac.min(1.0),
    };
    match w.kind.as_str() {
        "Conv2D" => {
            if conv_uses_fft(w) {
                vec![
                    mk(format!("{arch}_cgemm_32x32_tn"), 0.80),
                    mk("flip_filter".into(), 0.057),
                    mk("fft2d_r2c_16x16".into(), 0.056),
                    mk("fft2d_c2r_16x16".into(), 0.033),
                    mk("fft2d_r2c_16x16".into(), 0.033),
                    mk("ShuffleInTensor3Simple".into(), 0.008),
                    mk("compute_gemm_pointers".into(), 0.0005),
                ]
            } else {
                let tile = if w.flops_per_item > 1e8 { "128x128" } else { "128x64" };
                vec![
                    mk(format!("{arch}_scudnn_{tile}_relu_interior_nn_v1"), 0.93),
                    mk("ShuffleInTensor3Simple".into(), 0.05),
                    mk("compute_gemm_pointers".into(), 0.02),
                ]
            }
        }
        "Dense" | "MatMul" => vec![
            mk(format!("{arch}_sgemm_128x64_tn"), 0.95),
            mk("splitKreduce_kernel".into(), 0.05),
        ],
        "DepthwiseConv2D" => vec![mk("DepthwiseConv2dGPUKernelNHWC".into(), 1.0)],
        "Pool" => vec![mk("cudnn::pooling_fw_4d_kernel".into(), 1.0)],
        "BatchNorm" => vec![mk("cudnn::bn_fw_inf_1C11_kernel_NCHW".into(), 1.0)],
        "Relu" => vec![mk("op_generic_tensor_kernel".into(), 1.0)],
        "Softmax" => vec![mk("softmax_warp_forward".into(), 1.0)],
        "LRN" => vec![mk("cudnn::lrn_fw_4d_kernel".into(), 1.0)],
        "Add" => vec![mk("op_tensor_kernel".into(), 1.0)],
        "Concat" => vec![mk("concat_variable_kernel".into(), 1.0)],
        _ => vec![mk("generic_kernel".into(), 1.0)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysmodel::profile::systems;
    use crate::sysmodel::Device;

    fn sim() -> Simulator {
        Simulator::new(systems()["aws_p3"].clone(), Device::Gpu)
    }

    /// Paper §5.3: layer 208 (late-stage conv) launches 7 kernels with
    /// volta_cgemm_32x32_tn dominant.
    #[test]
    fn late_stage_conv_takes_fft_path_on_volta() {
        // conv2d_48: 512ch 7×7 — weights ≫ activations.
        let w = WorkUnit::new("Conv2D", 4e8, 2e5, 9.4e6);
        let s = sim();
        let t = s.layer_time(&w, 256);
        let ks = dominant_kernels(&s, &w, &t, 256);
        assert_eq!(ks.len(), 7, "{ks:?}");
        assert_eq!(ks[0].name, "volta_cgemm_32x32_tn");
        // Dominant kernel holds the largest share.
        assert!(ks.iter().all(|k| k.seconds <= ks[0].seconds));
        // Time split sums to ≈ total.
        let sum: f64 = ks.iter().map(|k| k.seconds).sum();
        assert!((sum - t.total).abs() / t.total < 0.05, "{sum} vs {}", t.total);
    }

    #[test]
    fn early_conv_takes_gemm_path() {
        // conv2d/Conv2D first layer: activations ≫ weights.
        let w = WorkUnit::new("Conv2D", 1.2e8, 3.2e6, 3.8e4);
        let s = sim();
        let t = s.layer_time(&w, 256);
        let ks = dominant_kernels(&s, &w, &t, 256);
        assert!(ks[0].name.contains("scudnn"), "{}", ks[0].name);
        assert!(ks[0].name.starts_with("volta_"));
    }

    #[test]
    fn arch_prefix_follows_system() {
        let w = WorkUnit::new("Dense", 1e8, 1e5, 1e6);
        for (sysname, prefix) in
            [("aws_p3", "volta"), ("ibm_p8", "pascal"), ("aws_g3", "maxwell"), ("aws_p2", "kepler")]
        {
            let s = Simulator::new(systems()[sysname].clone(), Device::Gpu);
            let t = s.layer_time(&w, 8);
            let ks = dominant_kernels(&s, &w, &t, 8);
            assert!(ks[0].name.starts_with(prefix), "{} → {}", sysname, ks[0].name);
        }
    }

    #[test]
    fn every_layer_kind_produces_kernels() {
        let s = sim();
        for kind in [
            "Conv2D", "Dense", "MatMul", "DepthwiseConv2D", "Pool", "BatchNorm", "Relu",
            "Softmax", "LRN", "Add", "Concat", "Unknown",
        ] {
            let w = WorkUnit::new(kind, 1e7, 1e5, 1e5);
            let t = s.layer_time(&w, 4);
            let ks = dominant_kernels(&s, &w, &t, 4);
            assert!(!ks.is_empty(), "{kind}");
            assert!(ks.iter().all(|k| k.seconds >= 0.0 && k.alloc_bytes >= 0.0));
        }
    }
}
