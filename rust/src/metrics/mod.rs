//! Benchmark metric primitives: latency samples, percentiles, trimmed mean,
//! throughput counters.
//!
//! The paper reports *trimmed mean* latency (drop the lowest/highest 20% and
//! average the rest — Table 2 footnote), 90th-percentile latency, and
//! maximum throughput. These definitions live here so every layer (agent,
//! analysis workflow, benches) computes them identically — the paper's F2
//! "consistent evaluation" applied to the metrics themselves.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A set of latency samples (seconds) with the paper's summary statistics.
#[derive(Debug, Clone, Default)]
pub struct LatencySamples {
    samples: Vec<f64>,
}

impl LatencySamples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_secs(samples: Vec<f64>) -> Self {
        LatencySamples { samples }
    }

    pub fn record(&mut self, d: Duration) {
        self.samples.push(d.as_secs_f64());
    }

    pub fn record_secs(&mut self, s: f64) {
        self.samples.push(s);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Paper Table 2 footnote: sort, drop `floor(0.2*n)` from each end, mean
    /// of the remainder.
    pub fn trimmed_mean(&self) -> f64 {
        trimmed_mean(&self.samples, 0.2)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by nearest-rank on the sorted samples; `q` in `[0, 100]`,
    /// clamped (`NaN` `q` → `NaN`) — see [`percentile`].
    pub fn percentile(&self, q: f64) -> f64 {
        percentile(&self.samples, q)
    }

    /// Sort once for repeated quantile queries — see [`SortedSamples`].
    pub fn sorted(&self) -> SortedSamples {
        SortedSamples::of(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(90.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }
}

/// `TrimmedMean(list) = Mean(Sort(list)[⌊0.2·len⌋ : -⌊0.2·len⌋])` — the exact
/// definition in the paper's footnote 1 (with a configurable fraction).
pub fn trimmed_mean(samples: &[f64], frac: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    // `total_cmp` everywhere a latency vector is sorted: a NaN sample (a
    // corrupt trace, a failed probe) sorts last instead of panicking the
    // metrics path — and then poisons the aggregate, which is the honest
    // outcome for NaN input.
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let cut = ((frac * sorted.len() as f64).floor() as usize).min((sorted.len() - 1) / 2);
    let kept = &sorted[cut..sorted.len() - cut];
    kept.iter().sum::<f64>() / kept.len() as f64
}

/// True sample median: midpoint of the two central order statistics for
/// even counts (unlike the nearest-rank [`percentile`]`(50)`, which snaps
/// to one of them). The regression gate ([`crate::regress`]) compares and
/// tracks *medians* — robust to tail outliers, sensitive to the typical
/// request — so the exact definition lives here beside the other shared
/// metric primitives. Empty input returns `NaN`, matching [`percentile`].
pub fn median(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let n = sorted.len();
    if n % 2 == 1 {
        sorted[n / 2]
    } else {
        (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
    }
}

/// Nearest-rank percentile over unsorted samples; `q` in `[0, 100]`.
///
/// Edge cases (pinned by tests): an **empty** input returns `NaN` — there
/// is no meaningful percentile of nothing, and `NaN` poisons downstream
/// arithmetic instead of silently reading as "0 ms latency". A single
/// sample is every percentile of itself; constant samples return that
/// constant for every `q`. An out-of-range `q` is clamped to `[0, 100]`
/// (a negative rank or a rank past the slice is never computed) and a
/// `NaN` `q` returns `NaN` — asking for the NaN-th percentile has no
/// answer, and silently reading it as p0 would hide the caller's bug.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    percentile_sorted(&sorted, q)
}

/// Nearest-rank percentile over **already-sorted** samples (ascending by
/// `f64::total_cmp`); `q` in `[0, 100]`, clamped, `NaN` `q` → `NaN`.
///
/// This is the allocation-free core of [`percentile`]: report paths that
/// ask for many quantiles of the same vector ([`SummaryStats::of`],
/// [`TenantLatencies::to_json`], [`SortedSamples`]) sort once and query
/// through here instead of paying a clone + `O(n log n)` sort per call.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() || q.is_nan() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 100.0);
    let rank = ((q / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// A latency vector sorted **once**, answering any number of quantile /
/// trimmed-mean / extrema queries without re-cloning or re-sorting.
///
/// [`percentile`] is O(n log n) *per call* because it must defensively
/// clone and sort; a report that asks for p50/p90/p99 across dozens of
/// tenants pays that dozens of times over identical data. `SortedSamples`
/// is the cached-sorted path: build it from the raw samples, then every
/// query is O(1) (quantiles, min/max) or O(n) (means) over the one sorted
/// buffer. All definitions delegate to the same primitives as the ad-hoc
/// helpers, so the two paths are observationally identical (pinned by
/// property test).
#[derive(Debug, Clone, Default)]
pub struct SortedSamples {
    sorted: Vec<f64>,
}

impl SortedSamples {
    /// Sort once (ascending `total_cmp`: NaN samples sort last and poison
    /// aggregates, same contract as [`trimmed_mean`]).
    pub fn of(samples: &[f64]) -> SortedSamples {
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        SortedSamples { sorted }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// The sorted samples, ascending.
    pub fn as_slice(&self) -> &[f64] {
        &self.sorted
    }

    /// Nearest-rank percentile; same clamp/`NaN` contract as [`percentile`].
    pub fn percentile(&self, q: f64) -> f64 {
        percentile_sorted(&self.sorted, q)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(90.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// True median (midpoint of central pair for even counts), matching
    /// [`median`].
    pub fn median(&self) -> f64 {
        let n = self.sorted.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            self.sorted[n / 2]
        } else {
            (self.sorted[n / 2 - 1] + self.sorted[n / 2]) / 2.0
        }
    }

    /// Trimmed mean over the pre-sorted buffer, matching [`trimmed_mean`].
    pub fn trimmed_mean(&self, frac: f64) -> f64 {
        if self.sorted.is_empty() {
            return f64::NAN;
        }
        let len = self.sorted.len();
        let cut = ((frac * len as f64).floor() as usize).min((len - 1) / 2);
        let kept = &self.sorted[cut..len - cut];
        kept.iter().sum::<f64>() / kept.len() as f64
    }

    pub fn mean(&self) -> f64 {
        if self.sorted.is_empty() {
            return f64::NAN;
        }
        self.sorted.iter().sum::<f64>() / self.sorted.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.sorted.first().copied().unwrap_or(f64::INFINITY)
    }

    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(f64::NEG_INFINITY)
    }
}

/// Compact summary of a sample set — the fields every aggregate view
/// (per-signature span profiles, per-tenant tails) reports. Built once from
/// the raw samples so all consumers share [`percentile`]'s definitions (F2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SummaryStats {
    pub count: usize,
    /// Mean (`NaN` on empty input, like [`percentile`]).
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
}

impl SummaryStats {
    pub fn of(samples: &[f64]) -> SummaryStats {
        if samples.is_empty() {
            return SummaryStats { count: 0, mean: f64::NAN, p50: f64::NAN, p99: f64::NAN };
        }
        // One sort serves both quantiles (the old path sorted twice).
        let sorted = SortedSamples::of(samples);
        SummaryStats {
            count: sorted.len(),
            mean: sorted.mean(),
            p50: sorted.p50(),
            p99: sorted.p99(),
        }
    }
}

/// A fixed-boundary histogram for cheap hot-path latency recording (used by
/// the agent where keeping every raw sample would be a scaling hazard, F4).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds, seconds, ascending; final bucket is +inf.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    /// Exponential buckets from `start` seconds, `factor` growth, `n` buckets.
    pub fn exponential(start: f64, factor: f64, n: usize) -> Histogram {
        assert!(start > 0.0 && factor > 1.0 && n > 0);
        let mut bounds = Vec::with_capacity(n);
        let mut b = start;
        for _ in 0..n {
            bounds.push(b);
            b *= factor;
        }
        Histogram { counts: vec![0; n + 1], bounds, total: 0, sum: 0.0 }
    }

    /// Default latency histogram: 10µs → ~84s in 32 ×1.6 buckets.
    pub fn latency_default() -> Histogram {
        Histogram::exponential(10e-6, 1.6, 32)
    }

    pub fn record(&mut self, secs: f64) {
        let idx = self.bounds.partition_point(|b| *b < secs);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += secs;
    }

    /// Remove one previously-[`Histogram::record`]ed sample — the eviction
    /// half of a rolling window (see [`crate::slo::RollingSloJudge`]). The
    /// sample must have been recorded; forgetting a value that wasn't is a
    /// saturating no-op on the bucket rather than an underflow panic.
    pub fn forget(&mut self, secs: f64) {
        let idx = self.bounds.partition_point(|b| *b < secs);
        if self.counts[idx] == 0 {
            return;
        }
        self.counts[idx] -= 1;
        self.total -= 1;
        self.sum -= secs;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    /// Quantile estimate by linear interpolation within the bucket; `q` in
    /// `[0, 1]`.
    ///
    /// Edge cases (pinned by tests): an **empty** histogram returns `NaN`
    /// (same contract as [`percentile`]); single and constant samples
    /// return a value inside the bucket holding them, i.e. within one
    /// bucket growth factor of the true value — bucketing trades exactness
    /// for O(1) streaming recording.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() { self.bounds[i] } else { self.bounds[self.bounds.len() - 1] * 2.0 };
                let frac = (target - seen) as f64 / c as f64;
                return lo + (hi - lo) * frac;
            }
            seen += c;
        }
        *self.bounds.last().unwrap()
    }
}

/// Batch-occupancy + queue-delay series produced by the cross-request
/// batcher ([`crate::batcher`]) and reported by the analysis workflow.
///
/// Occupancy is recorded per executed batch (requests coalesced into one
/// predictor call, out of `capacity`); queue delay is recorded per request
/// (time spent waiting for its batch to close). Both use the same summary
/// statistics as the paper's latency metrics so reports stay consistent
/// (F2).
#[derive(Debug, Clone, Default)]
pub struct BatchingSeries {
    /// The batcher's `max_batch_size`.
    pub capacity: usize,
    /// Requests per executed batch, in batch order.
    pub occupancy: Vec<f64>,
    /// Per-request batching delay, seconds.
    pub queue_delay_s: Vec<f64>,
}

impl BatchingSeries {
    pub fn batches(&self) -> usize {
        self.occupancy.len()
    }

    pub fn mean_occupancy(&self) -> f64 {
        if self.occupancy.is_empty() {
            return 0.0;
        }
        self.occupancy.iter().sum::<f64>() / self.occupancy.len() as f64
    }

    /// Mean occupancy as a fraction of capacity, in `[0, 1]`.
    pub fn fill_ratio(&self) -> f64 {
        if self.capacity == 0 {
            return 0.0;
        }
        self.mean_occupancy() / self.capacity as f64
    }

    pub fn mean_queue_delay_ms(&self) -> f64 {
        if self.queue_delay_s.is_empty() {
            return 0.0;
        }
        self.queue_delay_s.iter().sum::<f64>() / self.queue_delay_s.len() as f64 * 1e3
    }

    pub fn p90_queue_delay_ms(&self) -> f64 {
        if self.queue_delay_s.is_empty() {
            return 0.0;
        }
        percentile(&self.queue_delay_s, 90.0) * 1e3
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("capacity", Json::num(self.capacity as f64)),
            ("batches", Json::num(self.batches() as f64)),
            ("mean_occupancy", Json::num(self.mean_occupancy())),
            ("fill_ratio", Json::num(self.fill_ratio())),
            ("mean_queue_delay_ms", Json::num(self.mean_queue_delay_ms())),
            ("p90_queue_delay_ms", Json::num(self.p90_queue_delay_ms())),
            (
                "occupancy",
                Json::arr(self.occupancy.iter().map(|o| Json::num(*o)).collect()),
            ),
            (
                "queue_delay_ms",
                Json::arr(self.queue_delay_s.iter().map(|d| Json::num(d * 1e3)).collect()),
            ),
        ])
    }

    /// Rebuild from the JSON stored in an evaluation record's metadata.
    pub fn from_json(j: &crate::util::json::Json) -> Option<BatchingSeries> {
        Some(BatchingSeries {
            capacity: j.f64_or("capacity", 0.0) as usize,
            occupancy: j
                .get("occupancy")?
                .as_arr()?
                .iter()
                .filter_map(|v| v.as_f64())
                .collect(),
            queue_delay_s: j
                .get("queue_delay_ms")?
                .as_arr()?
                .iter()
                .filter_map(|v| v.as_f64().map(|d| d / 1e3))
                .collect(),
        })
    }
}

/// Per-tenant shed accounting for one admission-controlled run — the
/// load-shedding sibling of [`BatchingSeries`]. One row per tenant:
/// how much was offered, how much was admitted, and how much was shed by
/// which mechanism (token bucket vs. queueing deadline). Stored in the
/// evaluation record's metadata (`meta["admission"]`) and rendered by
/// [`crate::analysis`] next to the latency tables.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShedSeries {
    pub rows: std::collections::BTreeMap<String, ShedRow>,
}

/// One tenant's admission outcome.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShedRow {
    /// `"high"` / `"low"` — the tenant's [`crate::batcher::Priority`].
    pub priority: String,
    pub offered: usize,
    pub admitted: usize,
    pub shed_rate_limited: usize,
    pub shed_deadline: usize,
}

impl ShedRow {
    pub fn shed_total(&self) -> usize {
        self.shed_rate_limited + self.shed_deadline
    }
}

impl ShedSeries {
    pub fn row_mut(&mut self, tenant: &str) -> &mut ShedRow {
        self.rows.entry(tenant.to_string()).or_default()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Total requests shed for tenants of the given priority label.
    pub fn shed_for_priority(&self, priority: &str) -> usize {
        self.rows
            .values()
            .filter(|r| r.priority == priority)
            .map(ShedRow::shed_total)
            .sum()
    }

    pub fn total_shed(&self) -> usize {
        self.rows.values().map(ShedRow::shed_total).sum()
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Obj(
            self.rows
                .iter()
                .map(|(tenant, r)| {
                    (
                        tenant.clone(),
                        Json::obj(vec![
                            ("priority", Json::str(r.priority.clone())),
                            ("offered", Json::num(r.offered as f64)),
                            ("admitted", Json::num(r.admitted as f64)),
                            ("shed_rate_limited", Json::num(r.shed_rate_limited as f64)),
                            ("shed_deadline", Json::num(r.shed_deadline as f64)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// Rebuild from the JSON stored in an evaluation record's metadata.
    pub fn from_json(j: &crate::util::json::Json) -> Option<ShedSeries> {
        let obj = j.as_obj()?;
        let mut series = ShedSeries::default();
        for (tenant, row) in obj {
            series.rows.insert(
                tenant.clone(),
                ShedRow {
                    priority: row.str_or("priority", "high").to_string(),
                    offered: row.f64_or("offered", 0.0) as usize,
                    admitted: row.f64_or("admitted", 0.0) as usize,
                    shed_rate_limited: row.f64_or("shed_rate_limited", 0.0) as usize,
                    shed_deadline: row.f64_or("shed_deadline", 0.0) as usize,
                },
            );
        }
        Some(series)
    }
}

/// Latency samples grouped by tenant — the per-tenant view of a
/// multi-tenant ([`crate::scenario::Scenario::Mix`]) run. Each tenant gets
/// its own [`LatencySamples`], so per-tenant tails (the fairness question:
/// "did tenant B's burst blow up tenant A's p99?") use exactly the same
/// summary statistics as single-tenant reports (F2).
#[derive(Debug, Clone, Default)]
pub struct TenantLatencies {
    map: std::collections::BTreeMap<String, LatencySamples>,
}

impl TenantLatencies {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, tenant: &str, secs: f64) {
        self.map.entry(tenant.to_string()).or_default().record_secs(secs);
    }

    pub fn get(&self, tenant: &str) -> Option<&LatencySamples> {
        self.map.get(tenant)
    }

    pub fn tenants(&self) -> Vec<&str> {
        self.map.keys().map(|k| k.as_str()).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &LatencySamples)> {
        self.map.iter()
    }

    /// Per-tenant summary (count, mean, p50/p99 in ms) for record metadata.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Obj(
            self.map
                .iter()
                .map(|(name, l)| {
                    // One sort per tenant answers both tails.
                    let sorted = SortedSamples::of(l.samples());
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("count", Json::num(sorted.len() as f64)),
                            ("mean_ms", Json::num(sorted.mean() * 1e3)),
                            ("p50_ms", Json::num(sorted.p50() * 1e3)),
                            ("p99_ms", Json::num(sorted.p99() * 1e3)),
                        ]),
                    )
                })
                .collect(),
        )
    }
}

/// Monotonic throughput counter (inputs/sec over a window).
#[derive(Debug, Default)]
pub struct Throughput {
    items: AtomicU64,
}

impl Throughput {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, n: u64) {
        self.items.fetch_add(n, Ordering::Relaxed);
    }

    pub fn total(&self) -> u64 {
        self.items.load(Ordering::Relaxed)
    }

    /// Items/sec given the wall-clock window that produced them.
    pub fn per_sec(&self, window: Duration) -> f64 {
        let s = window.as_secs_f64();
        if s <= 0.0 {
            return f64::NAN;
        }
        self.total() as f64 / s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trimmed_mean_matches_paper_definition() {
        // 10 samples, 20% trim → drop 2 from each end.
        let xs: Vec<f64> = vec![100.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 0.0];
        // sorted: 0,1,2,3,4,5,6,7,8,100 → keep 2..8 → mean(2..=7) = 4.5
        assert!((trimmed_mean(&xs, 0.2) - 4.5).abs() < 1e-12);
    }

    #[test]
    fn trimmed_mean_small_inputs() {
        assert_eq!(trimmed_mean(&[5.0], 0.2), 5.0);
        assert_eq!(trimmed_mean(&[1.0, 3.0], 0.2), 2.0);
        assert!(trimmed_mean(&[], 0.2).is_nan());
    }

    #[test]
    fn median_interpolates_even_counts() {
        assert!(median(&[]).is_nan());
        assert_eq!(median(&[7.0]), 7.0);
        assert_eq!(median(&[3.0, 1.0]), 2.0);
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
        // Even count: midpoint of the two central values, unordered input.
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        // Robust to a tail outlier where the mean is not.
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0, 1000.0]), 3.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        let p90 = percentile(&xs, 90.0);
        assert!((89.0..=91.0).contains(&p90), "p90 {p90}");
    }

    #[test]
    fn percentile_empty_single_and_constant_inputs() {
        // Empty: NaN, never a fake "0 ms" (pinned contract).
        assert!(percentile(&[], 0.0).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
        assert!(percentile(&[], 100.0).is_nan());
        let l = LatencySamples::new();
        assert!(l.p50().is_nan() && l.p99().is_nan());
        // Single sample: every percentile is that sample.
        for q in [0.0, 1.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(percentile(&[0.25], q), 0.25);
        }
        // Constant samples: every percentile is the constant.
        let xs = vec![3.5; 40];
        for q in [0.0, 10.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&xs, q), 3.5);
        }
    }

    #[test]
    fn percentile_clamps_out_of_range_q() {
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        // Below-range q clamps to p0, above-range to p100 — never an
        // out-of-bounds rank.
        assert_eq!(percentile(&xs, -5.0), 1.0);
        assert_eq!(percentile(&xs, -1e9), 1.0);
        assert_eq!(percentile(&xs, 150.0), 10.0);
        assert_eq!(percentile(&xs, 1e9), 10.0);
        assert_eq!(percentile(&xs, f64::INFINITY), 10.0);
        assert_eq!(percentile(&xs, f64::NEG_INFINITY), 1.0);
        // NaN q: there is no NaN-th percentile.
        assert!(percentile(&xs, f64::NAN).is_nan());
        let l = LatencySamples::from_secs(xs.clone());
        assert_eq!(l.percentile(101.0), 10.0);
        assert_eq!(l.percentile(-0.1), 1.0);
        // Sorted path shares the exact same contract.
        let s = SortedSamples::of(&xs);
        assert_eq!(s.percentile(-5.0), 1.0);
        assert_eq!(s.percentile(150.0), 10.0);
        assert!(s.percentile(f64::NAN).is_nan());
        assert!(SortedSamples::of(&[]).percentile(50.0).is_nan());
    }

    #[test]
    fn sorted_samples_match_adhoc_helpers() {
        crate::util::rng::forall(33, 40, |rng| {
            let n = 1 + rng.below(200) as usize;
            let xs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 5.0)).collect();
            let s = SortedSamples::of(&xs);
            for q in [0.0, 12.5, 50.0, 90.0, 99.0, 100.0] {
                assert_eq!(s.percentile(q), percentile(&xs, q), "q{q} n{n}");
            }
            assert_eq!(s.median(), median(&xs));
            assert!((s.trimmed_mean(0.2) - trimmed_mean(&xs, 0.2)).abs() < 1e-12);
            let l = LatencySamples::from_secs(xs.clone());
            assert_eq!(s.min(), l.min());
            assert_eq!(s.max(), l.max());
            assert!((s.mean() - l.mean()).abs() < 1e-12);
        });
    }

    #[test]
    fn histogram_quantile_empty_single_and_constant_inputs() {
        // Empty: NaN (pinned, same contract as `percentile`).
        let h = Histogram::latency_default();
        assert!(h.quantile(0.5).is_nan());
        assert!(h.mean().is_nan());
        // Single sample: quantiles land in the sample's bucket — within
        // one ×1.6 bucket factor of the true value.
        let mut h1 = Histogram::latency_default();
        h1.record(0.004);
        for q in [0.0, 0.5, 0.99, 1.0] {
            let v = h1.quantile(q);
            assert!(v >= 0.004 / 1.6 && v <= 0.004 * 1.6, "q{q} → {v}");
        }
        // Constant samples: same bucket bound, and monotone in q.
        let mut hc = Histogram::latency_default();
        for _ in 0..100 {
            hc.record(0.004);
        }
        let mut prev = 0.0;
        for q in [0.01, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let v = hc.quantile(q);
            assert!(v >= 0.004 / 1.6 && v <= 0.004 * 1.6, "q{q} → {v}");
            assert!(v >= prev, "quantile not monotone at q{q}");
            prev = v;
        }
        assert_eq!(hc.count(), 100);
        assert!((hc.mean() - 0.004).abs() < 1e-12);
    }

    #[test]
    fn summary_stats_match_percentile_definitions() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = SummaryStats::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.p50, percentile(&xs, 50.0));
        assert_eq!(s.p99, percentile(&xs, 99.0));
        let empty = SummaryStats::of(&[]);
        assert_eq!(empty.count, 0);
        assert!(empty.mean.is_nan() && empty.p50.is_nan() && empty.p99.is_nan());
    }

    #[test]
    fn tenant_latencies_group_and_summarize() {
        let mut t = TenantLatencies::new();
        assert!(t.is_empty());
        for ms in [10.0, 20.0, 30.0] {
            t.record("steady", ms / 1e3);
        }
        t.record("bursty", 0.5);
        assert_eq!(t.tenants(), vec!["bursty", "steady"]);
        assert_eq!(t.get("steady").unwrap().len(), 3);
        assert!((t.get("steady").unwrap().p99() - 0.030).abs() < 1e-12);
        assert!((t.get("bursty").unwrap().mean() - 0.5).abs() < 1e-12);
        assert!(t.get("missing").is_none());
        let j = t.to_json();
        assert_eq!(j.get_path("steady.count").unwrap().as_f64(), Some(3.0));
        assert!((j.get_path("bursty.p99_ms").unwrap().as_f64().unwrap() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn latency_samples_stats() {
        let mut l = LatencySamples::new();
        for ms in [10.0, 20.0, 30.0, 40.0, 50.0] {
            l.record_secs(ms / 1e3);
        }
        assert_eq!(l.len(), 5);
        assert!((l.mean() - 0.030).abs() < 1e-12);
        assert!((l.min() - 0.010).abs() < 1e-12);
        assert!((l.max() - 0.050).abs() < 1e-12);
        assert!(l.p90() >= l.p50());
    }

    #[test]
    fn histogram_quantiles_bracket_truth() {
        let mut h = Histogram::latency_default();
        let mut l = LatencySamples::new();
        let mut rng = crate::util::rng::Xorshift::new(11);
        for _ in 0..10_000 {
            let v = rng.range_f64(0.001, 0.050);
            h.record(v);
            l.record_secs(v);
        }
        let hq = h.quantile(0.90);
        let lq = l.p90();
        // Bucketed estimate within one bucket factor of the exact value.
        assert!(hq / lq < 1.7 && lq / hq < 1.7, "hist {hq} exact {lq}");
        assert_eq!(h.count(), 10_000);
        assert!((h.mean() - l.mean()).abs() / l.mean() < 0.01);
    }

    #[test]
    fn property_quantiles_monotone() {
        crate::util::rng::forall(21, 50, |rng| {
            let n = 1 + rng.below(300) as usize;
            let xs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 10.0)).collect();
            let l = LatencySamples::from_secs(xs.clone());
            let (p50, p90, p99) = (l.p50(), l.p90(), l.p99());
            assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
            let tm = l.trimmed_mean();
            assert!(tm >= l.min() - 1e-12 && tm <= l.max() + 1e-12);
        });
    }

    #[test]
    fn batching_series_summaries() {
        let s = BatchingSeries {
            capacity: 8,
            occupancy: vec![8.0, 8.0, 4.0],
            queue_delay_s: vec![0.001; 16]
                .into_iter()
                .chain(vec![0.009; 4])
                .collect(),
        };
        assert_eq!(s.batches(), 3);
        assert!((s.mean_occupancy() - 20.0 / 3.0).abs() < 1e-12);
        assert!((s.fill_ratio() - 20.0 / 24.0).abs() < 1e-12);
        assert!(s.mean_queue_delay_ms() > 1.0 && s.mean_queue_delay_ms() < 9.0);
        assert!(s.p90_queue_delay_ms() >= s.mean_queue_delay_ms());
        // JSON roundtrip preserves the series.
        let back = BatchingSeries::from_json(&s.to_json()).unwrap();
        assert_eq!(back.capacity, 8);
        assert_eq!(back.occupancy, s.occupancy);
        assert_eq!(back.queue_delay_s.len(), 20);
        assert!((back.p90_queue_delay_ms() - s.p90_queue_delay_ms()).abs() < 1e-9);
    }

    #[test]
    fn batching_series_empty_is_zero() {
        let s = BatchingSeries::default();
        assert_eq!(s.mean_occupancy(), 0.0);
        assert_eq!(s.fill_ratio(), 0.0);
        assert_eq!(s.p90_queue_delay_ms(), 0.0);
    }

    #[test]
    fn throughput_counter() {
        let t = Throughput::new();
        t.add(500);
        t.add(500);
        assert_eq!(t.total(), 1000);
        assert!((t.per_sec(Duration::from_secs(2)) - 500.0).abs() < 1e-9);
    }
}
