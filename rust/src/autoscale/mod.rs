//! SLO-driven autoscaling: spawn/retire agents from SLO headroom.
//!
//! The fleet was static: overload just queued, and the only defense was
//! over-provisioning for the daily peak. This module closes the loop the
//! ROADMAP calls for — a target-tracking controller that measures SLO
//! headroom with the same judges the benchmarking path uses
//! ([`crate::slo::SloJudge`] / [`crate::slo::RollingSloJudge`]) and sizes
//! the fleet to the *current* offered load:
//!
//! - **Measure**: completed-request latencies feed a rolling-window judge;
//!   its percentile estimate is the controller input. The verdict the
//!   bench prints comes from the very same numbers the loop consumed.
//! - **Decide** ([`target_agents`]): a hysteresis band around the SLO bound
//!   — scale up proportionally when the rolling percentile crosses
//!   `scale_up_at · bound` (the further over, the more agents at once),
//!   scale down one step when it sinks below `scale_down_at · bound`.
//!   The dead band between the thresholds plus a cooldown keeps the
//!   controller from flapping on noise.
//! - **Act**: in the virtual-time replay ([`run_autoscaled_sim`]) capacity
//!   changes are [`crate::batcher::QueueSim::add_server`] /
//!   [`QueueSim::retire_server`] with a spawn delay (new capacity is never
//!   free); on a real fleet ([`Supervisor`]) scale-up first wakes
//!   registry-discovered standby agents, then spawns local simulator
//!   replicas, and scale-down reverses the same moves.
//!
//! Admission control ([`crate::batcher::admission`]) runs in front of the
//! controller: token buckets cap each tenant's sustained rate and
//! deadline-aware shedding drops batches whose predicted queueing delay
//! already blows their tenant's deadline — so overload degrades best-effort
//! traffic first, visibly, instead of everyone's p99 silently.

use crate::batcher::admission::{filter_workload, AdmissionConfig, Rejection, ShedCause};
use crate::batcher::{plan_batches, BatcherConfig, QueueSim};
use crate::metrics::{ShedSeries, TenantLatencies};
use crate::pipeline::{Envelope, Payload};
use crate::scenario::Workload;
use crate::slo::{RollingSloJudge, SloJudge, SloSpec};

/// Control-loop knobs. Defaults favor stability over reaction speed: a
/// 10%-under-bound scale-up trigger, a wide dead band, and a cooldown long
/// enough for freshly spawned capacity to show up in the rolling window
/// before the next decision.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    pub min_agents: usize,
    pub max_agents: usize,
    /// Seconds between control decisions (virtual time in the replay).
    pub interval_s: f64,
    /// Scale up when the rolling percentile exceeds `scale_up_at · bound`.
    pub scale_up_at: f64,
    /// Scale down when it sinks below `scale_down_at · bound`.
    pub scale_down_at: f64,
    /// Minimum seconds between capacity changes (anti-flap).
    pub cooldown_s: f64,
    /// Rolling judge window, in completed requests.
    pub window: usize,
    /// Seconds before a newly spawned agent takes its first batch (model
    /// load + warmup — new capacity is never free).
    pub spawn_delay_s: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_agents: 1,
            max_agents: 8,
            interval_s: 0.5,
            scale_up_at: 0.9,
            scale_down_at: 0.3,
            cooldown_s: 1.0,
            window: 512,
            spawn_delay_s: 0.25,
        }
    }
}

/// The target-tracking decision: given the rolling percentile (ms), pick
/// the fleet size. Pure — the testable core of the controller.
///
/// Above the scale-up threshold the step is proportional to the overshoot
/// (capped at 4× per decision) because a 10× traffic spike needs more than
/// +1 agent per cooldown; below the scale-down threshold the step is always
/// −1, because shrinking too fast re-triggers the spike it just absorbed.
pub fn target_agents(p_ms: f64, spec: &SloSpec, current: usize, cfg: &AutoscaleConfig) -> usize {
    let lo = cfg.min_agents.max(1);
    let hi = cfg.max_agents.max(lo);
    let current = current.clamp(lo, hi);
    if !p_ms.is_finite() {
        // No signal (empty window / NaN): hold.
        return current;
    }
    let up_at = spec.bound_ms * cfg.scale_up_at.max(0.0);
    let down_at = spec.bound_ms * cfg.scale_down_at.max(0.0);
    if up_at > 0.0 && p_ms > up_at {
        let factor = (p_ms / up_at).min(4.0);
        let target = (current as f64 * factor).ceil() as usize;
        // max-then-min, not clamp: at `current == hi` the lower edge
        // (current + 1) exceeds hi and clamp would panic.
        target.max(current + 1).min(hi)
    } else if p_ms < down_at {
        current.saturating_sub(1).max(lo)
    } else {
        current
    }
}

/// One capacity change, as the controller took it.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleEvent {
    /// Virtual (replay) or wall (supervisor) seconds.
    pub at_s: f64,
    pub from: usize,
    pub to: usize,
    /// The rolling percentile that triggered the decision, ms.
    pub p_ms: f64,
    pub reason: String,
}

/// The stateful controller: rolling judge + hysteresis + cooldown.
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    judge: RollingSloJudge,
    last_change_at: f64,
    events: Vec<ScaleEvent>,
}

impl Autoscaler {
    pub fn new(spec: SloSpec, cfg: AutoscaleConfig) -> Autoscaler {
        let window = cfg.window;
        Autoscaler {
            cfg,
            judge: RollingSloJudge::new(spec, window),
            last_change_at: f64::NEG_INFINITY,
            events: Vec::new(),
        }
    }

    /// Feed one completed request's latency.
    pub fn observe(&mut self, latency_s: f64) {
        self.judge.observe(latency_s);
    }

    /// Rolling percentile, ms (`NaN` before any sample).
    pub fn rolling_p_ms(&self) -> f64 {
        self.judge.achieved_ms()
    }

    pub fn events(&self) -> &[ScaleEvent] {
        &self.events
    }

    /// Take a control decision at `now_s` with `current` agents. Returns
    /// the new target only when a change is due (hysteresis passed and
    /// cooldown expired); records it as a [`ScaleEvent`].
    pub fn decide(&mut self, now_s: f64, current: usize) -> Option<usize> {
        if now_s - self.last_change_at < self.cfg.cooldown_s {
            return None;
        }
        let p_ms = self.judge.achieved_ms();
        let target = target_agents(p_ms, self.judge.spec(), current, &self.cfg);
        if target == current {
            return None;
        }
        self.last_change_at = now_s;
        self.events.push(ScaleEvent {
            at_s: now_s,
            from: current,
            to: target,
            p_ms,
            reason: {
                let (dir, frac) = if target > current {
                    ("over", self.cfg.scale_up_at)
                } else {
                    ("under", self.cfg.scale_down_at)
                };
                let pct = self.judge.spec().percentile;
                format!("p{pct} {p_ms:.2}ms {dir} {:.0}% of bound", frac * 100.0)
            },
        });
        Some(target)
    }
}

/// Linear batch service-time model for the virtual-time replay:
/// `base + per_item · occupancy` seconds per batch — the same shape the
/// roofline simulator produces (fixed launch overhead + per-item compute).
#[derive(Debug, Clone, Copy)]
pub struct ServiceModel {
    pub base_s: f64,
    pub per_item_s: f64,
}

impl ServiceModel {
    pub fn service_s(&self, occupancy: usize) -> f64 {
        self.base_s + self.per_item_s * occupancy as f64
    }
}

/// What one autoscaled (or static — `autoscale: false`) replay produced.
#[derive(Debug)]
pub struct FleetReport {
    /// Final verdict from the full-run [`SloJudge`] over every completed
    /// request — the same numbers the control loop consumed.
    pub passed: bool,
    /// Full-run percentile estimate, ms.
    pub achieved_ms: f64,
    /// Requests that completed service.
    pub completed: usize,
    /// Per-tenant admission/shed accounting (rate-limit + deadline drops).
    pub shed: ShedSeries,
    /// Every typed rejection, in decision order.
    pub rejections: Vec<Rejection>,
    /// Capacity changes the controller took.
    pub events: Vec<ScaleEvent>,
    pub peak_agents: usize,
    pub final_agents: usize,
    /// Per-tenant latency tails over completed requests.
    pub per_tenant: TenantLatencies,
}

/// Run a workload through admission control + batching + the virtual-time
/// queueing replay with the autoscale control loop in the loop. Fully
/// deterministic in its inputs; millions of simulated users cost only the
/// planning and replay time, never wall-clock waiting.
///
/// `initial` is the starting fleet; with `autoscale = false` the fleet
/// stays fixed (the static baseline the bench compares against) while
/// admission control still applies.
#[allow(clippy::too_many_arguments)]
pub fn run_autoscaled_sim(
    workload: &Workload,
    bcfg: &BatcherConfig,
    admission: &AdmissionConfig,
    spec: SloSpec,
    acfg: &AutoscaleConfig,
    svc: &ServiceModel,
    initial: usize,
    autoscale: bool,
) -> FleetReport {
    let tenant_names = workload.scenario.tenant_names();
    let tenant_name = |t: u32| -> String {
        tenant_names
            .get(t as usize)
            .cloned()
            .unwrap_or_else(|| format!("tenant-{t}"))
    };

    // 1. Admission: token buckets shed over-rate traffic up front.
    let (admitted, mut rejections) = filter_workload(admission, workload);
    let mut shed = ShedSeries::default();
    for r in &workload.requests {
        let row = shed.row_mut(&tenant_name(r.tenant));
        row.priority = admission.policy_for(r.tenant).priority.as_str().to_string();
        row.offered += 1;
    }
    for r in &admitted.requests {
        shed.row_mut(&tenant_name(r.tenant)).admitted += 1;
    }
    for rej in &rejections {
        shed.row_mut(&tenant_name(rej.tenant)).shed_rate_limited += 1;
    }

    // 2. Plan + replay with the controller taking capacity decisions on a
    //    virtual-time tick grid.
    let batches = plan_batches(&admitted, bcfg, |r| Envelope {
        seq: r.id,
        trace_id: 0,
        parent_span: None,
        payload: Payload::Bytes(Vec::new()),
    });
    let initial = initial.clamp(1, acfg.max_agents.max(1));
    let mut sim = QueueSim::new(&batches, initial, bcfg.policy());
    let mut judge = SloJudge::new(spec, admitted.requests.len());
    let mut scaler = Autoscaler::new(spec, acfg.clone());
    let mut per_tenant = TenantLatencies::new();
    let mut completed = 0usize;
    let mut peak = initial;
    let mut next_tick = acfg.interval_s.max(1e-3);

    let mut settle = |done: Vec<crate::batcher::CompletedRequest>,
                      judge: &mut SloJudge,
                      scaler: &mut Autoscaler,
                      per_tenant: &mut TenantLatencies,
                      completed: &mut usize| {
        for c in done {
            judge.observe(c.latency_s);
            scaler.observe(c.latency_s);
            per_tenant.record(&tenant_name(c.tenant), c.latency_s);
            *completed += 1;
        }
    };

    for (i, b) in batches.iter().enumerate() {
        // Control ticks due before this batch forms.
        while autoscale && next_tick <= b.formed_at_secs {
            let current = sim.active_servers();
            if let Some(target) = scaler.decide(next_tick, current) {
                if target > current {
                    for _ in current..target {
                        sim.add_server(next_tick + acfg.spawn_delay_s.max(0.0));
                    }
                } else {
                    for _ in target..current {
                        if !sim.retire_server() {
                            break;
                        }
                    }
                }
                peak = peak.max(sim.active_servers());
            }
            next_tick += acfg.interval_s.max(1e-3);
        }

        // Deadline shedding: if this batch's predicted queueing delay
        // already exceeds its tenant's deadline, reject it now — typed,
        // never a silent queue-forever.
        let policy = admission.policy_for(b.tenant);
        if let (Some(deadline_ms), Some(start)) =
            (policy.queue_deadline_ms, sim.predicted_start(i as u64))
        {
            let wait_s = start - b.formed_at_secs;
            if wait_s * 1e3 > deadline_ms {
                let row = shed.row_mut(&tenant_name(b.tenant));
                row.shed_deadline += b.len();
                row.admitted = row.admitted.saturating_sub(b.len());
                for (e, a) in b.envelopes.iter().zip(&b.arrivals) {
                    rejections.push(Rejection {
                        request_id: e.seq,
                        tenant: b.tenant,
                        priority: policy.priority,
                        cause: ShedCause::DeadlineExceeded,
                        at_secs: *a,
                    });
                }
                let done = sim.shed(i as u64);
                settle(done, &mut judge, &mut scaler, &mut per_tenant, &mut completed);
                continue;
            }
        }

        let done = sim.offer(i as u64, svc.service_s(b.len()));
        settle(done, &mut judge, &mut scaler, &mut per_tenant, &mut completed);
    }

    FleetReport {
        passed: judge.passed(),
        achieved_ms: judge.achieved_ms(),
        completed,
        shed,
        rejections,
        events: scaler.events().to_vec(),
        peak_agents: peak,
        final_agents: sim.active_servers(),
        per_tenant,
    }
}

/// What one [`Supervisor::tick`] did to the real fleet.
#[derive(Debug, Clone)]
pub struct SupervisorTick {
    /// Probe percentile that drove the decision, ms.
    pub p_ms: f64,
    pub before: usize,
    pub after: usize,
    /// Standby registry agents woken this tick.
    pub woken: Vec<String>,
    /// Fresh local replicas spawned this tick.
    pub spawned: Vec<String>,
    /// Agents retired (spawned replicas detached or remotes re-parked).
    pub retired: Vec<String>,
}

/// The real-fleet half of the control loop: measures SLO headroom with an
/// [`crate::slo::probe`] against the live fleet, then acts on the
/// [`crate::server::Server`] — waking registry-discovered standby agents
/// first (warm capacity), spawning local simulator replicas when standby
/// runs out, and retiring its own spawn/wake moves on scale-down. It only
/// ever retires capacity it added itself, so a fleet operator's manually
/// attached agents are never touched.
pub struct Supervisor {
    server: std::sync::Arc<crate::server::Server>,
    model: String,
    system: String,
    spec: SloSpec,
    cfg: AutoscaleConfig,
    bcfg: BatcherConfig,
    last_change_at: f64,
    /// Local replica ids this supervisor spawned (retire order: LIFO).
    spawned: Vec<String>,
    /// Remote agents this supervisor woke from standby (re-park on down).
    woken: Vec<String>,
}

impl Supervisor {
    pub fn new(
        server: std::sync::Arc<crate::server::Server>,
        model: &str,
        system: &str,
        spec: SloSpec,
        cfg: AutoscaleConfig,
        bcfg: BatcherConfig,
    ) -> Supervisor {
        Supervisor {
            server,
            model: model.to_string(),
            system: system.to_string(),
            spec,
            cfg,
            bcfg,
            last_change_at: f64::NEG_INFINITY,
            spawned: Vec::new(),
            woken: Vec::new(),
        }
    }

    /// Agents currently resolving for the supervised model.
    pub fn fleet_size(&self) -> usize {
        let Some(manifest) = self.server.registry.manifest(&self.model, None) else {
            return 0;
        };
        self.server
            .registry
            .resolve(&manifest, &crate::manifest::SystemRequirements::any())
            .len()
    }

    /// One control tick at `now_s` wall seconds: probe the live fleet at
    /// `qps` over `count` requests, then scale toward the target.
    pub fn tick(
        &mut self,
        now_s: f64,
        qps: f64,
        count: usize,
    ) -> Result<SupervisorTick, crate::server::ServerError> {
        let job = crate::server::EvalJob::new(
            &self.model,
            crate::scenario::Scenario::FixedQps { qps, count },
        );
        let probe = crate::slo::probe(&self.server, &job, &self.bcfg, self.spec, qps, count)?;
        let before = self.fleet_size();
        let mut tick = SupervisorTick {
            p_ms: probe.achieved_ms,
            before,
            after: before,
            woken: Vec::new(),
            spawned: Vec::new(),
            retired: Vec::new(),
        };
        if now_s - self.last_change_at < self.cfg.cooldown_s {
            return Ok(tick);
        }
        let target = target_agents(probe.achieved_ms, &self.spec, before, &self.cfg);
        if target > before {
            self.scale_up(target - before, &mut tick);
        } else if target < before {
            self.scale_down(before - target, &mut tick);
        }
        if tick.after != tick.before {
            self.last_change_at = now_s;
        }
        Ok(tick)
    }

    fn scale_up(&mut self, mut need: usize, tick: &mut SupervisorTick) {
        // Warm standby capacity first: registry-discovered agents parked by
        // the operator (or a previous scale-down) wake instantly.
        for id in self.server.registry.standby_agents() {
            if need == 0 {
                break;
            }
            if self.server.registry.set_standby(&id, false) {
                self.woken.push(id.clone());
                tick.woken.push(id);
                need -= 1;
            }
        }
        // Then spawn fresh local simulator replicas.
        while need > 0 {
            let Some((agent, _, _)) = crate::agent::try_sim_agent(
                &self.system,
                crate::sysmodel::Device::Gpu,
                crate::tracing::TraceLevel::None,
                self.server.evaldb.clone(),
                self.server.traces.clone(),
            ) else {
                break;
            };
            let id = self.server.attach_local_agent(agent);
            self.spawned.push(id.clone());
            tick.spawned.push(id);
            need -= 1;
        }
        tick.after = self.fleet_size();
    }

    fn scale_down(&mut self, mut excess: usize, tick: &mut SupervisorTick) {
        // Undo our own moves, newest first: detach spawned replicas, then
        // re-park woken standbys. Never touch operator-attached agents.
        while excess > 0 {
            if let Some(id) = self.spawned.pop() {
                self.server.detach_local_agent(&id);
                tick.retired.push(id);
                excess -= 1;
            } else if let Some(id) = self.woken.pop() {
                if self.server.registry.set_standby(&id, true) {
                    tick.retired.push(id);
                }
                excess -= 1;
            } else {
                break;
            }
        }
        tick.after = self.fleet_size();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcher::admission::TenantPolicy;
    use crate::scenario::Scenario;

    fn spec10() -> SloSpec {
        SloSpec { percentile: 99.0, bound_ms: 10.0 }
    }

    #[test]
    fn target_tracking_has_a_dead_band() {
        let cfg = AutoscaleConfig { min_agents: 1, max_agents: 8, ..Default::default() };
        let spec = spec10();
        // Inside the band (between 3ms and 9ms): hold.
        assert_eq!(target_agents(5.0, &spec, 2, &cfg), 2);
        assert_eq!(target_agents(8.9, &spec, 2, &cfg), 2);
        // Over the scale-up threshold: grow, proportionally to overshoot.
        assert_eq!(target_agents(9.5, &spec, 2, &cfg), 3);
        assert!(target_agents(40.0, &spec, 2, &cfg) > 3, "big overshoot scales faster");
        // Never past max.
        assert_eq!(target_agents(1000.0, &spec, 8, &cfg), 8);
        // Under the scale-down threshold: shrink by one, never below min.
        assert_eq!(target_agents(1.0, &spec, 3, &cfg), 2);
        assert_eq!(target_agents(1.0, &spec, 1, &cfg), 1);
        // NaN (empty window) holds instead of scaling on garbage.
        assert_eq!(target_agents(f64::NAN, &spec, 4, &cfg), 4);
    }

    #[test]
    fn cooldown_prevents_flapping() {
        let cfg = AutoscaleConfig { cooldown_s: 5.0, window: 8, ..Default::default() };
        let mut scaler = Autoscaler::new(spec10(), cfg);
        for _ in 0..8 {
            scaler.observe(0.050); // 50ms ≫ 10ms bound
        }
        assert!(scaler.decide(0.0, 1).is_some(), "first decision fires");
        assert!(scaler.decide(1.0, 2).is_none(), "cooldown holds");
        assert!(scaler.decide(6.0, 2).is_some(), "cooldown expired");
        assert_eq!(scaler.events().len(), 2);
        assert!(scaler.events()[0].to > scaler.events()[0].from);
    }

    #[test]
    fn autoscaled_replay_absorbs_a_spike_the_static_fleet_cannot() {
        // A 10× diurnal spike over a 1-agent baseline.
        let scenario = Scenario::Diurnal {
            peak_qps: 2000.0,
            trough_qps: 200.0,
            period_s: 8.0,
            count: 12_000,
        };
        let w = Workload::generate(&scenario, 7);
        let bcfg = BatcherConfig::new(8, 2.0);
        let svc = ServiceModel { base_s: 0.001, per_item_s: 0.0004 };
        let spec = spec10();
        let acfg = AutoscaleConfig { min_agents: 1, max_agents: 8, ..Default::default() };
        let adm = AdmissionConfig::default();
        let scaled = run_autoscaled_sim(&w, &bcfg, &adm, spec, &acfg, &svc, 1, true);
        let fixed = run_autoscaled_sim(&w, &bcfg, &adm, spec, &acfg, &svc, 1, false);
        assert!(scaled.peak_agents > 1, "controller grew the fleet");
        assert!(!scaled.events.is_empty());
        assert_eq!(fixed.peak_agents, 1, "static fleet never grew");
        assert_eq!(fixed.events.len(), 0);
        assert!(
            scaled.achieved_ms < fixed.achieved_ms,
            "autoscaled p99 {:.2}ms vs static {:.2}ms",
            scaled.achieved_ms,
            fixed.achieved_ms
        );
        assert_eq!(scaled.completed, 12_000, "nothing lost without deadlines");
    }

    #[test]
    fn deadline_shedding_produces_typed_rejections() {
        // One overloaded best-effort tenant with a tight queue deadline on
        // a single static server: most batches blow the deadline.
        let scenario = Scenario::FixedQps { qps: 2000.0, count: 2000 };
        let w = Workload::generate(&scenario, 3);
        let bcfg = BatcherConfig::new(8, 1.0);
        let svc = ServiceModel { base_s: 0.004, per_item_s: 0.001 };
        let adm = AdmissionConfig::default().with_tenant(
            0,
            TenantPolicy {
                priority: crate::batcher::Priority::Low,
                rate_per_s: None,
                burst: 1.0,
                queue_deadline_ms: Some(20.0),
            },
        );
        let acfg = AutoscaleConfig { max_agents: 1, ..Default::default() };
        let report = run_autoscaled_sim(&w, &bcfg, &adm, spec10(), &acfg, &svc, 1, false);
        assert!(report.shed.total_shed() > 0, "overload must shed");
        let row = &report.shed.rows["all"];
        assert!(row.shed_deadline > 0);
        assert_eq!(row.offered, 2000);
        assert_eq!(row.admitted + row.shed_deadline, 2000);
        assert_eq!(report.completed + report.shed.total_shed(), 2000, "every request accounted");
        let low = crate::batcher::Priority::Low;
        assert!(report
            .rejections
            .iter()
            .all(|r| r.cause == ShedCause::DeadlineExceeded && r.priority == low));
        // Determinism: the whole report reproduces.
        let again = run_autoscaled_sim(&w, &bcfg, &adm, spec10(), &acfg, &svc, 1, false);
        assert_eq!(report.shed, again.shed);
        assert_eq!(report.completed, again.completed);
    }

    #[test]
    fn supervisor_scales_the_live_fleet_and_only_retires_its_own() {
        use crate::tracing::TraceLevel;
        let server = crate::server::Server::sim_platform(TraceLevel::None);
        let base = {
            let m = server.registry.manifest("BVLC_AlexNet", None).unwrap();
            server
                .registry
                .resolve(&m, &crate::manifest::SystemRequirements::any())
                .len()
        };
        let spec = SloSpec { percentile: 99.0, bound_ms: 0.5 };
        let cfg = AutoscaleConfig {
            min_agents: 1,
            max_agents: base + 3,
            cooldown_s: 0.0,
            ..Default::default()
        };
        let mut sup = Supervisor::new(
            server.clone(),
            "BVLC_AlexNet",
            "aws_p3",
            spec,
            cfg,
            BatcherConfig::new(8, 2.0),
        );
        // Saturating load against a tight 0.5ms bound: the probe must blow
        // the SLO and the supervisor must add capacity.
        let tick = sup.tick(0.0, 4000.0, 256).expect("probe runs");
        assert!(tick.p_ms > 0.5, "probe saw the overload: {:.3}ms", tick.p_ms);
        assert!(tick.after > tick.before, "{tick:?}");
        assert!(!tick.spawned.is_empty() || !tick.woken.is_empty());
        // Forced scale-down retires only supervisor-spawned agents.
        let spawned = tick.spawned.clone();
        let mut down = SupervisorTick {
            p_ms: 0.0,
            before: sup.fleet_size(),
            after: 0,
            woken: vec![],
            spawned: vec![],
            retired: vec![],
        };
        sup.scale_down(spawned.len(), &mut down);
        assert_eq!(down.retired, spawned.iter().rev().cloned().collect::<Vec<_>>());
        assert_eq!(sup.fleet_size(), base, "operator fleet untouched");
    }
}
