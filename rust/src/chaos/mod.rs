//! Seeded fault injection for the distributed serving stack.
//!
//! Deep500 (arXiv:1901.10183) argues that benchmarking infrastructure must
//! itself be validated; for a *distributed* platform that means failure
//! scenarios — an agent process dying mid-batch, a partitioned connection,
//! a missed heartbeat — have to be reproducible and assertable, not
//! stumbled into. This module is that harness:
//!
//! - a [`FaultPlan`] declares *what* goes wrong and *when*, keyed by RPC
//!   method name and matching-call count (plus a seed for probabilistic
//!   faults), so a failure scenario is a pure function of the request
//!   sequence;
//! - a [`ChaosEngine`] evaluates the plan one request at a time and is
//!   consulted at the wire layer ([`crate::wire::RpcServer::serve_with_chaos`]
//!   for incoming RPCs, the agent heartbeat loop for outgoing beats) — the
//!   injection happens *below* the serving logic, exactly where real
//!   network/process failures strike;
//! - the CLI surfaces it as `mlms agent serve --chaos <plan>`, and
//!   `benches/fig_fleet.rs` + `tests/fleet_failover.rs` assert the
//!   failover semantics (exactly-once requeue, TTL-driven membership)
//!   under injected faults.
//!
//! Plan grammar (comma-separated items, `*` matches any method):
//!
//! ```text
//! kill:PredictBatch:3   serve 3 matching calls, then kill the target
//! drop:heartbeat:2      serve 2 matching calls, drop the rest
//! delay:*:25            delay every matching call by 25 ms
//! prob:Predict:0.25     drop each matching call with p=0.25 (seeded)
//! ```

use crate::util::json::Json;
use crate::util::rng::Xorshift;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One injected fault. `method` is an RPC method name or `*` for any.
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Serve the first `calls` matching requests, then *kill* the target:
    /// the triggering request is dropped, the engine's kill hook fires
    /// (process exit for `mlms agent serve`, server shutdown in tests) and
    /// every later request is dropped.
    KillAfter { method: String, calls: u64 },
    /// Serve the first `calls` matching requests, drop the rest (the
    /// connection closes with no reply — a crash from the caller's view).
    DropAfter { method: String, calls: u64 },
    /// Delay every matching request by `ms` milliseconds before serving it.
    /// A delay beyond the caller's deadline is a partition from its view.
    Delay { method: String, ms: u64 },
    /// Drop each matching request independently with probability `prob`,
    /// decided by the plan's seeded RNG — deterministic given the sequence
    /// of matching calls.
    DropWithProb { method: String, prob: f64 },
}

impl Fault {
    fn method(&self) -> &str {
        match self {
            Fault::KillAfter { method, .. }
            | Fault::DropAfter { method, .. }
            | Fault::Delay { method, .. }
            | Fault::DropWithProb { method, .. } => method,
        }
    }

    fn matches(&self, method: &str) -> bool {
        let m = self.method();
        m == "*" || m == method
    }

    fn kind(&self) -> &'static str {
        match self {
            Fault::KillAfter { .. } => "kill",
            Fault::DropAfter { .. } => "drop",
            Fault::Delay { .. } => "delay",
            Fault::DropWithProb { .. } => "prob",
        }
    }

    fn value(&self) -> f64 {
        match self {
            Fault::KillAfter { calls, .. } | Fault::DropAfter { calls, .. } => *calls as f64,
            Fault::Delay { ms, .. } => *ms as f64,
            Fault::DropWithProb { prob, .. } => *prob,
        }
    }
}

/// A seeded, declarative failure scenario.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, faults: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn kill_after(mut self, method: &str, calls: u64) -> FaultPlan {
        self.faults.push(Fault::KillAfter { method: method.to_string(), calls });
        self
    }

    pub fn drop_after(mut self, method: &str, calls: u64) -> FaultPlan {
        self.faults.push(Fault::DropAfter { method: method.to_string(), calls });
        self
    }

    pub fn delay(mut self, method: &str, ms: u64) -> FaultPlan {
        self.faults.push(Fault::Delay { method: method.to_string(), ms });
        self
    }

    pub fn drop_with_prob(mut self, method: &str, prob: f64) -> FaultPlan {
        self.faults.push(Fault::DropWithProb {
            method: method.to_string(),
            prob: prob.clamp(0.0, 1.0),
        });
        self
    }

    /// Parse the CLI grammar (see module docs). Every item must be
    /// `kind:method:value`; unknown kinds and unparsable values are errors,
    /// not silent no-ops — a typo'd chaos plan that injects nothing would
    /// make a failure test silently vacuous.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new(seed);
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let mut parts = item.splitn(3, ':');
            let (kind, method, value) = match (parts.next(), parts.next(), parts.next()) {
                (Some(k), Some(m), Some(v)) if !m.is_empty() => (k, m, v),
                _ => return Err(format!("bad fault {item:?} (want kind:method:value)")),
            };
            let num = |v: &str| -> Result<u64, String> {
                v.parse::<u64>().map_err(|_| format!("bad count/ms {v:?} in {item:?}"))
            };
            plan = match kind {
                "kill" => plan.kill_after(method, num(value)?),
                "drop" => plan.drop_after(method, num(value)?),
                "delay" => plan.delay(method, num(value)?),
                "prob" => {
                    let p = value
                        .parse::<f64>()
                        .ok()
                        .filter(|p| (0.0..=1.0).contains(p))
                        .ok_or_else(|| format!("bad probability {value:?} in {item:?}"))?;
                    plan.drop_with_prob(method, p)
                }
                other => return Err(format!("unknown fault kind {other:?} in {item:?}")),
            };
        }
        Ok(plan)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            (
                "faults",
                Json::arr(
                    self.faults
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("kind", Json::str(f.kind())),
                                ("method", Json::str(f.method())),
                                ("value", Json::num(f.value())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<FaultPlan> {
        let mut plan = FaultPlan::new(j.f64_or("seed", 0.0) as u64);
        for f in j.get("faults")?.as_arr()? {
            let method = f.get("method")?.as_str()?;
            let value = f.get("value")?.as_f64()?;
            plan = match f.get("kind")?.as_str()? {
                "kill" => plan.kill_after(method, value as u64),
                "drop" => plan.drop_after(method, value as u64),
                "delay" => plan.delay(method, value as u64),
                "prob" => plan.drop_with_prob(method, value),
                _ => return None,
            };
        }
        Some(plan)
    }
}

/// What the engine decided for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Serve normally.
    Pass,
    /// Sleep this many milliseconds, then serve.
    Delay(u64),
    /// Close the connection with no reply (or skip the outgoing call).
    Drop,
    /// The target just died: drop this request and everything after it.
    Kill,
}

/// Evaluates a [`FaultPlan`] one request at a time. Thread-safe; per-fault
/// matching-call counters make count-based faults exact even under
/// concurrent connections (the *total* order of matching calls decides).
pub struct ChaosEngine {
    plan: FaultPlan,
    counters: Vec<AtomicU64>,
    rng: Mutex<Xorshift>,
    killed: AtomicBool,
    kill_hook: Mutex<Option<Box<dyn FnOnce() + Send>>>,
}

impl ChaosEngine {
    pub fn new(plan: FaultPlan) -> Arc<ChaosEngine> {
        let counters = (0..plan.faults.len()).map(|_| AtomicU64::new(0)).collect();
        let rng = Mutex::new(Xorshift::new(plan.seed));
        Arc::new(ChaosEngine {
            plan,
            counters,
            rng,
            killed: AtomicBool::new(false),
            kill_hook: Mutex::new(None),
        })
    }

    /// Install the action taken when a [`Fault::KillAfter`] fires (at most
    /// once). `mlms agent serve` exits the process; in-process tests stop
    /// the RPC server instead.
    pub fn on_kill(&self, hook: impl FnOnce() + Send + 'static) {
        *self.kill_hook.lock().unwrap() = Some(Box::new(hook));
    }

    pub fn killed(&self) -> bool {
        self.killed.load(Ordering::Relaxed)
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Decide the fate of one request. Kill wins over drop wins over delay;
    /// a killed target drops everything from then on.
    pub fn decide(&self, method: &str) -> FaultAction {
        if self.killed() {
            return FaultAction::Drop;
        }
        let mut delay: Option<u64> = None;
        let mut dropped = false;
        let mut kill = false;
        for (i, f) in self.plan.faults.iter().enumerate() {
            if !f.matches(method) {
                continue;
            }
            // 0-based index of this matching call for this fault.
            let n = self.counters[i].fetch_add(1, Ordering::Relaxed);
            match f {
                Fault::KillAfter { calls, .. } => {
                    if n >= *calls {
                        kill = true;
                    }
                }
                Fault::DropAfter { calls, .. } => {
                    if n >= *calls {
                        dropped = true;
                    }
                }
                Fault::DropWithProb { prob, .. } => {
                    if self.rng.lock().unwrap().f64() < *prob {
                        dropped = true;
                    }
                }
                Fault::Delay { ms, .. } => {
                    delay = Some(delay.unwrap_or(0).max(*ms));
                }
            }
        }
        if kill {
            self.killed.store(true, Ordering::Relaxed);
            if let Some(hook) = self.kill_hook.lock().unwrap().take() {
                hook();
            }
            return FaultAction::Kill;
        }
        if dropped {
            return FaultAction::Drop;
        }
        match delay {
            Some(ms) => FaultAction::Delay(ms),
            None => FaultAction::Pass,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip_and_errors() {
        let plan =
            FaultPlan::parse("kill:PredictBatch:3, drop:heartbeat:2, delay:*:25, prob:Predict:0.25", 7)
                .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.faults.len(), 4);
        assert_eq!(
            plan.faults[0],
            Fault::KillAfter { method: "PredictBatch".into(), calls: 3 }
        );
        assert_eq!(plan.faults[2], Fault::Delay { method: "*".into(), ms: 25 });
        // JSON round trip preserves the plan exactly.
        let back = FaultPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
        // Typos are errors, not silent no-ops.
        assert!(FaultPlan::parse("explode:*:1", 0).is_err());
        assert!(FaultPlan::parse("kill:PredictBatch", 0).is_err());
        assert!(FaultPlan::parse("prob:*:1.5", 0).is_err());
        assert!(FaultPlan::parse("delay:*:soon", 0).is_err());
        // Empty spec is an empty (no-fault) plan.
        assert!(FaultPlan::parse("", 0).unwrap().is_empty());
    }

    #[test]
    fn kill_after_serves_then_kills_then_drops_everything() {
        let engine = ChaosEngine::new(FaultPlan::new(0).kill_after("PredictBatch", 2));
        let fired = std::sync::Arc::new(AtomicU64::new(0));
        let f = fired.clone();
        engine.on_kill(move || {
            f.fetch_add(1, Ordering::Relaxed);
        });
        // Non-matching methods never count.
        assert_eq!(engine.decide("Open"), FaultAction::Pass);
        assert_eq!(engine.decide("PredictBatch"), FaultAction::Pass);
        assert_eq!(engine.decide("PredictBatch"), FaultAction::Pass);
        assert_eq!(engine.decide("PredictBatch"), FaultAction::Kill);
        assert!(engine.killed());
        assert_eq!(fired.load(Ordering::Relaxed), 1, "kill hook fires exactly once");
        // Everything after the kill is dropped, any method.
        assert_eq!(engine.decide("PredictBatch"), FaultAction::Drop);
        assert_eq!(engine.decide("Open"), FaultAction::Drop);
        assert_eq!(fired.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drop_after_and_delay_compose() {
        let engine =
            ChaosEngine::new(FaultPlan::new(0).drop_after("Predict", 1).delay("*", 10));
        // First Predict: served, but delayed by the wildcard delay.
        assert_eq!(engine.decide("Predict"), FaultAction::Delay(10));
        // Second Predict: drop wins over delay.
        assert_eq!(engine.decide("Predict"), FaultAction::Drop);
        // Other methods only see the delay.
        assert_eq!(engine.decide("Evaluate"), FaultAction::Delay(10));
    }

    #[test]
    fn probabilistic_drops_are_seed_deterministic() {
        let run = |seed: u64| -> Vec<FaultAction> {
            let engine = ChaosEngine::new(FaultPlan::new(seed).drop_with_prob("echo", 0.5));
            (0..64).map(|_| engine.decide("echo")).collect()
        };
        assert_eq!(run(42), run(42), "same seed → same fault sequence");
        assert_ne!(run(42), run(43), "different seed → different sequence");
        let drops = run(42).iter().filter(|a| **a == FaultAction::Drop).count();
        assert!((10..=54).contains(&drops), "p=0.5 over 64 calls, got {drops}");
    }
}
