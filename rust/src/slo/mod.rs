//! SLO-driven benchmarking: latency-bounded throughput search.
//!
//! The paper's case studies (and the server-mode methodology MLCommons
//! formalized) show that raw throughput is rarely the question — the
//! question is *how much load can this (model, system, batching config)
//! serve while still meeting a latency SLO* such as "p99 ≤ 10 ms". This
//! module answers it:
//!
//! - [`SloSpec`] names the objective: a percentile and a bound.
//! - [`SloJudge`] scores one probe *streamingly*: every completed request's
//!   queueing-aware latency feeds a [`crate::metrics::Histogram`]-backed
//!   running percentile, and an exact over-bound counter aborts the probe
//!   the moment no completion could satisfy the SLO (if more than
//!   `⌊(1-p)·n⌋` of `n` requests have already exceeded the bound, the
//!   p-percentile over the full run must exceed it too) — a hopeless probe
//!   stops early instead of running out the clock.
//! - [`ProbeWatch`] wires the judge into the dispatcher through
//!   [`crate::batcher::DispatchWatch`], replaying observed batch service
//!   times through the deterministic virtual-time scheduler
//!   ([`crate::batcher::QueueSim`]) so the judge sees the same
//!   load-dependent latencies the server reports.
//! - [`search_max_qps`] runs the adaptive search: a geometric ramp over
//!   offered QPS (doubling octaves on a fixed dyadic grid) until a probe
//!   fails, then bisection on the grid between the last pass and the first
//!   fail. The result is the SLO frontier point
//!   `(model, batch config) → max_qps@p≤bound`.
//!
//! Frontier points store into the evaluation database (scenario key
//! `"slo:p99<=10.0ms"`-style) and render as the report's "SLO frontier"
//! section ([`crate::analysis::slo_frontier_table`]); the `mlms slo-search`
//! subcommand and `benches/fig_slo_frontier.rs` drive the whole path.

use crate::batcher::{Batch, BatchLogRow, BatcherConfig, DispatchWatch, QueueSim};
use crate::evaldb::{EvalKey, EvalRecord};
use crate::metrics::Histogram;
use crate::scenario::Scenario;
use crate::server::{EvalJob, Server, ServerError};
use crate::util::json::Json;
use crate::util::sync::lock_recover;
use std::sync::{Arc, Mutex};

/// A latency service-level objective: `percentile` (in `[0, 100]`) of
/// request latencies must not exceed `bound_ms`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    pub percentile: f64,
    pub bound_ms: f64,
}

impl SloSpec {
    pub fn new(percentile: f64, bound_ms: f64) -> SloSpec {
        SloSpec { percentile, bound_ms }
    }

    /// The common objective: p99 latency under `bound_ms`.
    pub fn p99(bound_ms: f64) -> SloSpec {
        SloSpec { percentile: 99.0, bound_ms }
    }

    pub fn bound_secs(&self) -> f64 {
        self.bound_ms / 1e3
    }

    /// How many of `total` samples may exceed the bound while the
    /// percentile still meets it: `⌊(1 - p/100)·total⌋`. This count-based
    /// criterion is the compliance definition the judge enforces — it makes
    /// early abort *exact*, not heuristic. A small epsilon absorbs the
    /// binary-float error in `(100 - p)/100` (e.g. p99.9 × 1000 computes
    /// as 0.99999…97, which must still floor to 1, not 0).
    pub fn allowed_over(&self, total: usize) -> u64 {
        ((100.0 - self.percentile) * total as f64 / 100.0 + 1e-9).floor().max(0.0) as u64
    }

    /// Human/key label, e.g. `p99<=10.0ms` or `p99.9<=10.0ms`. The
    /// percentile uses shortest-form `Display` so fractional percentiles
    /// survive (a `{:.0}` would round p99.9 up to a nonsensical p100 and
    /// collide distinct SLOs onto one key).
    pub fn label(&self) -> String {
        format!("p{}<={:.1}ms", self.percentile, self.bound_ms)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("percentile", Json::num(self.percentile)),
            ("bound_ms", Json::num(self.bound_ms)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<SloSpec> {
        Some(SloSpec {
            percentile: j.get("percentile")?.as_f64()?,
            bound_ms: j.get("bound_ms")?.as_f64()?,
        })
    }
}

/// The judge's verdict after one observed latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloVerdict {
    /// The probe can still meet the SLO.
    Within,
    /// Enough samples are over the bound that no completion can meet the
    /// SLO — abort the probe.
    Violated,
}

/// Streaming judge for one probe: histogram-backed running percentile for
/// reporting, exact over-bound counting for sound early abort.
pub struct SloJudge {
    spec: SloSpec,
    expected_total: usize,
    hist: Histogram,
    over: u64,
    seen: u64,
}

impl SloJudge {
    /// `expected_total` is the probe's full request count — the abort
    /// threshold is computed against it, so a verdict of [`SloVerdict::Violated`]
    /// is final no matter how the remaining requests would have behaved.
    pub fn new(spec: SloSpec, expected_total: usize) -> SloJudge {
        SloJudge {
            spec,
            expected_total,
            hist: Histogram::latency_default(),
            over: 0,
            seen: 0,
        }
    }

    pub fn observe(&mut self, secs: f64) -> SloVerdict {
        self.hist.record(secs);
        self.seen += 1;
        if secs > self.spec.bound_secs() {
            self.over += 1;
        }
        if self.over > self.spec.allowed_over(self.expected_total) {
            SloVerdict::Violated
        } else {
            SloVerdict::Within
        }
    }

    pub fn seen(&self) -> usize {
        self.seen as usize
    }

    /// Compliance so far: over-bound count within the full-run allowance.
    pub fn passed(&self) -> bool {
        self.over <= self.spec.allowed_over(self.expected_total)
    }

    /// Streaming estimate of the spec percentile, in ms (`NaN` before any
    /// sample, per the [`crate::metrics::Histogram::quantile`] contract).
    pub fn achieved_ms(&self) -> f64 {
        if self.seen == 0 {
            f64::NAN
        } else {
            self.hist.quantile((self.spec.percentile / 100.0).clamp(0.0, 1.0)) * 1e3
        }
    }
}

/// Rolling-window SLO judge — the measurement half of the autoscale control
/// loop ([`crate::autoscale`]). Where [`SloJudge`] accumulates a whole
/// probe, this one keeps only the last `window` samples (histogram record +
/// forget on eviction), so its percentile tracks *current* load and the
/// controller reacts to the spike, not the average of the whole day.
pub struct RollingSloJudge {
    spec: SloSpec,
    window: usize,
    samples: std::collections::VecDeque<f64>,
    hist: Histogram,
    /// Over-bound count within the current window.
    over: usize,
}

impl RollingSloJudge {
    pub fn new(spec: SloSpec, window: usize) -> RollingSloJudge {
        RollingSloJudge {
            spec,
            window: window.max(1),
            samples: std::collections::VecDeque::new(),
            hist: Histogram::latency_default(),
            over: 0,
        }
    }

    pub fn observe(&mut self, secs: f64) {
        if self.samples.len() == self.window {
            if let Some(old) = self.samples.pop_front() {
                self.hist.forget(old);
                if old > self.spec.bound_secs() {
                    self.over -= 1;
                }
            }
        }
        self.samples.push_back(secs);
        self.hist.record(secs);
        if secs > self.spec.bound_secs() {
            self.over += 1;
        }
    }

    pub fn seen(&self) -> usize {
        self.samples.len()
    }

    /// Window percentile in ms (`NaN` while empty).
    pub fn achieved_ms(&self) -> f64 {
        if self.samples.is_empty() {
            f64::NAN
        } else {
            self.hist.quantile((self.spec.percentile / 100.0).clamp(0.0, 1.0)) * 1e3
        }
    }

    /// Over-bound fraction within the window, in `[0, 1]`.
    pub fn over_fraction(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.over as f64 / self.samples.len() as f64
        }
    }

    pub fn spec(&self) -> &SloSpec {
        &self.spec
    }
}

struct ProbeState {
    replay: QueueSim,
    judge: SloJudge,
}

/// Dispatch watch for one SLO probe: replays each completed batch's service
/// time through the virtual-time scheduler and feeds the resulting request
/// latencies to the judge. Returns `false` (abort) on the first
/// [`SloVerdict::Violated`].
pub struct ProbeWatch {
    state: Mutex<ProbeState>,
}

impl ProbeWatch {
    pub fn new(
        batches: &[Batch],
        servers: usize,
        cfg: &BatcherConfig,
        spec: SloSpec,
        expected_total: usize,
    ) -> Arc<ProbeWatch> {
        Arc::new(ProbeWatch {
            state: Mutex::new(ProbeState {
                replay: QueueSim::new(batches, servers, cfg.policy()),
                judge: SloJudge::new(spec, expected_total),
            }),
        })
    }

    /// `(passed, achieved_ms, samples_seen)` at this instant. The state
    /// lock is poison-tolerant: a panicking dispatch worker must not wedge
    /// the probe's observers (judge state is updated whole-row at a time).
    pub fn snapshot(&self) -> (bool, f64, usize) {
        let st = lock_recover(&self.state);
        (st.judge.passed(), st.judge.achieved_ms(), st.judge.seen())
    }
}

impl DispatchWatch for ProbeWatch {
    fn on_batch(&self, row: &BatchLogRow) -> bool {
        let mut guard = lock_recover(&self.state);
        let st = &mut *guard;
        let completed = st.replay.offer(row.index, row.latency_s);
        for c in completed {
            if st.judge.observe(c.latency_s) == SloVerdict::Violated {
                return false;
            }
        }
        true
    }
}

/// One probe of the search: the offered rate and what the judge concluded.
#[derive(Debug, Clone)]
pub struct SloProbe {
    pub qps: f64,
    pub passed: bool,
    /// The judge cut this probe short.
    pub aborted: bool,
    /// Streaming estimate of the spec percentile over the probe, ms.
    pub achieved_ms: f64,
    /// Requests the judge scored (may be < the probe count when aborted).
    pub samples: usize,
    /// Serving-stack trace of the probe (batching/queueing/service spans)
    /// — the input to bottleneck attribution when a probe fails and the
    /// question becomes *where* the latency went. `None` when the job ran
    /// with tracing off.
    pub trace_id: Option<u64>,
}

/// Search configuration.
#[derive(Debug, Clone)]
pub struct SloSearchConfig {
    /// First probed rate; the ramp doubles from here.
    pub start_qps: f64,
    /// Requests per probe.
    pub probe_count: usize,
    /// Grid resolution: probed rates are `start_qps · 2^(e/steps)` for
    /// integer `e`, so bisection terminates at a relative resolution of
    /// `2^(1/steps) - 1` (~9% at the default 8). A shared grid also keeps
    /// frontiers comparable across bounds: every search quotes a rate from
    /// the same ladder.
    pub steps_per_octave: u32,
    /// Probe budget for ramp + bisection.
    pub max_probes: usize,
}

impl Default for SloSearchConfig {
    fn default() -> Self {
        SloSearchConfig { start_qps: 50.0, probe_count: 256, steps_per_octave: 8, max_probes: 24 }
    }
}

/// One point of the SLO frontier: the maximum sustainable rate for a
/// `(model, batch config, SLO)` triple, plus the probe log behind it.
#[derive(Debug, Clone)]
pub struct SloFrontierPoint {
    pub model: String,
    pub batch_size: usize,
    pub max_wait_ms: f64,
    pub fair: bool,
    pub spec: SloSpec,
    /// Highest probed rate that met the SLO (0 when even the lowest probe
    /// violated it).
    pub max_qps: f64,
    /// Achieved percentile at `max_qps`, ms (`NaN` when `max_qps` is 0).
    pub achieved_ms: f64,
    pub probes: Vec<SloProbe>,
}

impl SloFrontierPoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("max_wait_ms", Json::num(self.max_wait_ms)),
            ("fair", Json::Bool(self.fair)),
            ("percentile", Json::num(self.spec.percentile)),
            ("bound_ms", Json::num(self.spec.bound_ms)),
            ("max_qps", Json::num(self.max_qps)),
            ("achieved_ms", Json::num(self.achieved_ms)),
            ("probes", Json::num(self.probes.len() as f64)),
            (
                "probe_log",
                Json::arr(
                    self.probes
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("qps", Json::num(p.qps)),
                                ("passed", Json::Bool(p.passed)),
                                ("aborted", Json::Bool(p.aborted)),
                                ("achieved_ms", Json::num(p.achieved_ms)),
                                ("samples", Json::num(p.samples as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Run one probe: drive [`Server::evaluate_batched_watched`] at `qps` with
/// the streaming judge attached. The probe workload is `FixedQps` — fully
/// deterministic, so a probe is a pure function of `(job, cfg, qps)`.
pub fn probe(
    server: &Server,
    job: &EvalJob,
    cfg: &BatcherConfig,
    spec: SloSpec,
    qps: f64,
    count: usize,
) -> Result<SloProbe, ServerError> {
    let mut probe_job = job.clone();
    probe_job.scenario = Scenario::FixedQps { qps, count };
    let watch_slot: Mutex<Option<Arc<ProbeWatch>>> = Mutex::new(None);
    let factory = |batches: &[Batch], servers: usize| -> Arc<dyn DispatchWatch> {
        let w = ProbeWatch::new(batches, servers, cfg, spec, count);
        *lock_recover(&watch_slot) = Some(w.clone());
        w
    };
    let result = server.evaluate_batched_watched(&probe_job, cfg, Some(&factory))?;
    let watch = watch_slot
        .into_inner()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .expect("watch factory invoked");
    let (passed, achieved_ms, samples) = watch.snapshot();
    Ok(SloProbe {
        qps,
        passed: passed && !result.aborted,
        aborted: result.aborted,
        achieved_ms,
        samples,
        trace_id: result.serving_trace_id,
    })
}

/// Adaptive search for the maximum sustainable rate under `spec`:
/// geometric ramp (full octaves) until a probe fails, then bisection on the
/// dyadic grid between the last pass and the first fail. `job` supplies the
/// model, requirements and seed; its scenario is ignored (probes are
/// `FixedQps`).
pub fn search_max_qps(
    server: &Server,
    job: &EvalJob,
    cfg: &BatcherConfig,
    spec: SloSpec,
    sc: &SloSearchConfig,
) -> Result<SloFrontierPoint, ServerError> {
    let steps = sc.steps_per_octave.max(1) as i64;
    let max_probes = sc.max_probes.max(4);
    let qps_at = |e: i64| sc.start_qps * ((e as f64) / (steps as f64)).exp2();
    let mut probes: Vec<SloProbe> = Vec::new();

    let mut lo: Option<i64> = None; // highest exponent seen passing
    let mut hi: Option<i64> = None; // lowest exponent seen failing
    // 1. First probe at the start rate.
    let first = probe(server, job, cfg, spec, qps_at(0), sc.probe_count)?;
    let first_passed = first.passed;
    probes.push(first);
    if first_passed {
        lo = Some(0);
        // 2a. Ramp up by octaves until a probe fails.
        let mut e = steps;
        while probes.len() < max_probes {
            let p = probe(server, job, cfg, spec, qps_at(e), sc.probe_count)?;
            let passed = p.passed;
            probes.push(p);
            if passed {
                lo = Some(e);
                e += steps;
            } else {
                hi = Some(e);
                break;
            }
        }
    } else {
        hi = Some(0);
        // 2b. Ramp down looking for any passing rate (floor: start/64).
        let mut e = -steps;
        while probes.len() < max_probes && e >= -6 * steps {
            let p = probe(server, job, cfg, spec, qps_at(e), sc.probe_count)?;
            let passed = p.passed;
            probes.push(p);
            if passed {
                lo = Some(e);
                break;
            } else {
                hi = Some(e);
                e -= steps;
            }
        }
    }
    // 3. Bisect the bracket down to grid resolution.
    if let (Some(mut l), Some(mut h)) = (lo, hi) {
        while h - l > 1 && probes.len() < max_probes {
            let mid = l + (h - l) / 2;
            let p = probe(server, job, cfg, spec, qps_at(mid), sc.probe_count)?;
            let passed = p.passed;
            probes.push(p);
            if passed {
                l = mid;
            } else {
                h = mid;
            }
        }
        lo = Some(l);
    }

    let (max_qps, achieved_ms) = match lo {
        Some(l) => {
            let q = qps_at(l);
            let at_max = probes
                .iter()
                .rev()
                .find(|p| p.passed && (p.qps - q).abs() <= q * 1e-12);
            (q, at_max.map(|p| p.achieved_ms).unwrap_or(f64::NAN))
        }
        None => (0.0, f64::NAN),
    };
    Ok(SloFrontierPoint {
        model: job.model.clone(),
        batch_size: cfg.max_batch_size.max(1),
        max_wait_ms: cfg.max_wait_ms,
        fair: cfg.fair,
        spec,
        max_qps,
        achieved_ms,
        probes,
    })
}

/// Store a frontier point in the evaluation database so the analysis
/// workflow ([`crate::analysis::slo_frontier_table`]) reports it. The SLO
/// label *and* the batching config (wait window, fairness) are baked into
/// the scenario key — `EvalDb::latest` dedupes by key, so two frontiers
/// differing only in fairness or wait window must not collapse onto one
/// row.
pub fn store_frontier_point(server: &Server, point: &SloFrontierPoint) -> u64 {
    let model_version = server
        .registry
        .manifest(&point.model, None)
        .map(|m| m.version.to_string())
        .unwrap_or_else(|| "0.0.0".to_string());
    let key = EvalKey {
        model: point.model.clone(),
        model_version,
        framework: "-".to_string(),
        framework_version: "0.0.0".to_string(),
        system: "multi".to_string(),
        device: "-".to_string(),
        scenario: format!(
            "slo:{}:w{:.1}{}",
            point.spec.label(),
            point.max_wait_ms,
            if point.fair { ":fair" } else { "" }
        ),
        batch_size: point.batch_size,
    };
    let mut record = EvalRecord::new(key, Vec::new(), point.max_qps);
    record.meta = Json::obj(vec![("slo", point.to_json())]);
    server.evaldb.put(record)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::sim_agent;
    use crate::sysmodel::Device;
    use crate::tracing::TraceLevel;

    fn platform(agents: usize) -> Arc<Server> {
        let server = Server::standalone();
        server.register_zoo();
        for _ in 0..agents {
            let (agent, _sim, _tracer) = sim_agent(
                "aws_p3",
                Device::Gpu,
                TraceLevel::None,
                server.evaldb.clone(),
                server.traces.clone(),
            );
            server.attach_local_agent(agent);
        }
        server
    }

    #[test]
    fn spec_allowance_and_label() {
        let spec = SloSpec::p99(10.0);
        assert_eq!(spec.allowed_over(100), 1);
        assert_eq!(spec.allowed_over(99), 0);
        assert_eq!(spec.allowed_over(1000), 10);
        assert_eq!(SloSpec::new(50.0, 5.0).allowed_over(10), 5);
        assert_eq!(spec.label(), "p99<=10.0ms");
        let back = SloSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn judge_aborts_exactly_when_no_completion_can_pass() {
        // p99 over 100 expected samples → one over-bound sample allowed.
        let mut judge = SloJudge::new(SloSpec::p99(10.0), 100);
        for _ in 0..50 {
            assert_eq!(judge.observe(0.002), SloVerdict::Within);
        }
        // First violation: still salvageable.
        assert_eq!(judge.observe(0.050), SloVerdict::Within);
        assert!(judge.passed());
        // Second violation: 2 > allowed 1 — final, regardless of the rest.
        assert_eq!(judge.observe(0.050), SloVerdict::Violated);
        assert!(!judge.passed());
        assert_eq!(judge.seen(), 52);
        // The streaming percentile now sits in the violating tail: the p99
        // of 52 samples is one of the two 50 ms outliers (within one
        // histogram bucket factor).
        let est = judge.achieved_ms();
        assert!(est > 10.0 && est < 50.0 * 1.7, "p99 estimate {est}");
        // Before any sample the estimate is NaN, per the histogram
        // contract.
        assert!(SloJudge::new(SloSpec::p99(1.0), 10).achieved_ms().is_nan());
    }

    #[test]
    fn hopeless_probe_aborts_early() {
        let server = platform(2);
        let job = EvalJob::new("ResNet_v1_50", Scenario::Online { count: 1 });
        let cfg = BatcherConfig::new(8, 5.0);
        // A bound no real execution can meet: the probe must abort, not
        // run all 64 requests.
        let p = probe(&server, &job, &cfg, SloSpec::p99(1e-6), 500.0, 64).unwrap();
        assert!(!p.passed);
        assert!(p.aborted, "violating probe should cut short");
        assert!(p.samples < 64, "scored {} of 64", p.samples);
        // Aborted probes leave nothing in the evaluation database.
        assert_eq!(server.evaldb.len(), 0);
        // But the probe's serving-stack trace survives for attribution:
        // the question after a failed probe is *where* the latency went.
        let tl = server.traces.timeline(p.trace_id.expect("probe trace"));
        assert!(
            tl.spans.iter().any(|s| s.name == "batch_service"),
            "probe trace carries serving-stack spans"
        );
        let profile = crate::traceanalysis::profile(&[tl], 3);
        assert!(profile.critical_path_ms <= profile.total_ms + 1e-9);
    }

    #[test]
    fn search_brackets_a_frontier_and_tightening_monotone() {
        let server = platform(2);
        let job = EvalJob::new("MobileNet_v1_1.0_224", Scenario::Online { count: 1 });
        let cfg = BatcherConfig::new(8, 5.0);
        let sc = SloSearchConfig {
            start_qps: 20.0,
            probe_count: 48,
            steps_per_octave: 4,
            max_probes: 18,
        };
        // Calibrate a reachable bound from a light probe, then search.
        let cal = probe(&server, &job, &cfg, SloSpec::p99(1e9), 10.0, 32).unwrap();
        assert!(cal.passed);
        let base_ms = cal.achieved_ms;
        assert!(base_ms.is_finite() && base_ms > 0.0);
        let loose = search_max_qps(&server, &job, &cfg, SloSpec::p99(base_ms * 16.0), &sc).unwrap();
        let tight = search_max_qps(&server, &job, &cfg, SloSpec::p99(base_ms * 2.0), &sc).unwrap();
        assert!(loose.max_qps > 0.0, "loose bound must admit load");
        assert!(!loose.probes.is_empty() && !tight.probes.is_empty());
        assert!(
            tight.max_qps <= loose.max_qps + 1e-9,
            "tighter bound admitted more load: {} vs {}",
            tight.max_qps,
            loose.max_qps
        );
        // Stored frontier points land under distinct scenario keys.
        store_frontier_point(&server, &loose);
        store_frontier_point(&server, &tight);
        let slo_records: Vec<_> = server
            .evaldb
            .latest(&crate::evaldb::EvalQuery::model("MobileNet_v1_1.0_224"))
            .into_iter()
            .filter(|r| r.key.scenario.starts_with("slo:"))
            .collect();
        assert_eq!(slo_records.len(), 2);
        assert!(slo_records.iter().all(|r| r.meta.get("slo").is_some()));
    }

    /// SLO probes fan out across registry-discovered TCP agents just like
    /// any batched evaluation: one local agent + one wire agent serve the
    /// probe stream together.
    #[test]
    fn probes_fan_out_across_a_remote_wire_fleet() {
        let server = platform(1);
        let remote_db = Arc::new(crate::evaldb::EvalDb::in_memory());
        let sink = crate::tracing::MemorySink::new();
        let (remote, _sim, _tracer) =
            sim_agent("aws_p3", Device::Gpu, TraceLevel::None, remote_db, sink);
        let rpc = crate::wire::RpcServer::serve(
            "127.0.0.1:0",
            crate::agent::agent_service(remote.clone()),
        )
        .unwrap();
        server.registry.register_agent(
            remote.info(&rpc.addr().to_string()),
            Some(std::time::Duration::from_secs(60)),
        );
        let job = EvalJob::new("MobileNet_v1_1.0_224", Scenario::Online { count: 1 });
        let cfg = BatcherConfig::new(8, 5.0);
        // A single loose probe: every request is scored and both agents
        // participated (the registry now resolves two).
        let p = probe(&server, &job, &cfg, SloSpec::p99(1e9), 50.0, 48).unwrap();
        assert!(p.passed);
        assert_eq!(p.samples, 48, "every request's latency was judged");
        // The adaptive search runs over the same mixed fleet.
        let sc = SloSearchConfig {
            start_qps: 20.0,
            probe_count: 32,
            steps_per_octave: 2,
            max_probes: 6,
        };
        let point = search_max_qps(&server, &job, &cfg, SloSpec::p99(1e9), &sc).unwrap();
        assert!(point.max_qps > 0.0, "an unbounded SLO must admit load");
        // Probes never persist — the store holds no accidental records.
        assert_eq!(server.evaldb.len(), 0);
        rpc.stop();
    }
}
