//! The tracing server (paper §4.5.3): aggregates trace events published by
//! agents into a single end-to-end timeline and supports the "zoom-in"
//! analysis of §5.2 (Fig 8) and the layer↔kernel correlation of §5.3
//! (Table 3).

use crate::tracing::{Span, SpanSink, TraceLevel};
use crate::util::json::Json;
use crate::util::sync::lock_recover;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// In-process trace aggregation service. Accepts spans from any number of
/// publishers (it is a [`SpanSink`], so tracers can point straight at it or
/// reach it through the wire protocol) and assembles per-trace timelines.
///
/// Retention is bounded: beyond `max_traces` distinct traces, the oldest
/// (lowest trace id — ids are allocated from a monotonic pool) are evicted.
/// A long-running server under SLO-probe traffic publishes a serving trace
/// per probe; without a cap that memory would grow without bound.
pub struct TraceServer {
    by_trace: Mutex<BTreeMap<u64, Vec<Span>>>,
    max_traces: usize,
}

/// Default retention: plenty for every analysis workflow (reports read
/// recent traces), small enough that probe storms can't exhaust memory.
pub const DEFAULT_MAX_TRACES: usize = 1024;

impl Default for TraceServer {
    fn default() -> Self {
        TraceServer { by_trace: Mutex::new(BTreeMap::new()), max_traces: DEFAULT_MAX_TRACES }
    }
}

impl TraceServer {
    pub fn new() -> Arc<TraceServer> {
        Arc::new(TraceServer::default())
    }

    /// A server retaining at most `max_traces` traces (0 means unbounded).
    pub fn with_max_traces(max_traces: usize) -> Arc<TraceServer> {
        Arc::new(TraceServer { by_trace: Mutex::new(BTreeMap::new()), max_traces })
    }

    pub fn trace_ids(&self) -> Vec<u64> {
        lock_recover(&self.by_trace).keys().copied().collect()
    }

    pub fn span_count(&self) -> usize {
        lock_recover(&self.by_trace).values().map(|v| v.len()).sum()
    }

    /// The assembled timeline for one trace, sorted by start time (ties
    /// broken by span id so ordering is deterministic).
    pub fn timeline(&self, trace_id: u64) -> Timeline {
        let mut spans = lock_recover(&self.by_trace)
            .get(&trace_id)
            .cloned()
            .unwrap_or_default();
        spans.sort_by_key(|s| (s.start_ns, s.span_id));
        Timeline { trace_id, spans }
    }

    pub fn clear(&self) {
        lock_recover(&self.by_trace).clear();
    }

    /// Evict the oldest traces beyond the retention cap. Called with the
    /// map lock held, after any insertion batch.
    fn evict_over_cap(&self, map: &mut BTreeMap<u64, Vec<Span>>) {
        while self.max_traces > 0 && map.len() > self.max_traces {
            let oldest = *map.keys().next().unwrap();
            map.remove(&oldest);
        }
    }
}

impl SpanSink for TraceServer {
    fn publish(&self, span: Span) {
        let mut map = lock_recover(&self.by_trace);
        map.entry(span.trace_id).or_default().push(span);
        self.evict_over_cap(&mut map);
    }

    /// Batch insertion: one lock and one eviction sweep for the whole set,
    /// instead of a lock per span — the serving path publishes each trace's
    /// complete span set through here.
    fn publish_all(&self, spans: Vec<Span>) {
        if spans.is_empty() {
            return;
        }
        let mut map = lock_recover(&self.by_trace);
        for span in spans {
            map.entry(span.trace_id).or_default().push(span);
        }
        self.evict_over_cap(&mut map);
    }
}

/// One trace's spans, ordered, with zoom/correlation queries.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub trace_id: u64,
    pub spans: Vec<Span>,
}

impl Timeline {
    /// Assemble a timeline from a flat span set (fixtures, stored traces),
    /// applying the same deterministic ordering as [`TraceServer::timeline`].
    pub fn from_spans(trace_id: u64, mut spans: Vec<Span>) -> Timeline {
        spans.sort_by_key(|s| (s.start_ns, s.span_id));
        Timeline { trace_id, spans }
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Total traced interval (first start → last end), ms.
    pub fn total_ms(&self) -> f64 {
        let start = self.spans.iter().map(|s| s.start_ns).min().unwrap_or(0);
        let end = self.spans.iter().map(|s| s.end_ns).max().unwrap_or(0);
        (end.saturating_sub(start)) as f64 / 1e6
    }

    /// Spans at a given level.
    pub fn at_level(&self, level: TraceLevel) -> Vec<&Span> {
        self.spans.iter().filter(|s| s.level == level).collect()
    }

    /// "Zoom into" a span: the span plus its descendants (the paper's
    /// Fig-8 workflow — zoom into the longest-running layer).
    pub fn zoom(&self, span_id: u64) -> Vec<&Span> {
        let mut keep: Vec<&Span> = Vec::new();
        let mut frontier = vec![span_id];
        // Include the root span itself.
        if let Some(root) = self.spans.iter().find(|s| s.span_id == span_id) {
            keep.push(root);
        }
        while let Some(pid) = frontier.pop() {
            for s in self.spans.iter().filter(|s| s.parent_id == Some(pid)) {
                keep.push(s);
                frontier.push(s.span_id);
            }
        }
        keep.sort_by_key(|s| (s.start_ns, s.span_id));
        keep
    }

    /// The longest span at a level — e.g. "the longest-running layer (fc6)".
    pub fn longest(&self, level: TraceLevel) -> Option<&Span> {
        self.at_level(level).into_iter().max_by_key(|s| s.duration_ns())
    }

    /// Correlate SYSTEM-level kernels to their FRAMEWORK-level parent layer
    /// (Table 3): returns (layer, kernels) pairs ordered by layer time desc.
    pub fn layer_kernel_correlation(&self) -> Vec<(Span, Vec<Span>)> {
        let mut out: Vec<(Span, Vec<Span>)> = Vec::new();
        for layer in self.at_level(TraceLevel::Framework) {
            let kernels: Vec<Span> = self
                .spans
                .iter()
                .filter(|s| s.level == TraceLevel::System && s.parent_id == Some(layer.span_id))
                .cloned()
                .collect();
            out.push(((*layer).clone(), kernels));
        }
        out.sort_by(|a, b| b.0.duration_ns().cmp(&a.0.duration_ns()));
        out
    }

    /// ASCII rendering of the timeline (the web UI's visualization stand-in;
    /// indentation mirrors span nesting).
    pub fn render(&self) -> String {
        let mut out = format!("trace {} — {:.3} ms total\n", self.trace_id, self.total_ms());
        let origin = self.spans.iter().map(|s| s.start_ns).min().unwrap_or(0);
        // depth by walking parents
        let depth_of = |s: &Span| -> usize {
            let mut d = 0;
            let mut cur = s.parent_id;
            while let Some(pid) = cur {
                d += 1;
                cur = self.spans.iter().find(|x| x.span_id == pid).and_then(|x| x.parent_id);
                if d > 16 {
                    break;
                }
            }
            d
        };
        for s in &self.spans {
            let indent = "  ".repeat(depth_of(s));
            out.push_str(&format!(
                "{indent}[{:>9.3}ms +{:>8.3}ms] {} ({})\n",
                (s.start_ns - origin) as f64 / 1e6,
                s.duration_ms(),
                s.name,
                s.level.as_str(),
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace_id", Json::num(self.trace_id as f64)),
            ("total_ms", Json::num(self.total_ms())),
            ("spans", Json::arr(self.spans.iter().map(|s| s.to_json()).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracing::{SimClock, Tracer};

    /// Build a synthetic cold-start-style trace: model → layers → kernels.
    fn build_trace(server: &Arc<TraceServer>) -> u64 {
        let clock = Arc::new(SimClock::new());
        let tracer = Tracer::new(TraceLevel::Full, clock.clone(), server.clone());
        let t = tracer.new_trace();
        let root = tracer.start(t, None, TraceLevel::Model, "predict").unwrap();
        let rid = root.id();
        for (layer, _ms, kernels) in [
            ("conv1", 2.0, vec![("im2col", 0.5), ("sgemm", 1.5)]),
            ("fc6", 39.44, vec![("weight_copy_h2d", 35.0), ("sgemm", 4.44)]),
            ("fc7", 5.0, vec![("sgemm", 5.0)]),
        ] {
            let lspan = tracer.start(t, Some(rid), TraceLevel::Framework, layer).unwrap();
            let lid = lspan.id();
            for (k, kms) in kernels {
                let kspan = tracer.start(t, Some(lid), TraceLevel::System, k).unwrap();
                clock.advance_secs(kms / 1e3);
                kspan.finish();
            }
            lspan.finish();
        }
        root.finish();
        t
    }

    #[test]
    fn aggregates_into_single_timeline() {
        let server = TraceServer::new();
        let t = build_trace(&server);
        let tl = server.timeline(t);
        assert_eq!(tl.spans.len(), 1 + 3 + 5);
        assert!((tl.total_ms() - 46.44).abs() < 0.01, "{}", tl.total_ms());
    }

    #[test]
    fn zoom_into_longest_layer_finds_fc6_copy() {
        // The paper's §5.2 workflow: longest layer is fc6; zooming in shows
        // the weight copy dominates.
        let server = TraceServer::new();
        let t = build_trace(&server);
        let tl = server.timeline(t);
        let longest = tl.longest(TraceLevel::Framework).unwrap();
        assert_eq!(longest.name, "fc6");
        let inside = tl.zoom(longest.span_id);
        assert_eq!(inside.len(), 3); // fc6 + 2 kernels
        let copy = inside.iter().find(|s| s.name == "weight_copy_h2d").unwrap();
        assert!(copy.duration_ms() > 30.0);
    }

    #[test]
    fn layer_kernel_correlation_table3_shape() {
        let server = TraceServer::new();
        let t = build_trace(&server);
        let tl = server.timeline(t);
        let corr = tl.layer_kernel_correlation();
        assert_eq!(corr.len(), 3);
        // Ordered by layer time desc: fc6 first.
        assert_eq!(corr[0].0.name, "fc6");
        assert_eq!(corr[0].1.len(), 2);
        // Dominant kernel of fc6 is the weight copy.
        let dominant = corr[0].1.iter().max_by_key(|k| k.duration_ns()).unwrap();
        assert_eq!(dominant.name, "weight_copy_h2d");
    }

    #[test]
    fn multiple_traces_kept_separate() {
        let server = TraceServer::new();
        let t1 = build_trace(&server);
        let t2 = build_trace(&server);
        assert_ne!(t1, t2);
        assert_eq!(server.trace_ids().len(), 2);
        assert_eq!(server.timeline(t1).spans.len(), server.timeline(t2).spans.len());
    }

    #[test]
    fn render_indents_by_nesting() {
        let server = TraceServer::new();
        let t = build_trace(&server);
        let text = server.timeline(t).render();
        assert!(text.contains("predict"));
        assert!(text.contains("  ") && text.contains("fc6"));
        assert!(text.contains("    ") && text.contains("weight_copy_h2d"));
    }

    #[test]
    fn timeline_json_roundtrip_spans() {
        let server = TraceServer::new();
        let t = build_trace(&server);
        let j = server.timeline(t).to_json();
        let spans = j.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 9);
        assert!(Span::from_json(&spans[0]).is_some());
    }

    #[test]
    fn retention_cap_evicts_oldest_traces() {
        let server = TraceServer::with_max_traces(3);
        for trace_id in 1..=5u64 {
            server.publish(Span {
                trace_id,
                span_id: trace_id * 10,
                parent_id: None,
                name: "probe".into(),
                level: TraceLevel::Model,
                start_ns: 0,
                end_ns: 1,
                tags: Vec::new(),
            });
        }
        assert_eq!(server.trace_ids(), vec![3, 4, 5], "oldest evicted at the cap");
        assert!(server.timeline(1).is_empty());
        assert!(!server.timeline(5).is_empty());
        // Appending to a retained trace does not evict anything.
        server.publish(Span {
            trace_id: 4,
            span_id: 41,
            parent_id: None,
            name: "probe".into(),
            level: TraceLevel::Model,
            start_ns: 1,
            end_ns: 2,
            tags: Vec::new(),
        });
        assert_eq!(server.trace_ids(), vec![3, 4, 5]);
        assert_eq!(server.timeline(4).spans.len(), 2);
    }

    #[test]
    fn empty_trace_is_empty() {
        let server = TraceServer::new();
        let tl = server.timeline(999);
        assert!(tl.is_empty());
        assert_eq!(tl.total_ms(), 0.0);
    }
}
