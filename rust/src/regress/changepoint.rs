//! Offline change-point detection over a benchmark trajectory — the
//! "did this metric *step* at some commit?" half of the regression gate.
//!
//! The detector is penalized optimal partitioning (the exact objective
//! PELT optimizes): choose segment boundaries minimizing
//! `Σ SSE(segment) + β·(#segments)`, solved by an O(n²) dynamic program —
//! trajectories are one point per commit, so n stays tiny and exactness
//! beats the pruned variant's bookkeeping. The penalty is BIC-style,
//! `β = factor · σ̂² · ln n`, with the noise level σ̂ estimated robustly
//! from first differences (`median|Δ| / 0.9539`, the Gaussian consistency
//! constant for consecutive-difference MADs) so a noisy-but-flat history
//! stays quiet while a genuine step — which dwarfs σ̂² — always pays for
//! its boundary.

use crate::metrics::median;

/// Robust noise scale of a series, from the median absolute first
/// difference. For iid Gaussian noise `median|xᵢ₊₁−xᵢ| ≈ 0.9539σ`, so
/// dividing by that constant recovers σ. Noiseless series would estimate
/// exactly zero — and a zero penalty would split everywhere — so the
/// estimate is floored at a small fraction of the signal scale.
pub fn noise_sigma(series: &[f64]) -> f64 {
    if series.len() < 2 {
        return 0.0;
    }
    let diffs: Vec<f64> = series.windows(2).map(|w| (w[1] - w[0]).abs()).collect();
    let sigma = median(&diffs) / 0.9539;
    let scale = series.iter().fold(0.0f64, |m, v| m.max(v.abs())).max(1e-12);
    sigma.max(1e-3 * scale)
}

/// Detect change-points in `series`: returns the start indices (ascending,
/// all ≥ `min_segment`) of every segment after the first.
///
/// `penalty_factor` scales the BIC penalty `factor·σ̂²·ln n` — the gate's
/// default (8.0, see [`super::GateConfig`]) is deliberately conservative:
/// a CI gate pays more for a false alarm than for a one-commit detection
/// delay, and real step changes exceed the penalty by orders of magnitude.
/// Series shorter than `2·min_segment` cannot contain a boundary and
/// return no change-points.
pub fn detect(series: &[f64], penalty_factor: f64, min_segment: usize) -> Vec<usize> {
    let n = series.len();
    let min_seg = min_segment.max(1);
    if n < 2 * min_seg {
        return Vec::new();
    }
    // Prefix sums give O(1) segment SSE.
    let mut s = vec![0.0; n + 1];
    let mut s2 = vec![0.0; n + 1];
    for (i, &v) in series.iter().enumerate() {
        s[i + 1] = s[i] + v;
        s2[i + 1] = s2[i] + v * v;
    }
    let cost = |i: usize, j: usize| -> f64 {
        let len = (j - i) as f64;
        let sum = s[j] - s[i];
        (s2[j] - s2[i] - sum * sum / len).max(0.0)
    };
    let sigma = noise_sigma(series);
    let penalty = penalty_factor * sigma * sigma * (n as f64).ln();
    // f[j] = minimal penalized cost of series[0..j]; back[j] = the last
    // boundary. f[0] = −β so the first segment's +β cancels.
    let mut f = vec![f64::INFINITY; n + 1];
    let mut back = vec![0usize; n + 1];
    f[0] = -penalty;
    for j in min_seg..=n {
        for t in 0..=(j - min_seg) {
            if t != 0 && t < min_seg {
                continue; // first segment would be too short
            }
            if !f[t].is_finite() {
                continue;
            }
            let c = f[t] + cost(t, j) + penalty;
            if c < f[j] {
                f[j] = c;
                back[j] = t;
            }
        }
    }
    let mut cps = Vec::new();
    let mut j = n;
    while j > 0 {
        let t = back[j];
        if t > 0 {
            cps.push(t);
        }
        j = t;
    }
    cps.reverse();
    cps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_tiny_series_have_no_changepoints() {
        assert!(detect(&[], 8.0, 2).is_empty());
        assert!(detect(&[10.0], 8.0, 2).is_empty());
        assert!(detect(&[10.0, 20.0], 8.0, 2).is_empty(), "n < 2·min_segment");
        assert!(detect(&[10.0, 20.0, 30.0], 8.0, 2).is_empty());
    }

    #[test]
    fn flat_series_is_quiet() {
        assert!(detect(&[10.0; 24], 8.0, 2).is_empty());
    }

    #[test]
    fn noiseless_step_found_exactly() {
        let mut series = vec![10.0; 6];
        series.extend(vec![15.0; 6]);
        assert_eq!(detect(&series, 8.0, 2), vec![6]);
    }

    #[test]
    fn noisy_step_found_exactly() {
        // ±0.1 alternating jitter around each level; the 50% step at index
        // 12 towers over σ̂ ≈ 0.21.
        let series: Vec<f64> = (0..24)
            .map(|i| {
                let level = if i < 12 { 10.0 } else { 15.0 };
                level + if i % 2 == 0 { 0.1 } else { -0.1 }
            })
            .collect();
        assert_eq!(detect(&series, 8.0, 2), vec![12]);
    }

    #[test]
    fn noisy_flat_series_is_quiet() {
        // Deterministic worst case: the total SSE of a ±0.1 alternating
        // series (24·0.01 = 0.24) is below one penalty
        // (8·(0.2/0.9539)²·ln 24 ≈ 1.1), so no split can ever pay.
        let series: Vec<f64> =
            (0..24).map(|i| 10.0 + if i % 2 == 0 { 0.1 } else { -0.1 }).collect();
        assert!(detect(&series, 8.0, 2).is_empty());
        // And a seeded-jitter variant, well inside the penalty margin.
        let mut rng = crate::util::rng::Xorshift::new(11);
        let jittered: Vec<f64> = (0..40).map(|_| 10.0 + (rng.f64() - 0.5) * 0.1).collect();
        assert!(detect(&jittered, 8.0, 2).is_empty());
    }

    #[test]
    fn ramp_splits_into_few_segments() {
        // A strong linear drift is a real change: the piecewise-constant
        // fit pays for a handful of boundaries, not one per point.
        let series: Vec<f64> = (0..24).map(|i| 10.0 + 0.5 * i as f64).collect();
        let cps = detect(&series, 8.0, 2);
        assert!(!cps.is_empty(), "a 120% drift must register");
        assert!(cps.len() <= 6, "penalty bounds fragmentation: {cps:?}");
        for w in cps.windows(2) {
            assert!(w[1] > w[0], "ascending: {cps:?}");
        }
        assert!(cps.iter().all(|&c| c >= 2 && c <= 22), "min-segment respected: {cps:?}");
    }

    #[test]
    fn detection_is_deterministic() {
        let series: Vec<f64> = (0..30)
            .map(|i| if i < 17 { 4.0 } else { 9.0 } + (i % 3) as f64 * 0.01)
            .collect();
        let a = detect(&series, 8.0, 2);
        assert_eq!(a, detect(&series, 8.0, 2));
        assert_eq!(a, vec![17]);
    }
}
