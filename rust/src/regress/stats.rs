//! Two-sample statistics behind the regression gate: a hand-rolled,
//! tie-corrected Mann-Whitney U test and a seeded bootstrap confidence
//! interval on the relative median shift.
//!
//! Why these two and not a t-test on means: latency samples are skewed and
//! heavy-tailed (queueing, cold caches, allocator stalls), so a mean-based
//! test is dominated by exactly the outliers a benchmark should be robust
//! to. Mann-Whitney is rank-based — distribution-free, outlier-tolerant,
//! and exact about the question we ask ("does the treatment tend to be
//! slower?"). The bootstrap CI then sizes the shift in units people act on
//! (percent of the median), and requiring the CI to exclude zero keeps
//! statistically-significant-but-microscopic shifts from failing CI.
//!
//! Everything here is deterministic: the bootstrap PRNG is an explicit
//! [`Xorshift`] seed, and both inputs are sorted before resampling so the
//! result depends only on the sample *sets*, never their arrival order.

use crate::metrics::median;
use crate::util::rng::Xorshift;

/// Result of a two-sided Mann-Whitney U test.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MwTest {
    /// U statistic of the *treatment* sample (larger ⇒ treatment ranks
    /// higher ⇒ slower, for latency inputs).
    pub u: f64,
    /// Normal-approximation score with tie correction.
    pub z: f64,
    /// Two-sided p-value. All-tied inputs give `p = 1` (no evidence).
    pub p: f64,
}

/// Two-sided Mann-Whitney U test of `treatment` against `control`.
///
/// Mid-ranks are assigned to ties and the normal approximation uses the
/// tie-corrected variance
/// `σ² = (n₁n₂/12)·[(N+1) − Σ(t³−t)/(N(N−1))]`; a zero variance (every
/// pooled value identical) is reported as `z = 0, p = 1` rather than a
/// division by zero — identical runs are evidence of *no* change.
pub fn mann_whitney(control: &[f64], treatment: &[f64]) -> MwTest {
    if control.is_empty() || treatment.is_empty() {
        return MwTest { u: f64::NAN, z: 0.0, p: 1.0 };
    }
    let n1 = control.len() as f64;
    let n2 = treatment.len() as f64;
    let mut pooled: Vec<(f64, bool)> = control
        .iter()
        .map(|&v| (v, false))
        .chain(treatment.iter().map(|&v| (v, true)))
        .collect();
    pooled.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let n = pooled.len();
    let mut treatment_rank_sum = 0.0;
    let mut tie_term = 0.0; // Σ (t³ − t) over tie groups
    let mut i = 0;
    while i < n {
        let mut j = i + 1;
        while j < n && pooled[j].0 == pooled[i].0 {
            j += 1;
        }
        let t = (j - i) as f64;
        // 1-based mid-rank shared by the whole tie group.
        let mid_rank = ((i + 1) + j) as f64 / 2.0;
        for e in &pooled[i..j] {
            if e.1 {
                treatment_rank_sum += mid_rank;
            }
        }
        tie_term += t * t * t - t;
        i = j;
    }
    let u = treatment_rank_sum - n2 * (n2 + 1.0) / 2.0;
    let mean = n1 * n2 / 2.0;
    let nf = n as f64;
    let var = n1 * n2 / 12.0 * ((nf + 1.0) - tie_term / (nf * (nf - 1.0)));
    if var <= 0.0 {
        return MwTest { u, z: 0.0, p: 1.0 };
    }
    let z = (u - mean) / var.sqrt();
    MwTest { u, z, p: two_sided_p(z) }
}

/// Two-sided normal-tail p-value for a z score.
pub fn two_sided_p(z: f64) -> f64 {
    erfc(z.abs() / std::f64::consts::SQRT_2)
}

/// Complementary error function, `1 − erf(x)`.
pub fn erfc(x: f64) -> f64 {
    1.0 - erf(x)
}

/// Error function via the Abramowitz & Stegun 7.1.26 rational
/// approximation (max absolute error ≈ 1.5e-7 — far below the display
/// precision of any gate output).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Relative median shift of `treatment` over `control`:
/// `(median(t) − median(c)) / median(c)`. `NaN` when either side is empty
/// or the control median is zero.
pub fn relative_median_shift(control: &[f64], treatment: &[f64]) -> f64 {
    let mc = median(control);
    let mt = median(treatment);
    if mc == 0.0 || !mc.is_finite() || !mt.is_finite() {
        return f64::NAN;
    }
    (mt - mc) / mc
}

/// Seeded percentile-bootstrap 95% confidence interval on the relative
/// median shift. Returns `(lo, hi)`.
///
/// Both inputs are sorted before any resampling, so the interval is a
/// function of the sample sets and the seed alone — reordering either
/// input cannot move the gate. A fixed seed makes the interval (and with
/// it every verdict) bit-for-bit reproducible.
pub fn bootstrap_ci(
    control: &[f64],
    treatment: &[f64],
    resamples: usize,
    seed: u64,
) -> (f64, f64) {
    if control.is_empty() || treatment.is_empty() || resamples == 0 {
        return (f64::NAN, f64::NAN);
    }
    let sorted = |xs: &[f64]| {
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        v
    };
    let c = sorted(control);
    let t = sorted(treatment);
    let mut rng = Xorshift::new(seed);
    let mut cb = vec![0.0; c.len()];
    let mut tb = vec![0.0; t.len()];
    let mut deltas = Vec::with_capacity(resamples);
    for _ in 0..resamples {
        for slot in cb.iter_mut() {
            *slot = c[rng.below(c.len() as u64) as usize];
        }
        for slot in tb.iter_mut() {
            *slot = t[rng.below(t.len() as u64) as usize];
        }
        let d = relative_median_shift(&cb, &tb);
        if d.is_finite() {
            deltas.push(d);
        }
    }
    if deltas.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    deltas.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| deltas[((deltas.len() - 1) as f64 * p).round() as usize];
    (q(0.025), q(0.975))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_matches_reference_points() {
        // erf(0) = 0, erf(∞) → 1, and a couple of table values.
        assert!(erf(0.0).abs() < 1e-6);
        assert!((erf(1.0) - 0.8427008).abs() < 1e-6);
        assert!((erf(2.0) - 0.9953223).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427008).abs() < 1e-6);
        assert!(erf(6.0) > 0.999999);
    }

    #[test]
    fn mann_whitney_hand_computed_separated_groups() {
        // 8×10 vs 8×15: treatment wins every comparison → U = 64; the
        // tie-corrected σ is 8.26236 and z = 32/σ = 3.87298.
        let c = vec![10.0; 8];
        let t = vec![15.0; 8];
        let r = mann_whitney(&c, &t);
        assert_eq!(r.u, 64.0);
        assert!((r.z - 3.87298).abs() < 1e-4, "z = {}", r.z);
        assert!(r.p > 0.5e-4 && r.p < 1.5e-4, "p = {}", r.p);
    }

    #[test]
    fn mann_whitney_all_ties_is_no_evidence() {
        let r = mann_whitney(&[7.0; 10], &[7.0; 10]);
        assert_eq!(r.z, 0.0);
        assert_eq!(r.p, 1.0);
    }

    #[test]
    fn mann_whitney_symmetric_two_sided() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let b = vec![3.5, 4.5, 5.5, 6.5, 7.5];
        let fwd = mann_whitney(&a, &b);
        let rev = mann_whitney(&b, &a);
        assert!((fwd.p - rev.p).abs() < 1e-12);
        assert!((fwd.z + rev.z).abs() < 1e-12);
    }

    #[test]
    fn bootstrap_ci_constant_inputs_is_degenerate() {
        let (lo, hi) = bootstrap_ci(&[10.0; 8], &[15.0; 8], 200, 7);
        assert_eq!((lo, hi), (0.5, 0.5));
    }

    #[test]
    fn bootstrap_ci_deterministic_and_order_free() {
        let c = vec![9.0, 11.0, 10.0, 10.5, 9.5, 10.2, 9.8, 10.1];
        let t = vec![12.0, 13.0, 12.5, 12.2, 12.8, 12.4, 12.6, 12.1];
        let a = bootstrap_ci(&c, &t, 300, 42);
        let b = bootstrap_ci(&c, &t, 300, 42);
        assert_eq!(a, b, "same seed ⇒ identical interval");
        let mut c2 = c.clone();
        let mut t2 = t.clone();
        c2.reverse();
        t2.rotate_left(3);
        assert_eq!(bootstrap_ci(&c2, &t2, 300, 42), a, "order-free");
        assert!(a.0 > 0.0, "clear +20% shift: lo = {}", a.0);
    }
}
