//! Commit-over-commit regression detection — the platform's CI gate.
//!
//! The paper's platform exists to make benchmark results *comparable*
//! across time: the same model×system matrix is measured at every commit
//! and the question "did this change make anything slower?" must be
//! answered mechanically. This module is that answer, built on the
//! labeled-run substrate in [`crate::evaldb`] and [`crate::sweep`]:
//!
//! 1. `mlms regress --control <label> --treatment <label>` sweeps the
//!    matrix under both labels (each label is its own memoization line —
//!    re-gating a commit re-executes nothing);
//! 2. every cell measured under both labels is judged by a statistical
//!    gate ([`judge`]) — a tie-corrected Mann-Whitney U test on the raw
//!    latency samples plus a seeded bootstrap confidence interval on the
//!    relative median shift ([`stats`]) — **not** a bare comparison of
//!    means, which one garbage-collection pause would flip;
//! 3. a stored trajectory of per-cell medians ([`Trajectory`]) is
//!    extended and scanned for step changes by penalized optimal
//!    partitioning ([`changepoint`]), so a slow regression that no single
//!    commit-pair flags still fails CI at the commit where it lands.
//!
//! A cell is a [`Verdict::Regression`] only when all three hold: the
//! Mann-Whitney p-value clears `alpha`, the median shift exceeds
//! `min_effect`, and the bootstrap CI excludes zero. Improvements are the
//! symmetric case. Everything is seeded and deterministic: the same two
//! run lines produce byte-identical reports forever.

pub mod changepoint;
pub mod stats;

use crate::evaldb::{EvalDb, EvalKey, EvalQuery, EvalRecord};
use crate::metrics::median;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Thresholds and seeds for the statistical gate.
#[derive(Debug, Clone)]
pub struct GateConfig {
    /// Two-sided significance level for the Mann-Whitney test.
    pub alpha: f64,
    /// Minimum relative median shift (fraction, 0.05 = 5%) worth flagging.
    pub min_effect: f64,
    /// Bootstrap resamples behind the confidence interval.
    pub bootstrap_resamples: usize,
    /// PRNG seed for the bootstrap — fixed seed ⇒ reproducible interval.
    pub bootstrap_seed: u64,
    /// Penalty factor for trajectory change-point detection.
    pub cp_penalty: f64,
    /// Minimum trajectory segment length between change-points.
    pub cp_min_segment: usize,
}

impl Default for GateConfig {
    fn default() -> GateConfig {
        GateConfig {
            alpha: 0.01,
            min_effect: 0.05,
            bootstrap_resamples: 400,
            bootstrap_seed: 42,
            cp_penalty: 8.0,
            cp_min_segment: 2,
        }
    }
}

/// Gate outcome for one cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Regression,
    Improvement,
    NoChange,
}

impl Verdict {
    pub fn as_str(&self) -> &'static str {
        match self {
            Verdict::Regression => "REGRESSION",
            Verdict::Improvement => "IMPROVEMENT",
            Verdict::NoChange => "ok",
        }
    }
}

/// The full statistical judgement of one treatment/control sample pair.
#[derive(Debug, Clone, Copy)]
pub struct Judgement {
    /// Mann-Whitney U of the treatment sample.
    pub u: f64,
    /// Two-sided Mann-Whitney p-value.
    pub p: f64,
    /// Relative median shift (fraction; +0.5 = 50% slower).
    pub delta: f64,
    /// 95% bootstrap CI on the shift.
    pub ci: (f64, f64),
    pub verdict: Verdict,
}

/// Judge a treatment sample against a control sample (latencies, any
/// consistent unit). The verdict is reorder-invariant and, for a fixed
/// `cfg`, deterministic.
pub fn judge(control: &[f64], treatment: &[f64], cfg: &GateConfig) -> Judgement {
    let mw = stats::mann_whitney(control, treatment);
    let delta = stats::relative_median_shift(control, treatment);
    let (lo, hi) =
        stats::bootstrap_ci(control, treatment, cfg.bootstrap_resamples, cfg.bootstrap_seed);
    // Significant AND large enough AND the CI agrees on the sign — NaNs
    // from degenerate inputs fail every comparison and land on NoChange.
    let verdict = if mw.p < cfg.alpha && delta >= cfg.min_effect && lo > 0.0 {
        Verdict::Regression
    } else if mw.p < cfg.alpha && delta <= -cfg.min_effect && hi < 0.0 {
        Verdict::Improvement
    } else {
        Verdict::NoChange
    };
    Judgement { u: mw.u, p: mw.p, delta, ci: (lo, hi), verdict }
}

/// One cell's delta report.
#[derive(Debug, Clone)]
pub struct CellDelta {
    /// `model@system/scenario/bN`.
    pub cell: String,
    pub control_n: usize,
    pub treatment_n: usize,
    pub control_median_ms: f64,
    pub treatment_median_ms: f64,
    /// Relative median shift in percent.
    pub delta_pct: f64,
    pub ci_lo_pct: f64,
    pub ci_hi_pct: f64,
    pub u: f64,
    pub p_value: f64,
    pub verdict: Verdict,
}

/// A full control-vs-treatment comparison over the stored matrix.
#[derive(Debug, Clone)]
pub struct Comparison {
    pub control: String,
    pub treatment: String,
    /// Paired cells in deterministic (canonical-key) order.
    pub cells: Vec<CellDelta>,
    /// Cells measured under only one of the two labels.
    pub missing: Vec<String>,
}

impl Comparison {
    pub fn regressions(&self) -> usize {
        self.cells.iter().filter(|c| c.verdict == Verdict::Regression).count()
    }

    pub fn improvements(&self) -> usize {
        self.cells.iter().filter(|c| c.verdict == Verdict::Improvement).count()
    }

    pub fn has_regressions(&self) -> bool {
        self.regressions() > 0
    }
}

fn cell_name(k: &EvalKey) -> String {
    format!("{}@{}/{}/b{}", k.model, k.system, k.scenario, k.batch_size)
}

/// Compare the latest records of two labeled run lines, cell by cell.
///
/// Pairing is by the record's canonical evaluation key, so any two run
/// lines over the same matrix pair up regardless of how (sweep, direct
/// eval, replayed store) each was measured.
pub fn compare_labels(
    db: &EvalDb,
    control: &str,
    treatment: &str,
    cfg: &GateConfig,
) -> Comparison {
    let index = |label: &str| -> BTreeMap<String, EvalRecord> {
        db.latest(&EvalQuery::label(label))
            .into_iter()
            .map(|r| (r.key.canonical(), r))
            .collect()
    };
    let ctrl = index(control);
    let trt = index(treatment);
    let ms = |r: &EvalRecord| -> Vec<f64> { r.latencies.iter().map(|s| s * 1e3).collect() };
    let mut cells = Vec::new();
    let mut missing = Vec::new();
    for (k, c) in &ctrl {
        let Some(t) = trt.get(k) else {
            missing.push(format!("{} (no treatment run)", cell_name(&c.key)));
            continue;
        };
        let cms = ms(c);
        let tms = ms(t);
        let j = judge(&cms, &tms, cfg);
        cells.push(CellDelta {
            cell: cell_name(&c.key),
            control_n: cms.len(),
            treatment_n: tms.len(),
            control_median_ms: median(&cms),
            treatment_median_ms: median(&tms),
            delta_pct: j.delta * 100.0,
            ci_lo_pct: j.ci.0 * 100.0,
            ci_hi_pct: j.ci.1 * 100.0,
            u: j.u,
            p_value: j.p,
            verdict: j.verdict,
        });
    }
    for (k, t) in &trt {
        if !ctrl.contains_key(k) {
            missing.push(format!("{} (no control run)", cell_name(&t.key)));
        }
    }
    Comparison {
        control: control.to_string(),
        treatment: treatment.to_string(),
        cells,
        missing,
    }
}

/// One point of a per-cell benchmark trajectory.
#[derive(Debug, Clone, PartialEq)]
pub struct TrajectoryPoint {
    /// Run label (commit, tag, date — whatever names the run line).
    pub label: String,
    pub median_ms: f64,
}

/// A stored history of per-cell medians across run labels — the
/// `BENCH_*.json`-style artifact `mlms regress --trajectory` maintains so
/// CI can fail on *step changes* over many commits, not just on the
/// current pair.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trajectory {
    pub cells: BTreeMap<String, Vec<TrajectoryPoint>>,
}

impl Trajectory {
    /// Append (or, for a re-run of the same label, overwrite) a point.
    pub fn record(&mut self, cell: &str, label: &str, median_ms: f64) {
        let points = self.cells.entry(cell.to_string()).or_default();
        match points.iter_mut().find(|p| p.label == label) {
            Some(p) => p.median_ms = median_ms,
            None => points.push(TrajectoryPoint { label: label.to_string(), median_ms }),
        }
    }

    /// Change-point indices of one cell's series.
    pub fn changepoints(&self, cell: &str, cfg: &GateConfig) -> Vec<usize> {
        let Some(points) = self.cells.get(cell) else { return Vec::new() };
        let series: Vec<f64> = points.iter().map(|p| p.median_ms).collect();
        changepoint::detect(&series, cfg.cp_penalty, cfg.cp_min_segment)
    }

    /// Every `(cell, index, label)` whose change-point falls within the
    /// last `window` points — the CI failure condition: an *old* step is
    /// history, a recent one is this change's fault.
    pub fn recent_changepoints(
        &self,
        window: usize,
        cfg: &GateConfig,
    ) -> Vec<(String, usize, String)> {
        let mut out = Vec::new();
        for (cell, points) in &self.cells {
            for idx in self.changepoints(cell, cfg) {
                if idx + window >= points.len() {
                    out.push((cell.clone(), idx, points[idx].label.clone()));
                }
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let cells: Vec<(&str, Json)> = self
            .cells
            .iter()
            .map(|(cell, points)| {
                (
                    cell.as_str(),
                    Json::arr(
                        points
                            .iter()
                            .map(|p| {
                                Json::obj(vec![
                                    ("label", Json::str(&p.label)),
                                    ("median_ms", Json::num(p.median_ms)),
                                ])
                            })
                            .collect(),
                    ),
                )
            })
            .collect();
        Json::obj(vec![("cells", Json::obj(cells))])
    }

    /// Strict parse — a malformed trajectory must not silently drop
    /// history (a shortened series can hide the very step being gated).
    pub fn from_json(j: &Json) -> Option<Trajectory> {
        let mut out = Trajectory::default();
        for (cell, points) in j.get("cells")?.as_obj()? {
            let mut series = Vec::new();
            for p in points.as_arr()? {
                series.push(TrajectoryPoint {
                    label: p.get("label")?.as_str()?.to_string(),
                    median_ms: p.get("median_ms")?.as_f64()?,
                });
            }
            out.cells.insert(cell.clone(), series);
        }
        Some(out)
    }

    /// Load from a JSON file; a missing file is an empty trajectory.
    pub fn load(path: &str) -> std::io::Result<Trajectory> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Trajectory::default())
            }
            Err(e) => return Err(e),
        };
        Json::parse(&text)
            .ok()
            .and_then(|j| Trajectory::from_json(&j))
            .ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("{path}: not a trajectory file"),
                )
            })
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evaldb::RunMeta;

    fn key(model: &str) -> EvalKey {
        EvalKey {
            model: model.into(),
            model_version: "1.0.0".into(),
            framework: "TensorFlow".into(),
            framework_version: "1.15.0".into(),
            system: "aws_p3".into(),
            device: "gpu".into(),
            scenario: "online".into(),
            batch_size: 1,
        }
    }

    fn put(db: &EvalDb, model: &str, label: &str, ms: &[f64]) {
        let secs: Vec<f64> = ms.iter().map(|m| m / 1e3).collect();
        let mut r = EvalRecord::new(key(model), secs, 100.0);
        r.run_meta = RunMeta::labeled(label);
        db.put(r);
    }

    #[test]
    fn judge_flags_only_confirmed_shifts() {
        let cfg = GateConfig::default();
        // +50% with clean separation: regression.
        let j = judge(&[10.0; 8], &[15.0; 8], &cfg);
        assert_eq!(j.verdict, Verdict::Regression);
        assert_eq!(j.u, 64.0);
        assert!((j.delta - 0.5).abs() < 1e-12);
        assert_eq!(j.ci, (0.5, 0.5));
        // Identical samples: all ties, p = 1, no change.
        let j = judge(&[10.0; 8], &[10.0; 8], &cfg);
        assert_eq!(j.verdict, Verdict::NoChange);
        assert_eq!(j.p, 1.0);
        // −33%: improvement.
        let j = judge(&[15.0; 8], &[10.0; 8], &cfg);
        assert_eq!(j.verdict, Verdict::Improvement);
        // Significant but tiny (+1% < min_effect): not flagged.
        let c: Vec<f64> = (0..20).map(|i| 10.0 + (i % 5) as f64 * 1e-3).collect();
        let t: Vec<f64> = c.iter().map(|v| v * 1.01).collect();
        let j = judge(&c, &t, &cfg);
        assert_eq!(j.verdict, Verdict::NoChange, "p={} delta={}", j.p, j.delta);
        // Empty sides are never evidence.
        assert_eq!(judge(&[], &[10.0], &cfg).verdict, Verdict::NoChange);
    }

    #[test]
    fn compare_labels_pairs_cells_and_reports_unpaired() {
        let db = EvalDb::in_memory();
        put(&db, "alex", "base", &[10.0; 8]);
        put(&db, "alex", "cand", &[15.0; 8]);
        put(&db, "mobile", "base", &[5.0; 8]);
        put(&db, "mobile", "cand", &[5.0; 8]);
        put(&db, "resnet", "base", &[20.0; 8]); // no candidate run
        put(&db, "vgg", "cand", &[9.0; 8]); // no base run
        let cmp = compare_labels(&db, "base", "cand", &GateConfig::default());
        assert_eq!(cmp.cells.len(), 2);
        assert_eq!(cmp.regressions(), 1);
        assert_eq!(cmp.improvements(), 0);
        assert!(cmp.has_regressions());
        let alex = cmp.cells.iter().find(|c| c.cell.starts_with("alex@")).unwrap();
        assert_eq!(alex.verdict, Verdict::Regression);
        assert!((alex.delta_pct - 50.0).abs() < 1e-9);
        assert_eq!((alex.control_n, alex.treatment_n), (8, 8));
        assert_eq!(cmp.missing.len(), 2);
        assert!(cmp.missing.iter().any(|m| m.contains("resnet") && m.contains("no treatment")));
        assert!(cmp.missing.iter().any(|m| m.contains("vgg") && m.contains("no control")));
    }

    #[test]
    fn compare_uses_latest_record_per_line() {
        let db = EvalDb::in_memory();
        put(&db, "alex", "base", &[10.0; 8]);
        put(&db, "alex", "cand", &[15.0; 8]);
        // A newer, fixed candidate run supersedes the slow one.
        put(&db, "alex", "cand", &[10.0; 8]);
        let cmp = compare_labels(&db, "base", "cand", &GateConfig::default());
        assert_eq!(cmp.cells.len(), 1);
        assert_eq!(cmp.cells[0].verdict, Verdict::NoChange);
    }

    #[test]
    fn trajectory_roundtrip_and_step_gating() {
        let mut traj = Trajectory::default();
        for (i, label) in ["c1", "c2", "c3", "c4", "c5", "c6"].iter().enumerate() {
            let level = if i < 4 { 10.0 } else { 15.0 };
            traj.record("alex@aws_p3/online/b1", label, level);
        }
        let cfg = GateConfig::default();
        assert_eq!(traj.changepoints("alex@aws_p3/online/b1", &cfg), vec![4]);
        // The step is 2 points old: inside a window of 3, outside 1.
        let recent = traj.recent_changepoints(3, &cfg);
        assert_eq!(recent.len(), 1);
        assert_eq!(recent[0].1, 4);
        assert_eq!(recent[0].2, "c5");
        assert!(traj.recent_changepoints(1, &cfg).is_empty());
        // Re-recording a label overwrites instead of appending.
        traj.record("alex@aws_p3/online/b1", "c6", 15.2);
        assert_eq!(traj.cells["alex@aws_p3/online/b1"].len(), 6);
        // JSON round-trip is exact.
        let back = Trajectory::from_json(&traj.to_json()).unwrap();
        assert_eq!(back, traj);
        // Malformed shapes reject instead of truncating history.
        assert!(Trajectory::from_json(&Json::parse("{}").unwrap()).is_none());
        assert!(Trajectory::from_json(
            &Json::parse(r#"{"cells":{"c":[{"label":"x"}]}}"#).unwrap()
        )
        .is_none());
        assert!(Trajectory::from_json(
            &Json::parse(r#"{"cells":{"c":[{"label":7,"median_ms":1.0}]}}"#).unwrap()
        )
        .is_none());
    }

    #[test]
    fn trajectory_file_io() {
        let path = std::env::temp_dir()
            .join(format!("mlms_traj_{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let _ = std::fs::remove_file(&path);
        // Missing file loads empty.
        let mut traj = Trajectory::load(&path).unwrap();
        assert!(traj.cells.is_empty());
        traj.record("cell", "c1", 10.0);
        traj.save(&path).unwrap();
        assert_eq!(Trajectory::load(&path).unwrap(), traj);
        // Corrupt file is an error, not an empty history.
        std::fs::write(&path, "[1,2,3]").unwrap();
        assert!(Trajectory::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
