//! Measurement harness for `cargo bench` targets (offline substitute for
//! criterion).
//!
//! Every paper table/figure has a `rust/benches/*.rs` target built on this:
//! warmup, timed iterations, trimmed-mean / p90 summaries (the paper's own
//! statistics via [`crate::metrics`]), and aligned table / CSV / heatmap
//! rendering so benches print the same rows and series the paper reports.

use crate::metrics::LatencySamples;
use std::time::{Duration, Instant};

/// Configuration for one measured benchmark.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop early once this much wall-clock time has been spent measuring.
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            max_time: Duration::from_secs(3),
        }
    }
}

impl BenchConfig {
    /// A faster profile for expensive end-to-end cases.
    pub fn quick() -> Self {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            max_time: Duration::from_secs(2),
        }
    }
}

/// Result of measuring one case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: LatencySamples,
    pub iters: usize,
}

impl Measurement {
    pub fn trimmed_mean_ms(&self) -> f64 {
        self.samples.trimmed_mean() * 1e3
    }

    pub fn p90_ms(&self) -> f64 {
        self.samples.p90() * 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.samples.mean() * 1e3
    }
}

/// Measure `f` per the config; each call is one sample.
pub fn bench(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = LatencySamples::new();
    let started = Instant::now();
    let mut iters = 0;
    while iters < cfg.max_iters && (iters < cfg.min_iters || started.elapsed() < cfg.max_time) {
        let t0 = Instant::now();
        f();
        samples.record(t0.elapsed());
        iters += 1;
    }
    Measurement { name: name.to_string(), samples, iters }
}

/// Measure a function that reports how many items it processed, returning
/// throughput (items/sec) alongside latency.
pub fn bench_throughput(
    name: &str,
    cfg: &BenchConfig,
    mut f: impl FnMut() -> u64,
) -> (Measurement, f64) {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = LatencySamples::new();
    let mut items = 0u64;
    let started = Instant::now();
    let mut iters = 0;
    while iters < cfg.max_iters && (iters < cfg.min_iters || started.elapsed() < cfg.max_time) {
        let t0 = Instant::now();
        items += f();
        samples.record(t0.elapsed());
        iters += 1;
    }
    let total = samples.samples().iter().sum::<f64>();
    let tput = if total > 0.0 { items as f64 / total } else { f64::NAN };
    (Measurement { name: name.to_string(), samples, iters }, tput)
}

/// A column-aligned text table (the benches' stdout mirrors paper tables).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_count(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:width$}  ", c, width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV serialization for downstream plotting.
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to the bench output for plotting.
    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// ASCII heatmap (Fig 6): rows × cols of values rendered as shade ramps.
pub fn heatmap(title: &str, row_labels: &[String], col_labels: &[String], values: &[Vec<f64>]) -> String {
    const RAMP: &[char] = &[' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let max = values
        .iter()
        .flat_map(|r| r.iter())
        .copied()
        .fold(f64::NEG_INFINITY, f64::max)
        .max(1e-12);
    let label_w = row_labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("\n== {title} (max={max:.1}) ==\n");
    for (ri, row) in values.iter().enumerate() {
        out.push_str(&format!("{:label_w$} |", row_labels[ri], label_w = label_w));
        for v in row {
            let idx = ((v / max) * (RAMP.len() - 1) as f64).round().clamp(0.0, 9.0) as usize;
            out.push(RAMP[idx]);
            out.push(RAMP[idx]);
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "{:label_w$} +{}\n  cols: {}\n",
        "",
        "-".repeat(col_labels.len() * 2),
        col_labels.join(","),
        label_w = label_w
    ));
    out
}

/// ASCII scatter plot (Figs 4/5): points labelled by id.
pub fn scatter(
    title: &str,
    x_label: &str,
    y_label: &str,
    points: &[(f64, f64, String)],
    width: usize,
    height: usize,
) -> String {
    if points.is_empty() {
        return format!("\n== {title} == (no points)\n");
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for (x, y, _) in points {
        xmin = xmin.min(*x);
        xmax = xmax.max(*x);
        ymin = ymin.min(*y);
        ymax = ymax.max(*y);
    }
    let xspan = (xmax - xmin).max(1e-12);
    let yspan = (ymax - ymin).max(1e-12);
    let mut grid = vec![vec![String::new(); width]; height];
    for (x, y, label) in points {
        let cx = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
        let cy = (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
        let cell = &mut grid[height - 1 - cy][cx];
        if cell.is_empty() {
            *cell = label.clone();
        } else {
            cell.push(',');
            cell.push_str(label);
        }
    }
    let mut out = format!("\n== {title} ==  (y: {y_label}, x: {x_label})\n");
    for row in &grid {
        out.push('|');
        for cell in row {
            if cell.is_empty() {
                out.push_str(" .");
            } else {
                // Print the first id; multiple points collapse visually.
                let id = cell.split(',').next().unwrap();
                out.push_str(&format!("{:>2}", &id[..id.len().min(2)]));
            }
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "+{}\n x: [{:.3}, {:.3}]  y: [{:.3}, {:.3}]\n",
        "-".repeat(width * 2),
        xmin,
        xmax,
        ymin,
        ymax
    ));
    out
}

/// Standard header printed by every bench binary.
pub fn bench_header(name: &str, paper_ref: &str) {
    println!("\n######################################################");
    println!("# bench: {name}");
    println!("# reproduces: {paper_ref}");
    println!("######################################################");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let cfg = BenchConfig { warmup_iters: 1, min_iters: 5, max_iters: 5, max_time: Duration::from_secs(1) };
        let m = bench("noop", &cfg, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(m.iters, 5);
        assert_eq!(m.samples.len(), 5);
        assert!(m.mean_ms() >= 0.0);
    }

    #[test]
    fn bench_respects_max_time() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 1_000_000,
            max_time: Duration::from_millis(50),
        };
        let m = bench("sleepy", &cfg, || std::thread::sleep(Duration::from_millis(5)));
        assert!(m.iters < 1000, "stopped early, got {}", m.iters);
    }

    #[test]
    fn throughput_bench() {
        let cfg = BenchConfig::quick();
        let (_m, tput) = bench_throughput("batch", &cfg, || {
            std::thread::sleep(Duration::from_millis(1));
            100
        });
        assert!(tput > 0.0 && tput.is_finite());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["model", "latency (ms)"]);
        t.row(&["resnet50".into(), "6.33".into()]);
        t.row(&["vgg16".into(), "22.43".into()]);
        let s = t.render();
        assert!(s.contains("resnet50"));
        assert!(s.contains("== demo =="));
        let csv = t.to_csv();
        assert!(csv.starts_with("model,latency (ms)\n"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn heatmap_renders() {
        let s = heatmap(
            "h",
            &["b1".into(), "b2".into()],
            &["m1".into(), "m2".into(), "m3".into()],
            &[vec![1.0, 2.0, 3.0], vec![3.0, 2.0, 1.0]],
        );
        assert!(s.contains("b1"));
        assert!(s.contains("cols: m1,m2,m3"));
    }

    #[test]
    fn scatter_renders() {
        let pts = vec![(1.0, 2.0, "1".to_string()), (3.0, 4.0, "2".to_string())];
        let s = scatter("sc", "lat", "acc", &pts, 20, 10);
        assert!(s.contains("== sc =="));
        assert!(s.contains("x: [1.000, 3.000]"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["hello, world".into()]);
        assert!(t.to_csv().contains("\"hello, world\""));
    }
}
