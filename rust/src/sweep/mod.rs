//! The reproducible sweep engine (§4.5.2, §5.1).
//!
//! The paper's headline result — an automated case study of 37 models
//! across 4 systems — rests on a consistent, *resumable* evaluation
//! workflow backed by a queryable result store. This module makes that
//! cross-product a first-class plan instead of a shell loop:
//!
//! - a [`Plan`] is the cross-product of zoo models × system models ×
//!   scenario templates × batch sizes, resolved into concrete [`Cell`]s;
//! - every cell has a content-addressed spec digest
//!   ([`crate::evaldb::EvalSpec`]) computed *before* execution, so a fresh
//!   digest hit in the evaluation database **memoizes** the cell — the run
//!   is skipped and the stored record is reused;
//! - execution fans out across the registry fleet, one worker per system
//!   (cells on the same simulated agent run sequentially, keeping the
//!   simulated clocks — and therefore the stored latencies — deterministic);
//! - because each executed cell's record is persisted under its digest as
//!   soon as it completes, a crashed or interrupted sweep is **resumable**:
//!   re-running the identical plan executes only the missing cells, and
//!   `resume(resume(x)) == resume(x)`.
//!
//! Surfaced as `mlms sweep`, reported by
//! [`crate::analysis::model_system_matrix`], and self-asserted by
//! `benches/fig_sweep.rs`.

use crate::batcher::BatcherConfig;
use crate::evaldb::{EvalDb, EvalRecord, EvalSpec, RunMeta};
use crate::manifest::{Accelerator, SystemRequirements};
use crate::registry::Registry;
use crate::scenario::Scenario;
use crate::server::{EvalJob, Server};
use crate::tracing::TraceLevel;
use crate::util::json::Json;
use crate::util::threadpool::parallel_map;
use std::collections::HashSet;
use std::sync::Arc;

/// A sweep plan: the declarative cross-product plus the execution knobs
/// that are part of each cell's spec (accelerator, trace level, seed,
/// dispatch config).
#[derive(Debug, Clone)]
pub struct Plan {
    /// Zoo model names.
    pub models: Vec<String>,
    /// System profile names (e.g. the Table-1 fleet).
    pub systems: Vec<String>,
    /// Scenario templates; each is resolved per batch size (see
    /// [`resolve_scenario`]).
    pub scenarios: Vec<Scenario>,
    /// Batch sizes crossed with every scenario template.
    pub batch_sizes: Vec<usize>,
    /// Device class every cell targets. `Any` normalizes to `Gpu` — the
    /// digest needs a concrete device for identical configs to be
    /// identical by construction.
    pub accelerator: Accelerator,
    pub trace_level: TraceLevel,
    /// Workload seed shared by every cell (part of each spec digest).
    pub seed: u64,
    /// When set, single-item cells run through cross-request batched
    /// dispatch ([`Server::evaluate_batched`]) instead of the classic
    /// per-request path; the config is folded into the spec digest.
    pub dispatch: Option<BatcherConfig>,
    /// Worker cap for the per-system fan-out.
    pub parallelism: usize,
    /// Run metadata stamped on every record the sweep stores. The label
    /// folds into each cell's spec digest, so sweeping the same matrix
    /// under two labels measures both run lines while re-running one label
    /// memoizes — the substrate `mlms regress` is built on.
    pub run_meta: RunMeta,
}

/// One resolved cross-product cell.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub model: String,
    pub system: String,
    /// The resolved scenario (template × batch size).
    pub scenario: Scenario,
    /// The batch-size coordinate this cell came from.
    pub batch_size: usize,
}

impl Cell {
    pub fn label(&self) -> String {
        format!(
            "{}@{}/{}/b{}",
            self.model,
            self.system,
            self.scenario.name(),
            self.batch_size
        )
    }
}

/// Resolve a scenario template at a batch size: `Batched` templates take
/// the batch directly; single-item templates at batch 1 run as-is; at
/// batch > 1 they degrade to a throughput run (`Batched`) covering the
/// same number of items — the paper's Fig-6 batch-sweep semantics.
pub fn resolve_scenario(template: &Scenario, batch: usize) -> Scenario {
    match template {
        Scenario::Batched { batches, .. } => {
            Scenario::Batched { batch_size: batch.max(1), batches: *batches }
        }
        other if batch <= 1 => other.clone(),
        other => {
            let items = other.total_items().max(batch);
            Scenario::Batched { batch_size: batch, batches: (items / batch).max(1) }
        }
    }
}

impl Plan {
    /// A latency-oriented default plan: `Online` scenario, batch 1, GPU,
    /// no tracing.
    pub fn new(models: Vec<String>, systems: Vec<String>) -> Plan {
        Plan {
            models,
            systems,
            scenarios: vec![Scenario::Online { count: 16 }],
            batch_sizes: vec![1],
            accelerator: Accelerator::Gpu,
            trace_level: TraceLevel::None,
            seed: 42,
            dispatch: None,
            parallelism: 4,
            run_meta: RunMeta::default(),
        }
    }

    fn effective_accelerator(&self) -> Accelerator {
        match self.accelerator {
            Accelerator::Any => Accelerator::Gpu,
            a => a,
        }
    }

    fn device(&self) -> &'static str {
        self.effective_accelerator().as_str()
    }

    /// The full cross-product, in (model, system, scenario, batch) order.
    pub fn cells(&self) -> Vec<Cell> {
        let mut out = Vec::with_capacity(
            self.models.len()
                * self.systems.len()
                * self.scenarios.len()
                * self.batch_sizes.len(),
        );
        for model in &self.models {
            for system in &self.systems {
                for template in &self.scenarios {
                    for &batch in &self.batch_sizes {
                        out.push(Cell {
                            model: model.clone(),
                            system: system.clone(),
                            scenario: resolve_scenario(template, batch),
                            batch_size: batch,
                        });
                    }
                }
            }
        }
        out
    }

    /// Whether a cell executes through cross-request batched dispatch.
    pub fn uses_dispatch(&self, cell: &Cell) -> bool {
        self.dispatch.is_some() && cell.scenario.batch_size() == 1
    }

    /// The cell's fully-resolved spec — `None` when the model is not in
    /// the registry. Mirrors exactly what the execution path stores, so
    /// plan-time digests and stored digests match by construction.
    pub fn spec(&self, registry: &Registry, cell: &Cell) -> Option<EvalSpec> {
        let manifest = registry.manifest(&cell.model, None)?;
        let (batch_size, dispatch) = if self.uses_dispatch(cell) {
            let cfg = self.dispatch.as_ref().unwrap();
            (cfg.max_batch_size.max(1), cfg.fingerprint_json())
        } else {
            (cell.scenario.batch_size(), Json::Null)
        };
        let mut spec = EvalSpec::for_request(
            &manifest,
            &cell.system,
            self.device(),
            &cell.scenario,
            batch_size,
            self.trace_level,
            self.seed,
            dispatch,
        );
        spec.run_label = self.run_meta.label.clone();
        Some(spec)
    }

    /// The cell's memoization digest (`None` for unknown models).
    pub fn digest(&self, registry: &Registry, cell: &Cell) -> Option<String> {
        self.spec(registry, cell).map(|s| s.digest())
    }

    /// The evaluation job a cell runs.
    pub fn job(&self, cell: &Cell) -> EvalJob {
        let mut job = EvalJob::new(&cell.model, cell.scenario.clone());
        job.trace_level = self.trace_level;
        job.seed = self.seed;
        job.requirements = SystemRequirements::on_system(&cell.system);
        job.requirements.accelerator = self.effective_accelerator();
        job.run_meta = self.run_meta.clone();
        job
    }

    /// The cells a run would actually execute: the cross-product minus
    /// fresh digest hits in `db`, deduped by digest (two cells resolving
    /// to the identical spec execute once).
    pub fn pending(&self, registry: &Registry, db: &EvalDb) -> Vec<Cell> {
        self.partition(registry, db).pending.into_iter().map(|(c, _)| c).collect()
    }

    fn partition(&self, registry: &Registry, db: &EvalDb) -> Partition {
        let mut p = Partition::default();
        let mut seen: HashSet<String> = HashSet::new();
        for cell in self.cells() {
            let digest = match self.digest(registry, &cell) {
                Some(d) => d,
                None => {
                    p.failed.push((cell, "model not in registry".to_string()));
                    continue;
                }
            };
            if let Some(r) = db.get_by_digest(&digest) {
                p.memoized += 1;
                p.records.push(r);
                continue;
            }
            if !seen.insert(digest.clone()) {
                p.memoized += 1;
                continue;
            }
            p.pending.push((cell, digest));
        }
        p
    }
}

#[derive(Default)]
struct Partition {
    pending: Vec<(Cell, String)>,
    memoized: usize,
    failed: Vec<(Cell, String)>,
    records: Vec<EvalRecord>,
}

/// The result of one sweep pass.
pub struct Outcome {
    /// Cross-product size.
    pub cells: usize,
    /// Cells executed this pass.
    pub executed: usize,
    /// Cells skipped via digest memoization (including in-run duplicates).
    pub memoized: usize,
    /// Cells whose first execution failed and were retried once against a
    /// fresh agent resolution (fleet failover; see [`run`]).
    pub retried: usize,
    /// Cells that could not run, with their errors.
    pub failed: Vec<(Cell, String)>,
    /// One record per covered cell — memoized records first, then fresh
    /// ones in completion order.
    pub records: Vec<EvalRecord>,
    /// Wall-clock time of the pass, seconds.
    pub wall_s: f64,
}

impl Outcome {
    pub fn summary(&self) -> String {
        format!(
            "sweep: {} cells — {} executed, {} retried, {} memoized, {} failed in {:.2}s",
            self.cells,
            self.executed,
            self.retried,
            self.memoized,
            self.failed.len(),
            self.wall_s
        )
    }
}

/// Run one cell's job through the path its plan prescribes.
fn execute_cell(server: &Server, plan: &Plan, cell: &Cell) -> Result<Vec<EvalRecord>, String> {
    let job = plan.job(cell);
    if plan.uses_dispatch(cell) {
        server
            .evaluate_batched(&job, plan.dispatch.as_ref().unwrap())
            .map(|b| vec![b.record])
            .map_err(|e| e.to_string())
    } else {
        server.evaluate(&job).map_err(|e| e.to_string())
    }
}

/// Execute a plan against a server's fleet with memoization and crash-safe
/// resume (see the module docs). Cells are grouped by system: groups run
/// in parallel (the fleet dimension), cells within a group sequentially
/// (one simulated agent's clock must not be shared by concurrent runs).
///
/// **Failover:** a cell whose execution fails (an agent process died
/// mid-batch, a connection dropped) is retried **exactly once** against a
/// fresh agent resolution — by then the dead agent's lease has lapsed or
/// its connection refuses, so the retry lands on a survivor. Nothing was
/// stored for the failed attempt (both execution paths store only on
/// success), so the retry keeps every cell exactly-once in the store.
pub fn run(server: &Arc<Server>, plan: &Plan) -> Outcome {
    let t0 = std::time::Instant::now();
    let total = plan.cells().len();
    let part = plan.partition(&server.registry, &server.evaldb);
    // Dashboard progress: the cross-product size up front, then memoized /
    // unresolvable cells settle immediately; executed cells tick in as
    // their groups complete.
    server.gauges.sweep_started(total);
    server.gauges.cells_memoized(part.memoized);
    if !part.failed.is_empty() {
        server.gauges.cells_failed(part.failed.len());
    }
    let mut failed = part.failed;
    let mut records = part.records;

    let mut groups: Vec<(String, Vec<(Cell, String)>)> = Vec::new();
    for (cell, digest) in part.pending {
        match groups.iter().position(|(s, _)| *s == cell.system) {
            Some(i) => groups[i].1.push((cell, digest)),
            None => groups.push((cell.system.clone(), vec![(cell, digest)])),
        }
    }
    let workers = plan.parallelism.max(1).min(groups.len().max(1));
    let server2 = server.clone();
    let plan2 = plan.clone();
    let group_results = parallel_map(groups, workers, move |(_, cells)| {
        let mut out = Vec::with_capacity(cells.len());
        for (cell, _digest) in cells {
            let result = execute_cell(&server2, &plan2, &cell);
            if result.is_ok() {
                server2.gauges.cell_executed();
            }
            out.push((cell, result));
        }
        out
    });

    let mut executed = 0usize;
    let mut exec_failed: Vec<(Cell, String)> = Vec::new();
    for (cell, result) in group_results.into_iter().flatten() {
        match result {
            Ok(mut rs) => {
                executed += 1;
                records.append(&mut rs);
            }
            Err(e) => exec_failed.push((cell, e)),
        }
    }
    // Failover pass: retry each failed cell once on whatever agents still
    // resolve. Sequential — by now the fleet may be down to few survivors.
    let retried = exec_failed.len();
    for (cell, first_err) in exec_failed {
        match execute_cell(server, plan, &cell) {
            Ok(mut rs) => {
                executed += 1;
                server.gauges.cell_executed();
                records.append(&mut rs);
            }
            Err(e) => {
                server.gauges.cells_failed(1);
                failed.push((cell, format!("{first_err}; retry: {e}")));
            }
        }
    }
    Outcome {
        cells: total,
        executed,
        memoized: part.memoized,
        retried,
        failed,
        records,
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evaldb::EvalQuery;

    fn small_plan() -> Plan {
        let mut plan = Plan::new(
            vec!["BVLC_AlexNet".to_string(), "MobileNet_v1_0.25_128".to_string()],
            vec!["aws_p3".to_string(), "ibm_p8".to_string()],
        );
        plan.scenarios = vec![Scenario::Online { count: 4 }];
        plan.batch_sizes = vec![1, 8];
        plan.parallelism = 2;
        plan
    }

    #[test]
    fn cells_are_the_full_cross_product() {
        let plan = small_plan();
        let cells = plan.cells();
        assert_eq!(cells.len(), 2 * 2 * 1 * 2);
        // Batch 1 keeps the template; batch 8 resolves to a throughput run
        // over the same item count.
        let b1 = cells.iter().find(|c| c.batch_size == 1).unwrap();
        assert_eq!(b1.scenario, Scenario::Online { count: 4 });
        let b8 = cells.iter().find(|c| c.batch_size == 8).unwrap();
        assert_eq!(b8.scenario, Scenario::Batched { batch_size: 8, batches: 1 });
    }

    #[test]
    fn cold_sweep_executes_then_memoizes() {
        let server = Server::sim_platform(TraceLevel::None);
        let plan = small_plan();
        let cold = run(&server, &plan);
        assert_eq!(cold.cells, 8);
        assert_eq!(cold.executed, 8, "failures: {:?}", cold.failed);
        assert_eq!(cold.memoized, 0);
        assert_eq!(server.evaldb.len(), 8, "every cell stored exactly once");
        // Each cell's digest is now a fresh hit.
        for cell in plan.cells() {
            let d = plan.digest(&server.registry, &cell).unwrap();
            assert!(server.evaldb.get_by_digest(&d).is_some(), "{}", cell.label());
        }
        assert!(plan.pending(&server.registry, &server.evaldb).is_empty());
        // Second pass: pure memoization, nothing re-runs or re-stores.
        let warm = run(&server, &plan);
        assert_eq!(warm.executed, 0);
        assert_eq!(warm.memoized, 8);
        assert_eq!(warm.records.len(), 8);
        assert_eq!(server.evaldb.len(), 8);
    }

    #[test]
    fn sweep_records_are_queryable_per_cell() {
        let server = Server::sim_platform(TraceLevel::None);
        let plan = small_plan();
        run(&server, &plan);
        for cell in plan.cells() {
            let q = EvalQuery {
                model: Some(cell.model.clone()),
                system: Some(cell.system.clone()),
                scenario: Some(cell.scenario.name().to_string()),
                batch_size: Some(cell.scenario.batch_size()),
                ..Default::default()
            };
            assert_eq!(server.evaldb.latest(&q).len(), 1, "{}", cell.label());
        }
    }

    #[test]
    fn unknown_model_is_reported_not_fatal() {
        let server = Server::sim_platform(TraceLevel::None);
        let mut plan = small_plan();
        plan.models.push("NotInZoo".to_string());
        let out = run(&server, &plan);
        assert_eq!(out.cells, 12);
        assert_eq!(out.executed, 8);
        assert_eq!(out.failed.len(), 4, "{:?}", out.failed);
        assert!(out.failed.iter().all(|(c, _)| c.model == "NotInZoo"));
    }

    #[test]
    fn labeled_sweeps_form_independent_memoization_lines() {
        let server = Server::sim_platform(TraceLevel::None);
        let mut plan = small_plan();
        plan.run_meta = RunMeta::labeled("control");
        let cold = run(&server, &plan);
        assert_eq!(cold.executed, 8, "failures: {:?}", cold.failed);
        // Same label re-run: pure memoization.
        let warm = run(&server, &plan);
        assert_eq!(warm.executed, 0);
        assert_eq!(warm.memoized, 8);
        // A different label is a different experiment: all cells pending.
        let mut treatment = plan.clone();
        treatment.run_meta = RunMeta::labeled("treatment");
        assert_eq!(treatment.pending(&server.registry, &server.evaldb).len(), 8);
        let t = run(&server, &treatment);
        assert_eq!(t.executed, 8, "failures: {:?}", t.failed);
        assert_eq!(server.evaldb.len(), 16, "8 cells per label line");
        // Every stored record carries its line's label.
        assert_eq!(server.evaldb.query(&EvalQuery::label("control")).len(), 8);
        assert_eq!(server.evaldb.query(&EvalQuery::label("treatment")).len(), 8);
    }

    #[test]
    fn dispatch_cells_memoize_under_their_config() {
        let server = Server::sim_platform(TraceLevel::None);
        let mut plan = small_plan();
        plan.scenarios = vec![Scenario::Poisson { rate: 2000.0, count: 16 }];
        plan.batch_sizes = vec![1];
        plan.dispatch = Some(BatcherConfig::new(8, 10.0));
        let cold = run(&server, &plan);
        assert_eq!(cold.executed, 4, "failures: {:?}", cold.failed);
        let warm = run(&server, &plan);
        assert_eq!(warm.executed, 0);
        assert_eq!(warm.memoized, 4);
        // A different dispatch config is a different experiment.
        let mut other = plan.clone();
        other.dispatch = Some(BatcherConfig::new(4, 2.0));
        assert_eq!(other.pending(&server.registry, &server.evaldb).len(), 4);
    }
}
