//! The built-in model zoo — the 37 Table-2 models.
//!
//! The paper bootstraps MLModelScope with built-in models; its evaluation
//! (§5.1) uses 37 TensorFlow image-classification models. Each entry here
//! carries the paper's published metadata (Top-1 accuracy, frozen-graph
//! size) exactly as Table 2 lists it, plus an analytic layer description
//! generated from the real architecture ([`arch`]). Five families also have
//! *real* JAX/Pallas counterparts compiled into `artifacts/` and executed
//! via PJRT (see `python/compile/model.py`); `hlo_family()` maps an entry to
//! its artifact family.

pub mod arch;

pub use arch::LayerSpec;

use crate::manifest::ModelManifest;
use crate::util::json::Json;

/// One catalog entry (a Table-2 row).
#[derive(Debug, Clone)]
pub struct ZooModel {
    /// Table-2 ID (1–37), used as scatter-plot label in Figs 4/5.
    pub id: usize,
    pub name: String,
    /// Published Top-1 accuracy (%, ImageNet) — metadata, as in the paper.
    pub top1_accuracy: f64,
    /// Frozen-graph size in MB — Table-2 column.
    pub graph_size_mb: f64,
    /// Input resolution (square).
    pub resolution: usize,
    /// Architecture family tag, e.g. `resnet`, `mobilenet`.
    pub family: &'static str,
    gen: fn(usize) -> Vec<LayerSpec>,
}

impl ZooModel {
    /// Generate the per-layer workload description.
    pub fn layers(&self) -> Vec<LayerSpec> {
        (self.gen)(self.resolution)
    }

    /// Analytic weight size (MB) — cross-checked against `graph_size_mb`.
    pub fn analytic_weight_mb(&self) -> f64 {
        arch::total_weight_bytes(&self.layers()) / 1e6
    }

    /// The AOT artifact family exercising this architecture class for real
    /// (`None` → simulation only).
    pub fn hlo_family(&self) -> Option<&'static str> {
        match self.family {
            "resnet" | "resnet_v2" => Some("tiny_resnet"),
            "vgg" => Some("tiny_vgg"),
            "mobilenet" => Some("tiny_mobilenet"),
            "inception" | "inception_resnet" | "googlenet" => Some("tiny_inception"),
            "alexnet" => Some("tiny_alexnet"),
            _ => None,
        }
    }

    /// Build the built-in model manifest for this entry (§4.6 "Adding
    /// Models": models are defined purely by manifest).
    pub fn manifest(&self) -> ModelManifest {
        let yaml = format!(
            r#"
name: {name}
version: 1.0.0
description: built-in zoo model (Table 2 id {id})
framework:
  name: TensorFlow
  version: '>=1.12.0 <2.0'
inputs:
  - type: image
    layer_name: input_tensor
    element_type: float32
    steps:
      - decode:
          data_layout: NHWC
          color_mode: RGB
      - resize:
          dimensions: [3, {res}, {res}]
          method: bilinear
          keep_aspect_ratio: true
      - normalize:
          mean: [123.68, 116.78, 103.94]
          rescale: 1.0
outputs:
  - type: probability
    layer_name: prob
    element_type: float32
    steps:
      - argsort:
          labels_url: https://mlmodelscope.example/synset.txt
model:
  base_url: builtin://zoo/
  graph_path: {name}.pb
  checksum: zoo-{id}
attributes:
  training_dataset: ImageNet
  top1_accuracy: {acc}
  graph_size_mb: {size}
  family: {family}
"#,
            name = self.name,
            id = self.id,
            res = self.resolution,
            acc = self.top1_accuracy,
            size = self.graph_size_mb,
            family = self.family,
        );
        ModelManifest::from_yaml(&yaml).expect("zoo manifest must parse")
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("name", Json::str(&self.name)),
            ("top1_accuracy", Json::num(self.top1_accuracy)),
            ("graph_size_mb", Json::num(self.graph_size_mb)),
            ("resolution", Json::num(self.resolution as f64)),
            ("family", Json::str(self.family)),
        ])
    }
}

macro_rules! zoo {
    ($($id:expr, $name:expr, $acc:expr, $size:expr, $res:expr, $family:expr, $gen:expr;)*) => {
        vec![$(ZooModel {
            id: $id,
            name: $name.to_string(),
            top1_accuracy: $acc,
            graph_size_mb: $size,
            resolution: $res,
            family: $family,
            gen: $gen,
        }),*]
    };
}

/// The full Table-2 catalog, in the paper's accuracy-sorted order.
pub fn all() -> Vec<ZooModel> {
    zoo![
        1,  "Inception_ResNet_v2",    80.40, 214.0, 299, "inception_resnet", |r| arch::inception_resnet_v2(r);
        2,  "Inception_v4",           80.20, 163.0, 299, "inception", |r| arch::inception(4, r);
        3,  "Inception_v3",           78.00,  91.0, 299, "inception", |r| arch::inception(3, r);
        4,  "ResNet_v2_152",          77.80, 231.0, 224, "resnet_v2", |r| arch::resnet(152, true, r);
        5,  "ResNet_v2_101",          77.00, 170.0, 224, "resnet_v2", |r| arch::resnet(101, true, r);
        6,  "ResNet_v1_152",          76.80, 230.0, 224, "resnet", |r| arch::resnet(152, false, r);
        7,  "MLPerf_ResNet50_v1.5",   76.46, 103.0, 224, "resnet", |r| arch::resnet(50, false, r);
        8,  "ResNet_v1_101",          76.40, 170.0, 224, "resnet", |r| arch::resnet(101, false, r);
        9,  "AI_Matrix_ResNet152",    75.93, 230.0, 224, "resnet", |r| arch::resnet(152, false, r);
        10, "ResNet_v2_50",           75.60,  98.0, 224, "resnet_v2", |r| arch::resnet(50, true, r);
        11, "ResNet_v1_50",           75.20,  98.0, 224, "resnet", |r| arch::resnet(50, false, r);
        12, "AI_Matrix_ResNet50",     74.38,  98.0, 224, "resnet", |r| arch::resnet(50, false, r);
        13, "Inception_v2",           73.90,  43.0, 224, "inception", |r| arch::inception(2, r);
        14, "AI_Matrix_DenseNet121",  73.29,  31.0, 224, "densenet", |r| arch::densenet121(r);
        15, "MLPerf_MobileNet_v1",    71.68,  17.0, 224, "mobilenet", |r| arch::mobilenet_v1(1.0, r);
        16, "VGG16",                  71.50, 528.0, 224, "vgg", |r| arch::vgg(16, r);
        17, "VGG19",                  71.10, 548.0, 224, "vgg", |r| arch::vgg(19, r);
        18, "MobileNet_v1_1.0_224",   70.90,  16.0, 224, "mobilenet", |r| arch::mobilenet_v1(1.0, r);
        19, "AI_Matrix_GoogleNet",    70.01,  27.0, 224, "googlenet", |r| arch::googlenet(r);
        20, "MobileNet_v1_1.0_192",   70.00,  16.0, 192, "mobilenet", |r| arch::mobilenet_v1(1.0, r);
        21, "Inception_v1",           69.80,  26.0, 224, "inception", |r| arch::inception(1, r);
        22, "BVLC_GoogLeNet",         68.70,  27.0, 224, "googlenet", |r| arch::googlenet(r);
        23, "MobileNet_v1_0.75_224",  68.40,  10.0, 224, "mobilenet", |r| arch::mobilenet_v1(0.75, r);
        24, "MobileNet_v1_1.0_160",   68.00,  16.0, 160, "mobilenet", |r| arch::mobilenet_v1(1.0, r);
        25, "MobileNet_v1_0.75_192",  67.20,  10.0, 192, "mobilenet", |r| arch::mobilenet_v1(0.75, r);
        26, "MobileNet_v1_0.75_160",  65.30,  10.0, 160, "mobilenet", |r| arch::mobilenet_v1(0.75, r);
        27, "MobileNet_v1_1.0_128",   65.20,  16.0, 128, "mobilenet", |r| arch::mobilenet_v1(1.0, r);
        28, "MobileNet_v1_0.5_224",   63.30,   5.2, 224, "mobilenet", |r| arch::mobilenet_v1(0.5, r);
        29, "MobileNet_v1_0.75_128",  62.10,  10.0, 128, "mobilenet", |r| arch::mobilenet_v1(0.75, r);
        30, "MobileNet_v1_0.5_192",   61.70,   5.2, 192, "mobilenet", |r| arch::mobilenet_v1(0.5, r);
        31, "MobileNet_v1_0.5_160",   59.10,   5.2, 160, "mobilenet", |r| arch::mobilenet_v1(0.5, r);
        32, "BVLC_AlexNet",           57.10, 233.0, 224, "alexnet", |r| arch::alexnet(r);
        33, "MobileNet_v1_0.5_128",   56.30,   5.2, 128, "mobilenet", |r| arch::mobilenet_v1(0.5, r);
        34, "MobileNet_v1_0.25_224",  49.80,   1.9, 224, "mobilenet", |r| arch::mobilenet_v1(0.25, r);
        35, "MobileNet_v1_0.25_192",  47.70,   1.9, 192, "mobilenet", |r| arch::mobilenet_v1(0.25, r);
        36, "MobileNet_v1_0.25_160",  45.50,   1.9, 160, "mobilenet", |r| arch::mobilenet_v1(0.25, r);
        37, "MobileNet_v1_0.25_128",  41.50,   1.9, 128, "mobilenet", |r| arch::mobilenet_v1(0.25, r);
    ]
}

/// All zoo model names in Table-2 order (sweep defaults, CLI listings).
pub fn names() -> Vec<String> {
    all().into_iter().map(|m| m.name).collect()
}

/// Look up a zoo model by name (case-sensitive, as registered).
pub fn by_name(name: &str) -> Option<ZooModel> {
    all().into_iter().find(|m| m.name == name)
}

/// Look up by Table-2 id.
pub fn by_id(id: usize) -> Option<ZooModel> {
    all().into_iter().find(|m| m.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_37_models_sorted_by_accuracy() {
        let zoo = all();
        assert_eq!(zoo.len(), 37);
        for w in zoo.windows(2) {
            assert!(
                w[0].top1_accuracy >= w[1].top1_accuracy,
                "{} vs {}",
                w[0].name,
                w[1].name
            );
        }
        // ids are 1..=37 in order.
        for (i, m) in zoo.iter().enumerate() {
            assert_eq!(m.id, i + 1);
        }
    }

    #[test]
    fn table2_spot_values() {
        let r50 = by_name("MLPerf_ResNet50_v1.5").unwrap();
        assert_eq!(r50.id, 7);
        assert_eq!(r50.top1_accuracy, 76.46);
        assert_eq!(r50.graph_size_mb, 103.0);
        let alex = by_id(32).unwrap();
        assert_eq!(alex.name, "BVLC_AlexNet");
        assert_eq!(alex.graph_size_mb, 233.0);
    }

    #[test]
    fn analytic_weights_track_graph_size() {
        // The analytic FP32 weight estimate should be within 2.5× of the
        // published frozen-graph size for the weight-dominated models
        // (graph protos also carry topology, so exact match isn't expected).
        for name in ["VGG16", "VGG19", "BVLC_AlexNet", "ResNet_v1_50", "MobileNet_v1_1.0_224"] {
            let m = by_name(name).unwrap();
            let est = m.analytic_weight_mb();
            let ratio = est / m.graph_size_mb;
            assert!(
                (0.4..2.5).contains(&ratio),
                "{name}: analytic {est:.0} MB vs table {} MB (ratio {ratio:.2})",
                m.graph_size_mb
            );
        }
    }

    #[test]
    fn manifests_parse_for_all_entries() {
        for m in all() {
            let manifest = m.manifest();
            assert_eq!(manifest.name, m.name);
            assert_eq!(manifest.accuracy(), Some(m.top1_accuracy));
            assert_eq!(manifest.graph_size_mb(), Some(m.graph_size_mb));
            assert_eq!(manifest.inputs[0].steps.len(), 3);
        }
    }

    #[test]
    fn every_model_generates_layers() {
        for m in all() {
            let layers = m.layers();
            assert!(layers.len() > 10, "{} has {} layers", m.name, layers.len());
            assert!(arch::total_flops(&layers) > 1e7, "{}", m.name);
        }
    }

    #[test]
    fn hlo_family_mapping() {
        assert_eq!(by_name("ResNet_v1_50").unwrap().hlo_family(), Some("tiny_resnet"));
        assert_eq!(by_name("VGG16").unwrap().hlo_family(), Some("tiny_vgg"));
        assert_eq!(by_name("BVLC_AlexNet").unwrap().hlo_family(), Some("tiny_alexnet"));
        assert_eq!(by_name("MobileNet_v1_0.5_160").unwrap().hlo_family(), Some("tiny_mobilenet"));
        assert_eq!(by_name("AI_Matrix_DenseNet121").unwrap().hlo_family(), None);
    }

    #[test]
    fn resolution_affects_workload_not_metadata() {
        let m224 = by_name("MobileNet_v1_1.0_224").unwrap();
        let m128 = by_name("MobileNet_v1_1.0_128").unwrap();
        assert!(arch::total_flops(&m224.layers()) > arch::total_flops(&m128.layers()));
    }
}
