//! Layer-level workload generators for the paper's model families.
//!
//! Each generator produces the per-layer [`WorkUnit`] sequence of a network
//! from its architectural parameters (channel widths, block counts, input
//! resolution). FLOP/byte counts use the standard analytic formulas; the
//! result is what the simulator executes and what the tracer reports as
//! FRAMEWORK-level spans. Weights here are FP32.

use crate::sysmodel::WorkUnit;

/// One framework-level layer: name + tensor shape + analytic work.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub index: usize,
    pub name: String,
    pub kind: String,
    /// Output shape (batch dim written as N).
    pub shape: Vec<usize>,
    pub work: WorkUnit,
}

/// Incrementally builds a network's layer list with TF-style layer names
/// (`conv2d_48/Conv2D`), tracking spatial dims and per-kind counters.
pub struct NetBuilder {
    layers: Vec<LayerSpec>,
    h: usize,
    w: usize,
    c: usize,
    conv_count: usize,
    dense_count: usize,
}

const F32: f64 = 4.0;

impl NetBuilder {
    pub fn new(resolution: usize, channels: usize) -> NetBuilder {
        NetBuilder { layers: Vec::new(), h: resolution, w: resolution, c: channels, conv_count: 0, dense_count: 0 }
    }

    pub fn hw(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    pub fn channels(&self) -> usize {
        self.c
    }

    fn push(&mut self, name: String, kind: &str, work: WorkUnit) {
        let shape = vec![self.c, self.h, self.w];
        self.layers.push(LayerSpec {
            index: self.layers.len(),
            name,
            kind: kind.to_string(),
            shape,
            work,
        });
    }

    /// Standard convolution `k×k`, `cout` filters, stride `s`.
    pub fn conv(&mut self, k: usize, cout: usize, s: usize) -> &mut Self {
        self.grouped_conv(k, cout, s, 1)
    }

    /// Grouped convolution (BVLC AlexNet's conv2/4/5 use groups = 2):
    /// each group sees `cin/groups` input channels, dividing FLOPs and
    /// weights by `groups`.
    pub fn grouped_conv(&mut self, k: usize, cout: usize, s: usize, groups: usize) -> &mut Self {
        let cin = self.c;
        self.h = (self.h + s - 1) / s;
        self.w = (self.w + s - 1) / s;
        self.c = cout;
        let out_elems = (self.h * self.w * cout) as f64;
        let flops = 2.0 * (k * k * cin / groups) as f64 * out_elems;
        let weight_bytes = (k * k * cin * cout / groups) as f64 * F32;
        let act_bytes = (out_elems + (self.h * s * self.w * s * cin) as f64) * F32;
        let name = if self.conv_count == 0 {
            "conv2d/Conv2D".to_string()
        } else {
            format!("conv2d_{}/Conv2D", self.conv_count)
        };
        self.conv_count += 1;
        self.push(name, "Conv2D", WorkUnit::new("Conv2D", flops, act_bytes, weight_bytes));
        self
    }

    /// Depthwise separable convolution (MobileNet): depthwise k×k then
    /// pointwise 1×1 to `cout`.
    pub fn depthwise_separable(&mut self, k: usize, cout: usize, s: usize) -> &mut Self {
        let cin = self.c;
        self.h = (self.h + s - 1) / s;
        self.w = (self.w + s - 1) / s;
        let dw_out = (self.h * self.w * cin) as f64;
        let dw_flops = 2.0 * (k * k) as f64 * dw_out;
        let dw_weights = (k * k * cin) as f64 * F32;
        let n = self.conv_count;
        self.conv_count += 1;
        self.push(
            format!("conv_dw_{n}/depthwise"),
            "DepthwiseConv2D",
            WorkUnit::new("DepthwiseConv2D", dw_flops, dw_out * 2.0 * F32, dw_weights),
        );
        self.batch_norm().relu();
        self.c = cin; // pointwise takes over channel change
        self.conv(1, cout, 1);
        self.batch_norm().relu();
        self
    }

    pub fn dense(&mut self, units: usize) -> &mut Self {
        let cin = self.c * self.h * self.w;
        let flops = 2.0 * (cin * units) as f64;
        let weight_bytes = (cin * units) as f64 * F32;
        let act_bytes = (cin + units) as f64 * F32;
        self.h = 1;
        self.w = 1;
        self.c = units;
        let name = if self.dense_count < 6 {
            format!("fc{}", self.dense_count + 6) // fc6, fc7, fc8 à la AlexNet/VGG
        } else {
            format!("dense_{}", self.dense_count)
        };
        self.dense_count += 1;
        self.push(name, "Dense", WorkUnit::new("Dense", flops, act_bytes, weight_bytes));
        self
    }

    pub fn pool(&mut self, k: usize, s: usize) -> &mut Self {
        let elems = (self.h * self.w * self.c) as f64;
        self.h = (self.h + s - 1) / s;
        self.w = (self.w + s - 1) / s;
        let flops = elems * (k * k) as f64 * 0.25;
        self.push(
            format!("pool_{}", self.layers.len()),
            "Pool",
            WorkUnit::new("Pool", flops, elems * 1.25 * F32, 0.0),
        );
        self
    }

    pub fn global_pool(&mut self) -> &mut Self {
        let elems = (self.h * self.w * self.c) as f64;
        self.h = 1;
        self.w = 1;
        self.push(
            "global_pool".to_string(),
            "Pool",
            WorkUnit::new("Pool", elems, elems * F32, 0.0),
        );
        self
    }

    pub fn batch_norm(&mut self) -> &mut Self {
        let elems = (self.h * self.w * self.c) as f64;
        self.push(
            format!("bn_{}", self.layers.len()),
            "BatchNorm",
            WorkUnit::new("BatchNorm", 4.0 * elems, 2.0 * elems * F32, self.c as f64 * 4.0 * F32),
        );
        self
    }

    pub fn relu(&mut self) -> &mut Self {
        let elems = (self.h * self.w * self.c) as f64;
        self.push(
            format!("relu_{}", self.layers.len()),
            "Relu",
            WorkUnit::new("Relu", elems, 2.0 * elems * F32, 0.0),
        );
        self
    }

    pub fn lrn(&mut self) -> &mut Self {
        let elems = (self.h * self.w * self.c) as f64;
        self.push(
            format!("lrn_{}", self.layers.len()),
            "LRN",
            WorkUnit::new("LRN", 8.0 * elems, 2.0 * elems * F32, 0.0),
        );
        self
    }

    pub fn add(&mut self) -> &mut Self {
        let elems = (self.h * self.w * self.c) as f64;
        self.push(
            format!("add_{}", self.layers.len()),
            "Add",
            WorkUnit::new("Add", elems, 3.0 * elems * F32, 0.0),
        );
        self
    }

    pub fn concat(&mut self, extra_channels: usize) -> &mut Self {
        self.c += extra_channels;
        let elems = (self.h * self.w * self.c) as f64;
        self.push(
            format!("concat_{}", self.layers.len()),
            "Concat",
            WorkUnit::new("Concat", 0.0, 2.0 * elems * F32, 0.0),
        );
        self
    }

    pub fn softmax(&mut self) -> &mut Self {
        let elems = self.c as f64;
        self.push(
            "prob".to_string(),
            "Softmax",
            WorkUnit::new("Softmax", 5.0 * elems, 2.0 * elems * F32, 0.0),
        );
        self
    }

    pub fn finish(self) -> Vec<LayerSpec> {
        self.layers
    }
}

/// ResNet v1/v2 with bottleneck blocks (50/101/152).
pub fn resnet(depth: usize, v2: bool, resolution: usize) -> Vec<LayerSpec> {
    let blocks: [usize; 4] = match depth {
        50 => [3, 4, 6, 3],
        101 => [3, 4, 23, 3],
        152 => [3, 8, 36, 3],
        _ => [3, 4, 6, 3],
    };
    let mut b = NetBuilder::new(resolution, 3);
    b.conv(7, 64, 2).batch_norm().relu().pool(3, 2);
    let mut width = 64usize;
    for (stage, &n) in blocks.iter().enumerate() {
        let out = width * 4;
        for block in 0..n {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            // v2 does BN-ReLU before conv; same analytic work either way,
            // but v2 carries one extra BN+ReLU per block.
            if v2 {
                b.batch_norm().relu();
            }
            if block == 0 {
                // Projection shortcut on the first block of each stage —
                // runs in parallel with the main path, so restore dims.
                let (h, w) = b.hw();
                let c_in = b.channels();
                b.conv(1, out, stride).batch_norm();
                b.c = c_in;
                b.h = h;
                b.w = w;
            }
            b.conv(1, width, stride).batch_norm().relu();
            b.conv(3, width, 1).batch_norm().relu();
            b.conv(1, out, 1).batch_norm();
            b.add().relu();
        }
        width *= 2;
    }
    b.global_pool().dense(1000).softmax();
    b.finish()
}

/// VGG 16/19.
pub fn vgg(depth: usize, resolution: usize) -> Vec<LayerSpec> {
    let per_stage: [usize; 5] = if depth >= 19 { [2, 2, 4, 4, 4] } else { [2, 2, 3, 3, 3] };
    let widths = [64, 128, 256, 512, 512];
    let mut b = NetBuilder::new(resolution, 3);
    for (stage, &n) in per_stage.iter().enumerate() {
        for _ in 0..n {
            b.conv(3, widths[stage], 1).relu();
        }
        b.pool(2, 2);
    }
    b.dense(4096).relu().dense(4096).relu().dense(1000).softmax();
    b.finish()
}

/// MobileNet v1 at width multiplier `alpha` and input `resolution`.
pub fn mobilenet_v1(alpha: f64, resolution: usize) -> Vec<LayerSpec> {
    let ch = |c: usize| ((c as f64 * alpha).round() as usize).max(8);
    let mut b = NetBuilder::new(resolution, 3);
    b.conv(3, ch(32), 2).batch_norm().relu();
    let plan: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (cout, s) in plan {
        b.depthwise_separable(3, ch(cout), s);
    }
    b.global_pool().dense(1000).softmax();
    b.finish()
}

/// Inception v1 (GoogLeNet) through v4 as width/depth-scaled variants.
pub fn inception(version: usize, resolution: usize) -> Vec<LayerSpec> {
    // Inception modules: parallel 1×1 / 3×3 / 5×5 / pool-proj branches; we
    // model the aggregate work of each module with the published branch
    // widths, then a concat.
    let mut b = NetBuilder::new(resolution, 3);
    b.conv(7, 64, 2).relu().pool(3, 2);
    b.conv(1, 64, 1).conv(3, 192, 1).relu().pool(3, 2);
    // (module count, base width) grows with version.
    let (modules, scale): (usize, f64) = match version {
        1 => (9, 1.0),
        2 => (10, 1.1),
        3 => (11, 1.35),
        4 => (14, 1.5),
        _ => (9, 1.0),
    };
    for m in 0..modules {
        // Branch widths loosely following GoogLeNet's inception(3a..5b).
        let base = ((64 + 16 * m) as f64 * scale) as usize;
        let c_in = b.channels();
        b.conv(1, base, 1).relu(); // 1×1 branch
        b.c = c_in;
        b.conv(1, base, 1).conv(3, base * 2, 1).relu(); // 3×3 branch
        b.c = c_in;
        b.conv(1, base / 2, 1).conv(5, base / 2, 1).relu(); // 5×5 branch
        b.c = base * 2 + base; // aggregate main branches
        b.concat(base / 2 + base / 4); // + pool-proj
        if m == modules / 3 || m == (2 * modules) / 3 {
            b.pool(3, 2);
        }
    }
    b.global_pool().dense(1000).softmax();
    b.finish()
}

/// Inception-ResNet v2: inception modules + residual adds.
pub fn inception_resnet_v2(resolution: usize) -> Vec<LayerSpec> {
    let mut layers = inception(4, resolution);
    // Residual adds after each module — approximate by interleaving Adds.
    let mut b = NetBuilder::new(8, 1536);
    for _ in 0..10 {
        b.add().relu();
    }
    let extra = b.finish();
    let base = layers.len();
    layers.extend(extra.into_iter().enumerate().map(|(i, mut l)| {
        l.index = base + i;
        l
    }));
    layers
}

/// DenseNet-121: dense blocks with concatenative growth (k = 32).
pub fn densenet121(resolution: usize) -> Vec<LayerSpec> {
    let mut b = NetBuilder::new(resolution, 3);
    b.conv(7, 64, 2).batch_norm().relu().pool(3, 2);
    let blocks = [6usize, 12, 24, 16];
    let growth = 32;
    for (i, &n) in blocks.iter().enumerate() {
        for _ in 0..n {
            let c_in = b.channels();
            b.conv(1, 4 * growth, 1).batch_norm().relu();
            b.conv(3, growth, 1).batch_norm().relu();
            b.c = c_in;
            b.concat(growth);
        }
        if i < 3 {
            // transition: 1×1 halve channels + avgpool
            let c = b.channels() / 2;
            b.conv(1, c, 1).batch_norm().pool(2, 2);
        }
    }
    b.global_pool().dense(1000).softmax();
    b.finish()
}

/// BVLC AlexNet (the Fig-8 cold-start subject): huge fc6 weights.
/// conv2/4/5 are grouped (groups = 2), as in the original Caffe model.
pub fn alexnet(resolution: usize) -> Vec<LayerSpec> {
    let mut b = NetBuilder::new(resolution, 3);
    b.conv(11, 96, 4).relu().lrn().pool(3, 2);
    b.grouped_conv(5, 256, 1, 2).relu().lrn().pool(3, 2);
    b.conv(3, 384, 1).relu();
    b.grouped_conv(3, 384, 1, 2).relu();
    b.grouped_conv(3, 256, 1, 2).relu().pool(3, 2);
    b.dense(4096).relu(); // fc6 — 9216×4096 weights ≈ 151 MB
    b.dense(4096).relu(); // fc7
    b.dense(1000); // fc8
    b.softmax();
    b.finish()
}

/// BVLC GoogLeNet — inception v1 shape.
pub fn googlenet(resolution: usize) -> Vec<LayerSpec> {
    inception(1, resolution)
}

/// Total weight bytes of a layer list.
pub fn total_weight_bytes(layers: &[LayerSpec]) -> f64 {
    layers.iter().map(|l| l.work.weight_bytes).sum()
}

/// Total FLOPs per item.
pub fn total_flops(layers: &[LayerSpec]) -> f64 {
    layers.iter().map(|l| l.work.flops_per_item).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_layer_count_near_paper() {
        // Paper Table 3 caption: "in total, there are 234 layers" for
        // TF-slim ResNet50. Our generator must land in that neighbourhood.
        // Paper's 234 counts every TF graph op (incl. pads/identities); our
        // generator counts compute layers — same order of magnitude.
        let layers = resnet(50, false, 224);
        assert!(
            (150..300).contains(&layers.len()),
            "resnet50 layer count {}",
            layers.len()
        );
        // 53 convolutions + 1 fc in ResNet50-v1 (stem + 16 blocks×3 + 4 shortcuts).
        let convs = layers.iter().filter(|l| l.kind == "Conv2D").count();
        assert!((49..=56).contains(&convs), "conv count {convs}");
    }

    #[test]
    fn resnet_depth_ordering() {
        let f50 = total_flops(&resnet(50, false, 224));
        let f101 = total_flops(&resnet(101, false, 224));
        let f152 = total_flops(&resnet(152, false, 224));
        assert!(f50 < f101 && f101 < f152);
        // ResNet50 ≈ 7.7 GFLOPs (2×3.86 MACs) at 224².
        assert!((4e9..12e9).contains(&f50), "resnet50 flops {f50:e}");
    }

    #[test]
    fn vgg16_weights_match_table2_scale() {
        let layers = vgg(16, 224);
        let mb = total_weight_bytes(&layers) / 1e6;
        // Table 2: VGG16 graph 528 MB (FP32 weights ≈ 528 MB).
        assert!((450.0..600.0).contains(&mb), "vgg16 weights {mb} MB");
        let l19 = vgg(19, 224);
        assert!(total_weight_bytes(&l19) > total_weight_bytes(&layers));
    }

    #[test]
    fn mobilenet_scales_with_alpha_and_resolution() {
        let f100_224 = total_flops(&mobilenet_v1(1.0, 224));
        let f50_224 = total_flops(&mobilenet_v1(0.5, 224));
        let f100_128 = total_flops(&mobilenet_v1(1.0, 128));
        assert!(f50_224 < f100_224);
        assert!(f100_128 < f100_224);
        // MobileNet v1 1.0 224 ≈ 1.1 GFLOPs.
        assert!((0.6e9..2.5e9).contains(&f100_224), "{f100_224:e}");
        let mb = total_weight_bytes(&mobilenet_v1(1.0, 224)) / 1e6;
        assert!((10.0..25.0).contains(&mb), "mobilenet weights {mb} MB");
    }

    #[test]
    fn alexnet_fc6_dominates_weights() {
        let layers = alexnet(224);
        let fc6 = layers.iter().find(|l| l.name == "fc6").expect("fc6 layer");
        assert_eq!(fc6.kind, "Dense");
        // fc6 ≈ 151–205 MB of FP32 (9216×4096 at valid-padding spatial dims;
        // our same-padding generator lands at 7×7×256×4096) — the Fig-8
        // bottleneck either way.
        let mb = fc6.work.weight_bytes / 1e6;
        assert!((100.0..260.0).contains(&mb), "fc6 {mb} MB");
        let total = total_weight_bytes(&layers);
        assert!(fc6.work.weight_bytes / total > 0.5, "fc6 must dominate");
    }

    #[test]
    fn inception_versions_grow() {
        let f1 = total_flops(&inception(1, 224));
        let f3 = total_flops(&inception(3, 299));
        let f4 = total_flops(&inception(4, 299));
        assert!(f1 < f3 && f3 < f4);
    }

    #[test]
    fn layer_indices_sequential() {
        for layers in [resnet(50, true, 224), vgg(16, 224), densenet121(224), alexnet(224)] {
            for (i, l) in layers.iter().enumerate() {
                assert_eq!(l.index, i);
            }
            assert!(layers.last().unwrap().kind == "Softmax");
        }
    }

    #[test]
    fn densenet_smaller_than_resnet_weights() {
        // Table 2: DenseNet121 31 MB vs ResNet50 98 MB.
        let d = total_weight_bytes(&densenet121(224));
        let r = total_weight_bytes(&resnet(50, false, 224));
        assert!(d < r);
    }
}
