//! A minimal dense f32 tensor — the unit of data between pipeline stages
//! and the predictor boundary.

use crate::util::json::Json;

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Deterministic pseudo-random tensor (synthetic model inputs).
    pub fn random(shape: Vec<usize>, seed: u64) -> Tensor {
        let n: usize = shape.iter().product();
        let mut rng = crate::util::rng::Xorshift::new(seed);
        Tensor { shape, data: (0..n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect() }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn byte_size(&self) -> usize {
        self.data.len() * 4
    }

    pub fn batch(&self) -> usize {
        self.shape.first().copied().unwrap_or(1)
    }

    /// Stack `n` copies along a new leading batch axis — how the batcher
    /// turns per-request tensors into a batched predictor call.
    pub fn stack(items: &[&Tensor]) -> Option<Tensor> {
        let first = items.first()?;
        if items.iter().any(|t| t.shape != first.shape) {
            return None;
        }
        // Leading dim of each item must be 1 (single-input tensors).
        let mut inner = first.shape.clone();
        if inner.first() == Some(&1) {
            inner.remove(0);
        }
        let mut shape = vec![items.len()];
        shape.extend(inner);
        let mut data = Vec::with_capacity(first.data.len() * items.len());
        for t in items {
            data.extend_from_slice(&t.data);
        }
        Some(Tensor::new(shape, data))
    }

    /// Split a batched tensor back into per-item tensors (leading axis).
    pub fn unstack(&self) -> Vec<Tensor> {
        let n = self.batch().max(1);
        let per = self.data.len() / n;
        let mut inner = vec![1];
        inner.extend_from_slice(&self.shape[1..]);
        (0..n)
            .map(|i| Tensor::new(inner.clone(), self.data[i * per..(i + 1) * per].to_vec()))
            .collect()
    }

    /// Binary codec: `u32 ndim | u32×ndim shape | f32×n data`, all LE.
    /// The wire protocol's fast path (§Perf: JSON float formatting was the
    /// RPC bottleneck for tensor payloads).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + self.shape.len() * 4 + self.data.len() * 4);
        out.extend_from_slice(&(self.shape.len() as u32).to_le_bytes());
        for d in &self.shape {
            out.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Decode the binary codec; `None` on malformed input.
    pub fn from_bytes(bytes: &[u8]) -> Option<Tensor> {
        if bytes.len() < 4 {
            return None;
        }
        let ndim = u32::from_le_bytes(bytes[0..4].try_into().ok()?) as usize;
        if ndim > 16 || bytes.len() < 4 + ndim * 4 {
            return None;
        }
        let mut shape = Vec::with_capacity(ndim);
        for i in 0..ndim {
            let o = 4 + i * 4;
            shape.push(u32::from_le_bytes(bytes[o..o + 4].try_into().ok()?) as usize);
        }
        let n: usize = shape.iter().product();
        let body = &bytes[4 + ndim * 4..];
        if body.len() != n * 4 {
            return None;
        }
        let data = body
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Some(Tensor { shape, data })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "shape",
                Json::arr(self.shape.iter().map(|s| Json::num(*s as f64)).collect()),
            ),
            (
                "data",
                Json::arr(self.data.iter().map(|v| Json::num(*v as f64)).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Tensor> {
        let shape: Vec<usize> = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as usize)
            .collect();
        let data: Vec<f32> = j
            .get("data")?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as f32)
            .collect();
        if shape.iter().product::<usize>() != data.len() {
            return None;
        }
        Some(Tensor { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_unstack_roundtrip() {
        let a = Tensor::random(vec![1, 2, 2, 3], 1);
        let b = Tensor::random(vec![1, 2, 2, 3], 2);
        let stacked = Tensor::stack(&[&a, &b]).unwrap();
        assert_eq!(stacked.shape, vec![2, 2, 2, 3]);
        let parts = stacked.unstack();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn stack_rejects_mismatched_shapes() {
        let a = Tensor::zeros(vec![1, 4]);
        let b = Tensor::zeros(vec![1, 5]);
        assert!(Tensor::stack(&[&a, &b]).is_none());
    }

    #[test]
    fn json_roundtrip() {
        let t = Tensor::random(vec![2, 3], 9);
        let back = Tensor::from_json(&t.to_json()).unwrap();
        assert_eq!(back.shape, t.shape);
        for (a, b) in t.data.iter().zip(&back.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn binary_roundtrip_exact() {
        let t = Tensor::random(vec![3, 5, 7], 17);
        let back = Tensor::from_bytes(&t.to_bytes()).unwrap();
        assert_eq!(back, t, "binary codec must be bit-exact");
    }

    #[test]
    fn binary_rejects_malformed() {
        assert!(Tensor::from_bytes(&[]).is_none());
        assert!(Tensor::from_bytes(&[1, 0, 0, 0]).is_none()); // shape missing
        let mut good = Tensor::zeros(vec![2, 2]).to_bytes();
        good.pop(); // truncated data
        assert!(Tensor::from_bytes(&good).is_none());
        let huge_ndim = 1000u32.to_le_bytes().to_vec();
        assert!(Tensor::from_bytes(&huge_ndim).is_none());
    }

    #[test]
    fn byte_size_and_batch() {
        let t = Tensor::zeros(vec![8, 224, 224, 3]);
        assert_eq!(t.batch(), 8);
        assert_eq!(t.byte_size(), 8 * 224 * 224 * 3 * 4);
    }
}
