//! Input pre-processing operators (§2.1, §4.1.1).
//!
//! Implements the built-in pipeline steps of the model manifest: image
//! decode → resize → normalize (+ crop/cast), operating on the same
//! `[N, H, W, C]` layout convention the paper describes. The "image codec"
//! here is a minimal PPM-style raw format ([`RawImage`]) — datasets in this
//! reproduction are synthetic, but the code path (decode bytes → u8 tensor
//! → resize → f32 normalize) is byte-for-byte the shape of a real
//! JPEG→tensor pipeline and carries the same data-movement cost profile.

pub mod tensor;

pub use tensor::Tensor;

use crate::manifest::PreprocessStep;

/// A raw interleaved-RGB image (the decoded form).
#[derive(Debug, Clone, PartialEq)]
pub struct RawImage {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    /// Row-major interleaved `H×W×C` bytes.
    pub pixels: Vec<u8>,
}

impl RawImage {
    pub fn new(height: usize, width: usize, channels: usize) -> RawImage {
        RawImage { height, width, channels, pixels: vec![0; height * width * channels] }
    }

    /// Deterministic synthetic image (gradient + seed hash) — the dataset
    /// substitute; content is irrelevant to benchmarking, size is not.
    pub fn synthetic(height: usize, width: usize, seed: u64) -> RawImage {
        let mut img = RawImage::new(height, width, 3);
        let mut rng = crate::util::rng::Xorshift::new(seed);
        let bias = rng.below(64) as usize;
        for y in 0..height {
            for x in 0..width {
                let o = (y * width + x) * 3;
                img.pixels[o] = ((x + bias) % 256) as u8;
                img.pixels[o + 1] = ((y + bias) % 256) as u8;
                img.pixels[o + 2] = ((x + y) % 256) as u8;
            }
        }
        img
    }

    /// Serialize to the wire/disk format: `P7 <h> <w> <c>\n` + raw bytes.
    pub fn encode(&self) -> Vec<u8> {
        let header = format!("P7 {} {} {}\n", self.height, self.width, self.channels);
        let mut out = header.into_bytes();
        out.extend_from_slice(&self.pixels);
        out
    }

    /// Decode the raw format; the manifest `decode` step's implementation.
    pub fn decode(bytes: &[u8]) -> Result<RawImage, PreprocessError> {
        let nl = bytes
            .iter()
            .position(|b| *b == b'\n')
            .ok_or_else(|| PreprocessError::Decode("missing header".into()))?;
        let header = std::str::from_utf8(&bytes[..nl])
            .map_err(|_| PreprocessError::Decode("bad header utf8".into()))?;
        let mut parts = header.split_whitespace();
        if parts.next() != Some("P7") {
            return Err(PreprocessError::Decode("bad magic".into()));
        }
        let mut dim = || -> Result<usize, PreprocessError> {
            parts
                .next()
                .and_then(|p| p.parse().ok())
                .ok_or_else(|| PreprocessError::Decode("bad dims".into()))
        };
        let (height, width, channels) = (dim()?, dim()?, dim()?);
        let body = &bytes[nl + 1..];
        if body.len() != height * width * channels {
            return Err(PreprocessError::Decode(format!(
                "size mismatch: {} vs {}",
                body.len(),
                height * width * channels
            )));
        }
        Ok(RawImage { height, width, channels, pixels: body.to_vec() })
    }
}

#[derive(Debug)]
pub enum PreprocessError {
    Decode(String),
    Unsupported(String),
}

impl std::fmt::Display for PreprocessError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PreprocessError::Decode(m) => write!(f, "decode: {m}"),
            PreprocessError::Unsupported(m) => write!(f, "unsupported step: {m}"),
        }
    }
}

impl std::error::Error for PreprocessError {}

/// Bilinear resize to `(out_h, out_w)`.
pub fn resize_bilinear(img: &RawImage, out_h: usize, out_w: usize) -> RawImage {
    let mut out = RawImage::new(out_h, out_w, img.channels);
    let sy = img.height as f32 / out_h as f32;
    let sx = img.width as f32 / out_w as f32;
    for y in 0..out_h {
        let fy = ((y as f32 + 0.5) * sy - 0.5).max(0.0);
        let y0 = fy.floor() as usize;
        let y1 = (y0 + 1).min(img.height - 1);
        let wy = fy - y0 as f32;
        for x in 0..out_w {
            let fx = ((x as f32 + 0.5) * sx - 0.5).max(0.0);
            let x0 = fx.floor() as usize;
            let x1 = (x0 + 1).min(img.width - 1);
            let wx = fx - x0 as f32;
            for c in 0..img.channels {
                let p = |yy: usize, xx: usize| {
                    img.pixels[(yy * img.width + xx) * img.channels + c] as f32
                };
                let top = p(y0, x0) * (1.0 - wx) + p(y0, x1) * wx;
                let bot = p(y1, x0) * (1.0 - wx) + p(y1, x1) * wx;
                out.pixels[(y * out_w + x) * img.channels + c] =
                    (top * (1.0 - wy) + bot * wy).round().clamp(0.0, 255.0) as u8;
            }
        }
    }
    out
}

/// Nearest-neighbour resize (the cheap path).
pub fn resize_nearest(img: &RawImage, out_h: usize, out_w: usize) -> RawImage {
    let mut out = RawImage::new(out_h, out_w, img.channels);
    for y in 0..out_h {
        let sy = y * img.height / out_h;
        for x in 0..out_w {
            let sx = x * img.width / out_w;
            for c in 0..img.channels {
                out.pixels[(y * out_w + x) * img.channels + c] =
                    img.pixels[(sy * img.width + sx) * img.channels + c];
            }
        }
    }
    out
}

/// Center-crop to `(h, w)` (pads with zeros if the source is smaller).
pub fn center_crop(img: &RawImage, h: usize, w: usize) -> RawImage {
    let mut out = RawImage::new(h, w, img.channels);
    let oy = img.height.saturating_sub(h) / 2;
    let ox = img.width.saturating_sub(w) / 2;
    for y in 0..h.min(img.height) {
        for x in 0..w.min(img.width) {
            for c in 0..img.channels {
                out.pixels[(y * w + x) * img.channels + c] =
                    img.pixels[((y + oy) * img.width + (x + ox)) * img.channels + c];
            }
        }
    }
    out
}

/// Normalize `u8 HWC` → `f32 NHWC` tensor: `(px - mean[c]) / rescale`.
pub fn normalize(img: &RawImage, mean: [f64; 3], rescale: f64) -> Tensor {
    let mut data = Vec::with_capacity(img.pixels.len());
    let inv = 1.0 / rescale as f32;
    let mean_f: [f32; 3] = [mean[0] as f32, mean[1] as f32, mean[2] as f32];
    for (i, px) in img.pixels.iter().enumerate() {
        let c = i % img.channels;
        data.push((*px as f32 - mean_f[c.min(2)]) * inv);
    }
    Tensor::new(vec![1, img.height, img.width, img.channels], data)
}

/// Execute a manifest's pre-processing pipeline on encoded input bytes,
/// producing the model-ready tensor. Steps run in manifest order (§4.1.1).
pub fn run_pipeline(steps: &[PreprocessStep], input: &[u8]) -> Result<Tensor, PreprocessError> {
    let mut img: Option<RawImage> = None;
    let mut tensor: Option<Tensor> = None;
    for step in steps {
        match step {
            PreprocessStep::Decode { .. } => {
                img = Some(RawImage::decode(input)?);
            }
            PreprocessStep::Resize { dimensions, method, .. } => {
                let cur = img.take().ok_or_else(|| {
                    PreprocessError::Unsupported("resize before decode".into())
                })?;
                let (h, w) = (dimensions[1], dimensions[2]);
                img = Some(match method.as_str() {
                    "nearest" => resize_nearest(&cur, h, w),
                    _ => resize_bilinear(&cur, h, w),
                });
            }
            PreprocessStep::CenterCrop { height, width } => {
                let cur = img.take().ok_or_else(|| {
                    PreprocessError::Unsupported("crop before decode".into())
                })?;
                img = Some(center_crop(&cur, *height, *width));
            }
            PreprocessStep::Normalize { mean, rescale } => {
                let cur = img.take().ok_or_else(|| {
                    PreprocessError::Unsupported("normalize before decode".into())
                })?;
                tensor = Some(normalize(&cur, *mean, *rescale));
            }
            PreprocessStep::CastTo { .. } => { /* f32 is native */ }
        }
    }
    match (tensor, img) {
        (Some(t), _) => Ok(t),
        // Pipelines without an explicit normalize still produce a tensor.
        (None, Some(img)) => Ok(normalize(&img, [0.0; 3], 1.0)),
        (None, None) => Err(PreprocessError::Unsupported("pipeline produced no tensor".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_image_roundtrip() {
        let img = RawImage::synthetic(33, 47, 7);
        let enc = img.encode();
        let dec = RawImage::decode(&enc).unwrap();
        assert_eq!(dec, img);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(RawImage::decode(b"JPEG....").is_err());
        assert!(RawImage::decode(b"P7 2 2 3\nxx").is_err()); // truncated
    }

    #[test]
    fn resize_shapes() {
        let img = RawImage::synthetic(100, 200, 1);
        let out = resize_bilinear(&img, 224, 224);
        assert_eq!((out.height, out.width), (224, 224));
        let out = resize_nearest(&img, 16, 16);
        assert_eq!(out.pixels.len(), 16 * 16 * 3);
    }

    #[test]
    fn resize_identity_preserves_content() {
        let img = RawImage::synthetic(64, 64, 3);
        let same = resize_bilinear(&img, 64, 64);
        // Identity resize must be (nearly) exact.
        let diffs = img
            .pixels
            .iter()
            .zip(&same.pixels)
            .filter(|(a, b)| (**a as i16 - **b as i16).abs() > 1)
            .count();
        assert_eq!(diffs, 0);
    }

    #[test]
    fn center_crop_extracts_middle() {
        let mut img = RawImage::new(4, 4, 1);
        for (i, p) in img.pixels.iter_mut().enumerate() {
            *p = i as u8;
        }
        let c = center_crop(&img, 2, 2);
        assert_eq!(c.pixels, vec![5, 6, 9, 10]);
    }

    #[test]
    fn normalize_applies_mean_and_rescale() {
        let mut img = RawImage::new(1, 1, 3);
        img.pixels = vec![200, 150, 100];
        let t = normalize(&img, [123.68, 116.78, 103.94], 2.0);
        assert_eq!(t.shape, vec![1, 1, 1, 3]);
        assert!((t.data[0] - (200.0 - 123.68) / 2.0).abs() < 1e-4);
        assert!((t.data[1] - (150.0 - 116.78) / 2.0).abs() < 1e-4);
        assert!((t.data[2] - (100.0 - 103.94) / 2.0).abs() < 1e-4);
    }

    #[test]
    fn listing1_pipeline_end_to_end() {
        let m = crate::manifest::ModelManifest::from_yaml(
            crate::manifest::model_listing1(),
        )
        .unwrap();
        let input = RawImage::synthetic(480, 640, 3).encode();
        let t = run_pipeline(&m.inputs[0].steps, &input).unwrap();
        assert_eq!(t.shape, vec![1, 224, 224, 3]);
        // Normalized values centred around zero-ish.
        let mean: f32 = t.data.iter().sum::<f32>() / t.data.len() as f32;
        assert!(mean.abs() < 128.0);
    }

    #[test]
    fn pipeline_order_enforced() {
        let steps = vec![PreprocessStep::Resize {
            dimensions: [3, 8, 8],
            method: "bilinear".into(),
            keep_aspect_ratio: false,
        }];
        assert!(run_pipeline(&steps, b"P7 1 1 3\nabc").is_err());
    }

    #[test]
    fn property_resize_bounds_preserved() {
        crate::util::rng::forall(41, 30, |rng| {
            let h = 8 + rng.below(64) as usize;
            let w = 8 + rng.below(64) as usize;
            let img = RawImage::synthetic(h, w, rng.next_u64());
            let out = resize_bilinear(&img, 16 + rng.below(48) as usize, 16 + rng.below(48) as usize);
            // Bilinear interpolation can't exceed source value range.
            let (smin, smax) = (
                *img.pixels.iter().min().unwrap(),
                *img.pixels.iter().max().unwrap(),
            );
            assert!(out.pixels.iter().all(|p| *p >= smin && *p <= smax));
        });
    }
}
