//! Binary frame codec for the hot RPC frames.
//!
//! JSON stays on the wire for control messages (`OpenBatch`, registry
//! registration, heartbeats) where readability and back-compat matter and
//! the payloads are tiny. The hot frames — stacked tensor attachments on
//! `PredictBatch` requests and the streamed result-row chunks coming back —
//! skip JSON envelope formatting/parsing entirely and ride a fixed binary
//! header instead:
//!
//! ```text
//! offset  size  field
//! 0       2     magic   0xB1FA (BE) — distinguishes binary from JSON ('{')
//!                        and from the legacy 0x01 attachment envelope
//! 2       1     version 0x01
//! 3       1     flags   bit0 RESPONSE, bit1 CHUNK, bit2 HAS_BLOB, bit3 OK
//! 4       8     id      request id (BE) — multiplexing key
//! 12      4     length  json section length (BE)
//! 16      len   json    method/params (request), chunk metadata (chunk),
//!                        result or error string (response)
//! 16+len  rest  payload opaque binary blob (tensor bytes) when HAS_BLOB
//! ```
//!
//! The whole frame still travels inside the transport's `u32 BE length`
//! prefix, so readers enforce [`super::MAX_FRAME`] before any allocation.
//! [`decode_msg`] accepts all three encodings (binary, legacy envelope,
//! pure JSON) so old peers and hand-rolled test sockets keep working.

use super::WireError;
use crate::util::json::Json;

/// First two bytes of every binary frame.
pub const MAGIC: [u8; 2] = [0xB1, 0xFA];
/// Binary frame format version.
pub const VERSION: u8 = 1;
/// Fixed header size: magic + version + flags + id + json length.
pub const HEADER_LEN: usize = 16;

pub const FLAG_RESPONSE: u8 = 1 << 0;
pub const FLAG_CHUNK: u8 = 1 << 1;
pub const FLAG_BLOB: u8 = 1 << 2;
pub const FLAG_OK: u8 = 1 << 3;

/// One decoded RPC frame, independent of its wire encoding.
#[derive(Debug, Clone)]
pub enum WireMsg {
    /// `method(params)` call, optionally with a binary attachment.
    Request { id: u64, method: String, params: Json, blob: Option<Vec<u8>> },
    /// Interim stream frame for an in-flight request.
    Chunk { id: u64, chunk: Json, blob: Option<Vec<u8>> },
    /// Final frame resolving a request. `body` is the result when `ok`,
    /// the error message (as a JSON string) otherwise.
    Response { id: u64, ok: bool, body: Json, blob: Option<Vec<u8>> },
}

impl WireMsg {
    pub fn id(&self) -> u64 {
        match self {
            WireMsg::Request { id, .. }
            | WireMsg::Chunk { id, .. }
            | WireMsg::Response { id, .. } => *id,
        }
    }
}

fn encode_binary(id: u64, flags: u8, json: &Json, blob: Option<&[u8]>) -> Vec<u8> {
    let j = json.to_string().into_bytes();
    let b = blob.unwrap_or(&[]);
    let mut out = Vec::with_capacity(HEADER_LEN + j.len() + b.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(flags | if blob.is_some() { FLAG_BLOB } else { 0 });
    out.extend_from_slice(&id.to_be_bytes());
    out.extend_from_slice(&(j.len() as u32).to_be_bytes());
    out.extend_from_slice(&j);
    out.extend_from_slice(b);
    out
}

/// Encode one message. Hot frames (anything carrying a blob, and every
/// stream chunk) use the binary header; blob-less unary requests and
/// responses — the control plane — stay pure JSON for back-compat and
/// debuggability.
pub fn encode_msg(msg: &WireMsg) -> Vec<u8> {
    match msg {
        WireMsg::Request { id, method, params, blob } => match blob {
            Some(b) => encode_binary(
                *id,
                0,
                &Json::obj(vec![
                    ("method", Json::str(method.as_str())),
                    ("params", params.clone()),
                ]),
                Some(b),
            ),
            None => Json::obj(vec![
                ("id", Json::num(*id as f64)),
                ("method", Json::str(method.as_str())),
                ("params", params.clone()),
            ])
            .to_string()
            .into_bytes(),
        },
        WireMsg::Chunk { id, chunk, blob } => {
            encode_binary(*id, FLAG_CHUNK, chunk, blob.as_deref())
        }
        WireMsg::Response { id, ok, body, blob } => match blob {
            Some(b) => encode_binary(
                *id,
                FLAG_RESPONSE | if *ok { FLAG_OK } else { 0 },
                body,
                Some(b),
            ),
            None => {
                let field = if *ok { "result" } else { "error" };
                Json::obj(vec![
                    ("id", Json::num(*id as f64)),
                    ("ok", Json::Bool(*ok)),
                    (field, body.clone()),
                ])
                .to_string()
                .into_bytes()
            }
        },
    }
}

fn decode_binary(frame: &[u8]) -> Result<WireMsg, WireError> {
    if frame.len() < HEADER_LEN {
        return Err(WireError::Protocol("truncated binary frame header".into()));
    }
    if frame[2] != VERSION {
        return Err(WireError::Protocol(format!(
            "unsupported binary frame version {}",
            frame[2]
        )));
    }
    let flags = frame[3];
    let id = u64::from_be_bytes(frame[4..12].try_into().unwrap());
    let jlen = u32::from_be_bytes(frame[12..16].try_into().unwrap()) as usize;
    // The declared json length is attacker-controlled: bound it by what
    // actually arrived (itself capped at MAX_FRAME by the reader) before
    // slicing — never trust it into an allocation or an index.
    if jlen > frame.len().saturating_sub(HEADER_LEN) {
        return Err(WireError::Protocol(format!(
            "binary frame json length {jlen} exceeds frame body {}",
            frame.len() - HEADER_LEN
        )));
    }
    let json = Json::parse(
        std::str::from_utf8(&frame[HEADER_LEN..HEADER_LEN + jlen])
            .map_err(|_| WireError::Protocol("binary frame json not utf-8".into()))?,
    )
    .map_err(|e| WireError::Protocol(e.to_string()))?;
    let blob = if flags & FLAG_BLOB != 0 {
        Some(frame[HEADER_LEN + jlen..].to_vec())
    } else if frame.len() > HEADER_LEN + jlen {
        return Err(WireError::Protocol(
            "binary frame carries trailing bytes without HAS_BLOB".into(),
        ));
    } else {
        None
    };
    if flags & FLAG_CHUNK != 0 {
        Ok(WireMsg::Chunk { id, chunk: json, blob })
    } else if flags & FLAG_RESPONSE != 0 {
        Ok(WireMsg::Response { id, ok: flags & FLAG_OK != 0, body: json, blob })
    } else {
        let method = json.str_or("method", "").to_string();
        let params = json.get("params").cloned().unwrap_or(Json::Null);
        Ok(WireMsg::Request { id, method, params, blob })
    }
}

/// Legacy attachment envelope (`0x01 | u32 BE json_len | json | blob`) and
/// pure-JSON bodies, kept so pre-binary peers and raw-socket tests decode.
fn decode_legacy(frame: &[u8]) -> Result<(Json, Option<Vec<u8>>), WireError> {
    if frame.first() == Some(&0x01) {
        if frame.len() < 5 {
            return Err(WireError::Protocol("truncated binary envelope".into()));
        }
        let jlen = u32::from_be_bytes(frame[1..5].try_into().unwrap()) as usize;
        if jlen > frame.len().saturating_sub(5) {
            return Err(WireError::Protocol("truncated binary envelope json".into()));
        }
        let json = Json::parse(
            std::str::from_utf8(&frame[5..5 + jlen])
                .map_err(|_| WireError::Protocol("envelope json not utf-8".into()))?,
        )
        .map_err(|e| WireError::Protocol(e.to_string()))?;
        Ok((json, Some(frame[5 + jlen..].to_vec())))
    } else {
        let json = Json::parse(
            std::str::from_utf8(frame)
                .map_err(|_| WireError::Protocol("request not utf-8".into()))?,
        )
        .map_err(|e| WireError::Protocol(e.to_string()))?;
        Ok((json, None))
    }
}

/// Decode one frame body in any of the three wire encodings into a
/// [`WireMsg`].
pub fn decode_msg(frame: &[u8]) -> Result<WireMsg, WireError> {
    if frame.len() >= 2 && frame[0..2] == MAGIC {
        return decode_binary(frame);
    }
    let (json, blob) = decode_legacy(frame)?;
    let id = json.f64_or("id", 0.0) as u64;
    if json.get("stream").and_then(|v| v.as_bool()) == Some(true) {
        let chunk = json.get("chunk").cloned().unwrap_or(Json::Null);
        return Ok(WireMsg::Chunk { id, chunk, blob });
    }
    if json.get("method").is_some() {
        return Ok(WireMsg::Request {
            id,
            method: json.str_or("method", "").to_string(),
            params: json.get("params").cloned().unwrap_or(Json::Null),
            blob,
        });
    }
    if let Some(ok) = json.get("ok").and_then(|v| v.as_bool()) {
        let body = if ok {
            json.get("result").cloned().unwrap_or(Json::Null)
        } else {
            Json::str(json.str_or("error", "unknown error"))
        };
        return Ok(WireMsg::Response { id, ok, body, blob });
    }
    Err(WireError::Protocol(
        "frame is neither a request, a stream chunk, nor a response".into(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_request_round_trip() {
        let msg = WireMsg::Request {
            id: 42,
            method: "PredictBatch".into(),
            params: Json::obj(vec![("session", Json::num(7.0))]),
            blob: Some(vec![1, 2, 3, 4]),
        };
        let bytes = encode_msg(&msg);
        assert_eq!(bytes[0..2], MAGIC, "blob-carrying requests are binary");
        match decode_msg(&bytes).unwrap() {
            WireMsg::Request { id, method, params, blob } => {
                assert_eq!(id, 42);
                assert_eq!(method, "PredictBatch");
                assert_eq!(params.f64_or("session", 0.0), 7.0);
                assert_eq!(blob, Some(vec![1, 2, 3, 4]));
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn control_request_stays_json() {
        let msg = WireMsg::Request {
            id: 3,
            method: "heartbeat".into(),
            params: Json::obj(vec![("id", Json::str("a1"))]),
            blob: None,
        };
        let bytes = encode_msg(&msg);
        assert_eq!(bytes[0], b'{', "control messages remain readable JSON");
        match decode_msg(&bytes).unwrap() {
            WireMsg::Request { id, method, .. } => {
                assert_eq!((id, method.as_str()), (3, "heartbeat"));
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn chunk_and_response_round_trip() {
        let chunk = WireMsg::Chunk {
            id: 9,
            chunk: Json::obj(vec![("offset", Json::num(16.0))]),
            blob: Some(vec![0xAB; 32]),
        };
        match decode_msg(&encode_msg(&chunk)).unwrap() {
            WireMsg::Chunk { id, chunk, blob } => {
                assert_eq!(id, 9);
                assert_eq!(chunk.f64_or("offset", 0.0), 16.0);
                assert_eq!(blob.unwrap().len(), 32);
            }
            other => panic!("wrong decode: {other:?}"),
        }
        let err = WireMsg::Response { id: 11, ok: false, body: Json::str("boom"), blob: None };
        match decode_msg(&encode_msg(&err)).unwrap() {
            WireMsg::Response { id, ok, body, .. } => {
                assert_eq!((id, ok), (11, false));
                assert_eq!(body.as_str(), Some("boom"));
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn declared_json_length_is_bounds_checked_before_use() {
        // Valid header but a json length far past the delivered bytes.
        let mut f = Vec::new();
        f.extend_from_slice(&MAGIC);
        f.push(VERSION);
        f.push(FLAG_CHUNK);
        f.extend_from_slice(&1u64.to_be_bytes());
        f.extend_from_slice(&0xFFFF_FF00u32.to_be_bytes());
        f.extend_from_slice(b"{}");
        let err = decode_msg(&f).unwrap_err();
        assert!(
            matches!(err, WireError::Protocol(ref m) if m.contains("json length")),
            "{err}"
        );
    }

    #[test]
    fn unknown_version_and_truncated_header_reject() {
        let mut f = Vec::new();
        f.extend_from_slice(&MAGIC);
        f.push(99);
        f.extend_from_slice(&[0; 13]);
        assert!(matches!(decode_msg(&f), Err(WireError::Protocol(_))));
        assert!(matches!(decode_msg(&MAGIC), Err(WireError::Protocol(_))));
    }

    #[test]
    fn legacy_json_shapes_still_decode() {
        let req = br#"{"id": 5, "method": "echo", "params": 1}"#;
        assert!(matches!(
            decode_msg(req).unwrap(),
            WireMsg::Request { id: 5, .. }
        ));
        let resp = br#"{"id": 5, "ok": true, "result": 1}"#;
        assert!(matches!(
            decode_msg(resp).unwrap(),
            WireMsg::Response { id: 5, ok: true, .. }
        ));
        let chunk = br#"{"id": 5, "stream": true, "chunk": {"i": 0}}"#;
        assert!(matches!(decode_msg(chunk).unwrap(), WireMsg::Chunk { id: 5, .. }));
        // Legacy 0x01 attachment envelope.
        let inner = br#"{"id": 6, "ok": true, "result": null}"#;
        let mut env = vec![0x01];
        env.extend_from_slice(&(inner.len() as u32).to_be_bytes());
        env.extend_from_slice(inner);
        env.extend_from_slice(&[7, 7]);
        match decode_msg(&env).unwrap() {
            WireMsg::Response { id: 6, ok: true, blob, .. } => {
                assert_eq!(blob, Some(vec![7, 7]));
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }
}
