//! Multiplexed, pooled RPC client.
//!
//! The pre-multiplex client held one stream mutex across the entire
//! request/response round-trip, so concurrent callers on a connection fully
//! serialized — and a panic while holding the lock poisoned it, turning
//! every later call into a `lock().unwrap()` process kill. This client
//! fixes both:
//!
//! - **per-frame writer lock**: a call holds the stream only long enough to
//!   write its request frame; the response is routed back by id, so any
//!   number of calls share one TCP connection concurrently;
//! - **reader task**: one thread per connection reads frames and routes
//!   them to per-id waiters (stream chunks and the final response alike);
//!   out-of-order completion is the normal case, not a protocol error;
//! - **typed poisoning**: a poisoned lock (a caller panicked mid-frame) is
//!   mapped to the broken-connection [`WireError`] path — later calls fail
//!   fast with a typed error instead of panicking;
//! - **connection pool**: [`RpcClient::connect_pooled`] opens N parallel
//!   connections and spreads calls round-robin; a broken member is skipped
//!   until all are broken.
//!
//! Deadlines are enforced by the response router (`recv_timeout` on the
//! waiter's queue), not `SO_RCVTIMEO` — there is no socket option left to
//! fail silently on the read path. A deadline still marks the connection
//! broken: a late reply to a timed-out call must never be mistaken for the
//! answer to a later one.

use super::frame::{decode_msg, encode_msg, WireMsg};
use super::{read_frame, write_frame, WireError};
use crate::util::json::Json;
use std::collections::HashMap;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// What the reader routes to a waiting call.
enum Route {
    Chunk(Json, Option<Vec<u8>>),
    Final(bool, Json, Option<Vec<u8>>),
    Failed(FailKind, String),
}

/// Reader-side failure classification ([`WireError`] is not `Clone`, and
/// one failure must fan out to every in-flight waiter).
#[derive(Clone, Copy)]
enum FailKind {
    Protocol,
    Io,
    Deadline,
}

impl FailKind {
    fn to_error(self, msg: &str) -> WireError {
        match self {
            FailKind::Protocol => WireError::Protocol(msg.to_string()),
            FailKind::Deadline => WireError::Deadline(msg.to_string()),
            FailKind::Io => {
                WireError::Io(std::io::Error::new(std::io::ErrorKind::Other, msg.to_string()))
            }
        }
    }
}

type PendingMap = Arc<Mutex<HashMap<u64, mpsc::Sender<Route>>>>;

/// One pooled connection: writer handle + reader thread + waiter table.
struct ClientConn {
    writer: Mutex<TcpStream>,
    /// Clone used to `shutdown()` the socket so the reader unblocks.
    shutdown_handle: TcpStream,
    pending: PendingMap,
    broken: Arc<AtomicBool>,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ClientConn {
    fn open(stream: TcpStream) -> Result<Arc<ClientConn>, WireError> {
        // Socket-option failures surface as typed errors, not silent
        // `.ok()`: a connection whose options can't be set is refused.
        stream.set_nodelay(true)?;
        let reader_stream = stream.try_clone()?;
        let shutdown_handle = stream.try_clone()?;
        let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
        let broken = Arc::new(AtomicBool::new(false));
        let (p, b) = (pending.clone(), broken.clone());
        let reader = std::thread::Builder::new()
            .name("rpc-client-reader".into())
            .spawn(move || reader_loop(reader_stream, p, b))?;
        Ok(Arc::new(ClientConn {
            writer: Mutex::new(stream),
            shutdown_handle,
            pending,
            broken,
            reader: Mutex::new(Some(reader)),
        }))
    }

    fn is_broken(&self) -> bool {
        self.broken.load(Ordering::Relaxed)
    }

    /// Mark broken and wake the reader so it fails remaining waiters.
    fn break_now(&self) {
        self.broken.store(true, Ordering::Relaxed);
        let _ = self.shutdown_handle.shutdown(std::net::Shutdown::Both);
    }

    fn register(&self, id: u64, tx: mpsc::Sender<Route>) -> Result<(), WireError> {
        match self.pending.lock() {
            Ok(mut map) => {
                // Checked under the map lock: `fail_all` flips `broken`
                // before draining the map, so either we see the flag here
                // or our waiter lands in the map before the drain and gets
                // failed with everyone else. Without this check, a call
                // racing the reader's death could register into an
                // already-drained map and wait forever.
                if self.is_broken() {
                    return Err(WireError::Protocol(
                        "connection marked broken by an earlier transport failure".into(),
                    ));
                }
                map.insert(id, tx);
                Ok(())
            }
            Err(_) => {
                // A waiter panicked while holding the table: routing state
                // is unknowable — broken connection, typed error.
                self.break_now();
                Err(WireError::Protocol(
                    "connection state poisoned by a panicked caller; connection marked broken"
                        .into(),
                ))
            }
        }
    }

    fn unregister(&self, id: u64) {
        if let Ok(mut map) = self.pending.lock() {
            map.remove(&id);
        }
    }
}

impl Drop for ClientConn {
    fn drop(&mut self) {
        self.break_now();
        if let Ok(mut slot) = self.reader.lock() {
            if let Some(handle) = slot.take() {
                let _ = handle.join();
            }
        }
    }
}

/// Deliver `kind/msg` to every in-flight waiter and mark the connection
/// broken. Request/response pairing can no longer be trusted after any
/// transport-level failure.
fn fail_all(pending: &PendingMap, broken: &AtomicBool, kind: FailKind, msg: &str) {
    broken.store(true, Ordering::Relaxed);
    let waiters: Vec<mpsc::Sender<Route>> = match pending.lock() {
        Ok(mut map) => map.drain().map(|(_, tx)| tx).collect(),
        Err(poisoned) => poisoned.into_inner().drain().map(|(_, tx)| tx).collect(),
    };
    for tx in waiters {
        let _ = tx.send(Route::Failed(kind, msg.to_string()));
    }
}

fn reader_loop(mut stream: TcpStream, pending: PendingMap, broken: Arc<AtomicBool>) {
    loop {
        let frame = match read_frame(&mut stream) {
            // EOF: clean from the peer's view, but every in-flight call
            // just lost its response.
            Ok(None) => {
                fail_all(&pending, &broken, FailKind::Protocol, "connection closed mid-call");
                return;
            }
            Err(WireError::Protocol(m)) => {
                // Includes an oversized declared frame length: rejected
                // from the header alone, before any allocation.
                fail_all(&pending, &broken, FailKind::Protocol, &m);
                return;
            }
            Err(WireError::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                fail_all(&pending, &broken, FailKind::Deadline, "no response within the read timeout");
                return;
            }
            Err(e) => {
                if broken.load(Ordering::Relaxed) {
                    // Our own shutdown (deadline or drop) raced the read.
                    fail_all(&pending, &broken, FailKind::Protocol, "connection closed mid-call");
                } else {
                    fail_all(&pending, &broken, FailKind::Io, &e.to_string());
                }
                return;
            }
            Ok(Some(f)) => f,
        };
        let msg = match decode_msg(&frame) {
            Ok(m) => m,
            Err(e) => {
                fail_all(&pending, &broken, FailKind::Protocol, &e.to_string());
                return;
            }
        };
        let id = msg.id();
        let (route, is_final) = match msg {
            WireMsg::Chunk { chunk, blob, .. } => (Route::Chunk(chunk, blob), false),
            WireMsg::Response { ok, body, blob, .. } => (Route::Final(ok, body, blob), true),
            WireMsg::Request { .. } => {
                fail_all(&pending, &broken, FailKind::Protocol, "peer sent a request frame");
                return;
            }
        };
        let tx = match pending.lock() {
            Ok(mut map) => {
                if is_final {
                    map.remove(&id)
                } else {
                    map.get(&id).cloned()
                }
            }
            Err(_) => {
                fail_all(
                    &pending,
                    &broken,
                    FailKind::Protocol,
                    "connection state poisoned by a panicked caller",
                );
                return;
            }
        };
        match tx {
            Some(tx) => {
                let _ = tx.send(route);
            }
            None => {
                // A frame for an id nobody is waiting on: either the peer
                // is confused (protocol violation) or a reply raced a
                // deadline we already declared. Pairing is untrustworthy
                // either way.
                fail_all(&pending, &broken, FailKind::Protocol, "response id mismatch");
                return;
            }
        }
    }
}

/// An issued call whose response has not been awaited yet. Obtained from
/// [`RpcClient::start_streamed`]; lets one thread keep thousands of calls
/// in flight on a pooled connection (the 10k-stream bench drives this).
pub struct PendingCall {
    conn: Arc<ClientConn>,
    id: u64,
    rx: mpsc::Receiver<Route>,
    timeout: Option<Duration>,
}

impl PendingCall {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Await the final response, handing interim stream chunks to
    /// `on_chunk` in arrival order. The deadline (if any) applies per
    /// frame, exactly as the old socket read timeout did.
    pub fn wait(
        self,
        mut on_chunk: impl FnMut(&Json, Option<&[u8]>),
    ) -> Result<(Json, Option<Vec<u8>>), WireError> {
        loop {
            let route = match self.timeout {
                Some(d) => match self.rx.recv_timeout(d) {
                    Ok(r) => r,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        // A late reply must never be matched to a later
                        // call: the whole connection is done.
                        self.conn.unregister(self.id);
                        self.conn.break_now();
                        return Err(WireError::Deadline(
                            "no response within the read timeout".into(),
                        ));
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        return Err(WireError::Protocol("connection closed mid-call".into()))
                    }
                },
                None => match self.rx.recv() {
                    Ok(r) => r,
                    Err(_) => {
                        return Err(WireError::Protocol("connection closed mid-call".into()))
                    }
                },
            };
            match route {
                Route::Chunk(chunk, blob) => on_chunk(&chunk, blob.as_deref()),
                Route::Final(true, body, blob) => return Ok((body, blob)),
                Route::Final(false, body, _) => {
                    return Err(WireError::Remote(
                        body.as_str().unwrap_or("unknown error").to_string(),
                    ))
                }
                Route::Failed(kind, msg) => return Err(kind.to_error(&msg)),
            }
        }
    }
}

/// Client side: a small pool of persistent connections issuing multiplexed
/// unary or streamed calls.
///
/// Any transport-level failure (I/O error, deadline, protocol violation —
/// anything except a clean [`WireError::Remote`]) marks the affected
/// connection *broken*: request/response pairing can no longer be trusted,
/// so in-flight calls on it fail with typed errors and later calls skip it.
/// Once every pooled connection is broken the client fails fast.
pub struct RpcClient {
    conns: Vec<Arc<ClientConn>>,
    next_id: AtomicU64,
    rr: AtomicUsize,
    /// Per-frame response deadline in nanoseconds; 0 = wait forever.
    timeout_ns: AtomicU64,
}

impl RpcClient {
    /// Connect a single-connection client (the default for control-plane
    /// callers: registry, heartbeats, one-shot dispatch).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<RpcClient, WireError> {
        let stream = TcpStream::connect(addr)?;
        Ok(RpcClient {
            conns: vec![ClientConn::open(stream)?],
            next_id: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            timeout_ns: AtomicU64::new(0),
        })
    }

    /// Connect a pool of `pool` parallel connections to the same endpoint;
    /// calls are spread round-robin and multiplexed per connection. Data-
    /// plane callers ([`crate::agent::RemoteBatchSession`]) use this so one
    /// slow batch never serializes the others behind it.
    pub fn connect_pooled(
        addr: impl ToSocketAddrs + Clone,
        pool: usize,
    ) -> Result<RpcClient, WireError> {
        let mut conns = Vec::with_capacity(pool.max(1));
        for _ in 0..pool.max(1) {
            let stream = TcpStream::connect(addr.clone())?;
            conns.push(ClientConn::open(stream)?);
        }
        Ok(RpcClient {
            conns,
            next_id: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            timeout_ns: AtomicU64::new(0),
        })
    }

    /// Number of pooled connections (broken or not).
    pub fn pool_size(&self) -> usize {
        self.conns.len()
    }

    /// Per-frame response deadline: a call whose next frame does not arrive
    /// within `timeout` fails with [`WireError::Deadline`] (and breaks its
    /// connection). `None` waits forever. Enforced by the response router —
    /// no socket option involved, so nothing can silently fail to arm.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) {
        let ns = timeout.map(|d| d.as_nanos().min(u64::MAX as u128) as u64).unwrap_or(0);
        self.timeout_ns.store(ns.max(u64::from(timeout.is_some())), Ordering::Relaxed);
    }

    fn timeout(&self) -> Option<Duration> {
        match self.timeout_ns.load(Ordering::Relaxed) {
            0 => None,
            ns => Some(Duration::from_nanos(ns)),
        }
    }

    /// Every pooled connection has suffered a transport failure.
    pub fn is_broken(&self) -> bool {
        self.conns.iter().all(|c| c.is_broken())
    }

    fn pick(&self) -> Result<Arc<ClientConn>, WireError> {
        let n = self.conns.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        for i in 0..n {
            let conn = &self.conns[(start + i) % n];
            if !conn.is_broken() {
                return Ok(conn.clone());
            }
        }
        Err(WireError::Protocol(
            "connection marked broken by an earlier transport failure".into(),
        ))
    }

    /// Unary call: send request, await the matching response.
    pub fn call(&self, method: &str, params: Json) -> Result<Json, WireError> {
        self.call_binary(method, params, None).map(|(j, _)| j)
    }

    /// Unary call with an opaque binary attachment (the tensor fast path).
    pub fn call_binary(
        &self,
        method: &str,
        params: Json,
        blob: Option<&[u8]>,
    ) -> Result<(Json, Option<Vec<u8>>), WireError> {
        self.call_streamed(method, params, blob, |_, _| {})
    }

    /// Streamed call: interim chunk frames are handed to
    /// `on_chunk(chunk_json, chunk_blob)` in arrival order; the final frame
    /// resolves the call like a unary response.
    pub fn call_streamed(
        &self,
        method: &str,
        params: Json,
        blob: Option<&[u8]>,
        on_chunk: impl FnMut(&Json, Option<&[u8]>),
    ) -> Result<(Json, Option<Vec<u8>>), WireError> {
        self.start_streamed(method, params, blob)?.wait(on_chunk)
    }

    /// Issue a call without waiting for its response: the request frame is
    /// written (writer lock held only for the frame) and a [`PendingCall`]
    /// handle is returned. This is the multiplexing primitive — N pending
    /// calls on one connection are N in-flight ids, not N blocked threads.
    pub fn start_streamed(
        &self,
        method: &str,
        params: Json,
        blob: Option<&[u8]>,
    ) -> Result<PendingCall, WireError> {
        let conn = self.pick()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        conn.register(id, tx)?;
        let frame = encode_msg(&WireMsg::Request {
            id,
            method: method.to_string(),
            params,
            blob: blob.map(|b| b.to_vec()),
        });
        let write_result = match conn.writer.lock() {
            Ok(mut stream) => write_frame(&mut *stream, &frame),
            Err(_) => Err(WireError::Protocol(
                "connection state poisoned by a panicked caller; connection marked broken".into(),
            )),
        };
        if let Err(e) = write_result {
            conn.unregister(id);
            conn.break_now();
            return Err(e);
        }
        Ok(PendingCall { conn, id, rx, timeout: self.timeout() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A poisoned writer lock maps to the typed broken-connection path —
    /// the regression for the old `lock().unwrap()` process kill.
    #[test]
    fn poisoned_writer_lock_is_a_typed_error_not_a_panic() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let client = RpcClient::connect(listener.local_addr().unwrap()).unwrap();
        let (_server_side, _) = listener.accept().unwrap();
        // Poison the writer mutex the way a real caller would: panic while
        // holding it.
        let conn = client.conns[0].clone();
        let _ = std::thread::spawn(move || {
            let _guard = conn.writer.lock().unwrap();
            panic!("deliberate panic while holding the stream lock");
        })
        .join();
        let err = client.call("echo", Json::Null).unwrap_err();
        assert!(
            matches!(err, WireError::Protocol(ref m) if m.contains("poisoned")),
            "{err}"
        );
        assert!(client.is_broken(), "poisoning breaks the connection");
        let err = client.call("echo", Json::Null).unwrap_err();
        assert!(matches!(err, WireError::Protocol(ref m) if m.contains("broken")), "{err}");
    }

    #[test]
    fn pool_skips_broken_members_until_all_are_gone() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // Echo server good enough for two connections.
        std::thread::spawn(move || {
            for conn in listener.incoming().flatten().take(2) {
                std::thread::spawn(move || {
                    let mut stream = conn;
                    while let Ok(Some(frame)) = read_frame(&mut stream) {
                        if let Ok(WireMsg::Request { id, params, .. }) = decode_msg(&frame) {
                            let resp = encode_msg(&WireMsg::Response {
                                id,
                                ok: true,
                                body: params,
                                blob: None,
                            });
                            if write_frame(&mut stream, &resp).is_err() {
                                return;
                            }
                        }
                    }
                });
            }
        });
        let client = RpcClient::connect_pooled(addr, 2).unwrap();
        assert_eq!(client.pool_size(), 2);
        client.conns[0].break_now();
        for i in 0..8 {
            let out = client.call("echo", Json::num(i as f64)).unwrap();
            assert_eq!(out.as_f64(), Some(i as f64), "healthy member serves");
        }
        assert!(!client.is_broken(), "one live member keeps the client usable");
        client.conns[1].break_now();
        assert!(client.is_broken());
        let err = client.call("echo", Json::Null).unwrap_err();
        assert!(matches!(err, WireError::Protocol(ref m) if m.contains("broken")), "{err}");
    }
}
