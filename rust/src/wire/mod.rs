//! The server↔agent RPC protocol (paper §4.3/§4.4 gRPC, Listing 4).
//!
//! gRPC is unavailable offline, so this is a length-prefixed framed RPC
//! over TCP with the same service shape as the paper's protobuf
//! definition:
//!
//! ```text
//! Open(OpenRequest)        -> PredictorHandle
//! Predict(handle, input)   -> FeaturesResponse   (unary or streamed)
//! Close(handle)            -> CloseResponse
//! ```
//!
//! Transport framing is `u32 BE length | body`. Bodies come in two
//! encodings: JSON (`{"id": n, "method": ..., "params": ...}` requests and
//! `{"id": n, "ok": bool, ...}` responses) for the control plane, and the
//! binary format of [`frame`] (magic, id, flags, length, payload) for the
//! hot frames — tensor attachments and streamed result-row chunks.
//!
//! The transport is **multiplexed and non-blocking** end to end:
//!
//! - [`RpcServer`] (see [`server`]) runs a hand-rolled readiness loop —
//!   non-blocking streams polled as a registered set — and executes
//!   requests on a worker pool, so many requests per connection are in
//!   flight at once and responses interleave by id;
//! - [`RpcClient`] (see [`client`]) holds a small connection pool; each
//!   connection has a reader task routing response frames to per-id
//!   waiters, and the stream write lock is held only per frame — never
//!   across a round-trip.

use crate::util::json::Json;
use std::io::Read;
use std::net::TcpStream;
use std::time::Duration;

pub mod client;
pub mod frame;
pub mod server;

pub use client::{PendingCall, RpcClient};
pub use frame::{decode_msg, encode_msg, WireMsg};
pub use server::{RpcServer, WireOpts};

/// Max accepted frame: 256 MB (a batch-256 224² f32 tensor is ~154 MB).
pub const MAX_FRAME: u32 = 256 << 20;

/// Once a frame's length prefix has arrived, the body must follow within
/// this window — a peer that stalls mid-frame (a partition, a half-dead
/// process) must not pin the connection forever. Idle connections
/// *between* frames are legal and never time out.
pub const MIDFRAME_TIMEOUT: Duration = Duration::from_secs(5);

#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    Protocol(String),
    Remote(String),
    /// A deadline elapsed: a client read timeout, or a peer stalling
    /// mid-frame. The connection is unusable afterwards.
    Deadline(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io: {e}"),
            WireError::Protocol(m) => write!(f, "protocol: {m}"),
            WireError::Remote(m) => write!(f, "remote error: {m}"),
            WireError::Deadline(m) => write!(f, "deadline: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Write one frame.
pub fn write_frame(stream: &mut impl std::io::Write, payload: &[u8]) -> Result<(), WireError> {
    if payload.len() as u64 > MAX_FRAME as u64 {
        return Err(WireError::Protocol(format!("frame too large: {}", payload.len())));
    }
    stream.write_all(&(payload.len() as u32).to_be_bytes())?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary. The
/// declared length is checked against [`MAX_FRAME`] *before* the body
/// allocation — on the client read path exactly as on the server's.
pub fn read_frame(stream: &mut impl Read) -> Result<Option<Vec<u8>>, WireError> {
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(WireError::Protocol(format!("frame too large: {len}")));
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf)?;
    Ok(Some(buf))
}

/// As [`read_frame`], for blocking TCP streams: the length-prefix read
/// blocks indefinitely (idle persistent connections are legal), but once a
/// prefix arrives the body must land within `body_timeout` or the read
/// fails with [`WireError::Deadline`]. The multiplexed server enforces the
/// same policy inside its event loop; this function remains for blocking
/// callers and as the reference semantics.
///
/// Socket-option failures are surfaced as [`WireError::Io`] — a timeout
/// that silently failed to arm would make the deadline vacuous.
pub fn read_frame_guarded(
    stream: &mut TcpStream,
    body_timeout: Duration,
) -> Result<Option<Vec<u8>>, WireError> {
    // First prefix byte: may block forever (an idle connection is at a
    // frame boundary). Everything after it — the rest of the prefix AND
    // the body — is mid-frame and runs under the timeout.
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf[..1]) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    stream.set_read_timeout(Some(body_timeout))?;
    let guarded = (|| -> Result<Vec<u8>, std::io::Error> {
        stream.read_exact(&mut len_buf[1..])?;
        let len = u32::from_be_bytes(len_buf);
        if len > MAX_FRAME {
            // Sentinel mapped back to Protocol below (keeps the closure's
            // error type uniform without reading `len` twice).
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame too large: {len}"),
            ));
        }
        let mut buf = vec![0u8; len as usize];
        stream.read_exact(&mut buf)?;
        Ok(buf)
    })();
    stream.set_read_timeout(None)?;
    match guarded {
        Ok(buf) => Ok(Some(buf)),
        Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
            Err(WireError::Protocol(e.to_string()))
        }
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) =>
        {
            Err(WireError::Deadline(format!(
                "frame stalled mid-read (no data within {body_timeout:?})"
            )))
        }
        Err(e) => Err(e.into()),
    }
}

/// A request handler: `method` + `params` → `Ok(result)` or `Err(message)`.
pub trait Service: Send + Sync + 'static {
    fn call(&self, method: &str, params: &Json) -> Result<Json, String>;

    /// Binary-attachment fast path (§Perf): JSON float formatting made
    /// tensor payloads the RPC bottleneck, so calls may carry one opaque
    /// binary blob alongside the JSON envelope. Default: ignore the blob
    /// and delegate to [`Service::call`].
    fn call_binary(
        &self,
        method: &str,
        params: &Json,
        _blob: Option<&[u8]>,
    ) -> Result<(Json, Option<Vec<u8>>), String> {
        self.call(method, params).map(|j| (j, None))
    }

    /// Streaming call: may push any number of interim frames through
    /// `emit(chunk_json, chunk_blob)` — delivered in order on the same
    /// connection, each carrying the request id — before returning the
    /// final (normal) response. The `PredictBatch` RPC streams large
    /// batched tensor results in bounded chunks this way. Default: unary.
    fn call_stream(
        &self,
        method: &str,
        params: &Json,
        blob: Option<&[u8]>,
        _emit: &mut dyn FnMut(Json, Option<Vec<u8>>) -> Result<(), WireError>,
    ) -> Result<(Json, Option<Vec<u8>>), String> {
        self.call_binary(method, params, blob)
    }
}

impl<F> Service for F
where
    F: Fn(&str, &Json) -> Result<Json, String> + Send + Sync + 'static,
{
    fn call(&self, method: &str, params: &Json) -> Result<Json, String> {
        self(method, params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::TcpListener;
    use std::sync::Arc;

    fn echo_service() -> Arc<dyn Service> {
        Arc::new(|method: &str, params: &Json| -> Result<Json, String> {
            match method {
                "echo" => Ok(params.clone()),
                "add" => {
                    let a = params.f64_or("a", 0.0);
                    let b = params.f64_or("b", 0.0);
                    Ok(Json::obj(vec![("sum", Json::num(a + b))]))
                }
                "fail" => Err("deliberate failure".to_string()),
                other => Err(format!("unknown method {other:?}")),
            }
        })
    }

    #[test]
    fn unary_roundtrip() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        let out = client
            .call("add", Json::obj(vec![("a", Json::num(2.0)), ("b", Json::num(40.0))]))
            .unwrap();
        assert_eq!(out.get("sum").unwrap().as_f64(), Some(42.0));
        server.stop();
    }

    #[test]
    fn remote_errors_propagate() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        let err = client.call("fail", Json::Null).unwrap_err();
        assert!(matches!(err, WireError::Remote(ref m) if m.contains("deliberate")));
        // Connection still usable after an error response.
        let ok = client.call("echo", Json::str("still alive")).unwrap();
        assert_eq!(ok.as_str(), Some("still alive"));
        server.stop();
    }

    #[test]
    fn multiple_sequential_calls_one_connection() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        for i in 0..50 {
            let out = client.call("echo", Json::num(i as f64)).unwrap();
            assert_eq!(out.as_f64(), Some(i as f64));
        }
        server.stop();
    }

    #[test]
    fn concurrent_clients() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let client = RpcClient::connect(addr).unwrap();
                    for i in 0..25 {
                        let v = (t * 100 + i) as f64;
                        let out = client.call("echo", Json::num(v)).unwrap();
                        assert_eq!(out.as_f64(), Some(v));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
    }

    #[test]
    fn large_frame_roundtrip() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        // ~1 MB payload.
        let big: Vec<Json> = (0..100_000).map(|i| Json::num(i as f64)).collect();
        let out = client.call("echo", Json::arr(big)).unwrap();
        assert_eq!(out.as_arr().unwrap().len(), 100_000);
        server.stop();
    }

    #[test]
    fn frame_encoding_rejects_oversize() {
        let mut sink = Vec::new();
        let huge = vec![0u8; (MAX_FRAME + 1) as usize];
        assert!(write_frame(&mut sink, &huge).is_err());
    }

    #[test]
    fn clean_eof_is_none() {
        let data: &[u8] = &[];
        let mut cursor = std::io::Cursor::new(data);
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn non_json_frame_is_typed_protocol_error() {
        assert!(matches!(decode_msg(b"not json at all"), Err(WireError::Protocol(_))));
        // Invalid UTF-8 is protocol too, not a panic.
        assert!(matches!(decode_msg(&[0xFF, 0xFE, 0x80]), Err(WireError::Protocol(_))));
        // Truncated legacy binary envelopes reject cleanly.
        assert!(matches!(decode_msg(&[0x01, 0, 0]), Err(WireError::Protocol(_))));
        assert!(matches!(
            decode_msg(&[0x01, 0, 0, 0, 99, b'{', b'}']),
            Err(WireError::Protocol(_))
        ));
    }

    /// A service that streams three chunks before its final response.
    struct StreamingEcho;

    impl Service for StreamingEcho {
        fn call(&self, _method: &str, params: &Json) -> Result<Json, String> {
            Ok(params.clone())
        }

        fn call_stream(
            &self,
            method: &str,
            params: &Json,
            blob: Option<&[u8]>,
            emit: &mut dyn FnMut(Json, Option<Vec<u8>>) -> Result<(), WireError>,
        ) -> Result<(Json, Option<Vec<u8>>), String> {
            if method != "stream" {
                return self.call_binary(method, params, blob);
            }
            for i in 0..3u8 {
                emit(
                    Json::obj(vec![("i", Json::num(i as f64))]),
                    Some(vec![i, i, i]),
                )
                .map_err(|e| e.to_string())?;
            }
            Ok((Json::obj(vec![("chunks", Json::num(3.0))]), None))
        }
    }

    #[test]
    fn streamed_call_delivers_chunks_in_order_then_final() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(StreamingEcho)).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        let mut chunks: Vec<(f64, Vec<u8>)> = Vec::new();
        let (result, _) = client
            .call_streamed("stream", Json::Null, None, |chunk, blob| {
                chunks.push((chunk.f64_or("i", -1.0), blob.unwrap_or_default().to_vec()));
            })
            .unwrap();
        assert_eq!(result.f64_or("chunks", 0.0), 3.0);
        assert_eq!(
            chunks,
            vec![(0.0, vec![0, 0, 0]), (1.0, vec![1, 1, 1]), (2.0, vec![2, 2, 2])]
        );
        // A unary call on the same connection still works, and silently
        // tolerates services that never stream.
        let out = client.call("echo", Json::str("plain")).unwrap();
        assert_eq!(out.as_str(), Some("plain"));
        server.stop();
    }

    #[test]
    fn midframe_stall_is_a_deadline_not_a_hang() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // Announce a 10-byte frame, deliver 3 bytes, stall (conn open).
            s.write_all(&10u32.to_be_bytes()).unwrap();
            s.write_all(&[1, 2, 3]).unwrap();
            std::thread::sleep(Duration::from_millis(600));
            s
        });
        let (mut conn, _) = listener.accept().unwrap();
        let t0 = std::time::Instant::now();
        let err = read_frame_guarded(&mut conn, Duration::from_millis(100)).unwrap_err();
        assert!(matches!(err, WireError::Deadline(_)), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5), "returned promptly");
        drop(writer.join().unwrap());
    }

    /// Mid-frame stalls are enforced by the event loop too: a connection
    /// that goes quiet halfway through a frame is closed within the
    /// window, and the server keeps serving everyone else.
    #[test]
    fn event_loop_closes_midframe_stalls() {
        let mut opts = WireOpts::default();
        opts.midframe_timeout = Duration::from_millis(100);
        let server =
            RpcServer::serve_with_opts("127.0.0.1:0", echo_service(), None, opts).unwrap();
        let mut staller = TcpStream::connect(server.addr()).unwrap();
        staller.write_all(&10u32.to_be_bytes()).unwrap();
        staller.write_all(&[1, 2, 3]).unwrap();
        let t0 = std::time::Instant::now();
        let mut buf = [0u8; 1];
        // The server closes us: read returns 0 well before MIDFRAME_TIMEOUT.
        loop {
            match staller.read(&mut buf) {
                Ok(0) => break,
                Ok(_) => {}
                Err(e) => panic!("expected clean close, got {e}"),
            }
        }
        assert!(t0.elapsed() < Duration::from_secs(3), "closed promptly");
        let client = RpcClient::connect(server.addr()).unwrap();
        assert_eq!(client.call("echo", Json::num(5.0)).unwrap().as_f64(), Some(5.0));
        server.stop();
    }

    #[test]
    fn client_read_timeout_is_typed_and_breaks_the_connection() {
        // A service that never answers within the client's deadline.
        let slow: Arc<dyn Service> = Arc::new(|_m: &str, p: &Json| -> Result<Json, String> {
            std::thread::sleep(Duration::from_millis(500));
            Ok(p.clone())
        });
        let server = RpcServer::serve("127.0.0.1:0", slow).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_millis(50)));
        let err = client.call("echo", Json::num(1.0)).unwrap_err();
        assert!(matches!(err, WireError::Deadline(_)), "{err}");
        assert!(client.is_broken());
        // Pairing can't be trusted any more: later calls fail fast.
        let err = client.call("echo", Json::num(2.0)).unwrap_err();
        assert!(matches!(err, WireError::Protocol(ref m) if m.contains("broken")), "{err}");
        server.stop();
    }
}
