//! The server↔agent RPC protocol (paper §4.3/§4.4 gRPC, Listing 4).
//!
//! gRPC is unavailable offline, so this is a length-prefixed framed RPC
//! over TCP carrying JSON payloads, with the same service shape as the
//! paper's protobuf definition:
//!
//! ```text
//! Open(OpenRequest)        -> PredictorHandle
//! Predict(handle, input)   -> FeaturesResponse   (unary or streamed)
//! Close(handle)            -> CloseResponse
//! ```
//!
//! Frame format: `u32 BE length | JSON bytes`. A request carries
//! `{"id": n, "method": "...", "params": {...}}`; a response
//! `{"id": n, "ok": bool, "result"| "error": ...}`. The server side
//! dispatches to a [`Service`] implementation; one thread per connection.

use crate::util::json::Json;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Max accepted frame: 256 MB (a batch-256 224² f32 tensor is ~154 MB).
const MAX_FRAME: u32 = 256 << 20;

/// Once a frame's length prefix has arrived, the body must follow within
/// this window — a peer that stalls mid-frame (a partition, a half-dead
/// process) must not pin the connection thread forever. Idle connections
/// *between* frames are legal and never time out.
const MIDFRAME_TIMEOUT: Duration = Duration::from_secs(5);

#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    Protocol(String),
    Remote(String),
    /// A deadline elapsed: a client read timeout, or a peer stalling
    /// mid-frame. The connection is unusable afterwards.
    Deadline(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io: {e}"),
            WireError::Protocol(m) => write!(f, "protocol: {m}"),
            WireError::Remote(m) => write!(f, "remote error: {m}"),
            WireError::Deadline(m) => write!(f, "deadline: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Write one frame.
pub fn write_frame(stream: &mut impl Write, payload: &[u8]) -> Result<(), WireError> {
    if payload.len() as u64 > MAX_FRAME as u64 {
        return Err(WireError::Protocol(format!("frame too large: {}", payload.len())));
    }
    stream.write_all(&(payload.len() as u32).to_be_bytes())?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame(stream: &mut impl Read) -> Result<Option<Vec<u8>>, WireError> {
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(WireError::Protocol(format!("frame too large: {len}")));
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf)?;
    Ok(Some(buf))
}

/// As [`read_frame`], for TCP streams: the length-prefix read blocks
/// indefinitely (idle persistent connections are legal), but once a prefix
/// arrives the body must land within `body_timeout` or the read fails with
/// [`WireError::Deadline`] — a peer stalling mid-frame can never hang a
/// connection thread. Used by the server side of every connection.
pub fn read_frame_guarded(
    stream: &mut TcpStream,
    body_timeout: Duration,
) -> Result<Option<Vec<u8>>, WireError> {
    // First prefix byte: may block forever (an idle connection is at a
    // frame boundary). Everything after it — the rest of the prefix AND
    // the body — is mid-frame and runs under the timeout.
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf[..1]) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    stream.set_read_timeout(Some(body_timeout)).ok();
    let guarded = (|| -> Result<Vec<u8>, std::io::Error> {
        stream.read_exact(&mut len_buf[1..])?;
        let len = u32::from_be_bytes(len_buf);
        if len > MAX_FRAME {
            // Sentinel mapped back to Protocol below (keeps the closure's
            // error type uniform without reading `len` twice).
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame too large: {len}"),
            ));
        }
        let mut buf = vec![0u8; len as usize];
        stream.read_exact(&mut buf)?;
        Ok(buf)
    })();
    stream.set_read_timeout(None).ok();
    match guarded {
        Ok(buf) => Ok(Some(buf)),
        Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
            Err(WireError::Protocol(e.to_string()))
        }
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) =>
        {
            Err(WireError::Deadline(format!(
                "frame stalled mid-read (no data within {body_timeout:?})"
            )))
        }
        Err(e) => Err(e.into()),
    }
}

/// A request handler: `method` + `params` → `Ok(result)` or `Err(message)`.
pub trait Service: Send + Sync + 'static {
    fn call(&self, method: &str, params: &Json) -> Result<Json, String>;

    /// Binary-attachment fast path (§Perf): JSON float formatting made
    /// tensor payloads the RPC bottleneck, so calls may carry one opaque
    /// binary blob alongside the JSON envelope. Default: ignore the blob
    /// and delegate to [`Service::call`].
    fn call_binary(
        &self,
        method: &str,
        params: &Json,
        _blob: Option<&[u8]>,
    ) -> Result<(Json, Option<Vec<u8>>), String> {
        self.call(method, params).map(|j| (j, None))
    }

    /// Streaming call: may push any number of interim frames through
    /// `emit(chunk_json, chunk_blob)` — delivered in order on the same
    /// connection, each wrapped in a `{"stream": true, "chunk": ...}`
    /// envelope carrying the request id — before returning the final
    /// (normal) response. The `PredictBatch` RPC streams large batched
    /// tensor results in bounded chunks this way. Default: unary.
    fn call_stream(
        &self,
        method: &str,
        params: &Json,
        blob: Option<&[u8]>,
        _emit: &mut dyn FnMut(Json, Option<Vec<u8>>) -> Result<(), WireError>,
    ) -> Result<(Json, Option<Vec<u8>>), String> {
        self.call_binary(method, params, blob)
    }
}

impl<F> Service for F
where
    F: Fn(&str, &Json) -> Result<Json, String> + Send + Sync + 'static,
{
    fn call(&self, method: &str, params: &Json) -> Result<Json, String> {
        self(method, params)
    }
}

/// A running RPC server (one accept thread + one thread per connection).
pub struct RpcServer {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl RpcServer {
    /// Bind and serve `service` on `addr` (use port 0 for ephemeral).
    pub fn serve(addr: &str, service: Arc<dyn Service>) -> Result<RpcServer, WireError> {
        RpcServer::serve_with_chaos(addr, service, None)
    }

    /// As [`RpcServer::serve`], with an optional [`crate::chaos::ChaosEngine`]
    /// consulted before every request is dispatched — the injection point
    /// for deterministic distributed-failure scenarios. A `Kill` verdict
    /// flips the server's shutdown flag (and fires the engine's kill hook),
    /// so every connection dies no later than its next request.
    pub fn serve_with_chaos(
        addr: &str,
        service: Arc<dyn Service>,
        chaos: Option<Arc<crate::chaos::ChaosEngine>>,
    ) -> Result<RpcServer, WireError> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = shutdown.clone();
        let accept_thread = std::thread::Builder::new()
            .name(format!("rpc-accept-{local}"))
            .spawn(move || {
                for conn in listener.incoming() {
                    if sd.load(Ordering::Relaxed) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let service = service.clone();
                            let sd = sd.clone();
                            let chaos = chaos.clone();
                            std::thread::spawn(move || {
                                let _ = handle_connection(stream, service, sd, chaos);
                            });
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn rpc accept thread");
        Ok(RpcServer { addr: local, shutdown, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. Existing connections
    /// finish their in-flight request.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Nudge the blocking accept with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Frame content: pure JSON (starts with `{`, back-compat) or a binary
/// envelope `0x01 | u32 BE json_len | json | blob`.
fn encode_envelope(json: &Json, blob: Option<&[u8]>) -> Vec<u8> {
    match blob {
        None => json.to_string().into_bytes(),
        Some(blob) => {
            let j = json.to_string().into_bytes();
            let mut out = Vec::with_capacity(5 + j.len() + blob.len());
            out.push(0x01);
            out.extend_from_slice(&(j.len() as u32).to_be_bytes());
            out.extend_from_slice(&j);
            out.extend_from_slice(blob);
            out
        }
    }
}

fn decode_envelope(frame: &[u8]) -> Result<(Json, Option<Vec<u8>>), WireError> {
    if frame.first() == Some(&0x01) {
        if frame.len() < 5 {
            return Err(WireError::Protocol("truncated binary envelope".into()));
        }
        let jlen = u32::from_be_bytes(frame[1..5].try_into().unwrap()) as usize;
        if frame.len() < 5 + jlen {
            return Err(WireError::Protocol("truncated binary envelope json".into()));
        }
        let json = Json::parse(
            std::str::from_utf8(&frame[5..5 + jlen])
                .map_err(|_| WireError::Protocol("envelope json not utf-8".into()))?,
        )
        .map_err(|e| WireError::Protocol(e.to_string()))?;
        Ok((json, Some(frame[5 + jlen..].to_vec())))
    } else {
        let json = Json::parse(
            std::str::from_utf8(frame)
                .map_err(|_| WireError::Protocol("request not utf-8".into()))?,
        )
        .map_err(|e| WireError::Protocol(e.to_string()))?;
        Ok((json, None))
    }
}

fn handle_connection(
    mut stream: TcpStream,
    service: Arc<dyn Service>,
    shutdown: Arc<AtomicBool>,
    chaos: Option<Arc<crate::chaos::ChaosEngine>>,
) -> Result<(), WireError> {
    stream.set_nodelay(true).ok();
    while !shutdown.load(Ordering::Relaxed) {
        let frame = match read_frame_guarded(&mut stream, MIDFRAME_TIMEOUT)? {
            Some(f) => f,
            None => return Ok(()), // clean disconnect
        };
        let (req, blob) = decode_envelope(&frame)?;
        let id = req.f64_or("id", 0.0);
        let method = req.str_or("method", "");
        let params = req.get("params").cloned().unwrap_or(Json::Null);
        if let Some(engine) = &chaos {
            match engine.decide(method) {
                crate::chaos::FaultAction::Pass => {}
                crate::chaos::FaultAction::Delay(ms) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                // Close with no reply: from the caller's view this is
                // exactly a crashed peer mid-call.
                crate::chaos::FaultAction::Drop => return Ok(()),
                crate::chaos::FaultAction::Kill => {
                    shutdown.store(true, Ordering::Relaxed);
                    return Ok(());
                }
            }
        }
        let result = {
            let mut emit = |chunk: Json, chunk_blob: Option<Vec<u8>>| -> Result<(), WireError> {
                let envelope = Json::obj(vec![
                    ("id", Json::num(id)),
                    ("stream", Json::Bool(true)),
                    ("chunk", chunk),
                ]);
                write_frame(&mut stream, &encode_envelope(&envelope, chunk_blob.as_deref()))
            };
            service.call_stream(method, &params, blob.as_deref(), &mut emit)
        };
        let (response, out_blob) = match result {
            Ok((result, out_blob)) => (
                Json::obj(vec![
                    ("id", Json::num(id)),
                    ("ok", Json::Bool(true)),
                    ("result", result),
                ]),
                out_blob,
            ),
            Err(msg) => (
                Json::obj(vec![
                    ("id", Json::num(id)),
                    ("ok", Json::Bool(false)),
                    ("error", Json::str(msg)),
                ]),
                None,
            ),
        };
        write_frame(&mut stream, &encode_envelope(&response, out_blob.as_deref()))?;
    }
    Ok(())
}

/// Client side: a persistent connection issuing unary or streamed calls.
///
/// Any transport-level failure (I/O error, deadline, protocol violation —
/// anything except a clean [`WireError::Remote`]) marks the connection
/// *broken*: request/response pairing can no longer be trusted (a late
/// reply to a timed-out call would be mis-matched to the next request), so
/// every later call fails fast with a typed error instead.
pub struct RpcClient {
    stream: std::sync::Mutex<TcpStream>,
    next_id: AtomicU64,
    broken: AtomicBool,
}

impl RpcClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<RpcClient, WireError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(RpcClient {
            stream: std::sync::Mutex::new(stream),
            next_id: AtomicU64::new(1),
            broken: AtomicBool::new(false),
        })
    }

    /// Per-call deadline: reads past it fail with [`WireError::Deadline`]
    /// (and break the connection). `None` waits forever.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) {
        let stream = self.stream.lock().unwrap();
        stream.set_read_timeout(timeout).ok();
    }

    /// A transport failure poisoned this connection.
    pub fn is_broken(&self) -> bool {
        self.broken.load(Ordering::Relaxed)
    }

    /// Unary call: send request, await the matching response.
    pub fn call(&self, method: &str, params: Json) -> Result<Json, WireError> {
        self.call_binary(method, params, None).map(|(j, _)| j)
    }

    /// Unary call with an opaque binary attachment (the tensor fast path).
    pub fn call_binary(
        &self,
        method: &str,
        params: Json,
        blob: Option<&[u8]>,
    ) -> Result<(Json, Option<Vec<u8>>), WireError> {
        self.call_streamed(method, params, blob, |_, _| {})
    }

    /// Streamed call: interim `{"stream": true}` frames are handed to
    /// `on_chunk(chunk_json, chunk_blob)` in arrival order; the final frame
    /// resolves the call like a unary response.
    pub fn call_streamed(
        &self,
        method: &str,
        params: Json,
        blob: Option<&[u8]>,
        mut on_chunk: impl FnMut(&Json, Option<&[u8]>),
    ) -> Result<(Json, Option<Vec<u8>>), WireError> {
        if self.is_broken() {
            return Err(WireError::Protocol(
                "connection marked broken by an earlier transport failure".into(),
            ));
        }
        let result = self.call_streamed_inner(method, params, blob, &mut on_chunk);
        if !matches!(result, Ok(_) | Err(WireError::Remote(_))) {
            self.broken.store(true, Ordering::Relaxed);
        }
        result
    }

    fn call_streamed_inner(
        &self,
        method: &str,
        params: Json,
        blob: Option<&[u8]>,
        on_chunk: &mut dyn FnMut(&Json, Option<&[u8]>),
    ) -> Result<(Json, Option<Vec<u8>>), WireError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("method", Json::str(method)),
            ("params", params),
        ]);
        let mut stream = self.stream.lock().unwrap();
        write_frame(&mut *stream, &encode_envelope(&req, blob))?;
        loop {
            let frame = read_frame(&mut *stream)
                .map_err(map_client_timeout)?
                .ok_or_else(|| WireError::Protocol("connection closed mid-call".into()))?;
            let (resp, out_blob) = decode_envelope(&frame)?;
            if resp.f64_or("id", -1.0) != id as f64 {
                return Err(WireError::Protocol("response id mismatch".into()));
            }
            if resp.get("stream").and_then(|v| v.as_bool()) == Some(true) {
                on_chunk(resp.get("chunk").unwrap_or(&Json::Null), out_blob.as_deref());
                continue;
            }
            drop(stream);
            return if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
                Ok((resp.get("result").cloned().unwrap_or(Json::Null), out_blob))
            } else {
                Err(WireError::Remote(resp.str_or("error", "unknown error").to_string()))
            };
        }
    }
}

/// A read timeout on the client socket surfaces as an I/O error; retype it
/// as the deadline it is.
fn map_client_timeout(e: WireError) -> WireError {
    match e {
        WireError::Io(io)
            if matches!(
                io.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) =>
        {
            WireError::Deadline("no response within the read timeout".into())
        }
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_service() -> Arc<dyn Service> {
        Arc::new(|method: &str, params: &Json| -> Result<Json, String> {
            match method {
                "echo" => Ok(params.clone()),
                "add" => {
                    let a = params.f64_or("a", 0.0);
                    let b = params.f64_or("b", 0.0);
                    Ok(Json::obj(vec![("sum", Json::num(a + b))]))
                }
                "fail" => Err("deliberate failure".to_string()),
                other => Err(format!("unknown method {other:?}")),
            }
        })
    }

    #[test]
    fn unary_roundtrip() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        let out = client
            .call("add", Json::obj(vec![("a", Json::num(2.0)), ("b", Json::num(40.0))]))
            .unwrap();
        assert_eq!(out.get("sum").unwrap().as_f64(), Some(42.0));
        server.stop();
    }

    #[test]
    fn remote_errors_propagate() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        let err = client.call("fail", Json::Null).unwrap_err();
        assert!(matches!(err, WireError::Remote(ref m) if m.contains("deliberate")));
        // Connection still usable after an error response.
        let ok = client.call("echo", Json::str("still alive")).unwrap();
        assert_eq!(ok.as_str(), Some("still alive"));
        server.stop();
    }

    #[test]
    fn multiple_sequential_calls_one_connection() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        for i in 0..50 {
            let out = client.call("echo", Json::num(i as f64)).unwrap();
            assert_eq!(out.as_f64(), Some(i as f64));
        }
        server.stop();
    }

    #[test]
    fn concurrent_clients() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let client = RpcClient::connect(addr).unwrap();
                    for i in 0..25 {
                        let v = (t * 100 + i) as f64;
                        let out = client.call("echo", Json::num(v)).unwrap();
                        assert_eq!(out.as_f64(), Some(v));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
    }

    #[test]
    fn large_frame_roundtrip() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        // ~1 MB payload.
        let big: Vec<Json> = (0..100_000).map(|i| Json::num(i as f64)).collect();
        let out = client.call("echo", Json::arr(big)).unwrap();
        assert_eq!(out.as_arr().unwrap().len(), 100_000);
        server.stop();
    }

    #[test]
    fn frame_encoding_rejects_oversize() {
        let mut sink = Vec::new();
        let huge = vec![0u8; (MAX_FRAME + 1) as usize];
        assert!(write_frame(&mut sink, &huge).is_err());
    }

    #[test]
    fn clean_eof_is_none() {
        let data: &[u8] = &[];
        let mut cursor = std::io::Cursor::new(data);
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn non_json_frame_is_typed_protocol_error() {
        assert!(matches!(
            decode_envelope(b"not json at all"),
            Err(WireError::Protocol(_))
        ));
        // Invalid UTF-8 is protocol too, not a panic.
        assert!(matches!(decode_envelope(&[0xFF, 0xFE, 0x80]), Err(WireError::Protocol(_))));
        // Truncated binary envelopes reject cleanly.
        assert!(matches!(decode_envelope(&[0x01, 0, 0]), Err(WireError::Protocol(_))));
        assert!(matches!(
            decode_envelope(&[0x01, 0, 0, 0, 99, b'{', b'}']),
            Err(WireError::Protocol(_))
        ));
    }

    /// A service that streams three chunks before its final response.
    struct StreamingEcho;

    impl Service for StreamingEcho {
        fn call(&self, _method: &str, params: &Json) -> Result<Json, String> {
            Ok(params.clone())
        }

        fn call_stream(
            &self,
            method: &str,
            params: &Json,
            blob: Option<&[u8]>,
            emit: &mut dyn FnMut(Json, Option<Vec<u8>>) -> Result<(), WireError>,
        ) -> Result<(Json, Option<Vec<u8>>), String> {
            if method != "stream" {
                return self.call_binary(method, params, blob);
            }
            for i in 0..3u8 {
                emit(
                    Json::obj(vec![("i", Json::num(i as f64))]),
                    Some(vec![i, i, i]),
                )
                .map_err(|e| e.to_string())?;
            }
            Ok((Json::obj(vec![("chunks", Json::num(3.0))]), None))
        }
    }

    #[test]
    fn streamed_call_delivers_chunks_in_order_then_final() {
        let server = RpcServer::serve("127.0.0.1:0", Arc::new(StreamingEcho)).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        let mut chunks: Vec<(f64, Vec<u8>)> = Vec::new();
        let (result, _) = client
            .call_streamed("stream", Json::Null, None, |chunk, blob| {
                chunks.push((chunk.f64_or("i", -1.0), blob.unwrap_or_default().to_vec()));
            })
            .unwrap();
        assert_eq!(result.f64_or("chunks", 0.0), 3.0);
        assert_eq!(
            chunks,
            vec![(0.0, vec![0, 0, 0]), (1.0, vec![1, 1, 1]), (2.0, vec![2, 2, 2])]
        );
        // A unary call on the same connection still works, and silently
        // tolerates services that never stream.
        let out = client.call("echo", Json::str("plain")).unwrap();
        assert_eq!(out.as_str(), Some("plain"));
        server.stop();
    }

    #[test]
    fn midframe_stall_is_a_deadline_not_a_hang() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // Announce a 10-byte frame, deliver 3 bytes, stall (conn open).
            s.write_all(&10u32.to_be_bytes()).unwrap();
            s.write_all(&[1, 2, 3]).unwrap();
            std::thread::sleep(Duration::from_millis(600));
            s
        });
        let (mut conn, _) = listener.accept().unwrap();
        let t0 = std::time::Instant::now();
        let err = read_frame_guarded(&mut conn, Duration::from_millis(100)).unwrap_err();
        assert!(matches!(err, WireError::Deadline(_)), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5), "returned promptly");
        drop(writer.join().unwrap());
    }

    #[test]
    fn client_read_timeout_is_typed_and_breaks_the_connection() {
        // A service that never answers within the client's deadline.
        let slow: Arc<dyn Service> = Arc::new(|_m: &str, p: &Json| -> Result<Json, String> {
            std::thread::sleep(Duration::from_millis(500));
            Ok(p.clone())
        });
        let server = RpcServer::serve("127.0.0.1:0", slow).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        client.set_read_timeout(Some(Duration::from_millis(50)));
        let err = client.call("echo", Json::num(1.0)).unwrap_err();
        assert!(matches!(err, WireError::Deadline(_)), "{err}");
        assert!(client.is_broken());
        // Pairing can't be trusted any more: later calls fail fast.
        let err = client.call("echo", Json::num(2.0)).unwrap_err();
        assert!(matches!(err, WireError::Protocol(ref m) if m.contains("broken")), "{err}");
        server.stop();
    }
}
