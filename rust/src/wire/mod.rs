//! The server↔agent RPC protocol (paper §4.3/§4.4 gRPC, Listing 4).
//!
//! gRPC is unavailable offline, so this is a length-prefixed framed RPC
//! over TCP carrying JSON payloads, with the same service shape as the
//! paper's protobuf definition:
//!
//! ```text
//! Open(OpenRequest)        -> PredictorHandle
//! Predict(handle, input)   -> FeaturesResponse   (unary or streamed)
//! Close(handle)            -> CloseResponse
//! ```
//!
//! Frame format: `u32 BE length | JSON bytes`. A request carries
//! `{"id": n, "method": "...", "params": {...}}`; a response
//! `{"id": n, "ok": bool, "result"| "error": ...}`. The server side
//! dispatches to a [`Service`] implementation; one thread per connection.

use crate::util::json::Json;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Max accepted frame: 256 MB (a batch-256 224² f32 tensor is ~154 MB).
const MAX_FRAME: u32 = 256 << 20;

#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    Protocol(String),
    Remote(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io: {e}"),
            WireError::Protocol(m) => write!(f, "protocol: {m}"),
            WireError::Remote(m) => write!(f, "remote error: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Write one frame.
pub fn write_frame(stream: &mut impl Write, payload: &[u8]) -> Result<(), WireError> {
    if payload.len() as u64 > MAX_FRAME as u64 {
        return Err(WireError::Protocol(format!("frame too large: {}", payload.len())));
    }
    stream.write_all(&(payload.len() as u32).to_be_bytes())?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame(stream: &mut impl Read) -> Result<Option<Vec<u8>>, WireError> {
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(WireError::Protocol(format!("frame too large: {len}")));
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf)?;
    Ok(Some(buf))
}

/// A request handler: `method` + `params` → `Ok(result)` or `Err(message)`.
pub trait Service: Send + Sync + 'static {
    fn call(&self, method: &str, params: &Json) -> Result<Json, String>;

    /// Binary-attachment fast path (§Perf): JSON float formatting made
    /// tensor payloads the RPC bottleneck, so calls may carry one opaque
    /// binary blob alongside the JSON envelope. Default: ignore the blob
    /// and delegate to [`Service::call`].
    fn call_binary(
        &self,
        method: &str,
        params: &Json,
        _blob: Option<&[u8]>,
    ) -> Result<(Json, Option<Vec<u8>>), String> {
        self.call(method, params).map(|j| (j, None))
    }
}

impl<F> Service for F
where
    F: Fn(&str, &Json) -> Result<Json, String> + Send + Sync + 'static,
{
    fn call(&self, method: &str, params: &Json) -> Result<Json, String> {
        self(method, params)
    }
}

/// A running RPC server (one accept thread + one thread per connection).
pub struct RpcServer {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl RpcServer {
    /// Bind and serve `service` on `addr` (use port 0 for ephemeral).
    pub fn serve(addr: &str, service: Arc<dyn Service>) -> Result<RpcServer, WireError> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = shutdown.clone();
        let accept_thread = std::thread::Builder::new()
            .name(format!("rpc-accept-{local}"))
            .spawn(move || {
                for conn in listener.incoming() {
                    if sd.load(Ordering::Relaxed) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let service = service.clone();
                            let sd = sd.clone();
                            std::thread::spawn(move || {
                                let _ = handle_connection(stream, service, sd);
                            });
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn rpc accept thread");
        Ok(RpcServer { addr: local, shutdown, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. Existing connections
    /// finish their in-flight request.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Nudge the blocking accept with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Frame content: pure JSON (starts with `{`, back-compat) or a binary
/// envelope `0x01 | u32 BE json_len | json | blob`.
fn encode_envelope(json: &Json, blob: Option<&[u8]>) -> Vec<u8> {
    match blob {
        None => json.to_string().into_bytes(),
        Some(blob) => {
            let j = json.to_string().into_bytes();
            let mut out = Vec::with_capacity(5 + j.len() + blob.len());
            out.push(0x01);
            out.extend_from_slice(&(j.len() as u32).to_be_bytes());
            out.extend_from_slice(&j);
            out.extend_from_slice(blob);
            out
        }
    }
}

fn decode_envelope(frame: &[u8]) -> Result<(Json, Option<Vec<u8>>), WireError> {
    if frame.first() == Some(&0x01) {
        if frame.len() < 5 {
            return Err(WireError::Protocol("truncated binary envelope".into()));
        }
        let jlen = u32::from_be_bytes(frame[1..5].try_into().unwrap()) as usize;
        if frame.len() < 5 + jlen {
            return Err(WireError::Protocol("truncated binary envelope json".into()));
        }
        let json = Json::parse(
            std::str::from_utf8(&frame[5..5 + jlen])
                .map_err(|_| WireError::Protocol("envelope json not utf-8".into()))?,
        )
        .map_err(|e| WireError::Protocol(e.to_string()))?;
        Ok((json, Some(frame[5 + jlen..].to_vec())))
    } else {
        let json = Json::parse(
            std::str::from_utf8(frame)
                .map_err(|_| WireError::Protocol("request not utf-8".into()))?,
        )
        .map_err(|e| WireError::Protocol(e.to_string()))?;
        Ok((json, None))
    }
}

fn handle_connection(
    mut stream: TcpStream,
    service: Arc<dyn Service>,
    shutdown: Arc<AtomicBool>,
) -> Result<(), WireError> {
    stream.set_nodelay(true).ok();
    while !shutdown.load(Ordering::Relaxed) {
        let frame = match read_frame(&mut stream)? {
            Some(f) => f,
            None => return Ok(()), // clean disconnect
        };
        let (req, blob) = decode_envelope(&frame)?;
        let id = req.f64_or("id", 0.0);
        let method = req.str_or("method", "");
        let params = req.get("params").cloned().unwrap_or(Json::Null);
        let (response, out_blob) = match service.call_binary(method, &params, blob.as_deref()) {
            Ok((result, out_blob)) => (
                Json::obj(vec![
                    ("id", Json::num(id)),
                    ("ok", Json::Bool(true)),
                    ("result", result),
                ]),
                out_blob,
            ),
            Err(msg) => (
                Json::obj(vec![
                    ("id", Json::num(id)),
                    ("ok", Json::Bool(false)),
                    ("error", Json::str(msg)),
                ]),
                None,
            ),
        };
        write_frame(&mut stream, &encode_envelope(&response, out_blob.as_deref()))?;
    }
    Ok(())
}

/// Client side: a persistent connection issuing unary calls.
pub struct RpcClient {
    stream: std::sync::Mutex<TcpStream>,
    next_id: AtomicU64,
}

impl RpcClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<RpcClient, WireError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(RpcClient { stream: std::sync::Mutex::new(stream), next_id: AtomicU64::new(1) })
    }

    /// Unary call: send request, await the matching response.
    pub fn call(&self, method: &str, params: Json) -> Result<Json, WireError> {
        self.call_binary(method, params, None).map(|(j, _)| j)
    }

    /// Unary call with an opaque binary attachment (the tensor fast path).
    pub fn call_binary(
        &self,
        method: &str,
        params: Json,
        blob: Option<&[u8]>,
    ) -> Result<(Json, Option<Vec<u8>>), WireError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("method", Json::str(method)),
            ("params", params),
        ]);
        let mut stream = self.stream.lock().unwrap();
        write_frame(&mut *stream, &encode_envelope(&req, blob))?;
        let frame = read_frame(&mut *stream)?
            .ok_or_else(|| WireError::Protocol("connection closed mid-call".into()))?;
        drop(stream);
        let (resp, out_blob) = decode_envelope(&frame)?;
        if resp.f64_or("id", -1.0) != id as f64 {
            return Err(WireError::Protocol("response id mismatch".into()));
        }
        if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            Ok((resp.get("result").cloned().unwrap_or(Json::Null), out_blob))
        } else {
            Err(WireError::Remote(resp.str_or("error", "unknown error").to_string()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_service() -> Arc<dyn Service> {
        Arc::new(|method: &str, params: &Json| -> Result<Json, String> {
            match method {
                "echo" => Ok(params.clone()),
                "add" => {
                    let a = params.f64_or("a", 0.0);
                    let b = params.f64_or("b", 0.0);
                    Ok(Json::obj(vec![("sum", Json::num(a + b))]))
                }
                "fail" => Err("deliberate failure".to_string()),
                other => Err(format!("unknown method {other:?}")),
            }
        })
    }

    #[test]
    fn unary_roundtrip() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        let out = client
            .call("add", Json::obj(vec![("a", Json::num(2.0)), ("b", Json::num(40.0))]))
            .unwrap();
        assert_eq!(out.get("sum").unwrap().as_f64(), Some(42.0));
        server.stop();
    }

    #[test]
    fn remote_errors_propagate() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        let err = client.call("fail", Json::Null).unwrap_err();
        assert!(matches!(err, WireError::Remote(ref m) if m.contains("deliberate")));
        // Connection still usable after an error response.
        let ok = client.call("echo", Json::str("still alive")).unwrap();
        assert_eq!(ok.as_str(), Some("still alive"));
        server.stop();
    }

    #[test]
    fn multiple_sequential_calls_one_connection() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        for i in 0..50 {
            let out = client.call("echo", Json::num(i as f64)).unwrap();
            assert_eq!(out.as_f64(), Some(i as f64));
        }
        server.stop();
    }

    #[test]
    fn concurrent_clients() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let client = RpcClient::connect(addr).unwrap();
                    for i in 0..25 {
                        let v = (t * 100 + i) as f64;
                        let out = client.call("echo", Json::num(v)).unwrap();
                        assert_eq!(out.as_f64(), Some(v));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
    }

    #[test]
    fn large_frame_roundtrip() {
        let server = RpcServer::serve("127.0.0.1:0", echo_service()).unwrap();
        let client = RpcClient::connect(server.addr()).unwrap();
        // ~1 MB payload.
        let big: Vec<Json> = (0..100_000).map(|i| Json::num(i as f64)).collect();
        let out = client.call("echo", Json::arr(big)).unwrap();
        assert_eq!(out.as_arr().unwrap().len(), 100_000);
        server.stop();
    }

    #[test]
    fn frame_encoding_rejects_oversize() {
        let mut sink = Vec::new();
        let huge = vec![0u8; (MAX_FRAME + 1) as usize];
        assert!(write_frame(&mut sink, &huge).is_err());
    }

    #[test]
    fn clean_eof_is_none() {
        let data: &[u8] = &[];
        let mut cursor = std::io::Cursor::new(data);
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }
}
