//! Non-blocking, multiplexed RPC server.
//!
//! The pre-multiplex server ran one blocking thread per connection and one
//! request at a time per thread — fine for 3 agents, a wall at 3,000. This
//! implementation is a hand-rolled readiness loop (no external deps, per
//! the offline build):
//!
//! - the **accept thread** hands new connections to the event loop;
//! - the **event loop** sets every stream non-blocking and polls the
//!   registered set, accumulating bytes into per-connection buffers and
//!   slicing complete `u32 BE length`-prefixed frames out of them; a
//!   connection that stalls mid-frame past [`super::MIDFRAME_TIMEOUT`] is
//!   closed, and an oversized declared length closes the connection before
//!   any allocation;
//! - complete request frames are dispatched to a **worker pool**, so many
//!   requests from one connection execute concurrently and a slow call
//!   never blocks fast ones behind it;
//! - workers write chunk and response frames back under a per-connection
//!   writer lock held only per frame — responses from different requests
//!   interleave freely and the client routes them by id.
//!
//! In-flight accounting (frames parsed, final response not yet written) is
//! exposed via [`RpcServer::inflight`] / [`RpcServer::inflight_peak`]; the
//! `fig_fleet` bench gates on ≥10k concurrent in-flight streams.

use super::frame::{decode_msg, encode_msg, WireMsg};
use super::{Service, WireError, MAX_FRAME, MIDFRAME_TIMEOUT};
use crate::util::json::Json;
use crate::util::threadpool::{Channel, Receiver, Sender, ThreadPool};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Tuning knobs for [`RpcServer::serve_with_opts`].
#[derive(Debug, Clone)]
pub struct WireOpts {
    /// Worker threads executing requests (per server). Concurrency per
    /// *connection* is no longer 1 — any worker can run any request.
    pub workers: usize,
    /// Dispatch queue capacity; the event loop back-pressures (stops
    /// reading) when this many requests are queued unexecuted.
    pub queue_capacity: usize,
    /// Close a connection whose current frame has been partially received
    /// for longer than this.
    pub midframe_timeout: Duration,
    /// Give up on a peer that stops draining its socket for this long
    /// while a worker is writing a frame to it.
    pub write_stall_timeout: Duration,
}

impl Default for WireOpts {
    fn default() -> WireOpts {
        WireOpts {
            workers: 16,
            queue_capacity: 32_768,
            midframe_timeout: MIDFRAME_TIMEOUT,
            write_stall_timeout: Duration::from_secs(30),
        }
    }
}

/// Counters shared between the event loop, the workers, and the handle.
#[derive(Default)]
struct ServerStats {
    inflight: AtomicU64,
    inflight_peak: AtomicU64,
    socket_option_failures: AtomicU64,
}

impl ServerStats {
    fn enter(&self) {
        let now = self.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.inflight_peak.fetch_max(now, Ordering::Relaxed);
    }

    fn exit(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Writer half of a connection, shared with the workers serving its
/// requests. `dead` doubles as the close-request flag: the event loop
/// drops the connection on its next pass.
struct ConnWriter {
    stream: Mutex<TcpStream>,
    dead: AtomicBool,
    write_stall: Duration,
}

impl ConnWriter {
    /// Write one frame under the per-frame lock. The stream is
    /// non-blocking (it shares the socket with the reader side), so
    /// `WouldBlock` is retried with a short sleep up to the stall timeout.
    fn write_frame(&self, payload: &[u8]) -> Result<(), WireError> {
        if self.dead.load(Ordering::Relaxed) {
            return Err(WireError::Protocol("connection closed".into()));
        }
        if payload.len() as u64 > MAX_FRAME as u64 {
            return Err(WireError::Protocol(format!("frame too large: {}", payload.len())));
        }
        let mut guard = match self.stream.lock() {
            Ok(g) => g,
            Err(_) => {
                // A worker panicked mid-write: frame boundaries on this
                // socket are unknowable.
                self.dead.store(true, Ordering::Relaxed);
                return Err(WireError::Protocol(
                    "connection writer poisoned by a panicked worker".into(),
                ));
            }
        };
        let result = self
            .write_all_nb(&mut guard, &(payload.len() as u32).to_be_bytes())
            .and_then(|()| self.write_all_nb(&mut guard, payload));
        if result.is_err() {
            self.dead.store(true, Ordering::Relaxed);
        }
        result
    }

    fn write_all_nb(&self, stream: &mut TcpStream, mut buf: &[u8]) -> Result<(), WireError> {
        let mut stalled_since: Option<Instant> = None;
        while !buf.is_empty() {
            match stream.write(buf) {
                Ok(0) => return Err(WireError::Protocol("connection closed mid-write".into())),
                Ok(n) => {
                    buf = &buf[n..];
                    stalled_since = None;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    let since = *stalled_since.get_or_insert_with(Instant::now);
                    if since.elapsed() > self.write_stall {
                        return Err(WireError::Deadline(format!(
                            "peer stopped draining its socket for {:?}",
                            self.write_stall
                        )));
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }
}

/// Event-loop-side connection state.
struct Conn {
    stream: TcpStream,
    writer: Arc<ConnWriter>,
    /// Bytes received but not yet sliced into frames.
    rbuf: Vec<u8>,
    /// Set while a frame is partially received (mid-frame stall clock).
    partial_since: Option<Instant>,
}

/// A running RPC server: accept thread + readiness event loop + worker
/// pool.
pub struct RpcServer {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    loop_thread: Option<std::thread::JoinHandle<()>>,
}

impl RpcServer {
    /// Bind and serve `service` on `addr` (use port 0 for ephemeral).
    pub fn serve(addr: &str, service: Arc<dyn Service>) -> Result<RpcServer, WireError> {
        RpcServer::serve_with_opts(addr, service, None, WireOpts::default())
    }

    /// As [`RpcServer::serve`], with an optional [`crate::chaos::ChaosEngine`]
    /// consulted before every request is dispatched — the injection point
    /// for deterministic distributed-failure scenarios. A `Kill` verdict
    /// flips the server's shutdown flag (and fires the engine's kill hook),
    /// so every connection dies no later than its next request.
    pub fn serve_with_chaos(
        addr: &str,
        service: Arc<dyn Service>,
        chaos: Option<Arc<crate::chaos::ChaosEngine>>,
    ) -> Result<RpcServer, WireError> {
        RpcServer::serve_with_opts(addr, service, chaos, WireOpts::default())
    }

    /// Full-control entry point: chaos engine plus [`WireOpts`] tuning.
    pub fn serve_with_opts(
        addr: &str,
        service: Arc<dyn Service>,
        chaos: Option<Arc<crate::chaos::ChaosEngine>>,
        opts: WireOpts,
    ) -> Result<RpcServer, WireError> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let (conn_tx, conn_rx) = Channel::<TcpStream>::bounded(1024);

        let accept_thread = {
            let sd = shutdown.clone();
            std::thread::Builder::new()
                .name(format!("rpc-accept-{local}"))
                .spawn(move || accept_loop(listener, conn_tx, sd))
                .map_err(WireError::Io)?
        };
        let loop_thread = {
            let sd = shutdown.clone();
            let stats = stats.clone();
            let pool_handle = PoolHandle { service, chaos, shutdown: sd.clone(), stats: stats.clone() };
            std::thread::Builder::new()
                .name(format!("rpc-loop-{local}"))
                .spawn(move || event_loop(conn_rx, pool_handle, sd, stats, opts))
                .map_err(WireError::Io)?
        };
        Ok(RpcServer {
            addr: local,
            shutdown,
            stats,
            accept_thread: Some(accept_thread),
            loop_thread: Some(loop_thread),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Requests received (frame fully parsed) whose final response has not
    /// been written yet.
    pub fn inflight(&self) -> u64 {
        self.stats.inflight.load(Ordering::Relaxed)
    }

    /// High-water mark of [`RpcServer::inflight`] over the server's life.
    pub fn inflight_peak(&self) -> u64 {
        self.stats.inflight_peak.load(Ordering::Relaxed)
    }

    /// Connections refused because a socket option (non-blocking mode,
    /// `TCP_NODELAY`) could not be set — surfaced instead of `.ok()`-ing
    /// away a socket whose deadline enforcement would be vacuous.
    pub fn socket_option_failures(&self) -> u64 {
        self.stats.socket_option_failures.load(Ordering::Relaxed)
    }

    /// Stop accepting, close every connection, and join the loop threads.
    /// In-flight requests on the worker pool finish (their writes fail).
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Nudge the blocking accept with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.loop_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn accept_loop(listener: TcpListener, conn_tx: Sender<TcpStream>, shutdown: Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        match conn {
            Ok(stream) => {
                if conn_tx.send(stream).is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Everything a dispatched request needs, bundled for the worker closure.
#[derive(Clone)]
struct PoolHandle {
    service: Arc<dyn Service>,
    chaos: Option<Arc<crate::chaos::ChaosEngine>>,
    shutdown: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
}

fn event_loop(
    conn_rx: Receiver<TcpStream>,
    handle: PoolHandle,
    shutdown: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
    opts: WireOpts,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let pool = ThreadPool::new("rpc-exec", opts.workers.max(2), opts.queue_capacity.max(64));
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        // Register newly accepted connections.
        while let Some(stream) = conn_rx.try_recv() {
            match register_conn(stream, &opts) {
                Ok(conn) => conns.push(conn),
                Err(_) => {
                    stats.socket_option_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        let mut progress = false;
        conns.retain_mut(|conn| {
            if conn.writer.dead.load(Ordering::Relaxed) {
                let _ = conn.stream.shutdown(std::net::Shutdown::Both);
                return false;
            }
            // Drain what the socket has, with a per-tick fairness bound so
            // one firehose connection cannot starve the set.
            for _ in 0..16 {
                match conn.stream.read(&mut scratch) {
                    Ok(0) => {
                        conn.writer.dead.store(true, Ordering::Relaxed);
                        return false;
                    }
                    Ok(n) => {
                        progress = true;
                        conn.rbuf.extend_from_slice(&scratch[..n]);
                        if !slice_frames(conn, &handle, &pool) {
                            // Oversized declared length: close with no
                            // reply, before any allocation.
                            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
                            return false;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => {
                        conn.writer.dead.store(true, Ordering::Relaxed);
                        return false;
                    }
                }
            }
            // Mid-frame stall guard: once part of a frame has arrived, the
            // rest must land within the window (idle *between* frames is
            // legal and never times out).
            if let Some(since) = conn.partial_since {
                if since.elapsed() > opts.midframe_timeout {
                    let _ = conn.stream.shutdown(std::net::Shutdown::Both);
                    return false;
                }
            }
            true
        });
        if !progress {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    for conn in &conns {
        let _ = conn.stream.shutdown(std::net::Shutdown::Both);
    }
    // pool drops here: queued jobs run to completion against closed
    // sockets (their writes fail fast), then the workers join.
}

fn register_conn(stream: TcpStream, opts: &WireOpts) -> Result<Conn, WireError> {
    // Failures are surfaced (counted + connection refused), not `.ok()`'d:
    // a blocking stream in a readiness loop would hang the whole set, and
    // without nodelay the per-frame latency story is fiction.
    stream.set_nonblocking(true)?;
    stream.set_nodelay(true)?;
    let writer_stream = stream.try_clone()?;
    Ok(Conn {
        stream,
        writer: Arc::new(ConnWriter {
            stream: Mutex::new(writer_stream),
            dead: AtomicBool::new(false),
            write_stall: opts.write_stall_timeout,
        }),
        rbuf: Vec::new(),
        partial_since: None,
    })
}

/// Slice every complete frame out of `conn.rbuf` and dispatch it. Returns
/// `false` when the connection must be closed without a reply (oversized
/// declared length).
fn slice_frames(conn: &mut Conn, handle: &PoolHandle, pool: &ThreadPool) -> bool {
    let mut off = 0usize;
    loop {
        let avail = conn.rbuf.len() - off;
        if avail < 4 {
            break;
        }
        let len = u32::from_be_bytes(conn.rbuf[off..off + 4].try_into().unwrap());
        if len > MAX_FRAME {
            return false;
        }
        let len = len as usize;
        if avail < 4 + len {
            break;
        }
        let frame = conn.rbuf[off + 4..off + 4 + len].to_vec();
        off += 4 + len;
        dispatch(frame, conn.writer.clone(), handle, pool);
    }
    if off > 0 {
        conn.rbuf.drain(..off);
    }
    if conn.rbuf.is_empty() {
        conn.partial_since = None;
    } else if conn.partial_since.is_none() {
        conn.partial_since = Some(Instant::now());
    }
    true
}

fn dispatch(frame: Vec<u8>, writer: Arc<ConnWriter>, handle: &PoolHandle, pool: &ThreadPool) {
    let handle = handle.clone();
    handle.stats.enter();
    pool.execute(move || {
        run_request(frame, writer, &handle);
        handle.stats.exit();
    });
}

fn run_request(frame: Vec<u8>, writer: Arc<ConnWriter>, handle: &PoolHandle) {
    let (id, method, params, blob) = match decode_msg(&frame) {
        Ok(WireMsg::Request { id, method, params, blob }) => (id, method, params, blob),
        // Malformed or non-request frame: close the connection, keep the
        // server serving everyone else.
        _ => {
            writer.dead.store(true, Ordering::Relaxed);
            return;
        }
    };
    if let Some(engine) = &handle.chaos {
        match engine.decide(&method) {
            crate::chaos::FaultAction::Pass => {}
            crate::chaos::FaultAction::Delay(ms) => {
                std::thread::sleep(Duration::from_millis(ms));
            }
            // Close with no reply: from the caller's view this is exactly
            // a crashed peer mid-call.
            crate::chaos::FaultAction::Drop => {
                writer.dead.store(true, Ordering::Relaxed);
                return;
            }
            crate::chaos::FaultAction::Kill => {
                handle.shutdown.store(true, Ordering::Relaxed);
                writer.dead.store(true, Ordering::Relaxed);
                return;
            }
        }
    }
    let result = {
        let writer = writer.clone();
        let mut emit = move |chunk: Json, chunk_blob: Option<Vec<u8>>| -> Result<(), WireError> {
            writer.write_frame(&encode_msg(&WireMsg::Chunk { id, chunk, blob: chunk_blob }))
        };
        handle.service.call_stream(&method, &params, blob.as_deref(), &mut emit)
    };
    let response = match result {
        Ok((body, out_blob)) => WireMsg::Response { id, ok: true, body, blob: out_blob },
        Err(msg) => WireMsg::Response { id, ok: false, body: Json::str(msg), blob: None },
    };
    let _ = writer.write_frame(&encode_msg(&response));
}
