//! The automated benchmarking analysis + reporting workflow (§4.3, §5.3, F8).
//!
//! Consumes evaluation records from the [`crate::evaldb`] and aggregated
//! timelines from the [`crate::traceserver`], correlates and summarizes
//! them, and renders the human-readable reports the paper's server returns
//! in the analysis workflow (steps a–e): per-model summaries (Table 2),
//! accuracy-vs-performance scatters (Figs 4/5), throughput-scalability
//! heatmaps (Fig 6), cross-system comparisons (Fig 7 + cost efficiency),
//! and the layer↔kernel breakdown (Table 3).

use crate::benchkit::{heatmap, scatter, Table};
use crate::evaldb::{EvalDb, EvalQuery, EvalRecord};
use crate::traceserver::Timeline;
use crate::tracing::TraceLevel;
use crate::util::json::Json;

/// Per-model summary across scenarios — one Table-2 row.
#[derive(Debug, Clone)]
pub struct ModelSummary {
    pub model: String,
    pub accuracy: Option<f64>,
    pub graph_size_mb: Option<f64>,
    /// Online (batch 1) trimmed-mean latency, ms.
    pub online_trimmed_mean_ms: f64,
    /// Online 90th-percentile latency, ms.
    pub online_p90_ms: f64,
    /// Maximum throughput over all batched runs, items/s.
    pub max_throughput: f64,
    /// Batch size achieving `max_throughput`.
    pub optimal_batch: usize,
}

/// Summarize one model's records (online + batched) into a Table-2 row.
pub fn summarize_model(model: &str, db: &EvalDb) -> Option<ModelSummary> {
    let online: Vec<EvalRecord> = db
        .latest(&EvalQuery {
            model: Some(model.to_string()),
            scenario: Some("online".into()),
            ..Default::default()
        })
        .into_iter()
        .collect();
    let batched = db.latest(&EvalQuery {
        model: Some(model.to_string()),
        scenario: Some("batched".into()),
        ..Default::default()
    });
    if online.is_empty() && batched.is_empty() {
        return None;
    }
    let (tm, p90) = online
        .first()
        .map(|r| (r.trimmed_mean_ms(), r.p90_ms()))
        .unwrap_or((f64::NAN, f64::NAN));
    let (max_tp, opt_batch) = batched
        .iter()
        .map(|r| (r.throughput, r.key.batch_size))
        .fold((0.0f64, 1usize), |acc, x| if x.0 > acc.0 { x } else { acc });
    let meta = online.first().or_else(|| batched.first()).map(|r| r.meta.clone());
    Some(ModelSummary {
        model: model.to_string(),
        accuracy: meta.as_ref().and_then(|m| m.get("accuracy")).and_then(|v| v.as_f64()),
        graph_size_mb: meta
            .as_ref()
            .and_then(|m| m.get("graph_size_mb"))
            .and_then(|v| v.as_f64()),
        online_trimmed_mean_ms: tm,
        online_p90_ms: p90,
        max_throughput: max_tp,
        optimal_batch: opt_batch,
    })
}

/// Render Table 2 for a set of models.
pub fn table2(models: &[String], db: &EvalDb) -> Table {
    let mut t = Table::new(
        "Table 2 — model accuracy, size, online latency, max throughput",
        &[
            "ID",
            "Name",
            "Top1 Acc",
            "Graph (MB)",
            "Online TM (ms)",
            "Online p90 (ms)",
            "Max Tput (items/s)",
            "Opt Batch",
        ],
    );
    for (i, m) in models.iter().enumerate() {
        if let Some(s) = summarize_model(m, db) {
            t.row(&[
                (i + 1).to_string(),
                s.model.clone(),
                s.accuracy.map(|a| format!("{a:.2}")).unwrap_or_else(|| "-".into()),
                s.graph_size_mb.map(|g| format!("{g:.1}")).unwrap_or_else(|| "-".into()),
                format!("{:.2}", s.online_trimmed_mean_ms),
                format!("{:.2}", s.online_p90_ms),
                format!("{:.1}", s.max_throughput),
                s.optimal_batch.to_string(),
            ]);
        }
    }
    t
}

/// Figure 4/5 scatter points: (x = latency ms | throughput, y = accuracy,
/// label = table id).
pub fn accuracy_scatter(
    summaries: &[ModelSummary],
    use_throughput: bool,
) -> Vec<(f64, f64, String)> {
    summaries
        .iter()
        .enumerate()
        .filter_map(|(i, s)| {
            let acc = s.accuracy?;
            let x = if use_throughput { s.max_throughput } else { s.online_trimmed_mean_ms };
            if !x.is_finite() {
                return None;
            }
            Some((x, acc, (i + 1).to_string()))
        })
        .collect()
}

/// Render Fig 4 (accuracy vs online latency) or Fig 5 (vs max throughput).
pub fn render_accuracy_figure(summaries: &[ModelSummary], use_throughput: bool) -> String {
    let pts = accuracy_scatter(summaries, use_throughput);
    scatter(
        if use_throughput {
            "Fig 5 — accuracy vs max throughput"
        } else {
            "Fig 4 — accuracy vs online latency"
        },
        if use_throughput { "items/s" } else { "ms" },
        "top-1 accuracy %",
        &pts,
        48,
        16,
    )
}

/// Fig 6: throughput speedup over batch 1 for each (model, batch) pair.
/// `rows` = batch sizes, `cols` = models.
pub fn throughput_speedup_matrix(
    models: &[String],
    batch_sizes: &[usize],
    db: &EvalDb,
) -> Vec<Vec<f64>> {
    let tput = |model: &str, batch: usize| -> f64 {
        db.latest(&EvalQuery {
            model: Some(model.to_string()),
            scenario: Some("batched".into()),
            batch_size: Some(batch),
            ..Default::default()
        })
        .first()
        .map(|r| r.throughput)
        .unwrap_or(f64::NAN)
    };
    batch_sizes
        .iter()
        .map(|b| {
            models
                .iter()
                .map(|m| {
                    let base = tput(m, 1);
                    let t = tput(m, *b);
                    if base > 0.0 {
                        t / base
                    } else {
                        f64::NAN
                    }
                })
                .collect()
        })
        .collect()
}

/// Render the Fig-6 heatmap.
pub fn render_fig6(models: &[String], batch_sizes: &[usize], db: &EvalDb) -> String {
    let matrix = throughput_speedup_matrix(models, batch_sizes, db);
    let rows: Vec<String> = batch_sizes.iter().map(|b| format!("b{b}")).collect();
    let cols: Vec<String> = (1..=models.len()).map(|i| i.to_string()).collect();
    heatmap("Fig 6 — throughput speedup over batch 1", &rows, &cols, &matrix)
}

/// Fig 7: one model's latency across systems/devices at a set of batch
/// sizes, plus the paper's cost-efficiency observation ($/1k inferences).
pub fn system_comparison(model: &str, db: &EvalDb) -> Table {
    let mut t = Table::new(
        &format!("Fig 7 — {model} latency across systems"),
        &["System", "Device", "Batch", "TrimmedMean (ms)", "Tput (items/s)", "$ / 1M items"],
    );
    let recs = db.latest(&EvalQuery::model(model));
    let systems = crate::sysmodel::profile_map();
    let mut rows: Vec<&EvalRecord> = recs.iter().collect();
    rows.sort_by(|a, b| {
        (&a.key.system, &a.key.device, a.key.batch_size)
            .cmp(&(&b.key.system, &b.key.device, b.key.batch_size))
    });
    for r in rows {
        let cost = systems
            .get(&r.key.system)
            .map(|p| p.cost_per_hr)
            .filter(|c| *c > 0.0)
            .map(|c| format!("{:.3}", c / 3600.0 / r.throughput * 1e6))
            .unwrap_or_else(|| "-".into());
        t.row(&[
            r.key.system.clone(),
            r.key.device.clone(),
            r.key.batch_size.to_string(),
            format!("{:.2}", r.trimmed_mean_ms()),
            format!("{:.1}", r.throughput),
            cost,
        ]);
    }
    t
}

/// Table 3: top-N most time-consuming FRAMEWORK layers with their dominant
/// SYSTEM kernel, from an aggregated timeline.
pub fn layer_kernel_table(timeline: &Timeline, top_n: usize) -> Table {
    let mut t = Table::new(
        "Table 3 — top layers and dominant GPU kernels",
        &["Layer Idx", "Layer Name", "Layer Type", "Shape", "Dominant Kernel", "Latency (ms)", "Alloc (MB)"],
    );
    let corr = timeline.layer_kernel_correlation();
    for (layer, kernels) in corr.iter().take(top_n) {
        let dominant = kernels.iter().max_by_key(|k| k.duration_ns());
        t.row(&[
            layer.tag("layer_index").unwrap_or("-").to_string(),
            layer.name.clone(),
            layer.tag("kind").unwrap_or("-").to_string(),
            layer.tag("shape").unwrap_or("-").to_string(),
            dominant.map(|k| k.name.clone()).unwrap_or_else(|| "-".into()),
            format!("{:.2}", layer.duration_ms()),
            layer
                .tag("alloc_mb")
                .map(|a| a.to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

/// Summary counts the paper quotes under Table 3 ("234 layers of which 143
/// take less than 1ms").
pub fn layer_population(timeline: &Timeline) -> (usize, usize) {
    let layers = timeline.at_level(TraceLevel::Framework);
    let fast = layers.iter().filter(|l| l.duration_ms() < 1.0).count();
    (layers.len(), fast)
}

/// Model × system matrix (the paper's §5.1 case-study artifact, fed by
/// `mlms sweep`): one row per model, one column per system present in the
/// store, each cell showing the latest online (batch-1) trimmed-mean
/// latency in ms and the maximum throughput in items/s measured on that
/// system (`-` marks unmeasured halves).
pub fn model_system_matrix(models: &[String], db: &EvalDb) -> Table {
    model_system_pivot(models, db).0
}

/// The matrix plus the number of distinct systems it covers — the report
/// includes the section only when results span more than one system, and
/// computing both in one pass avoids re-scanning the store.
fn model_system_pivot(models: &[String], db: &EvalDb) -> (Table, usize) {
    use std::collections::BTreeSet;
    let mut systems: BTreeSet<String> = BTreeSet::new();
    let mut per_model: Vec<(String, Option<f64>, Vec<EvalRecord>)> = Vec::new();
    for m in models {
        let recs = db.latest(&EvalQuery::model(m));
        if recs.is_empty() {
            continue;
        }
        for r in &recs {
            systems.insert(r.key.system.clone());
        }
        let acc = recs
            .iter()
            .find_map(|r| r.meta.get("accuracy").and_then(|v| v.as_f64()));
        per_model.push((m.clone(), acc, recs));
    }
    let systems: Vec<String> = systems.into_iter().collect();
    let mut header: Vec<&str> = vec!["Model", "Top1 Acc"];
    for s in &systems {
        header.push(s.as_str());
    }
    let mut t = Table::new(
        "Model × system matrix — online latency (ms) / max throughput (items/s)",
        &header,
    );
    for (m, acc, recs) in &per_model {
        let mut row = vec![
            m.clone(),
            acc.map(|a| format!("{a:.2}")).unwrap_or_else(|| "-".into()),
        ];
        for s in &systems {
            let lat = recs
                .iter()
                .filter(|r| {
                    &r.key.system == s && r.key.scenario == "online" && r.key.batch_size == 1
                })
                .max_by_key(|r| r.seq)
                .map(|r| format!("{:.2}", r.trimmed_mean_ms()))
                .unwrap_or_else(|| "-".into());
            let max_tput = recs
                .iter()
                .filter(|r| &r.key.system == s)
                .map(|r| r.throughput)
                .filter(|t| t.is_finite())
                .fold(f64::NAN, f64::max);
            let tput = if max_tput.is_finite() {
                format!("{max_tput:.0}")
            } else {
                "-".into()
            };
            row.push(format!("{lat} / {tput}"));
        }
        t.row(&row);
    }
    (t, systems.len())
}

/// Batching/dispatch report: one row per stored record carrying the
/// cross-request batcher's metadata ([`crate::batcher`]) — occupancy, fill
/// ratio, queue-delay tail, and how the dispatcher sharded the job.
pub fn batching_table(models: &[String], db: &EvalDb) -> Table {
    let mut t = Table::new(
        "Batching — occupancy, queue delay, dispatch sharding",
        &[
            "Model",
            "Scenario",
            "Agents",
            "Batches",
            "Mean Occ",
            "Fill %",
            "p90 Delay (ms)",
            "Requeued",
            "Tput (items/s)",
        ],
    );
    for m in models {
        for r in db.latest(&EvalQuery::model(m)) {
            let series = match r.meta.get("batching") {
                Some(bj) => match crate::metrics::BatchingSeries::from_json(bj) {
                    Some(s) => s,
                    None => continue,
                },
                None => continue,
            };
            t.row(&[
                m.clone(),
                r.key.scenario.clone(),
                format!("{}", r.meta.f64_or("agents", 1.0) as u64),
                series.batches().to_string(),
                format!("{:.2}", series.mean_occupancy()),
                format!("{:.0}", series.fill_ratio() * 100.0),
                format!("{:.3}", series.p90_queue_delay_ms()),
                format!("{}", r.meta.f64_or("requeued_batches", 0.0) as u64),
                format!("{:.1}", r.throughput),
            ]);
        }
    }
    t
}

/// Admission-control report: one row per tenant of each stored record
/// carrying shed accounting ([`crate::batcher::admission`], stored under
/// `meta["admission"]`) — what was offered, what was admitted, and what
/// was shed by which mechanism. This is where "the platform held its SLO"
/// meets "…by dropping whose traffic": load shedding is only acceptable
/// when it is visible.
pub fn admission_table(models: &[String], db: &EvalDb) -> Table {
    let mut t = Table::new(
        "Admission control — per-tenant offered/admitted/shed",
        &[
            "Model",
            "Scenario",
            "Tenant",
            "Priority",
            "Offered",
            "Admitted",
            "Shed (rate)",
            "Shed (deadline)",
            "Shed %",
        ],
    );
    for m in models {
        for r in db.latest(&EvalQuery::model(m)) {
            let series = match r.meta.get("admission") {
                Some(aj) => match crate::metrics::ShedSeries::from_json(aj) {
                    Some(s) => s,
                    None => continue,
                },
                None => continue,
            };
            for (tenant, row) in &series.rows {
                let shed_pct = if row.offered > 0 {
                    row.shed_total() as f64 / row.offered as f64 * 100.0
                } else {
                    0.0
                };
                t.row(&[
                    m.clone(),
                    r.key.scenario.clone(),
                    tenant.clone(),
                    row.priority.clone(),
                    row.offered.to_string(),
                    row.admitted.to_string(),
                    row.shed_rate_limited.to_string(),
                    row.shed_deadline.to_string(),
                    format!("{shed_pct:.1}"),
                ]);
            }
        }
    }
    t
}

/// SLO frontier report: one row per stored frontier point
/// ([`crate::slo::store_frontier_point`]) — the maximum sustainable rate
/// each (model, batch config) reached under each latency bound.
pub fn slo_frontier_table(models: &[String], db: &EvalDb) -> Table {
    let mut t = Table::new(
        "SLO frontier — max sustainable QPS under a latency bound",
        &[
            "Model",
            "Batch",
            "Wait (ms)",
            "Fair",
            "SLO",
            "Max QPS",
            "Achieved (ms)",
            "Probes",
        ],
    );
    for m in models {
        let mut rows: Vec<EvalRecord> = db
            .latest(&EvalQuery::model(m))
            .into_iter()
            .filter(|r| r.meta.get("slo").is_some())
            .collect();
        // Loosest bound first, so each column reads as a frontier. total_cmp
        // because the bound comes from stored metadata: a NaN in one record
        // must sort deterministically, not panic the whole report.
        rows.sort_by(|a, b| {
            let bound = |r: &EvalRecord| {
                r.meta.get("slo").map(|s| s.f64_or("bound_ms", 0.0)).unwrap_or(0.0)
            };
            bound(b).total_cmp(&bound(a))
        });
        for r in rows {
            let s = r.meta.get("slo").unwrap();
            t.row(&[
                m.clone(),
                format!("{}", s.f64_or("batch_size", 1.0) as u64),
                format!("{:.1}", s.f64_or("max_wait_ms", 0.0)),
                if s.get("fair").and_then(|v| v.as_bool()).unwrap_or(false) {
                    "yes".into()
                } else {
                    "no".into()
                },
                format!(
                    "p{:.0}<={:.1}ms",
                    s.f64_or("percentile", 99.0),
                    s.f64_or("bound_ms", 0.0)
                ),
                format!("{:.1}", s.f64_or("max_qps", 0.0)),
                format!("{:.2}", s.f64_or("achieved_ms", f64::NAN)),
                format!("{}", s.f64_or("probes", 0.0) as u64),
            ]);
        }
    }
    t
}

/// Regression section: the per-cell delta report of a control-vs-treatment
/// comparison ([`crate::regress::compare_labels`]) — median latencies, the
/// relative shift with its bootstrap CI, the Mann-Whitney p-value, and the
/// gate verdict — plus a one-line tally. `None` when no cell was measured
/// under both labels (nothing to gate is not "no regressions").
pub fn regression_section(cmp: &crate::regress::Comparison) -> Option<String> {
    if cmp.cells.is_empty() {
        return None;
    }
    let mut t = Table::new(
        &format!("Regression gate — {} vs {}", cmp.treatment, cmp.control),
        &["Cell", "Control (ms)", "Treatment (ms)", "Delta %", "95% CI", "p (MWU)", "Verdict"],
    );
    for c in &cmp.cells {
        t.row(&[
            c.cell.clone(),
            format!("{:.3}", c.control_median_ms),
            format!("{:.3}", c.treatment_median_ms),
            format!("{:+.1}", c.delta_pct),
            format!("[{:+.1}%, {:+.1}%]", c.ci_lo_pct, c.ci_hi_pct),
            format!("{:.4}", c.p_value),
            c.verdict.as_str().to_string(),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "{} cell(s) gated: {} regression(s), {} improvement(s), {} unchanged\n",
        cmp.cells.len(),
        cmp.regressions(),
        cmp.improvements(),
        cmp.cells.len() - cmp.regressions() - cmp.improvements(),
    ));
    Some(out)
}

/// Bottleneck section: aggregate the traces behind the models' stored
/// records ([`crate::traceanalysis::profile`] across every record carrying
/// a non-empty trace) and render self-time attribution + the automated
/// bottleneck verdict. `None` when no record has a usable trace.
pub fn bottleneck_section(
    models: &[String],
    db: &EvalDb,
    traces: &crate::traceserver::TraceServer,
    top_k: usize,
) -> Option<String> {
    let mut timelines = Vec::new();
    for m in models {
        for r in db.latest(&EvalQuery::model(m)) {
            if let Some(tid) = r.trace_id {
                let tl = traces.timeline(tid);
                if !tl.is_empty() {
                    timelines.push(tl);
                }
            }
        }
    }
    if timelines.is_empty() {
        return None;
    }
    let profile = crate::traceanalysis::profile(&timelines, top_k);
    Some(profile.render("stored evaluation traces"))
}

/// Full analysis report for a set of models — the analysis workflow's
/// output artifact (step e).
pub fn full_report(models: &[String], db: &EvalDb) -> String {
    let summaries: Vec<ModelSummary> =
        models.iter().filter_map(|m| summarize_model(m, db)).collect();
    let mut out = String::new();
    out.push_str(&table2(models, db).render());
    out.push_str(&render_accuracy_figure(&summaries, false));
    out.push_str(&render_accuracy_figure(&summaries, true));
    // The model×system matrix appears once results span multiple systems
    // (a single-system store is already covered by Table 2 / Fig 7).
    let (matrix, matrix_systems) = model_system_pivot(models, db);
    if matrix_systems > 1 {
        out.push_str(&matrix.render());
    }
    // The batching section appears only when some record carries the
    // batcher's metadata (built once; rendered only if it gained rows).
    let batching = batching_table(models, db);
    if batching.row_count() > 0 {
        out.push_str(&batching.render());
    }
    // Likewise the admission-control section…
    let admission = admission_table(models, db);
    if admission.row_count() > 0 {
        out.push_str(&admission.render());
    }
    // …and the SLO frontier section.
    let frontier = slo_frontier_table(models, db);
    if frontier.row_count() > 0 {
        out.push_str(&frontier.render());
    }
    out
}

/// [`full_report`] plus the bottleneck-attribution section, for callers
/// that hold the trace server (the `mlms` server's report endpoint does).
pub fn full_report_with_traces(
    models: &[String],
    db: &EvalDb,
    traces: &crate::traceserver::TraceServer,
) -> String {
    let mut out = full_report(models, db);
    if let Some(section) = bottleneck_section(models, db, traces, 5) {
        out.push_str(&section);
    }
    out
}

/// Write the full analysis report + per-figure CSVs to a directory — the
/// paper's published report pages (scalable20.mlmodelscope.org) as local
/// artifacts: `report.txt`, `summaries.json`, `table2.csv`.
pub fn write_report_dir(
    models: &[String],
    db: &EvalDb,
    dir: &std::path::Path,
) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join("report.txt"), full_report(models, db))?;
    std::fs::write(
        dir.join("summaries.json"),
        summaries_json(models, db).to_pretty(),
    )?;
    table2(models, db).save_csv(dir.join("table2.csv").to_str().unwrap())?;
    Ok(())
}

/// JSON form of the summaries (REST analysis endpoint payload).
pub fn summaries_json(models: &[String], db: &EvalDb) -> Json {
    Json::arr(
        models
            .iter()
            .filter_map(|m| summarize_model(m, db))
            .map(|s| {
                Json::obj(vec![
                    ("model", Json::str(&s.model)),
                    ("accuracy", s.accuracy.map(Json::num).unwrap_or(Json::Null)),
                    ("online_trimmed_mean_ms", Json::num(s.online_trimmed_mean_ms)),
                    ("online_p90_ms", Json::num(s.online_p90_ms)),
                    ("max_throughput", Json::num(s.max_throughput)),
                    ("optimal_batch", Json::num(s.optimal_batch as f64)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evaldb::EvalKey;

    fn put(db: &EvalDb, model: &str, system: &str, scenario: &str, batch: usize, lat_ms: f64, tput: f64, acc: f64) {
        let key = EvalKey {
            model: model.into(),
            model_version: "1.0.0".into(),
            framework: "TensorFlow".into(),
            framework_version: "1.15.0".into(),
            system: system.into(),
            device: "gpu".into(),
            scenario: scenario.into(),
            batch_size: batch,
        };
        let mut r = EvalRecord::new(key, vec![lat_ms / 1e3; 10], tput);
        r.meta = Json::obj(vec![
            ("accuracy", Json::num(acc)),
            ("graph_size_mb", Json::num(100.0)),
        ]);
        db.put(r);
    }

    fn seed_db() -> EvalDb {
        let db = EvalDb::in_memory();
        put(&db, "resnet50", "aws_p3", "online", 1, 6.33, 158.0, 76.46);
        for (b, tp) in [(1, 158.0), (32, 700.0), (256, 930.7), (64, 800.0)] {
            put(&db, "resnet50", "aws_p3", "batched", b, 6.33, tp, 76.46);
        }
        put(&db, "mobilenet", "aws_p3", "online", 1, 2.46, 406.0, 71.68);
        for (b, tp) in [(1, 406.0), (64, 2000.0), (128, 2576.4)] {
            put(&db, "mobilenet", "aws_p3", "batched", b, 2.46, tp, 71.68);
        }
        db
    }

    #[test]
    fn summarize_finds_optimal_batch() {
        let db = seed_db();
        let s = summarize_model("resnet50", &db).unwrap();
        assert_eq!(s.optimal_batch, 256);
        assert!((s.max_throughput - 930.7).abs() < 1e-9);
        assert!((s.online_trimmed_mean_ms - 6.33).abs() < 1e-9);
        assert_eq!(s.accuracy, Some(76.46));
    }

    #[test]
    fn table2_renders_rows() {
        let db = seed_db();
        let t = table2(&["resnet50".into(), "mobilenet".into(), "missing".into()], &db);
        let text = t.render();
        assert!(text.contains("resnet50"));
        assert!(text.contains("930.7"));
        assert!(!text.contains("missing"));
    }

    #[test]
    fn scatter_points_use_ids() {
        let db = seed_db();
        let sums: Vec<ModelSummary> = ["resnet50", "mobilenet"]
            .iter()
            .filter_map(|m| summarize_model(m, &db))
            .collect();
        let pts = accuracy_scatter(&sums, true);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].2, "1");
        assert!(pts[1].0 > pts[0].0, "mobilenet throughput higher");
        assert!(pts[1].1 < pts[0].1, "mobilenet accuracy lower");
    }

    #[test]
    fn fig6_speedups_relative_to_batch1() {
        let db = seed_db();
        let m = throughput_speedup_matrix(
            &["resnet50".into(), "mobilenet".into()],
            &[1, 64, 256],
            &db,
        );
        assert!((m[0][0] - 1.0).abs() < 1e-9, "batch 1 speedup is 1.0");
        assert!(m[2][0] > 5.0, "resnet50 @256 speedup {}", m[2][0]);
        assert!(m[1][1] > 4.0, "mobilenet @64 speedup {}", m[1][1]);
        assert!(m[2][1].is_nan(), "mobilenet has no 256 record");
    }

    #[test]
    fn system_comparison_includes_cost() {
        let db = seed_db();
        let t = system_comparison("resnet50", &db);
        let text = t.render();
        assert!(text.contains("aws_p3"));
        // $3.06/hr ÷ 3600 × 1e6 / 930.7 ≈ 0.913 $/1M items at max tput.
        assert!(text.contains("0.913"), "{text}");
    }

    #[test]
    fn full_report_contains_all_sections() {
        let db = seed_db();
        let rep = full_report(&["resnet50".into(), "mobilenet".into()], &db);
        assert!(rep.contains("Table 2"));
        assert!(rep.contains("Fig 4"));
        assert!(rep.contains("Fig 5"));
    }

    #[test]
    fn report_dir_artifacts_written() {
        let db = seed_db();
        let dir = std::env::temp_dir().join(format!("mlms_report_{}", std::process::id()));
        write_report_dir(&["resnet50".into(), "mobilenet".into()], &db, &dir).unwrap();
        let report = std::fs::read_to_string(dir.join("report.txt")).unwrap();
        assert!(report.contains("Table 2") && report.contains("Fig 5"));
        let sums = crate::util::json::Json::parse(
            &std::fs::read_to_string(dir.join("summaries.json")).unwrap(),
        )
        .unwrap();
        assert_eq!(sums.as_arr().unwrap().len(), 2);
        let csv = std::fs::read_to_string(dir.join("table2.csv")).unwrap();
        assert!(csv.lines().count() >= 3);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn batching_section_reports_series() {
        let db = seed_db();
        // A batched-dispatch record carrying the batcher's metadata.
        let key = EvalKey {
            model: "resnet50".into(),
            model_version: "1.0.0".into(),
            framework: "SimFramework-Volta".into(),
            framework_version: "1.0.0".into(),
            system: "aws_p3".into(),
            device: "gpu".into(),
            scenario: "poisson".into(),
            batch_size: 8,
        };
        let series = crate::metrics::BatchingSeries {
            capacity: 8,
            occupancy: vec![8.0, 8.0, 6.0],
            queue_delay_s: vec![0.002; 22],
        };
        let mut r = EvalRecord::new(key, vec![0.004; 22], 2400.0);
        r.meta = Json::obj(vec![
            ("batching", series.to_json()),
            ("agents", Json::num(4.0)),
            ("requeued_batches", Json::num(1.0)),
        ]);
        db.put(r);
        let text = batching_table(&["resnet50".into(), "mobilenet".into()], &db).render();
        assert!(text.contains("poisson"), "{text}");
        assert!(text.contains("7.33"), "mean occupancy rendered: {text}");
        assert!(text.contains("2400.0"), "{text}");
        // full_report includes the section only when records carry it.
        let with = full_report(&["resnet50".into()], &db);
        assert!(with.contains("Batching —"), "{with}");
        let without = full_report(&["mobilenet".into()], &db);
        assert!(!without.contains("Batching —"));
    }

    #[test]
    fn slo_frontier_section_reports_points() {
        let db = seed_db();
        for (bound, qps) in [(20.0, 400.0), (5.0, 150.0)] {
            let key = EvalKey {
                model: "resnet50".into(),
                model_version: "1.0.0".into(),
                framework: "-".into(),
                framework_version: "0.0.0".into(),
                system: "multi".into(),
                device: "-".into(),
                scenario: format!("slo:p99<={bound:.1}ms"),
                batch_size: 8,
            };
            let mut r = EvalRecord::new(key, vec![], qps);
            r.meta = Json::obj(vec![(
                "slo",
                Json::obj(vec![
                    ("batch_size", Json::num(8.0)),
                    ("max_wait_ms", Json::num(5.0)),
                    ("fair", Json::Bool(false)),
                    ("percentile", Json::num(99.0)),
                    ("bound_ms", Json::num(bound)),
                    ("max_qps", Json::num(qps)),
                    ("achieved_ms", Json::num(bound * 0.8)),
                    ("probes", Json::num(9.0)),
                ]),
            )]);
            db.put(r);
        }
        let text = slo_frontier_table(&["resnet50".into()], &db).render();
        assert!(text.contains("p99<=20.0ms"), "{text}");
        assert!(text.contains("p99<=5.0ms"), "{text}");
        assert!(text.contains("400.0"), "{text}");
        // Loosest bound renders first.
        assert!(
            text.find("p99<=20.0ms").unwrap() < text.find("p99<=5.0ms").unwrap(),
            "{text}"
        );
        // The full report gains the section only when points exist.
        let with = full_report(&["resnet50".into()], &db);
        assert!(with.contains("SLO frontier"), "{with}");
        let without = full_report(&["mobilenet".into()], &db);
        assert!(!without.contains("SLO frontier"));
    }

    #[test]
    fn bottleneck_section_appears_when_records_carry_traces() {
        use crate::tracing::{Span, SpanSink, TraceLevel as TL};
        let db = seed_db();
        let traces = crate::traceserver::TraceServer::new();
        // Records without traces → no section.
        assert!(bottleneck_section(&["resnet50".into()], &db, &traces, 5).is_none());
        assert!(!full_report_with_traces(&["resnet50".into()], &db, &traces)
            .contains("Bottleneck attribution"));
        // A record pointing at a real trace → section + verdict.
        let trace_id = 424242;
        let ms = |v: f64| (v * 1e6) as u64;
        for (id, parent, name, level, s, e) in [
            (1, None, "evaluate", TL::Model, 0.0, 10.0),
            (2, Some(1), "fc6", TL::Framework, 1.0, 9.0),
            (3, Some(2), "sgemm", TL::System, 1.0, 8.0),
        ] {
            traces.publish(Span {
                trace_id,
                span_id: id,
                parent_id: parent,
                name: name.into(),
                level,
                start_ns: ms(s),
                end_ns: ms(e),
                tags: Vec::new(),
            });
        }
        let key = EvalKey {
            model: "resnet50".into(),
            model_version: "1.0.0".into(),
            framework: "TensorFlow".into(),
            framework_version: "1.15.0".into(),
            system: "aws_p3".into(),
            device: "gpu".into(),
            scenario: "traced".into(),
            batch_size: 1,
        };
        let mut r = EvalRecord::new(key, vec![0.01; 5], 100.0);
        r.trace_id = Some(trace_id);
        db.put(r);
        let section = bottleneck_section(&["resnet50".into()], &db, &traces, 5).unwrap();
        assert!(section.contains("bottleneck verdict"), "{section}");
        assert!(section.contains("sgemm"), "{section}");
        let rep = full_report_with_traces(&["resnet50".into()], &db, &traces);
        assert!(rep.contains("Bottleneck attribution"), "{rep}");
        assert!(rep.contains("Table 2"), "classic sections still present");
    }

    #[test]
    fn model_system_matrix_pivots_by_system() {
        let db = seed_db();
        // Single-system store: the matrix renders but the report omits it.
        let rep = full_report(&["resnet50".into(), "mobilenet".into()], &db);
        assert!(!rep.contains("Model × system matrix"), "{rep}");
        // Add a second system: the pivot gains a column and the report the
        // section.
        put(&db, "resnet50", "ibm_p8", "online", 1, 8.10, 123.0, 76.46);
        let t = model_system_matrix(&["resnet50".into(), "mobilenet".into()], &db);
        assert_eq!(t.row_count(), 2);
        let text = t.render();
        assert!(text.contains("aws_p3") && text.contains("ibm_p8"), "{text}");
        assert!(text.contains("6.33"), "aws_p3 online latency: {text}");
        assert!(text.contains("8.10"), "ibm_p8 online latency: {text}");
        assert!(text.contains("931"), "max aws_p3 throughput 930.7 rounds up: {text}");
        // mobilenet has no ibm_p8 record → dashed cell.
        assert!(text.contains("- / -"), "{text}");
        let rep = full_report(&["resnet50".into(), "mobilenet".into()], &db);
        assert!(rep.contains("Model × system matrix"), "{rep}");
    }

    #[test]
    fn regression_section_renders_verdicts() {
        use crate::regress::{compare_labels, GateConfig};
        let db = seed_db();
        let cfg = GateConfig::default();
        // No labeled runs → nothing to gate → no section.
        assert!(regression_section(&compare_labels(&db, "base", "cand", &cfg)).is_none());
        let put_labeled = |label: &str, ms: f64| {
            let key = EvalKey {
                model: "resnet50".into(),
                model_version: "1.0.0".into(),
                framework: "TensorFlow".into(),
                framework_version: "1.15.0".into(),
                system: "aws_p3".into(),
                device: "gpu".into(),
                scenario: "online".into(),
                batch_size: 1,
            };
            let mut r = EvalRecord::new(key, vec![ms / 1e3; 8], 100.0);
            r.run_meta = crate::evaldb::RunMeta::labeled(label);
            db.put(r);
        };
        put_labeled("base", 10.0);
        put_labeled("cand", 15.0);
        let section =
            regression_section(&compare_labels(&db, "base", "cand", &cfg)).unwrap();
        assert!(section.contains("Regression gate — cand vs base"), "{section}");
        assert!(section.contains("resnet50@aws_p3/online/b1"), "{section}");
        assert!(section.contains("+50.0"), "{section}");
        assert!(section.contains("REGRESSION"), "{section}");
        assert!(section.contains("1 regression(s), 0 improvement(s), 0 unchanged"), "{section}");
    }

    #[test]
    fn summaries_json_shape() {
        let db = seed_db();
        let j = summaries_json(&["resnet50".into()], &db);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("optimal_batch").unwrap().as_f64(), Some(256.0));
    }
}
