//! Self-profiling: benchmark the benchmarker (Deep500's "measure the
//! harness" principle, ROADMAP item 3).
//!
//! The platform's tracing case studies are only credible if the platform's
//! *own* per-request cost is quantified and controlled. This module runs
//! the platform against itself:
//!
//! - **Per-level ablation.** One simulated evaluation per [`TraceLevel`]
//!   (NONE/MODEL/FRAMEWORK/FULL) on [`Server::sim_platform`]. Simulated
//!   compute time is *logical* (a [`crate::tracing::SimClock`] advances it
//!   analytically), so the evaluation's wall-clock time is almost pure
//!   harness cost — serde, span machinery, dispatch bookkeeping — and the
//!   per-request overhead at each level falls straight out of the wall
//!   time. Model compute is reported alongside from the record's simulated
//!   latencies, giving the "harness overhead vs. model compute" ratio.
//! - **No-op comparison.** The cost of a span *attempt* through a disabled
//!   tracer is measured against the same loop with no tracing call at all.
//!   Tracing-off must be within noise of the no-op harness — that is the
//!   contract that lets `--trace-level none` claim zero perturbation.
//! - **Component microbenches.** The three hot paths this PR attacks —
//!   evaldb puts (kept-open appender, batched [`EvalDb::put_all`]), span
//!   publication (sharded sink, batched publish), and percentile queries
//!   (cached-sorted [`SortedSamples`] vs. per-call re-sort) — each get a
//!   throughput measurement so `benches/fig_overhead.rs` can pin floors.
//! - **Self-attribution.** Every measurement phase runs under a wall-clock
//!   meta-span, and the resulting timeline goes through the platform's own
//!   [`crate::traceanalysis::profile`] — the bottleneck engine attributing
//!   the harness itself.
//!
//! [`measure`] produces an [`OverheadReport`]; [`OverheadReport::check`]
//! asserts the invariants (span volume monotone in level, tracing-off
//! within noise of no-op, NONE publishes nothing) so both the `mlms
//! overhead` command and the ratchet bench share one set of gates.

use crate::evaldb::{EvalDb, EvalKey, EvalRecord};
use crate::manifest::{Accelerator, SystemRequirements};
use crate::metrics::{percentile, SortedSamples};
use crate::scenario::Scenario;
use crate::server::{EvalJob, Server};
use crate::traceanalysis::{profile, TraceProfile};
use crate::tracing::{MemorySink, TraceLevel, Tracer, WallClock};
use crate::traceserver::Timeline;
use std::sync::Arc;
use std::time::Instant;

/// Configuration for one self-profiling run.
#[derive(Debug, Clone)]
pub struct OverheadConfig {
    /// Model evaluated on the simulated platform.
    pub model: String,
    /// System the job is pinned to.
    pub system: String,
    /// Requests per evaluation.
    pub requests: usize,
    /// Best-of trials per trace level (best-of damps scheduler noise; we
    /// compare cost floors).
    pub trials: usize,
    /// Iterations for each component microbench.
    pub iters: usize,
}

impl Default for OverheadConfig {
    fn default() -> Self {
        OverheadConfig {
            model: "ResNet_v1_50".into(),
            system: "aws_p3".into(),
            requests: 64,
            trials: 3,
            iters: 2000,
        }
    }
}

impl OverheadConfig {
    /// Small configuration for unit tests and smoke runs.
    pub fn quick() -> Self {
        OverheadConfig { requests: 8, trials: 1, iters: 200, ..Default::default() }
    }
}

/// One trace level's measured harness cost.
#[derive(Debug, Clone)]
pub struct LevelOverhead {
    pub level: TraceLevel,
    /// Best-of wall time of the whole evaluation, ms.
    pub wall_ms: f64,
    /// Wall time divided by request count, µs — the per-request harness
    /// tax at this level (compute is simulated, so wall ≈ harness).
    pub per_request_us: f64,
    /// Spans published into the trace server for the evaluation.
    pub spans: usize,
    /// Simulated model compute per request, ms (trimmed mean of the
    /// record's logical latencies) — the denominator of the overhead
    /// ratio.
    pub sim_compute_ms: f64,
}

/// Throughputs of the optimized hot paths, items/sec.
#[derive(Debug, Clone)]
pub struct ComponentCosts {
    /// File-backed sequential [`EvalDb::put`] records/sec.
    pub put_per_sec: f64,
    /// File-backed batched [`EvalDb::put_all`] records/sec.
    pub put_all_per_sec: f64,
    /// Enabled span start/finish through the sharded [`MemorySink`],
    /// spans/sec.
    pub span_per_sec: f64,
    /// Span *attempts* through a disabled tracer, attempts/sec.
    pub disabled_span_per_sec: f64,
    /// Baseline loop iterations (no tracing call at all), iters/sec — the
    /// no-op harness the disabled tracer is compared against.
    pub noop_per_sec: f64,
    /// p50/p90/p99 query sets against a cached [`SortedSamples`],
    /// queries/sec.
    pub percentile_cached_per_sec: f64,
    /// The same query set through per-call [`percentile`] (clone + sort
    /// each time), queries/sec — reported for the speedup ratio.
    pub percentile_naive_per_sec: f64,
}

/// Everything one self-profiling run learned.
#[derive(Debug, Clone)]
pub struct OverheadReport {
    pub config_requests: usize,
    pub levels: Vec<LevelOverhead>,
    pub components: ComponentCosts,
    /// The platform's bottleneck engine turned on the harness itself:
    /// every measurement phase ran under a wall-clock meta-span and this
    /// is [`profile`] over that timeline.
    pub self_profile: TraceProfile,
}

fn timed(f: impl FnOnce()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// Best-of-`trials` wall seconds of `f`.
fn best_of(trials: usize, mut f: impl FnMut() -> f64) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..trials.max(1) {
        best = best.min(f());
    }
    best
}

fn eval_key(model: &str, system: &str, i: usize) -> EvalKey {
    EvalKey {
        model: format!("{model}_{i}"),
        model_version: "1.0.0".into(),
        framework: "TensorFlow".into(),
        framework_version: "1.15.0".into(),
        system: system.into(),
        device: "gpu".into(),
        scenario: "overhead".into(),
        batch_size: 1,
    }
}

/// A scratch record with a couple of latency samples — small on purpose:
/// the put microbench measures the appender, not JSON volume.
fn scratch_record(i: usize) -> EvalRecord {
    EvalRecord::new(eval_key("overhead_probe", "aws_p3", i), vec![0.010, 0.012], 90.0)
}

/// Fresh scratch directory for the file-backed put microbench. Process-id
/// qualified so concurrent test runs never collide.
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mlms-overhead-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn measure_level(cfg: &OverheadConfig, level: TraceLevel) -> LevelOverhead {
    let mut wall_s = f64::INFINITY;
    let mut spans = 0usize;
    let mut sim_compute_ms = 0.0;
    for _ in 0..cfg.trials.max(1) {
        let server = Server::sim_platform(level);
        let mut job = EvalJob::new(&cfg.model, Scenario::Online { count: cfg.requests });
        job.trace_level = level;
        job.requirements = SystemRequirements::on_system(&cfg.system);
        job.requirements.accelerator = Accelerator::Gpu;
        let t0 = Instant::now();
        let records = server.evaluate(&job).expect("overhead evaluation");
        let wall = t0.elapsed().as_secs_f64();
        if wall < wall_s {
            wall_s = wall;
            spans = records[0]
                .trace_id
                .map(|t| server.traces.timeline(t).spans.len())
                .unwrap_or(0);
            sim_compute_ms = records[0].trimmed_mean_ms();
        }
    }
    LevelOverhead {
        level,
        wall_ms: wall_s * 1e3,
        per_request_us: wall_s * 1e6 / cfg.requests.max(1) as f64,
        spans,
        sim_compute_ms,
    }
}

fn measure_components(cfg: &OverheadConfig) -> ComponentCosts {
    let iters = cfg.iters.max(10);

    // evaldb: sequential puts through the kept-open appender.
    let put_dir = scratch_dir("put");
    let put_s = {
        let db = EvalDb::open(&put_dir).expect("open scratch evaldb");
        let t = timed(|| {
            for i in 0..iters {
                db.put(scratch_record(i));
            }
        });
        assert_eq!(db.dropped_writes(), 0, "scratch puts must not drop writes");
        t
    };
    let _ = std::fs::remove_dir_all(&put_dir);

    // evaldb: the same records through batched put_all (groups of 64).
    let put_all_dir = scratch_dir("put-all");
    let put_all_s = {
        let db = EvalDb::open(&put_all_dir).expect("open scratch evaldb");
        let batches: Vec<Vec<EvalRecord>> = (0..iters)
            .map(scratch_record)
            .collect::<Vec<_>>()
            .chunks(64)
            .map(|c| c.to_vec())
            .collect();
        timed(|| {
            for batch in batches {
                db.put_all(batch).expect("scratch put_all");
            }
        })
    };
    let _ = std::fs::remove_dir_all(&put_all_dir);

    // tracing: enabled start/finish through the sharded memory sink.
    let (tracer_on, sink) = Tracer::in_memory(TraceLevel::Full);
    let span_s = timed(|| {
        let t = tracer_on.new_trace();
        for _ in 0..iters {
            let s = tracer_on.start(t, None, TraceLevel::Model, "overhead_probe").unwrap();
            std::hint::black_box(s).finish();
        }
    });
    assert_eq!(sink.len(), iters, "every enabled span must publish");

    // tracing off: span attempts through a disabled tracer, versus the
    // same loop with no tracing call at all (the no-op harness). These are
    // single-digit-nanosecond operations, so the iteration count is fixed
    // high regardless of `cfg.iters` — a short loop would be timer
    // resolution, not the cost under test. Best-of-3 damps a scheduler
    // preemption landing inside one of the loops.
    const NS_ITERS: usize = 200_000;
    let disabled = Tracer::disabled();
    let disabled_s = best_of(3, || {
        timed(|| {
            for i in 0..NS_ITERS {
                std::hint::black_box(disabled.start(
                    std::hint::black_box(i as u64),
                    None,
                    TraceLevel::Model,
                    "x",
                ));
            }
        })
    });
    let noop_s = best_of(3, || {
        timed(|| {
            for i in 0..NS_ITERS {
                std::hint::black_box(i as u64);
            }
        })
    });

    // metrics: one sorted pass answering many quantiles, versus the
    // clone-and-sort-per-call path.
    let samples: Vec<f64> = (0..10_000).map(|i| ((i * 7919) % 10_000) as f64 / 1e3).collect();
    let queries = iters.min(500);
    let cached = SortedSamples::of(&samples);
    let cached_s = timed(|| {
        for _ in 0..queries {
            std::hint::black_box(cached.p50());
            std::hint::black_box(cached.p90());
            std::hint::black_box(cached.p99());
        }
    });
    let naive_queries = queries.min(100);
    let naive_s = timed(|| {
        for _ in 0..naive_queries {
            std::hint::black_box(percentile(&samples, 50.0));
            std::hint::black_box(percentile(&samples, 90.0));
            std::hint::black_box(percentile(&samples, 99.0));
        }
    });

    let rate = |n: usize, s: f64| if s > 0.0 { n as f64 / s } else { f64::INFINITY };
    ComponentCosts {
        put_per_sec: rate(iters, put_s),
        put_all_per_sec: rate(iters, put_all_s),
        span_per_sec: rate(iters, span_s),
        disabled_span_per_sec: rate(NS_ITERS, disabled_s),
        noop_per_sec: rate(NS_ITERS, noop_s),
        percentile_cached_per_sec: rate(queries * 3, cached_s),
        percentile_naive_per_sec: rate(naive_queries * 3, naive_s),
    }
}

/// Run the full self-profiling suite. Each phase executes under a
/// wall-clock meta-span so the returned report carries the platform's own
/// attribution of where *measurement* time went.
pub fn measure(cfg: &OverheadConfig) -> OverheadReport {
    let meta_sink = MemorySink::new();
    let meta = Tracer::new(TraceLevel::Full, Arc::new(WallClock::new()), meta_sink.clone());
    let trace = meta.new_trace();
    let root = meta.start(trace, None, TraceLevel::Model, "overhead_run").unwrap();
    let root_id = root.id();

    let order = [TraceLevel::None, TraceLevel::Model, TraceLevel::Framework, TraceLevel::Full];
    let mut levels = Vec::with_capacity(order.len());
    for level in order {
        let mut span = meta
            .start(trace, Some(root_id), TraceLevel::Model, format!("eval@{}", level.as_str()))
            .unwrap();
        let lo = measure_level(cfg, level);
        span.tag("stage", "compute");
        span.tag("spans_published", lo.spans.to_string());
        span.finish();
        levels.push(lo);
    }

    let comp_span = meta.start(trace, Some(root_id), TraceLevel::Model, "component_benches");
    let components = measure_components(cfg);
    drop(comp_span);
    root.finish();

    let timeline = Timeline::from_spans(trace, meta_sink.drain());
    let self_profile = profile(&[timeline], 8);

    OverheadReport { config_requests: cfg.requests, levels, components, self_profile }
}

impl OverheadReport {
    /// Per-level overhead table + component throughputs + self-attribution.
    pub fn render(&self) -> String {
        use crate::benchkit::Table;
        let mut out = String::new();
        let mut table = Table::new(
            &format!(
                "harness overhead by trace level ({} simulated requests; wall ≈ harness)",
                self.config_requests
            ),
            &["level", "wall (ms)", "per-request (µs)", "spans", "sim compute (ms/req)"],
        );
        for l in &self.levels {
            table.row(&[
                l.level.as_str().to_string(),
                format!("{:.2}", l.wall_ms),
                format!("{:.1}", l.per_request_us),
                l.spans.to_string(),
                format!("{:.3}", l.sim_compute_ms),
            ]);
        }
        out.push_str(&table.render());
        out.push('\n');

        let c = &self.components;
        let mut comp = Table::new(
            "hot-path component throughput",
            &["component", "items/sec"],
        );
        let fmt = |v: f64| format!("{:.0}", v);
        comp.row(&["evaldb put (file-backed)".into(), fmt(c.put_per_sec)]);
        comp.row(&["evaldb put_all (batch 64)".into(), fmt(c.put_all_per_sec)]);
        comp.row(&["span start/finish (sharded sink)".into(), fmt(c.span_per_sec)]);
        comp.row(&["span attempt (tracing off)".into(), fmt(c.disabled_span_per_sec)]);
        comp.row(&["no-op harness loop".into(), fmt(c.noop_per_sec)]);
        comp.row(&["percentile query (cached sort)".into(), fmt(c.percentile_cached_per_sec)]);
        comp.row(&["percentile query (re-sort)".into(), fmt(c.percentile_naive_per_sec)]);
        out.push_str(&comp.render());
        out.push('\n');
        out.push_str(&self.self_profile.render("the harness profiling itself"));
        out
    }

    /// The invariants every self-profiling run must satisfy. Returns the
    /// first violation as a message (the CLI exits non-zero on it; the
    /// ratchet bench panics on it).
    ///
    /// Wall-time comparisons use generous slack — these are correctness
    /// gates ("reducing the trace level must not make evaluation
    /// meaningfully slower"), not microbenchmark pins; the throughput
    /// floors live in `benches/fig_overhead.rs` where hardware is known.
    pub fn check(&self) -> Result<(), String> {
        let at = |level: TraceLevel| -> &LevelOverhead {
            self.levels.iter().find(|l| l.level == level).expect("level measured")
        };
        // NONE is tracing-off: nothing may be published.
        let none = at(TraceLevel::None);
        if none.spans != 0 {
            return Err(format!("NONE published {} spans; must be 0", none.spans));
        }
        // Span volume is exact and must be monotone in level.
        let (m, f, full) =
            (at(TraceLevel::Model), at(TraceLevel::Framework), at(TraceLevel::Full));
        if m.spans == 0 {
            return Err("MODEL level published no spans".into());
        }
        if !(m.spans <= f.spans && f.spans <= full.spans) {
            return Err(format!(
                "span volume not monotone in level: model {} framework {} full {}",
                m.spans, f.spans, full.spans
            ));
        }
        // Wall-clock overhead monotone-with-slack: each lower level bounded
        // by FULL (1.5x + 30 ms absorbs scheduler noise; a real inversion
        // blows far past it).
        for l in [none, m, f] {
            if l.wall_ms > full.wall_ms * 1.5 + 30.0 {
                return Err(format!(
                    "{} wall {:.1} ms exceeds full {:.1} ms + slack — overhead must be monotone in trace level",
                    l.level.as_str(),
                    l.wall_ms,
                    full.wall_ms
                ));
            }
        }
        // Tracing-off within noise of the no-op harness: a span attempt
        // through a disabled tracer is one branch, so its per-item cost may
        // exceed the empty loop's by at most 75 ns.
        let c = &self.components;
        let disabled_ns = 1e9 / c.disabled_span_per_sec;
        let noop_ns = 1e9 / c.noop_per_sec;
        if disabled_ns > noop_ns + 75.0 {
            return Err(format!(
                "tracing-off span attempt ({disabled_ns:.1} ns) not within noise of no-op harness ({noop_ns:.1} ns)"
            ));
        }
        // The self-profile must actually attribute the run.
        if self.self_profile.spans < self.levels.len() {
            return Err("self-profile missing meta-spans".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_self_profile_passes_its_own_gates() {
        let report = measure(&OverheadConfig::quick());
        report.check().expect("self-profiling invariants");
        assert_eq!(report.levels.len(), 4);
        let text = report.render();
        assert!(text.contains("harness overhead by trace level"));
        assert!(text.contains("evaldb put_all"));
        assert!(text.contains("the harness profiling itself"));
    }

    #[test]
    fn check_rejects_nonzero_spans_at_none() {
        let mut report = measure(&OverheadConfig::quick());
        report.levels[0].spans = 5;
        let err = report.check().unwrap_err();
        assert!(err.contains("NONE"), "{err}");
    }

    #[test]
    fn check_rejects_non_monotone_span_volume() {
        let mut report = measure(&OverheadConfig::quick());
        // Claim MODEL published more spans than FULL.
        report.levels[1].spans = report.levels[3].spans + 100;
        let err = report.check().unwrap_err();
        assert!(err.contains("monotone"), "{err}");
    }
}
