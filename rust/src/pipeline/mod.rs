//! Streaming evaluation pipeline executor (§4.4.2, F6).
//!
//! The model-evaluation pipeline — pre-processing → prediction →
//! post-processing — is composed of *pipeline operators* mapped onto
//! lightweight threads connected by bounded channels. Each operator is a
//! producer-consumer: it receives items from its inbound stream, applies
//! its function, and forwards results downstream. This overlaps input I/O
//! and pre-processing with model compute, which is the paper's F6
//! "efficient evaluation workflow" (the `ablation_pipeline` bench measures
//! streaming vs sequential execution).
//!
//! Tracing hooks are placed automatically around every operator at
//! MODEL level (§4.4.4 "Model-level").

use crate::postprocess::Prediction;
use crate::preprocess::Tensor;
use crate::tracing::{TraceLevel, Tracer};
use crate::util::threadpool::{Channel, Receiver, Sender};
use std::sync::Arc;

/// The payload flowing between operators.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Encoded input (e.g. an image file's bytes).
    Bytes(Vec<u8>),
    /// A decoded/pre-processed tensor.
    Tensor(Tensor),
    /// Final predictions.
    Predictions(Vec<Vec<Prediction>>),
    /// An error annotation; flows to the sink so per-item failures don't
    /// stall the stream.
    Error(String),
}

/// One item moving through the pipeline, with trace identity.
#[derive(Debug, Clone)]
pub struct Envelope {
    /// Position in the input stream (used to verify order preservation).
    pub seq: u64,
    pub trace_id: u64,
    pub parent_span: Option<u64>,
    pub payload: Payload,
}

/// A named pipeline operator.
pub struct Operator {
    pub name: String,
    func: Box<dyn Fn(Payload) -> Payload + Send + Sync>,
}

impl Operator {
    pub fn new(name: &str, func: impl Fn(Payload) -> Payload + Send + Sync + 'static) -> Operator {
        Operator { name: name.to_string(), func: Box::new(func) }
    }

    fn apply(&self, p: Payload) -> Payload {
        // Errors pass through untouched.
        if matches!(p, Payload::Error(_)) {
            return p;
        }
        (self.func)(p)
    }
}

/// Executor configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Bounded-channel capacity between operators (back-pressure depth).
    pub channel_capacity: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { channel_capacity: 8 }
    }
}

/// Run `inputs` through `operators` as a streaming pipeline: one thread per
/// operator, bounded channels between them. Returns outputs in input order.
pub fn run_streaming(
    operators: Vec<Operator>,
    inputs: Vec<Envelope>,
    tracer: &Arc<Tracer>,
    cfg: &PipelineConfig,
) -> Vec<Envelope> {
    assert!(!operators.is_empty(), "pipeline needs at least one operator");
    let n_out = inputs.len();

    // Source channel.
    let (src_tx, mut prev_rx): (Sender<Envelope>, Receiver<Envelope>) =
        Channel::bounded(cfg.channel_capacity);

    let mut handles = Vec::new();
    for op in operators {
        let (tx, rx) = Channel::bounded(cfg.channel_capacity);
        let in_rx = prev_rx;
        prev_rx = rx;
        let tracer = tracer.clone();
        handles.push(std::thread::spawn(move || {
            while let Ok(env) = in_rx.recv() {
                let span = tracer.start(env.trace_id, env.parent_span, TraceLevel::Model, &op.name);
                let payload = op.apply(env.payload);
                if let Some(mut s) = span {
                    s.tag("seq", env.seq.to_string());
                    s.finish();
                }
                if tx.send(Envelope { payload, ..env }).is_err() {
                    break;
                }
            }
            // Sender drops here → downstream channel closes.
        }));
    }

    // Feed inputs from this thread after spawning workers (bounded send
    // would deadlock otherwise).
    let feeder = std::thread::spawn(move || {
        for env in inputs {
            if src_tx.send(env).is_err() {
                break;
            }
        }
    });

    let mut out: Vec<Envelope> = Vec::with_capacity(n_out);
    while let Ok(env) = prev_rx.recv() {
        out.push(env);
    }
    feeder.join().expect("feeder");
    for h in handles {
        h.join().expect("operator thread");
    }
    out
}

/// Run the same operators one item at a time, no overlap — the baseline the
/// `ablation_pipeline` bench compares against.
pub fn run_sequential(
    operators: &[Operator],
    inputs: Vec<Envelope>,
    tracer: &Arc<Tracer>,
) -> Vec<Envelope> {
    inputs
        .into_iter()
        .map(|mut env| {
            for op in operators {
                let span = tracer.start(env.trace_id, env.parent_span, TraceLevel::Model, &op.name);
                env.payload = op.apply(env.payload);
                drop(span);
            }
            env
        })
        .collect()
}

/// Build the standard 3-stage evaluation pipeline from manifest pieces:
/// `preprocess → predict → postprocess` (Fig 3's top row).
pub fn standard_operators(
    pre_steps: Vec<crate::manifest::PreprocessStep>,
    predict: impl Fn(Tensor) -> Result<Tensor, String> + Send + Sync + 'static,
    post_steps: Vec<crate::manifest::PostprocessStep>,
) -> Vec<Operator> {
    vec![
        Operator::new("preprocess", move |p| match p {
            Payload::Bytes(b) => match crate::preprocess::run_pipeline(&pre_steps, &b) {
                Ok(t) => Payload::Tensor(t),
                Err(e) => Payload::Error(format!("preprocess: {e}")),
            },
            Payload::Tensor(t) => Payload::Tensor(t), // already decoded
            other => other,
        }),
        Operator::new("predict", move |p| match p {
            Payload::Tensor(t) => match predict(t) {
                Ok(out) => Payload::Tensor(out),
                Err(e) => Payload::Error(format!("predict: {e}")),
            },
            other => other,
        }),
        Operator::new("postprocess", move |p| match p {
            Payload::Tensor(t) => {
                Payload::Predictions(crate::postprocess::run_pipeline(&post_steps, &t))
            }
            other => other,
        }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn envelopes(n: usize) -> Vec<Envelope> {
        (0..n)
            .map(|i| Envelope {
                seq: i as u64,
                trace_id: 1,
                parent_span: None,
                payload: Payload::Bytes(vec![i as u8]),
            })
            .collect()
    }

    fn add_one_op(name: &str) -> Operator {
        Operator::new(name, |p| match p {
            Payload::Bytes(mut b) => {
                b[0] = b[0].wrapping_add(1);
                Payload::Bytes(b)
            }
            other => other,
        })
    }

    #[test]
    fn streaming_preserves_order_and_applies_all_stages() {
        let tracer = Tracer::disabled();
        let out = run_streaming(
            vec![add_one_op("a"), add_one_op("b"), add_one_op("c")],
            envelopes(50),
            &tracer,
            &PipelineConfig::default(),
        );
        assert_eq!(out.len(), 50);
        for (i, env) in out.iter().enumerate() {
            assert_eq!(env.seq, i as u64, "order preserved");
            match &env.payload {
                Payload::Bytes(b) => assert_eq!(b[0], (i as u8).wrapping_add(3)),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn streaming_overlaps_stages() {
        // Two stages that each sleep 5ms on 8 items: sequential ≈ 80ms,
        // streaming ≈ 45ms. Assert streaming beats 0.8× sequential.
        let mk = || {
            Operator::new("sleep", |p| {
                std::thread::sleep(std::time::Duration::from_millis(5));
                p
            })
        };
        let tracer = Tracer::disabled();
        let t0 = std::time::Instant::now();
        run_streaming(vec![mk(), mk()], envelopes(8), &tracer, &PipelineConfig::default());
        let streaming = t0.elapsed();
        let ops = vec![mk(), mk()];
        let t0 = std::time::Instant::now();
        run_sequential(&ops, envelopes(8), &tracer);
        let sequential = t0.elapsed();
        assert!(
            streaming.as_secs_f64() < sequential.as_secs_f64() * 0.8,
            "streaming {streaming:?} vs sequential {sequential:?}"
        );
    }

    #[test]
    fn errors_flow_through_without_stalling() {
        let fail_on_3 = Operator::new("maybe_fail", |p| match p {
            Payload::Bytes(b) if b[0] == 3 => Payload::Error("boom".into()),
            other => other,
        });
        let count_after = Arc::new(AtomicUsize::new(0));
        let c = count_after.clone();
        let counter = Operator::new("count", move |p| {
            if !matches!(p, Payload::Error(_)) {
                c.fetch_add(1, Ordering::Relaxed);
            }
            p
        });
        let tracer = Tracer::disabled();
        let out = run_streaming(
            vec![fail_on_3, counter],
            envelopes(10),
            &tracer,
            &PipelineConfig::default(),
        );
        assert_eq!(out.len(), 10);
        let errs = out.iter().filter(|e| matches!(e.payload, Payload::Error(_))).count();
        assert_eq!(errs, 1);
        assert_eq!(count_after.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn model_level_spans_recorded_per_operator() {
        let (tracer, sink) = Tracer::in_memory(TraceLevel::Model);
        run_streaming(
            vec![add_one_op("stage1"), add_one_op("stage2")],
            envelopes(4),
            &tracer,
            &PipelineConfig::default(),
        );
        let spans = sink.drain();
        assert_eq!(spans.len(), 8); // 2 operators × 4 items
        assert!(spans.iter().any(|s| s.name == "stage1"));
        assert!(spans.iter().any(|s| s.tag("seq") == Some("3")));
    }

    #[test]
    fn standard_pipeline_end_to_end() {
        let m = crate::manifest::ModelManifest::from_yaml(crate::manifest::model_listing1())
            .unwrap();
        // Identity "model": logits = flattened input prefix of 10 classes.
        let ops = standard_operators(
            m.inputs[0].steps.clone(),
            |t| Ok(Tensor::new(vec![1, 10], t.data[..10].to_vec())),
            m.outputs[0].steps.clone(),
        );
        let img = crate::preprocess::RawImage::synthetic(64, 64, 1).encode();
        let inputs = vec![Envelope {
            seq: 0,
            trace_id: 7,
            parent_span: None,
            payload: Payload::Bytes(img),
        }];
        let tracer = Tracer::disabled();
        let out = run_streaming(ops, inputs, &tracer, &PipelineConfig::default());
        match &out[0].payload {
            Payload::Predictions(p) => {
                assert_eq!(p.len(), 1);
                assert_eq!(p[0].len(), 5);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn sequential_matches_streaming_results() {
        let tracer = Tracer::disabled();
        let s1 = run_streaming(
            vec![add_one_op("a"), add_one_op("b")],
            envelopes(16),
            &tracer,
            &PipelineConfig::default(),
        );
        let ops = vec![add_one_op("a"), add_one_op("b")];
        let s2 = run_sequential(&ops, envelopes(16), &tracer);
        for (a, b) in s1.iter().zip(&s2) {
            match (&a.payload, &b.payload) {
                (Payload::Bytes(x), Payload::Bytes(y)) => assert_eq!(x, y),
                _ => panic!("payload mismatch"),
            }
        }
    }
}
