//! # MLModelScope-RS
//!
//! A scalable DL benchmarking platform — a from-scratch reproduction of
//! *"The Design and Implementation of a Scalable DL Benchmarking Platform"*
//! (Li, Dakkak, Xiong, Hwu; 2019).
//!
//! The crate implements the paper's full platform (Fig. 1):
//!
//! - **specification**: model + framework manifests ([`manifest`]),
//!   versioned with semantic-version constraints ([`util::semver`]);
//! - **distribution**: a TTL'd registry ([`registry`]), a framed RPC wire
//!   protocol with streamed batched prediction ([`wire`]), an HTTP REST
//!   server ([`httpd`]), the MLModelScope server ([`server`]) and agents
//!   ([`agent`]) — batched serving fans out across remote agent processes
//!   with heartbeat-driven membership and exactly-once failover, validated
//!   by a seeded fault-injection harness ([`chaos`]);
//! - **evaluation**: the streaming pipeline executor ([`pipeline`]) running
//!   pre-processing ([`preprocess`]), framework predictors ([`predictor`])
//!   and post-processing ([`postprocess`]) under pluggable benchmarking
//!   scenarios ([`scenario`]) — including the recorded-arrival
//!   `TraceReplay` and sinusoidal-rate `Diurnal` workloads — with
//!   cross-request dynamic batching and load-balanced multi-agent dispatch
//!   ([`batcher`]);
//! - **inspection**: across-stack tracing ([`tracing`]) aggregated by a
//!   trace server ([`traceserver`]), with model/framework/system levels,
//!   and attributed by the bottleneck engine ([`traceanalysis`]) — span
//!   trees with self time, critical-path extraction, multi-run signature
//!   aggregation, and an automated bottleneck verdict — turned on the
//!   platform itself by the self-profiling mode ([`overhead`]), which
//!   quantifies per-request harness cost at every trace level;
//! - **analysis**: the evaluation database ([`evaldb`]) — sharded segment
//!   logs with content-addressed spec digests — the reproducible
//!   model×system sweep engine with digest memoization ([`sweep`]), the
//!   commit-over-commit regression gate — Mann-Whitney + bootstrap deltas
//!   over labeled run lines, with trajectory change-point detection
//!   ([`regress`]) — and the automated analysis + reporting workflow
//!   ([`analysis`]);
//! - **models**: the 37-model zoo of the paper's Table 2 ([`zoo`]) — five
//!   families also exist as *real* JAX/Pallas models AOT-compiled to HLO and
//!   executed through the PJRT runtime ([`runtime`]);
//! - **systems**: roofline models of the paper's Table 1 hardware
//!   ([`sysmodel`]) used to simulate GPU execution (the paper §4.4.4
//!   explicitly supports simulator-published trace times).
//!
//! Python/JAX runs only at build time (`make artifacts`); the `mlms` binary
//! is self-contained afterwards.

pub mod util {
    pub mod cli;
    pub mod fs;
    pub mod json;
    pub mod rng;
    pub mod semver;
    pub mod sha256;
    pub mod sync;
    pub mod threadpool;
    pub mod yamlmini;
}

pub mod benchkit;
pub mod metrics;

pub mod manifest;
pub mod sysmodel;
pub mod zoo;

pub mod postprocess;
pub mod preprocess;

pub mod batcher;
pub mod pipeline;
pub mod scenario;

pub mod traceanalysis;
pub mod tracing;
pub mod traceserver;

pub mod analysis;
pub mod evaldb;
pub mod regress;
pub mod spec;
pub mod sweep;

pub mod dash;

pub mod predictor;
pub mod runtime;

pub mod chaos;
pub mod registry;
pub mod wire;

pub mod agent;

pub mod httpd;
pub mod server;

pub mod slo;

pub mod autoscale;

pub mod overhead;
