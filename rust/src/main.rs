//! `mlms` — the MLModelScope-RS command-line interface (F10).
//!
//! Subcommands mirror the paper's deployment units:
//!
//! - `server`  — run the MLModelScope server (REST API + registry + eval DB)
//! - `agent serve` — run an agent process (wire RPC), optionally joining a
//!   fleet registry with TTL heartbeats and a `--chaos` fault plan
//! - `fleet`   — host a registry, wait for remote agents, run work on them
//!   (`--dash` renders a live ANSI dashboard while work runs)
//! - `eval`    — one-shot evaluation through an in-process platform
//! - `run`     — execute a declarative YAML evaluation spec (`mlms run
//!   spec.yaml`): same engines, same digests, file-shaped
//! - `analyze` — run the analysis workflow over a stored evaluation DB
//! - `zoo`     — list built-in models / systems
//! - `trace`   — render a trace timeline
//! - `slo-search` — latency-bounded throughput search (the SLO frontier)
//! - `sweep`   — memoized, resumable model×system sweep across the fleet
//! - `regress` — commit-over-commit regression gate: labeled sweeps +
//!   Mann-Whitney/bootstrap deltas + trajectory change-point detection
//! - `overhead` — self-profile the harness: per-request overhead by trace
//!   level, hot-path component throughput, and the platform's own
//!   bottleneck attribution turned on itself
//!
//! `eval` is the "push-button" path: it assembles server + agents in one
//! process, evaluates, and prints the analysis — the CLI equivalent of the
//! paper's web-UI flow.

use mlmodelscope::agent::{sim_agent, xla_agent};
use mlmodelscope::manifest::SystemRequirements;
use mlmodelscope::predictor::InputMode;
use mlmodelscope::scenario::Scenario;
use mlmodelscope::server::{EvalJob, Server};
use mlmodelscope::sysmodel::Device;
use mlmodelscope::tracing::TraceLevel;
use mlmodelscope::util::cli::{usage, Args, Command};
use std::sync::Arc;

const COMMANDS: &[Command] = &[
    Command { name: "server", about: "run the MLModelScope server (REST API)" },
    Command {
        name: "agent",
        about: "run an agent process (wire RPC; `serve --registry` joins a fleet)",
    },
    Command {
        name: "fleet",
        about: "host a registry, wait for remote agents, run sweeps/evals on them",
    },
    Command { name: "eval", about: "one-shot evaluation (in-process platform)" },
    Command { name: "run", about: "execute a declarative YAML evaluation spec" },
    Command { name: "analyze", about: "analysis workflow over a stored eval DB" },
    Command { name: "zoo", about: "list built-in models / systems" },
    Command { name: "trace", about: "evaluate with tracing and render the timeline" },
    Command {
        name: "trace-analyze",
        about: "batched evaluation + across-stack bottleneck attribution",
    },
    Command { name: "slo-search", about: "max sustainable QPS under a latency SLO" },
    Command {
        name: "autoscale",
        about: "SLO-driven autoscaling replay: admission control + fleet sizing",
    },
    Command { name: "sweep", about: "memoized model×system sweep across the fleet" },
    Command {
        name: "regress",
        about: "commit-over-commit regression gate (Mann-Whitney + bootstrap CI)",
    },
    Command {
        name: "overhead",
        about: "self-profile the harness: per-request overhead by trace level",
    },
    Command { name: "client", about: "talk to a running mlms server over REST" },
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            print!("{}", usage("mlms", "a scalable DL benchmarking platform", COMMANDS));
            return;
        }
    };
    let args = Args::parse(&rest);
    let code = match cmd {
        "server" => cmd_server(&args),
        "agent" => cmd_agent(&args),
        "fleet" => cmd_fleet(&args),
        "eval" => cmd_eval(&args),
        "run" => cmd_run(&args),
        "analyze" => cmd_analyze(&args),
        "zoo" => cmd_zoo(&args),
        "trace" => cmd_trace(&args),
        "trace-analyze" => cmd_trace_analyze(&args),
        "slo-search" => cmd_slo_search(&args),
        "autoscale" => cmd_autoscale(&args),
        "sweep" => cmd_sweep(&args),
        "regress" => cmd_regress(&args),
        "overhead" => cmd_overhead(&args),
        "client" => cmd_client(&args),
        _ => {
            eprint!("{}", usage("mlms", "a scalable DL benchmarking platform", COMMANDS));
            2
        }
    };
    std::process::exit(code);
}

/// Unwrap a strict-parse result (`Args::try_u64`/`try_f64`/...,
/// [`parse_scenario`]) or print the usage error and exit the command with
/// code 2. Malformed numeric flags must fail loudly, never silently run
/// the default experiment.
macro_rules! cli_try {
    ($e:expr) => {
        match $e {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    };
}

/// Parse `--trace-level`, reporting invalid values as a usage error.
fn parse_trace_level(args: &Args) -> Result<TraceLevel, i32> {
    let raw = args.opt_or("trace-level", "model");
    TraceLevel::parse(raw).ok_or_else(|| {
        eprintln!("invalid --trace-level {raw:?} (none|model|framework|system|full)");
        2
    })
}

/// Build a standalone in-process platform: server + the four Table-1
/// simulated GPU agents (+ CPU agents) + optionally a real XLA agent.
fn build_platform(args: &Args, level: TraceLevel) -> Arc<Server> {
    build_platform_with_db(args, level, None)
}

/// As [`build_platform`], against an explicit (usually file-backed)
/// evaluation database — the persistence that makes `mlms sweep` resumable
/// across process restarts.
fn build_platform_with_db(
    args: &Args,
    level: TraceLevel,
    evaldb: Option<Arc<mlmodelscope::evaldb::EvalDb>>,
) -> Arc<Server> {
    let server = match evaldb {
        Some(db) => Server::new(
            mlmodelscope::registry::Registry::new(),
            db,
            mlmodelscope::traceserver::TraceServer::new(),
        ),
        None => Server::standalone(),
    };
    server.register_zoo();
    for sys in mlmodelscope::sysmodel::table1_system_names() {
        for dev in [Device::Gpu, Device::Cpu] {
            let (agent, _sim, _t) =
                sim_agent(&sys, dev, level, server.evaldb.clone(), server.traces.clone());
            server.attach_local_agent(agent);
        }
    }
    if !args.flag("no-xla") && !mlmodelscope::runtime::available_families().is_empty() {
        match mlmodelscope::runtime::Runtime::cpu() {
            Ok(rt) => {
                let (agent, _t) =
                    xla_agent(rt, level, server.evaldb.clone(), server.traces.clone());
                server.attach_local_agent(agent);
            }
            Err(e) => eprintln!("warning: PJRT unavailable: {e}"),
        }
    }
    server
}

/// Parse `--scenario` + its per-kind numeric flags strictly: a malformed
/// value (`--count 1O`, `--timestamps 0,abc`) or an unknown scenario name
/// is a usage error naming the offending token, never a silent default.
fn parse_scenario(args: &Args) -> Result<Scenario, String> {
    Ok(match args.opt_or("scenario", "online") {
        "online" => Scenario::Online { count: args.try_usize("count", 16)? },
        "batched" => Scenario::Batched {
            batch_size: args.try_usize("batch", 8)?,
            batches: args.try_usize("batches", 4)?,
        },
        "poisson" => Scenario::Poisson {
            rate: args.try_f64("rate", 20.0)?,
            count: args.try_usize("count", 32)?,
        },
        "fixed_qps" => Scenario::FixedQps {
            qps: args.try_f64("qps", 10.0)?,
            count: args.try_usize("count", 32)?,
        },
        "burst" => Scenario::Burst {
            burst_size: args.try_usize("burst-size", 8)?,
            period_s: args.try_f64("period", 1.0)?,
            bursts: args.try_usize("bursts", 4)?,
        },
        "diurnal" => Scenario::Diurnal {
            peak_qps: args.try_f64("peak-qps", 100.0)?,
            trough_qps: args.try_f64("trough-qps", 10.0)?,
            period_s: args.try_f64("period", 60.0)?,
            count: args.try_usize("count", 32)?,
        },
        // `--timestamps 0.0,0.01,0.5,...` — replay a recorded arrival log.
        "trace_replay" => {
            let timestamps = args.try_list_f64("timestamps")?;
            if timestamps.is_empty() {
                return Err("--timestamps must list at least one arrival time".to_string());
            }
            Scenario::TraceReplay { timestamps }
        }
        // MLPerf inference modes (MLHarness grammar).
        "single_stream" => Scenario::SingleStream { count: args.try_usize("count", 32)? },
        "multi_stream" => Scenario::MultiStream {
            streams: args.try_usize("streams", 8)?,
            period_s: args.try_f64("period", 0.05)?,
            intervals: args.try_usize("intervals", 8)?,
        },
        "server" => Scenario::Server {
            qps: args.try_f64("qps", 100.0)?,
            count: args.try_usize("count", 256)?,
        },
        "offline" => Scenario::Offline { count: args.try_usize("count", 256)? },
        other => {
            return Err(format!(
                "unknown --scenario {other:?} (online|batched|poisson|fixed_qps|burst|diurnal|\
                 trace_replay|single_stream|multi_stream|server|offline)"
            ))
        }
    })
}

fn cmd_server(args: &Args) -> i32 {
    let level = match parse_trace_level(args) {
        Ok(l) => l,
        Err(code) => return code,
    };
    let server = build_platform(args, level);
    let addr = args.opt_or("listen", "127.0.0.1:8080");
    match mlmodelscope::httpd::HttpServer::serve(addr, server.router()) {
        Ok(http) => {
            println!("mlms server listening on http://{}", http.addr());
            println!("  GET  /api/models /api/agents /api/systems");
            println!("  POST /api/evaluate");
            println!("  GET  /api/analyze?models=a,b  /api/report?models=a,b  /api/trace/:id");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            1
        }
    }
}

/// `mlms agent [serve]` — run one agent process serving the wire RPC.
///
/// Fleet mode: `--registry <host:port>` makes the agent self-register with
/// a remote registry (served by `mlms fleet` or any
/// [`mlmodelscope::registry::registry_service`]) and keep its lease alive
/// with TTL heartbeats (`--ttl-secs`, `--heartbeat-ms`). A lease that
/// lapses (or a registry restart) triggers re-registration under a fresh
/// id. `--chaos <plan>` + `--chaos-seed` install a seeded fault plan at the
/// wire layer (see [`mlmodelscope::chaos`]); a `kill` fault exits the
/// process — a real agent crash, observable by the whole fleet.
fn cmd_agent(args: &Args) -> i32 {
    match args.positional.first().map(|s| s.as_str()) {
        None | Some("serve") => {}
        Some(other) => {
            eprintln!("unknown agent action {other:?} (only `serve`)");
            return 2;
        }
    }
    let system = args.opt_or("system", "aws_p3").to_string();
    let db_path = args.opt_or("evaldb", "").to_string();
    let evaldb = Arc::new(if db_path.is_empty() {
        mlmodelscope::evaldb::EvalDb::in_memory()
    } else {
        match mlmodelscope::evaldb::EvalDb::open(&db_path) {
            Ok(db) => db,
            Err(e) => {
                eprintln!("open {db_path}: {e}");
                return 1;
            }
        }
    });
    let sink = mlmodelscope::traceserver::TraceServer::new();
    let level = match parse_trace_level(args) {
        Ok(l) => l,
        Err(code) => return code,
    };
    let agent = if system == "local" {
        match mlmodelscope::runtime::Runtime::cpu() {
            Ok(rt) => xla_agent(rt, level, evaldb, sink).0,
            Err(e) => {
                eprintln!("PJRT: {e}");
                return 1;
            }
        }
    } else {
        let device = match args.opt_or("device", "gpu") {
            "cpu" => Device::Cpu,
            _ => Device::Gpu,
        };
        sim_agent(&system, device, level, evaldb, sink).0
    };
    let chaos = match args.opt("chaos") {
        Some(spec) => {
            let chaos_seed = cli_try!(args.try_u64("chaos-seed", 0));
            match mlmodelscope::chaos::FaultPlan::parse(spec, chaos_seed) {
                Ok(plan) => {
                    eprintln!("chaos plan armed: {spec} (seed {})", plan.seed);
                    Some(mlmodelscope::chaos::ChaosEngine::new(plan))
                }
                Err(e) => {
                    eprintln!("invalid --chaos: {e}");
                    return 2;
                }
            }
        }
        None => None,
    };
    if let Some(engine) = &chaos {
        // A kill fault is a process death, not a polite shutdown.
        engine.on_kill(|| {
            eprintln!("chaos: kill fault fired — agent process exiting");
            std::process::exit(137);
        });
    }
    let addr = args.opt_or("listen", "127.0.0.1:0");
    // Wire-layer tuning: `--wire-workers N` sizes the request-execution
    // pool behind the readiness loop, `--wire-queue N` its dispatch queue
    // (the back-pressure bound on queued-but-unexecuted requests).
    let mut wire_opts = mlmodelscope::wire::WireOpts::default();
    wire_opts.workers =
        cli_try!(args.try_u64("wire-workers", wire_opts.workers as u64)).max(1) as usize;
    wire_opts.queue_capacity =
        cli_try!(args.try_u64("wire-queue", wire_opts.queue_capacity as u64)).max(64) as usize;
    let rpc = match mlmodelscope::wire::RpcServer::serve_with_opts(
        addr,
        mlmodelscope::agent::agent_service(agent.clone()),
        chaos.clone(),
        wire_opts,
    ) {
        Ok(rpc) => rpc,
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            return 1;
        }
    };
    println!("mlms agent ({system}) serving wire RPC on {}", rpc.addr());
    if let Some(registry_addr) = args.opt("registry") {
        let ttl_secs = cli_try!(args.try_f64("ttl-secs", 10.0)).max(0.1);
        let beat_default = ((ttl_secs * 1e3) as u64 / 4).max(100);
        let beat_ms = cli_try!(args.try_u64("heartbeat-ms", beat_default));
        let interval = std::time::Duration::from_millis(beat_ms);
        let registry_addr = registry_addr.to_string();
        let endpoint = rpc.addr().to_string();
        let agent = agent.clone();
        let chaos = chaos.clone();
        std::thread::spawn(move || {
            heartbeat_loop(registry_addr, agent, endpoint, ttl_secs, interval, chaos)
        });
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Keep one agent's registry lease alive: register (fresh id), then beat
/// every `interval`. A failed or expired beat falls back to registration —
/// re-registration after expiry always yields a fresh id. A chaos plan can
/// drop or delay beats (`drop:heartbeat:N`, `delay:heartbeat:MS`) so
/// membership-failure scenarios are injectable without touching the wire.
fn heartbeat_loop(
    registry_addr: String,
    agent: Arc<mlmodelscope::agent::Agent>,
    endpoint: String,
    ttl_secs: f64,
    interval: std::time::Duration,
    chaos: Option<Arc<mlmodelscope::chaos::ChaosEngine>>,
) {
    use mlmodelscope::chaos::FaultAction;
    use mlmodelscope::util::json::Json;
    loop {
        let client = match mlmodelscope::wire::RpcClient::connect(registry_addr.as_str()) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("registry {registry_addr}: connect failed ({e}); retrying");
                std::thread::sleep(std::time::Duration::from_millis(500));
                continue;
            }
        };
        client.set_read_timeout(Some(std::time::Duration::from_secs(5)));
        let mut info = agent.info(&endpoint).to_json();
        if let Json::Obj(map) = &mut info {
            map.insert("ttl_secs".into(), Json::num(ttl_secs));
        }
        let id = match client.call("register_agent", info) {
            Ok(resp) => resp.str_or("id", "").to_string(),
            Err(e) => {
                eprintln!("registry {registry_addr}: register failed ({e}); retrying");
                std::thread::sleep(std::time::Duration::from_millis(500));
                continue;
            }
        };
        if id.is_empty() {
            eprintln!("registry {registry_addr}: no id assigned; retrying");
            std::thread::sleep(std::time::Duration::from_millis(500));
            continue;
        }
        agent.adopt_id(&id);
        println!("registered with {registry_addr} as {id} (ttl {ttl_secs}s)");
        loop {
            std::thread::sleep(interval);
            if let Some(engine) = &chaos {
                match engine.decide("heartbeat") {
                    FaultAction::Pass => {}
                    FaultAction::Delay(ms) => {
                        std::thread::sleep(std::time::Duration::from_millis(ms))
                    }
                    // Skip this beat; enough skips and the lease lapses.
                    FaultAction::Drop | FaultAction::Kill => continue,
                }
            }
            let beat = client.call(
                "heartbeat",
                Json::obj(vec![
                    ("id", Json::str(&id)),
                    ("ttl_secs", Json::num(ttl_secs)),
                ]),
            );
            match beat {
                Ok(Json::Bool(true)) => {}
                Ok(_) => {
                    eprintln!("lease {id} expired; re-registering");
                    break;
                }
                Err(e) => {
                    eprintln!("heartbeat for {id} failed ({e}); reconnecting");
                    break;
                }
            }
        }
    }
}

fn cmd_eval(args: &Args) -> i32 {
    let model = match args.require("model") {
        Ok(m) => m.to_string(),
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let level = match parse_trace_level(args) {
        Ok(l) => l,
        Err(code) => return code,
    };
    let scenario = cli_try!(parse_scenario(args));
    let server = build_platform(args, level);
    let mut job = EvalJob::new(&model, scenario);
    job.trace_level = level;
    job.input_mode = InputMode::parse(args.opt_or("input-mode", "c"));
    job.seed = cli_try!(args.try_u64("seed", 42));
    job.all_agents = args.flag("all-agents");
    if let Some(sys) = args.opt("system") {
        job.requirements = SystemRequirements::on_system(sys);
    }
    if let Some(acc) = args.opt("accelerator") {
        job.requirements.accelerator = mlmodelscope::manifest::Accelerator::parse(acc);
    }
    match server.evaluate(&job) {
        Ok(records) => {
            for r in &records {
                println!(
                    "{} on {} [{}] batch={}: trimmed-mean {:.3} ms, p90 {:.3} ms, throughput {:.1} items/s",
                    r.key.model,
                    r.key.system,
                    r.key.device,
                    r.key.batch_size,
                    r.trimmed_mean_ms(),
                    r.p90_ms(),
                    r.throughput,
                );
            }
            println!("{}", server.report(&[model]));
            0
        }
        Err(e) => {
            eprintln!("evaluation failed: {e}");
            1
        }
    }
}

/// `mlms run <spec.yaml>` — execute a declarative evaluation spec through
/// the same engines the flag-driven subcommands use. The spec resolves to
/// the exact [`sweep::Plan`](mlmodelscope::sweep::Plan) the flags would
/// build, so every cell's content-addressed `EvalSpec` digest — and its
/// memoization line in the eval DB — is identical between the two
/// front-ends: `mlms run nightly.yaml` against a store already populated
/// by `mlms sweep` re-executes nothing.
///
/// ```sh
/// mlms run examples/specs/quickstart.yaml --evaldb sweep_db
/// ```
fn cmd_run(args: &Args) -> i32 {
    use mlmodelscope::spec::{EvalSpecFile, RunKind};
    let path = match args.positional.first() {
        Some(p) => p.to_string(),
        None => {
            eprintln!("usage: mlms run <spec.yaml> [--evaldb <path>]");
            return 2;
        }
    };
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("read {path}: {e}");
            return 1;
        }
    };
    let spec = match EvalSpecFile::parse(&text) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{path}: {e}");
            return 2;
        }
    };
    println!("spec {path} [{}] digest {}", spec.kind.as_str(), spec.digest());
    let evaldb = match args.opt("evaldb") {
        Some(p) => match mlmodelscope::evaldb::EvalDb::open(p) {
            Ok(db) => Some(Arc::new(db)),
            Err(e) => {
                eprintln!("open {p}: {e}");
                return 1;
            }
        },
        None => None,
    };
    match spec.kind {
        RunKind::Eval | RunKind::Sweep => {
            let plan = spec.to_plan();
            let server = build_platform_with_db(args, spec.trace_level, evaldb);
            let outcome = mlmodelscope::sweep::run(&server, &plan);
            println!("{}", outcome.summary());
            for (cell, err) in &outcome.failed {
                eprintln!("  failed {}: {err}", cell.label());
            }
            println!(
                "{}",
                mlmodelscope::analysis::model_system_matrix(&plan.models, &server.evaldb)
                    .render()
            );
            if outcome.failed.is_empty() {
                0
            } else {
                1
            }
        }
        RunKind::Regress => {
            use mlmodelscope::evaldb::RunMeta;
            use mlmodelscope::regress::{compare_labels, GateConfig, Verdict};
            let block = spec.regress.clone().expect("schema guarantees a regress block");
            let mut plan = spec.to_plan();
            let server = build_platform_with_db(args, spec.trace_level, evaldb);
            for label in [&block.control, &block.treatment] {
                plan.run_meta = RunMeta::labeled(label);
                let outcome = mlmodelscope::sweep::run(&server, &plan);
                println!("{label}: {}", outcome.summary());
                for (cell, err) in &outcome.failed {
                    eprintln!("  failed {}: {err}", cell.label());
                }
                if !outcome.failed.is_empty() {
                    return 1;
                }
            }
            let cfg = GateConfig {
                alpha: block.alpha,
                min_effect: block.min_effect,
                ..GateConfig::default()
            };
            let cmp = compare_labels(&server.evaldb, &block.control, &block.treatment, &cfg);
            match mlmodelscope::analysis::regression_section(&cmp) {
                Some(section) => println!("{section}"),
                None => println!(
                    "no cell measured under both {:?} and {:?}",
                    block.control, block.treatment
                ),
            }
            for m in &cmp.missing {
                eprintln!("  unpaired: {m}");
            }
            let flagged = cmp.cells.iter().filter(|c| c.verdict == Verdict::Regression).count();
            if flagged > 0 {
                eprintln!("regression gate FAILED: {flagged} regression(s)");
                1
            } else {
                println!("regression gate passed: {} cell(s) clean", cmp.cells.len());
                0
            }
        }
        RunKind::SloSearch => {
            use mlmodelscope::slo::{
                search_max_qps, store_frontier_point, SloSearchConfig, SloSpec,
            };
            let block = spec.slo.clone().unwrap_or_default();
            let cfg = spec
                .dispatch
                .clone()
                .unwrap_or_else(|| mlmodelscope::batcher::BatcherConfig::new(8, 5.0));
            let sc = SloSearchConfig {
                start_qps: block.start_qps,
                probe_count: block.probe_count,
                max_probes: block.max_probes,
                ..SloSearchConfig::default()
            };
            let server = build_platform_with_db(args, TraceLevel::None, evaldb);
            for model in &spec.models {
                let mut job = EvalJob::new(model, Scenario::Online { count: 1 });
                job.seed = spec.seed;
                // The frontier is searched on the spec's first system.
                if let Some(sys) = spec.systems.first() {
                    job.requirements = SystemRequirements::on_system(sys);
                }
                job.requirements.accelerator = spec.accelerator;
                for bound in &block.bounds_ms {
                    let slo = SloSpec::new(block.percentile, *bound);
                    match search_max_qps(&server, &job, &cfg, slo, &sc) {
                        Ok(point) => {
                            println!(
                                "{} {}: max {:.1} qps (achieved {:.2} ms, {} probes)",
                                model,
                                slo.label(),
                                point.max_qps,
                                point.achieved_ms,
                                point.probes.len()
                            );
                            store_frontier_point(&server, &point);
                        }
                        Err(e) => {
                            eprintln!("slo-search failed: {e}");
                            return 1;
                        }
                    }
                }
            }
            println!(
                "{}",
                mlmodelscope::analysis::slo_frontier_table(&spec.models, &server.evaldb).render()
            );
            0
        }
        RunKind::Autoscale => {
            use mlmodelscope::autoscale::{run_autoscaled_sim, AutoscaleConfig, ServiceModel};
            use mlmodelscope::scenario::Workload;
            use mlmodelscope::slo::SloSpec;
            let block = spec.autoscale.clone().unwrap_or_default();
            let workload = Workload::generate(&spec.scenario, spec.seed);
            let cfg = spec
                .dispatch
                .clone()
                .unwrap_or_else(|| mlmodelscope::batcher::BatcherConfig::new(8, 2.0));
            let slo = SloSpec::new(block.percentile, block.bound_ms);
            let acfg = AutoscaleConfig {
                min_agents: block.min_agents,
                max_agents: block.max_agents,
                interval_s: block.interval_s,
                cooldown_s: block.cooldown_s,
                spawn_delay_s: block.spawn_delay_s,
                ..AutoscaleConfig::default()
            };
            let svc = ServiceModel {
                base_s: block.service_base_ms * 1e-3,
                per_item_s: block.service_item_ms * 1e-3,
            };
            let adm = spec.admission.clone().unwrap_or_default();
            let initial = block.agents.unwrap_or(block.min_agents);
            let autoscale = !block.fixed;
            let report =
                run_autoscaled_sim(&workload, &cfg, &adm, slo, &acfg, &svc, initial, autoscale);
            println!(
                "{} requests offered, {} completed, {} shed — fleet {} -> {} (peak {})",
                workload.requests.len(),
                report.completed,
                report.shed.total_shed(),
                initial,
                report.final_agents,
                report.peak_agents,
            );
            for e in &report.events {
                println!("  t={:7.2}s  {} -> {} agents  ({})", e.at_s, e.from, e.to, e.reason);
            }
            for (tenant, row) in &report.shed.rows {
                println!(
                    "  tenant {tenant} ({}): offered {} admitted {} shed {} (rate {}, deadline {})",
                    row.priority,
                    row.offered,
                    row.admitted,
                    row.shed_total(),
                    row.shed_rate_limited,
                    row.shed_deadline,
                );
            }
            println!(
                "{}: achieved p{:.0} {:.2} ms vs bound {:.1} ms [{}]",
                if autoscale { "autoscaled" } else { "static" },
                slo.percentile,
                report.achieved_ms,
                slo.bound_ms,
                if report.passed { "SLO MET" } else { "SLO VIOLATED" },
            );
            if report.passed {
                0
            } else {
                1
            }
        }
    }
}

fn cmd_analyze(args: &Args) -> i32 {
    let db_path = args.opt_or("evaldb", "");
    if db_path.is_empty() {
        eprintln!("--evaldb <path> required (a .jsonl log or a sharded segment directory)");
        return 2;
    }
    let db = match mlmodelscope::evaldb::EvalDb::open(db_path) {
        Ok(db) => db,
        Err(e) => {
            eprintln!("open {db_path}: {e}");
            return 1;
        }
    };
    let models: Vec<String> = if args.opt("models").is_some() {
        args.list("models")
    } else {
        mlmodelscope::zoo::all().iter().map(|m| m.name.clone()).collect()
    };
    println!("{}", mlmodelscope::analysis::full_report(&models, &db));
    if let Some(dir) = args.opt("out-dir") {
        match mlmodelscope::analysis::write_report_dir(&models, &db, std::path::Path::new(dir)) {
            Ok(()) => println!("report artifacts written to {dir}/"),
            Err(e) => {
                eprintln!("write {dir}: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_zoo(args: &Args) -> i32 {
    if args.positional.first().map(|s| s.as_str()) == Some("systems") {
        let mut t = mlmodelscope::benchkit::Table::new(
            "Table 1 — systems",
            &["Name", "CPU", "GPU", "Arch", "TFLOPs", "Mem BW (GB/s)", "$/hr"],
        );
        for p in mlmodelscope::sysmodel::systems().values() {
            t.row(&[
                p.name.clone(),
                p.cpu_name.clone(),
                p.gpu_name.clone(),
                p.gpu_architecture.clone(),
                format!("{:.1}", p.gpu_tflops),
                format!("{:.0}", p.gpu_mem_bw_gbs),
                format!("{:.2}", p.cost_per_hr),
            ]);
        }
        println!("{}", t.render());
        return 0;
    }
    let mut t = mlmodelscope::benchkit::Table::new(
        "built-in model zoo (Table 2 metadata)",
        &["ID", "Name", "Top-1 Acc", "Graph (MB)", "Input", "Family", "HLO artifact"],
    );
    for m in mlmodelscope::zoo::all() {
        t.row(&[
            m.id.to_string(),
            m.name.clone(),
            format!("{:.2}", m.top1_accuracy),
            format!("{}", m.graph_size_mb),
            format!("{0}x{0}", m.resolution),
            m.family.to_string(),
            m.hlo_family().unwrap_or("-").to_string(),
        ]);
    }
    println!("{}", t.render());
    0
}

fn cmd_trace(args: &Args) -> i32 {
    let model = args.opt_or("model", "BVLC_AlexNet").to_string();
    let server = build_platform(args, TraceLevel::Full);
    let mut job = EvalJob::new(&model, Scenario::Online { count: 1 });
    job.trace_level = TraceLevel::Full;
    if let Some(sys) = args.opt("system") {
        job.requirements = SystemRequirements::on_system(sys);
        job.requirements.accelerator = mlmodelscope::manifest::Accelerator::Gpu;
    } else {
        job.requirements = SystemRequirements::gpu();
    }
    match server.evaluate(&job) {
        Ok(records) => {
            let trace_id = records[0].trace_id.unwrap_or(0);
            let tl = server.traces.timeline(trace_id);
            println!("{}", tl.render());
            println!(
                "{}",
                mlmodelscope::analysis::layer_kernel_table(&tl, cli_try!(args.try_usize("top", 5)))
                    .render()
            );
            let (total, fast) = mlmodelscope::analysis::layer_population(&tl);
            println!("{total} layers, {fast} under 1 ms");
            0
        }
        Err(e) => {
            eprintln!("trace failed: {e}");
            1
        }
    }
}

/// Across-stack bottleneck attribution: run a model through batched
/// dispatch N times, aggregate the serving-stack traces (batching /
/// queueing / service) and — at `framework`+ levels — the model-execution
/// traces, and print self-time attribution, the top contributors, and the
/// automated bottleneck verdict.
///
/// ```sh
/// mlms trace-analyze --model ResNet_v1_50 --runs 3 --rate 500 --count 128 \
///     --batch 8 --wait-ms 5 --trace-level full --top 8
/// ```
fn cmd_trace_analyze(args: &Args) -> i32 {
    use mlmodelscope::batcher::BatcherConfig;
    let model = match args.require("model") {
        Ok(m) => m.to_string(),
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // Attribution wants the whole stack by default; `--trace-level` still
    // narrows it.
    let raw_level = args.opt_or("trace-level", "full");
    let level = match TraceLevel::parse(raw_level) {
        Some(TraceLevel::None) | None => {
            eprintln!("invalid --trace-level {raw_level:?} (model|framework|system|full — attribution needs spans)");
            return 2;
        }
        Some(l) => l,
    };
    let server = build_platform(args, level);
    let runs = cli_try!(args.try_usize("runs", 3)).max(1);
    let mut cfg = BatcherConfig::new(
        cli_try!(args.try_usize("batch", 8)),
        cli_try!(args.try_f64("wait-ms", 5.0)),
    );
    cfg.fair = args.flag("fair");
    // Default workload: a Poisson stream brisk enough that queueing and
    // batching actually show up in the attribution.
    let scenario = if args.opt("scenario").is_some() {
        cli_try!(parse_scenario(args))
    } else {
        Scenario::Poisson {
            rate: cli_try!(args.try_f64("rate", 500.0)),
            count: cli_try!(args.try_usize("count", 128)),
        }
    };
    let base_seed = cli_try!(args.try_u64("seed", 42));
    let top = cli_try!(args.try_usize("top", 8));
    let mut serving = Vec::new();
    let mut sessions = Vec::new();
    for run in 0..runs {
        let mut job = EvalJob::new(&model, scenario.clone());
        job.trace_level = level;
        job.seed = base_seed.wrapping_add(run as u64);
        if let Some(sys) = args.opt("system") {
            job.requirements = SystemRequirements::on_system(sys);
        }
        match server.evaluate_batched(&job, &cfg) {
            Ok(out) => {
                if let Some(tid) = out.serving_trace_id {
                    serving.push(server.traces.timeline(tid));
                }
                for tid in &out.session_trace_ids {
                    let tl = server.traces.timeline(*tid);
                    if !tl.is_empty() {
                        sessions.push(tl);
                    }
                }
            }
            Err(e) => {
                eprintln!("trace-analyze failed: {e}");
                return 1;
            }
        }
    }
    if serving.is_empty() {
        eprintln!("no serving trace captured");
        return 1;
    }
    let profile = mlmodelscope::traceanalysis::profile(&serving, top);
    println!(
        "{}",
        profile.render(&format!("{model} serving stack, {runs} run(s) (batching / queueing / compute)"))
    );
    if !sessions.is_empty() {
        let deep = mlmodelscope::traceanalysis::profile(&sessions, top);
        println!(
            "{}",
            deep.render(&format!("{model} model execution ({} agent session(s))", sessions.len()))
        );
    }
    0
}

/// SLO-driven benchmarking: find the maximum sustainable QPS for a model
/// under one or more latency bounds and print the frontier table.
///
/// ```sh
/// mlms slo-search --model ResNet_v1_50 --bounds-ms 50,20,10,5 \
///     --percentile 99 --batch 8 --wait-ms 5 --count 256 --start-qps 50
/// ```
fn cmd_slo_search(args: &Args) -> i32 {
    use mlmodelscope::batcher::BatcherConfig;
    use mlmodelscope::slo::{search_max_qps, store_frontier_point, SloSearchConfig, SloSpec};
    let model = match args.require("model") {
        Ok(m) => m.to_string(),
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let server = build_platform(args, TraceLevel::None);
    let mut job = EvalJob::new(&model, Scenario::Online { count: 1 });
    job.seed = cli_try!(args.try_u64("seed", 42));
    if let Some(sys) = args.opt("system") {
        job.requirements = SystemRequirements::on_system(sys);
    }
    if let Some(acc) = args.opt("accelerator") {
        job.requirements.accelerator = mlmodelscope::manifest::Accelerator::parse(acc);
    }
    let mut cfg = BatcherConfig::new(
        cli_try!(args.try_usize("batch", 8)),
        cli_try!(args.try_f64("wait-ms", 5.0)),
    );
    cfg.fair = args.flag("fair");
    let sc = SloSearchConfig {
        start_qps: cli_try!(args.try_f64("start-qps", 50.0)),
        probe_count: cli_try!(args.try_usize("count", 256)),
        max_probes: cli_try!(args.try_usize("max-probes", 24)),
        ..SloSearchConfig::default()
    };
    let percentile = cli_try!(args.try_f64("percentile", 99.0));
    let bounds: Vec<f64> = if args.opt("bounds-ms").is_some() {
        let mut parsed = Vec::new();
        for raw in args.list("bounds-ms") {
            match raw.parse::<f64>() {
                Ok(b) if b > 0.0 => parsed.push(b),
                _ => {
                    eprintln!("invalid --bounds-ms entry {raw:?} (positive ms expected)");
                    return 2;
                }
            }
        }
        parsed
    } else {
        vec![50.0, 20.0, 10.0, 5.0]
    };
    if bounds.is_empty() {
        eprintln!("--bounds-ms must list at least one latency bound");
        return 2;
    }
    for bound in bounds {
        let spec = SloSpec::new(percentile, bound);
        match search_max_qps(&server, &job, &cfg, spec, &sc) {
            Ok(point) => {
                println!(
                    "{} {}: max {:.1} qps (achieved {:.2} ms, {} probes)",
                    model,
                    spec.label(),
                    point.max_qps,
                    point.achieved_ms,
                    point.probes.len()
                );
                store_frontier_point(&server, &point);
            }
            Err(e) => {
                eprintln!("slo-search failed: {e}");
                return 1;
            }
        }
    }
    println!(
        "{}",
        mlmodelscope::analysis::slo_frontier_table(&[model], &server.evaldb).render()
    );
    0
}

/// `mlms autoscale` — run a workload through admission control + batching
/// + the virtual-time queueing replay with the SLO-driven autoscale
/// control loop in the loop, and print what the controller did. The replay
/// is deterministic and runs at simulation speed, so `--scenario server
/// --qps 1000000` is cheap to explore.
///
/// ```sh
/// mlms autoscale --scenario diurnal --peak-qps 2000 --trough-qps 200 \
///     --count 20000 --bound-ms 10 --max-agents 8
/// mlms autoscale --static --agents 2 ...   # fixed-fleet baseline
/// ```
///
/// `--low-rate`/`--low-burst`/`--low-deadline-ms` attach a rate-limited
/// best-effort policy to tenant 1 (the second `Mix` tenant), showing
/// priority admission: overload sheds the low tenant, never the high one.
fn cmd_autoscale(args: &Args) -> i32 {
    use mlmodelscope::autoscale::{run_autoscaled_sim, AutoscaleConfig, ServiceModel};
    use mlmodelscope::batcher::admission::{AdmissionConfig, TenantPolicy};
    use mlmodelscope::batcher::{BatcherConfig, Priority};
    use mlmodelscope::scenario::Workload;
    use mlmodelscope::slo::SloSpec;

    let scenario = cli_try!(parse_scenario(args));
    let workload = Workload::generate(&scenario, cli_try!(args.try_u64("seed", 42)));
    let mut cfg = BatcherConfig::new(
        cli_try!(args.try_usize("batch", 8)),
        cli_try!(args.try_f64("wait-ms", 2.0)),
    );
    cfg.fair = args.flag("fair");
    let spec = SloSpec::new(
        cli_try!(args.try_f64("percentile", 99.0)),
        cli_try!(args.try_f64("bound-ms", 10.0)),
    );
    let acfg = AutoscaleConfig {
        min_agents: cli_try!(args.try_usize("min-agents", 1)),
        max_agents: cli_try!(args.try_usize("max-agents", 8)),
        interval_s: cli_try!(args.try_f64("interval", 0.5)),
        cooldown_s: cli_try!(args.try_f64("cooldown", 1.0)),
        spawn_delay_s: cli_try!(args.try_f64("spawn-delay", 0.25)),
        ..AutoscaleConfig::default()
    };
    let svc = ServiceModel {
        base_s: cli_try!(args.try_f64("service-base-ms", 1.0)) * 1e-3,
        per_item_s: cli_try!(args.try_f64("service-item-ms", 0.4)) * 1e-3,
    };
    let mut adm = AdmissionConfig::default();
    if args.opt("low-rate").is_some() || args.opt("low-deadline-ms").is_some() {
        let rate_per_s = match args.opt("low-rate") {
            Some(_) => Some(cli_try!(args.try_f64("low-rate", 500.0))),
            None => None,
        };
        let queue_deadline_ms = match args.opt("low-deadline-ms") {
            Some(_) => Some(cli_try!(args.try_f64("low-deadline-ms", 50.0))),
            None => None,
        };
        adm = adm.with_tenant(
            1,
            TenantPolicy {
                priority: Priority::Low,
                rate_per_s,
                burst: cli_try!(args.try_f64("low-burst", 64.0)),
                queue_deadline_ms,
            },
        );
    }
    let initial = cli_try!(args.try_usize("agents", acfg.min_agents));
    let autoscale = !args.flag("static");
    let report =
        run_autoscaled_sim(&workload, &cfg, &adm, spec, &acfg, &svc, initial, autoscale);

    println!(
        "{} requests offered, {} completed, {} shed — fleet {} -> {} (peak {})",
        workload.requests.len(),
        report.completed,
        report.shed.total_shed(),
        initial,
        report.final_agents,
        report.peak_agents,
    );
    for e in &report.events {
        println!("  t={:7.2}s  {} -> {} agents  ({})", e.at_s, e.from, e.to, e.reason);
    }
    for (tenant, row) in &report.shed.rows {
        println!(
            "  tenant {tenant} ({}): offered {} admitted {} shed {} (rate {}, deadline {})",
            row.priority,
            row.offered,
            row.admitted,
            row.shed_total(),
            row.shed_rate_limited,
            row.shed_deadline,
        );
    }
    println!(
        "{}: achieved p{:.0} {:.2} ms vs bound {:.1} ms [{}]",
        if autoscale { "autoscaled" } else { "static" },
        spec.percentile,
        report.achieved_ms,
        spec.bound_ms,
        if report.passed { "SLO MET" } else { "SLO VIOLATED" },
    );
    if report.passed {
        0
    } else {
        1
    }
}

/// Reproducible fleet-wide sweep: the cross-product of models × systems ×
/// scenario × batch sizes, executed with spec-digest memoization against
/// the evaluation database. Re-running the identical invocation skips
/// every cell already measured — interrupted sweeps resume for free when
/// `--evaldb` points at a persistent store.
///
/// ```sh
/// mlms sweep --models ResNet_v1_50,VGG16 --systems aws_p3,ibm_p8 \
///     --batches 1,8,32 --count 16 --evaldb sweep_db --seed 42
/// ```
///
/// Defaults reproduce the paper's §5.1 case study: all 37 zoo models on
/// the four Table-1 systems. `--dispatch` routes single-item cells through
/// the cross-request batcher (`--batch`, `--wait-ms`, `--fair`);
/// `--compact` runs latest-wins compaction on the store afterwards.
/// Parse the sweep-plan options shared by `mlms sweep` and `mlms fleet
/// sweep`. Returns a usage error message on invalid input.
fn build_sweep_plan(args: &Args, level: TraceLevel) -> Result<mlmodelscope::sweep::Plan, String> {
    use mlmodelscope::batcher::BatcherConfig;
    use mlmodelscope::sweep::Plan;
    let models: Vec<String> = if args.opt("models").is_some() {
        args.list("models")
    } else {
        mlmodelscope::zoo::names()
    };
    let systems: Vec<String> = if args.opt("systems").is_some() {
        args.list("systems")
    } else {
        mlmodelscope::sysmodel::table1_system_names()
    };
    let batch_sizes: Vec<usize> = if args.opt("batches").is_some() {
        let mut parsed = Vec::new();
        for raw in args.list("batches") {
            match raw.parse::<usize>() {
                Ok(b) if b >= 1 => parsed.push(b),
                _ => {
                    return Err(format!(
                        "invalid --batches entry {raw:?} (positive integer expected)"
                    ))
                }
            }
        }
        parsed
    } else {
        vec![1, 8]
    };
    if models.is_empty() || systems.is_empty() || batch_sizes.is_empty() {
        return Err("--models, --systems and --batches must each be non-empty".to_string());
    }
    let mut plan = Plan::new(models, systems);
    plan.batch_sizes = batch_sizes;
    plan.scenarios = vec![parse_scenario(args)?];
    plan.trace_level = level;
    plan.seed = args.try_u64("seed", 42)?;
    plan.parallelism = args.try_usize("jobs", 4)?;
    let acc = args.opt_or("accelerator", "gpu");
    if !["cpu", "gpu", "fpga", "any"].iter().any(|k| acc.eq_ignore_ascii_case(k)) {
        return Err(format!("invalid --accelerator {acc:?} (cpu|gpu|fpga|any)"));
    }
    plan.accelerator = mlmodelscope::manifest::Accelerator::parse(acc);
    if args.flag("dispatch") {
        let mut cfg = BatcherConfig::new(
            args.try_usize("batch", 8)?,
            args.try_f64("wait-ms", 5.0)?,
        );
        cfg.fair = args.flag("fair");
        plan.dispatch = Some(cfg);
    }
    Ok(plan)
}

fn cmd_sweep(args: &Args) -> i32 {
    use mlmodelscope::sweep::run;
    let raw_level = args.opt_or("trace-level", "none");
    let level = match TraceLevel::parse(raw_level) {
        Some(l) => l,
        None => {
            eprintln!("invalid --trace-level {raw_level:?} (none|model|framework|system|full)");
            return 2;
        }
    };
    let evaldb = match args.opt("evaldb") {
        Some(p) => match mlmodelscope::evaldb::EvalDb::open(p) {
            Ok(db) => Some(Arc::new(db)),
            Err(e) => {
                eprintln!("open {p}: {e}");
                return 1;
            }
        },
        None => None,
    };
    let plan = match build_sweep_plan(args, level) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let server = build_platform_with_db(args, level, evaldb);
    let outcome = run(&server, &plan);
    println!("{}", outcome.summary());
    for (cell, err) in &outcome.failed {
        eprintln!("  failed {}: {err}", cell.label());
    }
    println!(
        "{}",
        mlmodelscope::analysis::model_system_matrix(&plan.models, &server.evaldb).render()
    );
    if args.flag("compact") {
        match server.evaldb.compact() {
            Ok(st) => println!(
                "compaction: scanned {}, retained {}, dropped {}",
                st.scanned, st.retained, st.dropped
            ),
            Err(e) => {
                eprintln!("compact: {e}");
                return 1;
            }
        }
    }
    if outcome.failed.is_empty() {
        0
    } else {
        1
    }
}

/// `mlms regress --control <label> --treatment <label>` — the
/// commit-over-commit regression gate: sweep the model×system matrix under
/// both run labels (each label is its own memoization line, so re-gating a
/// commit that was already measured re-executes nothing), then judge every
/// paired cell with the Mann-Whitney + bootstrap gate and exit non-zero if
/// any cell regresses.
///
/// ```sh
/// mlms regress --control v1.4.0 --treatment HEAD \
///     --models ResNet_v1_50,VGG16 --systems aws_p3 --batches 1,8 \
///     --evaldb regress_db --alpha 0.01 --min-effect 0.05 \
///     --trajectory bench_history.json
/// ```
///
/// `--trajectory <file>` additionally appends each cell's treatment median
/// to a stored `BENCH_*.json`-style history and fails on a step change
/// landing within the last `--cp-window` points — the slow-regression
/// backstop the pairwise gate cannot see.
/// `mlms overhead` — benchmark the benchmarker: measure per-request harness
/// overhead vs. simulated model compute at every trace level, run the
/// hot-path component microbenches, and attribute the run with the
/// platform's own bottleneck engine. Exits non-zero if any self-profiling
/// invariant fails (span volume monotone in level, NONE publishes nothing,
/// tracing-off within noise of a no-op harness).
fn cmd_overhead(args: &Args) -> i32 {
    use mlmodelscope::overhead::{measure, OverheadConfig};
    let mut cfg = if args.flag("quick") {
        OverheadConfig::quick()
    } else {
        OverheadConfig::default()
    };
    cfg.model = args.opt_or("model", &cfg.model).to_string();
    cfg.system = args.opt_or("system", &cfg.system).to_string();
    cfg.requests = cli_try!(args.try_usize("requests", cfg.requests));
    cfg.trials = cli_try!(args.try_usize("trials", cfg.trials));
    cfg.iters = cli_try!(args.try_usize("iters", cfg.iters));
    if cfg.requests == 0 || cfg.trials == 0 {
        eprintln!("--requests and --trials must be positive");
        return 2;
    }
    let report = measure(&cfg);
    print!("{}", report.render());
    match report.check() {
        Ok(()) => {
            println!(
                "overhead gates passed: NONE publishes 0 spans, span volume monotone in level, tracing-off within noise of no-op."
            );
            0
        }
        Err(e) => {
            eprintln!("overhead gate FAILED: {e}");
            1
        }
    }
}

fn cmd_regress(args: &Args) -> i32 {
    use mlmodelscope::evaldb::RunMeta;
    use mlmodelscope::regress::{compare_labels, GateConfig, Trajectory, Verdict};
    use mlmodelscope::sweep::run;
    let (control, treatment) = match (args.require("control"), args.require("treatment")) {
        (Ok(c), Ok(t)) => (c.to_string(), t.to_string()),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if control == treatment {
        eprintln!("--control and --treatment must name different run lines");
        return 2;
    }
    let raw_level = args.opt_or("trace-level", "none");
    let level = match TraceLevel::parse(raw_level) {
        Some(l) => l,
        None => {
            eprintln!("invalid --trace-level {raw_level:?} (none|model|framework|system|full)");
            return 2;
        }
    };
    let evaldb = match args.opt("evaldb") {
        Some(p) => match mlmodelscope::evaldb::EvalDb::open(p) {
            Ok(db) => Some(Arc::new(db)),
            Err(e) => {
                eprintln!("open {p}: {e}");
                return 1;
            }
        },
        None => None,
    };
    let mut plan = match build_sweep_plan(args, level) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let server = build_platform_with_db(args, level, evaldb);
    // Measure both run lines. A label already in the store memoizes whole.
    for label in [&control, &treatment] {
        plan.run_meta = RunMeta::labeled(label);
        let outcome = run(&server, &plan);
        println!("{label}: {}", outcome.summary());
        for (cell, err) in &outcome.failed {
            eprintln!("  failed {}: {err}", cell.label());
        }
        if !outcome.failed.is_empty() {
            return 1;
        }
    }
    let cfg = GateConfig {
        alpha: cli_try!(args.try_f64("alpha", 0.01)),
        min_effect: cli_try!(args.try_f64("min-effect", 0.05)),
        bootstrap_resamples: cli_try!(args.try_usize("resamples", 400)).max(1),
        bootstrap_seed: cli_try!(args.try_u64("bootstrap-seed", 42)),
        cp_penalty: cli_try!(args.try_f64("cp-penalty", 8.0)),
        ..GateConfig::default()
    };
    let cmp = compare_labels(&server.evaldb, &control, &treatment, &cfg);
    match mlmodelscope::analysis::regression_section(&cmp) {
        Some(section) => println!("{section}"),
        None => println!("no cell measured under both {control:?} and {treatment:?}"),
    }
    for m in &cmp.missing {
        eprintln!("  unpaired: {m}");
    }
    // Extend the stored trajectory and gate on recently-landed steps.
    let mut step_changes = 0;
    if let Some(path) = args.opt("trajectory") {
        let mut traj = match Trajectory::load(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("load {path}: {e}");
                return 1;
            }
        };
        for cell in &cmp.cells {
            traj.record(&cell.cell, &treatment, cell.treatment_median_ms);
        }
        if let Err(e) = traj.save(path) {
            eprintln!("write {path}: {e}");
            return 1;
        }
        for (cell, idx, label) in
            traj.recent_changepoints(cli_try!(args.try_usize("cp-window", 3)), &cfg)
        {
            eprintln!("step change in {cell} at {label} (trajectory index {idx})");
            step_changes += 1;
        }
    }
    let regressions = cmp.cells.iter().filter(|c| c.verdict == Verdict::Regression).count();
    if regressions > 0 || step_changes > 0 {
        eprintln!("regression gate FAILED: {regressions} regression(s), {step_changes} step change(s)");
        1
    } else {
        println!("regression gate passed: {} cell(s) clean", cmp.cells.len());
        0
    }
}

/// `mlms fleet [sweep|eval|agents]` — the distributed-serving controller:
/// host the registry (+ eval DB + zoo) in this process, serve it over the
/// wire so `mlms agent serve --registry` processes can join, wait for
/// `--expect-agents` members, then run work across them.
///
/// ```sh
/// # terminal 1 — the controller
/// mlms fleet sweep --listen-registry 127.0.0.1:7700 --expect-agents 3 \
///     --models ResNet_v1_50,VGG16 --systems aws_p3 --batches 1 \
///     --scenario poisson --rate 2000 --count 64 --dispatch --batch 8
/// # terminals 2..4 — the agents
/// mlms agent serve --system aws_p3 --registry 127.0.0.1:7700 --ttl-secs 5
/// ```
///
/// Dispatch fans each batched evaluation across every live member;
/// heartbeat-driven membership plus the dispatcher's exactly-once requeue
/// and the sweep's retry-once failover mean a member lost mid-run costs
/// nothing but the failover (see `tests/fleet_failover.rs`).
fn cmd_fleet(args: &Args) -> i32 {
    use mlmodelscope::registry::registry_service;
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("sweep");
    if !matches!(action, "sweep" | "eval" | "agents") {
        eprintln!("unknown fleet action {action:?} (sweep|eval|agents)");
        return 2;
    }
    let raw_level = args.opt_or("trace-level", "none");
    let level = match TraceLevel::parse(raw_level) {
        Some(l) => l,
        None => {
            eprintln!("invalid --trace-level {raw_level:?} (none|model|framework|system|full)");
            return 2;
        }
    };
    let evaldb = match args.opt("evaldb") {
        Some(p) => match mlmodelscope::evaldb::EvalDb::open(p) {
            Ok(db) => Arc::new(db),
            Err(e) => {
                eprintln!("open {p}: {e}");
                return 1;
            }
        },
        None => Arc::new(mlmodelscope::evaldb::EvalDb::in_memory()),
    };
    // The fleet server has no local agents unless asked: the point is the
    // remote members.
    let server = if args.flag("with-local") {
        build_platform_with_db(args, level, Some(evaldb))
    } else {
        let s = Server::new(
            mlmodelscope::registry::Registry::new(),
            evaldb,
            mlmodelscope::traceserver::TraceServer::new(),
        );
        s.register_zoo();
        s
    };
    let listen = args.opt_or("listen-registry", "127.0.0.1:7700");
    // The registry serves every member's register/heartbeat traffic on the
    // multiplexed loop; `--wire-workers`/`--wire-queue` tune it the same
    // way they tune `mlms agent serve`.
    let mut wire_opts = mlmodelscope::wire::WireOpts::default();
    wire_opts.workers =
        cli_try!(args.try_u64("wire-workers", wire_opts.workers as u64)).max(1) as usize;
    wire_opts.queue_capacity =
        cli_try!(args.try_u64("wire-queue", wire_opts.queue_capacity as u64)).max(64) as usize;
    let registry_rpc = match mlmodelscope::wire::RpcServer::serve_with_opts(
        listen,
        registry_service(server.registry.clone()),
        None,
        wire_opts,
    ) {
        Ok(rpc) => rpc,
        Err(e) => {
            eprintln!("bind registry {listen}: {e}");
            return 1;
        }
    };
    println!(
        "fleet registry on {} — join with: mlms agent serve --registry {}",
        registry_rpc.addr(),
        registry_rpc.addr()
    );
    let expect = cli_try!(args.try_usize("expect-agents", 1));
    let wait_deadline = std::time::Instant::now()
        + std::time::Duration::from_secs_f64(cli_try!(args.try_f64("wait-secs", 60.0)));
    loop {
        let joined = server.registry.agents().len();
        if joined >= expect {
            break;
        }
        if std::time::Instant::now() > wait_deadline {
            eprintln!("fleet: only {joined}/{expect} agent(s) joined within the wait window");
            return 1;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let members = server.registry.agents();
    println!(
        "fleet: {} member(s): {}",
        members.len(),
        members
            .iter()
            .map(|a| format!("{}@{} [{}]", a.id, a.endpoint, a.system))
            .collect::<Vec<_>>()
            .join(", ")
    );
    // `--dash` renders the fleet dashboard while the action runs: lease
    // state per member, dispatcher queue depth, sweep progress, tenant tail
    // latencies. `--once` prints a single plain frame and skips the redraw
    // thread — the headless/CI form.
    let dash = if args.flag("dash") {
        if args.flag("once") {
            print!("{}", mlmodelscope::dash::render(&server.registry, &server.gauges));
            None
        } else {
            Some(mlmodelscope::dash::LiveDash::spawn(
                server.registry.clone(),
                server.gauges.clone(),
                std::time::Duration::from_millis(250),
            ))
        }
    } else {
        None
    };
    let code = match action {
        "agents" => 0,
        "eval" => {
            let model = match args.require("model") {
                Ok(m) => m.to_string(),
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let mut job = EvalJob::new(&model, cli_try!(parse_scenario(args)));
            job.trace_level = level;
            job.seed = cli_try!(args.try_u64("seed", 42));
            job.all_agents = args.flag("all-agents");
            if let Some(sys) = args.opt("system") {
                job.requirements = SystemRequirements::on_system(sys);
            }
            if args.flag("dispatch") {
                let mut cfg = mlmodelscope::batcher::BatcherConfig::new(
                    cli_try!(args.try_usize("batch", 8)),
                    cli_try!(args.try_f64("wait-ms", 5.0)),
                );
                cfg.fair = args.flag("fair");
                match server.evaluate_batched(&job, &cfg) {
                    Ok(result) => {
                        let r = &result.record;
                        println!(
                            "{} on {} via {} agent(s): p90 {:.3} ms, throughput {:.1} items/s, {} requeue(s)",
                            r.key.model,
                            r.key.system,
                            result.record.meta.f64_or("agents", 0.0),
                            r.p90_ms(),
                            r.throughput,
                            result.outcome.requeued_batches,
                        );
                        0
                    }
                    Err(e) => {
                        eprintln!("fleet eval failed: {e}");
                        1
                    }
                }
            } else {
                match server.evaluate(&job) {
                    Ok(records) => {
                        for r in &records {
                            println!(
                                "{} on {} [{}]: trimmed-mean {:.3} ms, throughput {:.1} items/s",
                                r.key.model,
                                r.key.system,
                                r.key.device,
                                r.trimmed_mean_ms(),
                                r.throughput,
                            );
                        }
                        0
                    }
                    Err(e) => {
                        eprintln!("fleet eval failed: {e}");
                        1
                    }
                }
            }
        }
        // Default: a memoized sweep executed by the remote members.
        _ => {
            let plan = match build_sweep_plan(args, level) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let outcome = mlmodelscope::sweep::run(&server, &plan);
            println!("{}", outcome.summary());
            for (cell, err) in &outcome.failed {
                eprintln!("  failed {}: {err}", cell.label());
            }
            println!(
                "{}",
                mlmodelscope::analysis::model_system_matrix(&plan.models, &server.evaldb)
                    .render()
            );
            if outcome.failed.is_empty() {
                0
            } else {
                1
            }
        }
    };
    if let Some(d) = dash {
        d.stop();
    }
    registry_rpc.stop();
    code
}

/// The REST client (§4.2): the command-line counterpart of the web UI,
/// driving a *remote* mlms server. Subactions (first positional):
/// `models`, `agents`, `systems`, `evaluate`, `analyze`, `report`, `trace`.
fn cmd_client(args: &Args) -> i32 {
    use mlmodelscope::httpd::http_request;
    use mlmodelscope::util::json::Json;
    let addr: std::net::SocketAddr = match args.opt_or("server", "127.0.0.1:8080").parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bad --server address: {e}");
            return 2;
        }
    };
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("models");
    let result = match action {
        "models" => http_request(addr, "GET", "/api/models", None),
        "agents" => http_request(addr, "GET", "/api/agents", None),
        "systems" => http_request(addr, "GET", "/api/systems", None),
        "analyze" => http_request(
            addr,
            "GET",
            &format!("/api/analyze?models={}", args.opt_or("models", "")),
            None,
        ),
        "report" => http_request(
            addr,
            "GET",
            &format!("/api/report?models={}", args.opt_or("models", "")),
            None,
        ),
        "trace" => http_request(
            addr,
            "GET",
            &format!("/api/trace/{}", args.opt_or("id", "0")),
            None,
        ),
        "evaluate" => {
            let model = match args.require("model") {
                Ok(m) => m.to_string(),
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let payload = Json::obj(vec![
                ("model", Json::str(model)),
                ("scenario", cli_try!(parse_scenario(args)).to_json()),
                ("trace_level", Json::str(args.opt_or("trace-level", "model"))),
                ("all_agents", Json::Bool(args.flag("all-agents"))),
            ]);
            http_request(addr, "POST", "/api/evaluate", Some(&payload))
        }
        other => {
            eprintln!("unknown client action {other:?} (models|agents|systems|evaluate|analyze|report|trace)");
            return 2;
        }
    };
    match result {
        Ok((status, body)) => {
            println!("{}", body.to_pretty());
            if (200..300).contains(&status) {
                0
            } else {
                eprintln!("server returned HTTP {status}");
                1
            }
        }
        Err(e) => {
            eprintln!("request failed: {e} (is `mlms server` running at {addr}?)");
            1
        }
    }
}
