//! The distributed registry (§4.5.1).
//!
//! A TTL'd key-value store holding registered model manifests and running
//! agents. The server uses it to discover models, solve user constraints
//! during agent resolution (§4.3 step 3), and load-balance requests across
//! agents. It is dynamic: agents heartbeat their entries and disappear when
//! the TTL lapses; manifests can be added/removed at runtime (§4.6).
//!
//! The store itself is in-process (the consul/etcd substitute); it is also
//! exposed over [`crate::wire`] so separate agent processes can register —
//! see [`registry_service`].

use crate::manifest::{Accelerator, ModelManifest, SystemRequirements};
use crate::util::json::Json;
use crate::util::semver::Version;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A registered agent's advertisement: its HW/SW stack + built-in models
/// (published during the paper's initialization workflow, step ①).
#[derive(Debug, Clone)]
pub struct AgentInfo {
    /// Unique agent id (assigned at registration).
    pub id: String,
    /// RPC endpoint (`host:port`) the server dispatches to; empty for
    /// in-process agents.
    pub endpoint: String,
    /// Framework name/version of the agent's predictor.
    pub framework: String,
    pub framework_version: Version,
    /// System profile name (a Table-1 row or `local`).
    pub system: String,
    /// CPU architecture (`x86_64`, `ppc64le`, ...).
    pub architecture: String,
    /// Device classes offered: `cpu`, `gpu`, `fpga`.
    pub devices: Vec<String>,
    pub interconnect: String,
    pub host_memory_gb: f64,
    pub device_memory_gb: f64,
    /// Model names this agent can evaluate.
    pub models: Vec<String>,
}

impl AgentInfo {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(&self.id)),
            ("endpoint", Json::str(&self.endpoint)),
            ("framework", Json::str(&self.framework)),
            ("framework_version", Json::str(self.framework_version.to_string())),
            ("system", Json::str(&self.system)),
            ("architecture", Json::str(&self.architecture)),
            ("devices", Json::arr(self.devices.iter().map(Json::str).collect())),
            ("interconnect", Json::str(&self.interconnect)),
            ("host_memory_gb", Json::num(self.host_memory_gb)),
            ("device_memory_gb", Json::num(self.device_memory_gb)),
            ("models", Json::arr(self.models.iter().map(Json::str).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Option<AgentInfo> {
        Some(AgentInfo {
            id: j.get("id")?.as_str()?.to_string(),
            endpoint: j.str_or("endpoint", "").to_string(),
            framework: j.str_or("framework", "").to_string(),
            framework_version: j.str_or("framework_version", "0.0.0").parse().ok()?,
            system: j.str_or("system", "local").to_string(),
            architecture: j.str_or("architecture", "x86_64").to_string(),
            devices: j
                .get("devices")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|d| d.as_str()).map(String::from).collect())
                .unwrap_or_default(),
            interconnect: j.str_or("interconnect", "none").to_string(),
            host_memory_gb: j.f64_or("host_memory_gb", 0.0),
            device_memory_gb: j.f64_or("device_memory_gb", 0.0),
            models: j
                .get("models")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|d| d.as_str()).map(String::from).collect())
                .unwrap_or_default(),
        })
    }
}

struct Entry<T> {
    value: T,
    expires: Option<Instant>,
}

/// The registry. Thread-safe; cheap to clone via `Arc`.
pub struct Registry {
    agents: Mutex<BTreeMap<String, Entry<AgentInfo>>>,
    manifests: Mutex<BTreeMap<String, Entry<ModelManifest>>>,
    next_agent: AtomicU64,
    /// Round-robin cursor for load balancing.
    rr: AtomicU64,
    /// Agents parked in standby: registered and heartbeating, but excluded
    /// from [`Registry::resolve`] until the autoscaler
    /// ([`crate::autoscale`]) wakes them. Warm capacity without serving
    /// traffic.
    standby: Mutex<std::collections::BTreeSet<String>>,
}

impl Registry {
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry {
            agents: Mutex::new(BTreeMap::new()),
            manifests: Mutex::new(BTreeMap::new()),
            next_agent: AtomicU64::new(1),
            rr: AtomicU64::new(0),
            standby: Mutex::new(std::collections::BTreeSet::new()),
        })
    }

    /// Register an agent with a TTL; returns the assigned id. The agent
    /// must re-register (heartbeat) within the TTL to stay visible.
    pub fn register_agent(&self, mut info: AgentInfo, ttl: Option<Duration>) -> String {
        if info.id.is_empty() {
            info.id = format!("agent-{}", self.next_agent.fetch_add(1, Ordering::Relaxed));
        }
        let id = info.id.clone();
        self.agents.lock().unwrap().insert(
            id.clone(),
            Entry { value: info, expires: ttl.map(|t| Instant::now() + t) },
        );
        id
    }

    /// Heartbeat: extend an agent's TTL. Returns false if it had expired.
    ///
    /// Extension is **monotone**: a heartbeat can only push the lease out,
    /// never pull it in. A beat carrying a shorter TTL than the time already
    /// remaining leaves the lease untouched (and a TTL-less in-process
    /// agent stays TTL-less) — otherwise a stale or misconfigured beat
    /// could shrink a healthy agent's lease out from under in-flight work.
    pub fn heartbeat(&self, id: &str, ttl: Duration) -> bool {
        let mut agents = self.agents.lock().unwrap();
        match agents.get_mut(id) {
            Some(e) if e.expires.map_or(true, |t| t > Instant::now()) => {
                let candidate = Instant::now() + ttl;
                e.expires = match e.expires {
                    None => None,
                    Some(current) => Some(current.max(candidate)),
                };
                true
            }
            _ => {
                agents.remove(id);
                false
            }
        }
    }

    /// Time left on an agent's lease: `None` when the id is unknown,
    /// `Duration::MAX` for TTL-less (in-process) agents, zero once the
    /// lease has lapsed but the entry has not yet been swept.
    pub fn lease_remaining(&self, id: &str) -> Option<Duration> {
        let agents = self.agents.lock().unwrap();
        agents.get(id).map(|e| match e.expires {
            None => Duration::MAX,
            Some(t) => t.saturating_duration_since(Instant::now()),
        })
    }

    pub fn deregister_agent(&self, id: &str) {
        self.agents.lock().unwrap().remove(id);
        self.standby.lock().unwrap().remove(id);
    }

    /// Park or wake an agent. A standby agent keeps its registration and
    /// lease but is skipped by [`Registry::resolve`], so the fleet can hold
    /// warm spare capacity the autoscaler brings in under load. Returns
    /// false when the id is unknown or its lease lapsed.
    pub fn set_standby(&self, id: &str, standby: bool) -> bool {
        if !self.is_live(id) {
            self.standby.lock().unwrap().remove(id);
            return false;
        }
        let mut set = self.standby.lock().unwrap();
        if standby {
            set.insert(id.to_string());
        } else {
            set.remove(id);
        }
        true
    }

    pub fn is_standby(&self, id: &str) -> bool {
        self.standby.lock().unwrap().contains(id)
    }

    /// Live agents currently parked in standby.
    pub fn standby_agents(&self) -> Vec<String> {
        let live: std::collections::BTreeSet<String> =
            self.agents().into_iter().map(|a| a.id).collect();
        self.standby
            .lock()
            .unwrap()
            .iter()
            .filter(|id| live.contains(*id))
            .cloned()
            .collect()
    }

    /// Live agents (expired entries are swept on read).
    pub fn agents(&self) -> Vec<AgentInfo> {
        let now = Instant::now();
        let mut agents = self.agents.lock().unwrap();
        agents.retain(|_, e| e.expires.map_or(true, |t| t > now));
        agents.values().map(|e| e.value.clone()).collect()
    }

    /// One consistent membership snapshot for the fleet dashboard: every
    /// live agent with its remaining lease (`Duration::MAX` for TTL-less
    /// in-process agents) and standby state, under a single sweep instead
    /// of one lock round-trip per row.
    pub fn lease_table(&self) -> Vec<(AgentInfo, Duration, bool)> {
        let now = Instant::now();
        let mut agents = self.agents.lock().unwrap();
        agents.retain(|_, e| e.expires.map_or(true, |t| t > now));
        let standby = self.standby.lock().unwrap();
        agents
            .values()
            .map(|e| {
                let lease = match e.expires {
                    None => Duration::MAX,
                    Some(t) => t.saturating_duration_since(now),
                };
                (e.value.clone(), lease, standby.contains(&e.value.id))
            })
            .collect()
    }

    /// Register a model manifest (F5: keyed `name:version`).
    pub fn register_manifest(&self, m: ModelManifest) {
        self.manifests
            .lock()
            .unwrap()
            .insert(m.key(), Entry { value: m, expires: None });
    }

    pub fn manifest(&self, name: &str, version: Option<&str>) -> Option<ModelManifest> {
        let manifests = self.manifests.lock().unwrap();
        match version {
            Some(v) => manifests.get(&format!("{name}:{v}")).map(|e| e.value.clone()),
            None => manifests
                .iter()
                .filter(|(k, _)| k.starts_with(&format!("{name}:")))
                .map(|(_, e)| e.value.clone())
                .max_by_key(|m| m.version),
        }
    }

    pub fn manifest_names(&self) -> Vec<String> {
        self.manifests.lock().unwrap().keys().cloned().collect()
    }

    pub fn remove_manifest(&self, key: &str) {
        self.manifests.lock().unwrap().remove(key);
    }

    /// Agent resolution (§4.3 step 3): agents satisfying the model's
    /// framework constraint + the user's system requirements, that also
    /// advertise the model (or are wildcard agents with no model list).
    pub fn resolve(
        &self,
        manifest: &ModelManifest,
        req: &SystemRequirements,
    ) -> Vec<AgentInfo> {
        let standby = self.standby.lock().unwrap().clone();
        self.agents()
            .into_iter()
            .filter(|a| {
                // Standby agents hold warm capacity but take no traffic
                // until the autoscaler wakes them.
                if standby.contains(&a.id) {
                    return false;
                }
                // Framework name + version constraint.
                let fw_ok = manifest.framework_constraint.is_any()
                    && (manifest.framework_name.is_empty() || manifest.framework_name == a.framework)
                    || (manifest.framework_name == a.framework
                        && manifest.framework_constraint.matches(a.framework_version));
                // Wildcard frameworks (e.g. the simulator advertises the
                // paper's TensorFlow models) match by model list instead.
                let fw_ok = fw_ok || a.models.contains(&manifest.name);
                if !fw_ok {
                    return false;
                }
                if !a.models.is_empty() && !a.models.contains(&manifest.name) {
                    return false;
                }
                // System requirements.
                match req.accelerator {
                    Accelerator::Any => {}
                    acc => {
                        if !a.devices.iter().any(|d| d == acc.as_str()) {
                            return false;
                        }
                    }
                }
                if let Some(arch) = &req.architecture {
                    if arch != &a.architecture {
                        return false;
                    }
                }
                if let Some(ic) = &req.interconnect {
                    if ic != &a.interconnect {
                        return false;
                    }
                }
                if let Some(mem) = req.min_memory_gb {
                    if a.host_memory_gb < mem {
                        return false;
                    }
                }
                if let Some(mem) = req.min_device_memory_gb {
                    if a.device_memory_gb < mem {
                        return false;
                    }
                }
                if let Some(sys) = &req.system_name {
                    if sys != &a.system {
                        return false;
                    }
                }
                true
            })
            .collect()
    }

    /// Is this agent still registered with an unexpired TTL? Expired
    /// entries are dropped on the spot (expire on read, not only on sweep)
    /// so a lapsed heartbeat can never win a selection race.
    pub fn is_live(&self, id: &str) -> bool {
        let mut agents = self.agents.lock().unwrap();
        let live = match agents.get(id) {
            Some(e) => e.expires.map_or(true, |t| t > Instant::now()),
            None => return false,
        };
        if !live {
            agents.remove(id);
        }
        live
    }

    /// Pick one resolved agent round-robin (load balancing across agents).
    ///
    /// Candidates whose TTL lapsed *after* resolution are filtered here —
    /// resolution results can be arbitrarily stale by the time dispatch
    /// happens, and dispatching to a dead agent costs a full connect
    /// timeout. Returns `None` when no candidate is still live.
    pub fn pick(&self, candidates: &[AgentInfo]) -> Option<AgentInfo> {
        let live: Vec<&AgentInfo> =
            candidates.iter().filter(|c| self.is_live(&c.id)).collect();
        if live.is_empty() {
            return None;
        }
        let i = self.rr.fetch_add(1, Ordering::Relaxed) as usize % live.len();
        Some(live[i].clone())
    }
}

/// Expose a registry over the wire protocol (methods: `register_agent`,
/// `heartbeat`, `agents`, `register_manifest`, `manifest_names`).
pub fn registry_service(registry: Arc<Registry>) -> Arc<dyn crate::wire::Service> {
    Arc::new(move |method: &str, params: &Json| -> Result<Json, String> {
        match method {
            "register_agent" => {
                let info = AgentInfo::from_json(params).ok_or("bad agent info")?;
                let ttl = params.get("ttl_secs").and_then(|v| v.as_f64());
                let id = registry.register_agent(info, ttl.map(Duration::from_secs_f64));
                Ok(Json::obj(vec![("id", Json::str(id))]))
            }
            "heartbeat" => {
                let id = params.str_or("id", "");
                let ttl = Duration::from_secs_f64(params.f64_or("ttl_secs", 10.0));
                Ok(Json::Bool(registry.heartbeat(id, ttl)))
            }
            "deregister_agent" => {
                registry.deregister_agent(params.str_or("id", ""));
                Ok(Json::Null)
            }
            "agents" => Ok(Json::arr(registry.agents().iter().map(|a| a.to_json()).collect())),
            "register_manifest" => {
                let m = ModelManifest::from_json(params).map_err(|e| e.to_string())?;
                registry.register_manifest(m);
                Ok(Json::Null)
            }
            "manifest_names" => {
                Ok(Json::arr(registry.manifest_names().iter().map(Json::str).collect()))
            }
            "set_standby" => {
                let id = params.str_or("id", "");
                let standby = params.get("standby").and_then(Json::as_bool).unwrap_or(true);
                Ok(Json::Bool(registry.set_standby(id, standby)))
            }
            other => Err(format!("unknown registry method {other:?}")),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn agent(system: &str, devices: &[&str], arch: &str, models: &[&str]) -> AgentInfo {
        AgentInfo {
            id: String::new(),
            endpoint: String::new(),
            framework: "TensorFlow".into(),
            framework_version: "1.15.0".parse().unwrap(),
            system: system.into(),
            architecture: arch.into(),
            devices: devices.iter().map(|d| d.to_string()).collect(),
            interconnect: if system == "ibm_p8" { "nvlink" } else { "pcie3" }.into(),
            host_memory_gb: 61.0,
            device_memory_gb: 16.0,
            models: models.iter().map(|m| m.to_string()).collect(),
        }
    }

    fn r50() -> ModelManifest {
        crate::zoo::by_name("MLPerf_ResNet50_v1.5").unwrap().manifest()
    }

    #[test]
    fn register_and_list() {
        let reg = Registry::new();
        let id = reg.register_agent(agent("aws_p3", &["cpu", "gpu"], "x86_64", &[]), None);
        assert!(id.starts_with("agent-"));
        assert_eq!(reg.agents().len(), 1);
        reg.deregister_agent(&id);
        assert!(reg.agents().is_empty());
    }

    #[test]
    fn ttl_expiry_and_heartbeat() {
        let reg = Registry::new();
        let id = reg.register_agent(
            agent("aws_p3", &["gpu"], "x86_64", &[]),
            Some(Duration::from_millis(30)),
        );
        assert_eq!(reg.agents().len(), 1);
        assert!(reg.heartbeat(&id, Duration::from_millis(60)));
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(reg.agents().len(), 1, "heartbeat extended the TTL");
        std::thread::sleep(Duration::from_millis(40));
        assert!(reg.agents().is_empty(), "expired after TTL");
        assert!(!reg.heartbeat(&id, Duration::from_millis(50)), "expired heartbeat fails");
    }

    #[test]
    fn manifest_versioning_latest_wins() {
        let reg = Registry::new();
        let mut m1 = r50();
        reg.register_manifest(m1.clone());
        m1.version = "1.2.0".parse().unwrap();
        reg.register_manifest(m1.clone());
        let got = reg.manifest("MLPerf_ResNet50_v1.5", None).unwrap();
        assert_eq!(got.version.to_string(), "1.2.0");
        let pinned = reg.manifest("MLPerf_ResNet50_v1.5", Some("1.0.0")).unwrap();
        assert_eq!(pinned.version.to_string(), "1.0.0");
        assert_eq!(reg.manifest_names().len(), 2);
    }

    #[test]
    fn resolution_matches_framework_constraint() {
        let reg = Registry::new();
        reg.register_agent(agent("aws_p3", &["gpu"], "x86_64", &[]), None);
        let mut old = agent("aws_p2", &["gpu"], "x86_64", &[]);
        old.framework_version = "2.1.0".parse().unwrap(); // outside >=1.12 <2
        reg.register_agent(old, None);
        let m = r50();
        let resolved = reg.resolve(&m, &SystemRequirements::any());
        assert_eq!(resolved.len(), 1);
        assert_eq!(resolved[0].system, "aws_p3");
    }

    #[test]
    fn resolution_honours_system_requirements() {
        let reg = Registry::new();
        reg.register_agent(agent("aws_p3", &["cpu", "gpu"], "x86_64", &[]), None);
        reg.register_agent(agent("ibm_p8", &["cpu", "gpu"], "ppc64le", &[]), None);
        let m = r50();
        // By accelerator + architecture.
        let req = SystemRequirements {
            accelerator: Accelerator::Gpu,
            architecture: Some("ppc64le".into()),
            ..SystemRequirements::any()
        };
        let resolved = reg.resolve(&m, &req);
        assert_eq!(resolved.len(), 1);
        assert_eq!(resolved[0].system, "ibm_p8");
        // By interconnect.
        let req = SystemRequirements {
            interconnect: Some("nvlink".into()),
            ..SystemRequirements::any()
        };
        assert_eq!(reg.resolve(&m, &req).len(), 1);
        // By memory floor nothing satisfies.
        let req = SystemRequirements { min_memory_gb: Some(1024.0), ..SystemRequirements::any() };
        assert!(reg.resolve(&m, &req).is_empty());
        // By exact system pin.
        let req = SystemRequirements::on_system("aws_p3");
        assert_eq!(reg.resolve(&m, &req)[0].system, "aws_p3");
    }

    #[test]
    fn standby_agents_are_held_out_of_resolution() {
        let reg = Registry::new();
        let a = reg.register_agent(agent("aws_p3", &["gpu"], "x86_64", &[]), None);
        let b = reg.register_agent(agent("aws_p3", &["gpu"], "x86_64", &[]), None);
        let m = r50();
        assert_eq!(reg.resolve(&m, &SystemRequirements::any()).len(), 2);
        // Parked: still registered + live, but invisible to resolution.
        assert!(reg.set_standby(&b, true));
        assert!(reg.is_standby(&b));
        assert_eq!(reg.agents().len(), 2, "standby keeps the registration");
        let resolved = reg.resolve(&m, &SystemRequirements::any());
        assert_eq!(resolved.len(), 1);
        assert_eq!(resolved[0].id, a);
        assert_eq!(reg.standby_agents(), vec![b.clone()]);
        // Woken: takes traffic again.
        assert!(reg.set_standby(&b, false));
        assert_eq!(reg.resolve(&m, &SystemRequirements::any()).len(), 2);
        // Unknown ids are refused; deregistration clears standby state.
        assert!(!reg.set_standby("agent-999", true));
        reg.set_standby(&b, true);
        reg.deregister_agent(&b);
        assert!(!reg.is_standby(&b));
        assert!(reg.standby_agents().is_empty());
    }

    #[test]
    fn model_list_filter() {
        let reg = Registry::new();
        reg.register_agent(agent("aws_p3", &["gpu"], "x86_64", &["VGG16"]), None);
        let resolved = reg.resolve(&r50(), &SystemRequirements::any());
        assert!(resolved.is_empty(), "agent only serves VGG16");
        let vgg = crate::zoo::by_name("VGG16").unwrap().manifest();
        assert_eq!(reg.resolve(&vgg, &SystemRequirements::any()).len(), 1);
    }

    #[test]
    fn round_robin_pick_balances() {
        let reg = Registry::new();
        for sys in ["aws_p3", "aws_g3", "aws_p2"] {
            reg.register_agent(agent(sys, &["gpu"], "x86_64", &[]), None);
        }
        let cands = reg.resolve(&r50(), &SystemRequirements::any());
        assert_eq!(cands.len(), 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            seen.insert(reg.pick(&cands).unwrap().system);
        }
        assert_eq!(seen.len(), 3, "round robin visits all agents");
    }

    #[test]
    fn pick_never_selects_expired_agents() {
        let reg = Registry::new();
        reg.register_agent(agent("aws_p3", &["gpu"], "x86_64", &[]), None);
        reg.register_agent(
            agent("aws_p2", &["gpu"], "x86_64", &[]),
            Some(Duration::from_millis(20)),
        );
        // Resolve while both are live: the candidate list holds two agents.
        let cands = reg.resolve(&r50(), &SystemRequirements::any());
        assert_eq!(cands.len(), 2);
        // Let the TTL'd agent lapse *after* resolution; pick must skip it.
        std::thread::sleep(Duration::from_millis(35));
        for _ in 0..6 {
            let picked = reg.pick(&cands).expect("one live candidate remains");
            assert_eq!(picked.system, "aws_p3", "expired agent must never be picked");
        }
        // The lapsed entry was expired on read, not just skipped.
        assert_eq!(reg.agents().len(), 1);
        // All candidates expired → None, not a stale pick.
        let ttl_only = {
            let reg2 = Registry::new();
            reg2.register_agent(
                agent("aws_g3", &["gpu"], "x86_64", &[]),
                Some(Duration::from_millis(10)),
            );
            let c = reg2.resolve(&r50(), &SystemRequirements::any());
            std::thread::sleep(Duration::from_millis(25));
            reg2.pick(&c)
        };
        assert!(ttl_only.is_none());
    }

    #[test]
    fn registry_over_the_wire() {
        let reg = Registry::new();
        let server =
            crate::wire::RpcServer::serve("127.0.0.1:0", registry_service(reg.clone())).unwrap();
        let client = crate::wire::RpcClient::connect(server.addr()).unwrap();
        let mut info = agent("aws_p3", &["gpu"], "x86_64", &[]).to_json();
        if let Json::Obj(m) = &mut info {
            m.insert("ttl_secs".into(), Json::num(60.0));
        }
        let resp = client.call("register_agent", info).unwrap();
        let id = resp.get("id").unwrap().as_str().unwrap().to_string();
        assert!(client
            .call("heartbeat", Json::obj(vec![("id", Json::str(&id)), ("ttl_secs", Json::num(60.0))]))
            .unwrap()
            .as_bool()
            .unwrap());
        let agents = client.call("agents", Json::Null).unwrap();
        assert_eq!(agents.as_arr().unwrap().len(), 1);
        // Local view agrees (same registry behind the service).
        assert_eq!(reg.agents().len(), 1);
        server.stop();
    }

    #[test]
    fn agent_info_json_roundtrip() {
        let a = agent("ibm_p8", &["cpu", "gpu"], "ppc64le", &["VGG16", "ResNet_v1_50"]);
        let back = AgentInfo::from_json(&a.to_json()).unwrap();
        assert_eq!(back.system, "ibm_p8");
        assert_eq!(back.devices, vec!["cpu", "gpu"]);
        assert_eq!(back.models.len(), 2);
        assert_eq!(back.interconnect, "nvlink");
    }
}
