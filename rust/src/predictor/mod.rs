//! The framework predictor interface (§4.4.3, Listing 3).
//!
//! "The wrapper is minimal and provides a uniform API across frameworks for
//! performing model loading, unloading, and inference": three functions —
//! `ModelLoad`, `Predict`, `ModelUnload`. Anything implementing
//! [`Predictor`] is a valid MLModelScope framework: here an XLA/PJRT
//! predictor executing real AOT artifacts, and a simulator predictor
//! standing in for GPU/FPGA hardware (§4.4.3's FPGA argument: "except for
//! implementing these 3 API functions, no code needs to change").
//!
//! Fig 2 (language-binding overhead) is reproduced by [`InputMode`]: the
//! `Boxed` path models Python lists (per-element unboxing into a fresh
//! numeric buffer), `NumpyLike` models NumPy (one extra buffer copy), and
//! `Direct` is the zero-copy C path.

mod sim;
mod xlapred;

pub use sim::SimPredictor;
pub use xlapred::XlaPredictor;

use crate::preprocess::Tensor;

/// Opaque handle returned by `ModelLoad`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelHandle(pub u64);

/// Prediction options (paper Listing 3's `PredictOptions`, trimmed to what
/// the evaluation uses).
#[derive(Debug, Clone, Default)]
pub struct PredictOptions {
    /// Batch size this call carries (for validation/metrics).
    pub batch_size: usize,
    /// Input marshalling mode (Fig 2 reproduction).
    pub input_mode: InputMode,
}

/// How inputs cross the framework boundary — the Fig-2 experiment variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum InputMode {
    /// Zero-copy: the tensor's buffer is handed to the framework as-is (C).
    #[default]
    Direct,
    /// One extra contiguous buffer copy (NumPy: the framework can use the
    /// internal numeric buffer but still copies it into its own arena).
    NumpyLike,
    /// Per-element unboxing: each scalar is converted individually, as when
    /// TensorFlow consumes a Python list of lists.
    Boxed,
}

impl InputMode {
    pub fn parse(s: &str) -> InputMode {
        match s.to_ascii_lowercase().as_str() {
            "numpy" | "numpy_like" => InputMode::NumpyLike,
            "boxed" | "python" => InputMode::Boxed,
            _ => InputMode::Direct,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            InputMode::Direct => "c",
            InputMode::NumpyLike => "numpy",
            InputMode::Boxed => "python",
        }
    }

    /// Apply the marshalling cost to an input tensor. `Direct` is free;
    /// the others really do the work so Fig 2 measures real cost.
    pub fn marshal(&self, t: &Tensor) -> Tensor {
        match self {
            InputMode::Direct => t.clone(),
            InputMode::NumpyLike => {
                // One extra buffer copy into a fresh allocation.
                let mut data = Vec::with_capacity(t.data.len());
                data.extend_from_slice(&t.data);
                Tensor::new(t.shape.clone(), data)
            }
            InputMode::Boxed => {
                // Per-element unbox: simulate the PyObject → double → float
                // chain TensorFlow performs for list inputs. The f64 round
                // trip + per-element branch models the unboxing cost.
                let data: Vec<f32> = t
                    .data
                    .iter()
                    .map(|v| {
                        let boxed: Box<f64> = Box::new(*v as f64);
                        (*boxed) as f32
                    })
                    .collect();
                Tensor::new(t.shape.clone(), data)
            }
        }
    }
}

/// Predictor errors.
#[derive(Debug)]
pub enum PredictError {
    Load(String),
    BadHandle,
    Inference(String),
    Shape { got: Vec<usize>, expect: String },
}

impl std::fmt::Display for PredictError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PredictError::Load(m) => write!(f, "model load failed: {m}"),
            PredictError::BadHandle => f.write_str("unknown model handle"),
            PredictError::Inference(m) => write!(f, "inference failed: {m}"),
            PredictError::Shape { got, expect } => {
                write!(f, "input shape {got:?} incompatible with model {expect}")
            }
        }
    }
}

impl std::error::Error for PredictError {}

/// The 3-function predictor interface (Listing 3).
pub trait Predictor: Send + Sync {
    /// Framework identity, e.g. `("XLA-PJRT", "0.5.1")`.
    fn framework(&self) -> (String, String);

    /// `ModelLoad` — open a predictor for a named model at a batch size.
    fn model_load(&self, model: &str, batch: usize) -> Result<ModelHandle, PredictError>;

    /// `Predict` — run inference on a batched input tensor.
    fn predict(
        &self,
        handle: ModelHandle,
        input: &Tensor,
        opts: &PredictOptions,
    ) -> Result<Tensor, PredictError>;

    /// `ModelUnload` — close the predictor and release resources.
    fn model_unload(&self, handle: ModelHandle) -> Result<(), PredictError>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_mode_marshal_identical_values() {
        let t = Tensor::random(vec![4, 8], 3);
        for mode in [InputMode::Direct, InputMode::NumpyLike, InputMode::Boxed] {
            let m = mode.marshal(&t);
            assert_eq!(m.shape, t.shape);
            assert_eq!(m.data, t.data, "{mode:?} must not alter values");
        }
    }

    #[test]
    fn input_mode_parse() {
        assert_eq!(InputMode::parse("python"), InputMode::Boxed);
        assert_eq!(InputMode::parse("NumPy"), InputMode::NumpyLike);
        assert_eq!(InputMode::parse("c"), InputMode::Direct);
    }
}
