//! The simulator predictor: a [`Predictor`] whose "device" is a Table-1
//! system model.
//!
//! This is the FPGA/ASIC argument of §4.4.3 made concrete: the simulated
//! GPU is exposed to the platform purely by implementing the 3-function
//! interface. `predict` walks the model's layer list through the roofline
//! simulator, publishes FRAMEWORK-level layer spans and SYSTEM-level kernel
//! spans stamped with *simulated* time (§4.4.4), and returns a plausible
//! logits tensor.

use super::{ModelHandle, PredictError, PredictOptions, Predictor};
use crate::preprocess::Tensor;
use crate::sysmodel::{dominant_kernels, Simulator};
use crate::tracing::{Clock, SimClock, Span, TraceLevel, Tracer};
use crate::zoo::LayerSpec;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

struct LoadedModel {
    layers: Vec<LayerSpec>,
    /// True until the first predict — models §5.2's "cold-start" weight
    /// copy (weights stream host→device lazily on first use).
    cold: bool,
    name: String,
}

/// Simulator-backed predictor for one (system, device) pair.
pub struct SimPredictor {
    sim: Simulator,
    clock: Arc<SimClock>,
    tracer: Mutex<Option<(Arc<Tracer>, u64, Option<u64>)>>,
    models: Mutex<HashMap<u64, LoadedModel>>,
    next: AtomicU64,
    /// Eager weight upload (Caffe2/TF-style) vs lazy per-layer copy
    /// (Caffe-style — the paper's observed cold-start bottleneck).
    pub eager_copy: bool,
}

impl SimPredictor {
    pub fn new(sim: Simulator) -> SimPredictor {
        SimPredictor {
            sim,
            clock: Arc::new(SimClock::new()),
            tracer: Mutex::new(None),
            models: Mutex::new(HashMap::new()),
            next: AtomicU64::new(1),
            eager_copy: true,
        }
    }

    /// The simulated clock (attach to a Tracer so span times are simulated).
    pub fn clock(&self) -> Arc<SimClock> {
        self.clock.clone()
    }

    /// Attach a tracer + trace context: subsequent predicts publish
    /// FRAMEWORK layer spans and SYSTEM kernel spans into it.
    pub fn attach_tracer(&self, tracer: Arc<Tracer>, trace_id: u64, parent: Option<u64>) {
        *self.tracer.lock().unwrap() = Some((tracer, trace_id, parent));
    }

    /// Simulated seconds for one predict at `batch` (no tracing, no state).
    pub fn simulate_seconds(&self, layers: &[LayerSpec], batch: usize, include_cold_copy: bool) -> f64 {
        let mut total = 0.0;
        for l in layers {
            if include_cold_copy && l.work.weight_bytes > 0.0 {
                total += self.sim.host_to_device(l.work.weight_bytes).seconds;
            }
            total += self.sim.layer_time(&l.work, batch).total;
        }
        total
    }

    fn publish_layer(
        &self,
        l: &LayerSpec,
        batch: usize,
        copy_secs: f64,
    ) -> f64 {
        let timing = self.sim.layer_time(&l.work, batch);
        let guard = self.tracer.lock().unwrap();
        if let Some((tracer, trace_id, parent)) = guard.as_ref() {
            let start = self.clock.now_ns();
            // Advance simulated time across the layer (copy + kernels).
            let layer_total = copy_secs + timing.total;
            let mut tags = vec![
                ("layer_index".to_string(), l.index.to_string()),
                ("kind".to_string(), l.kind.clone()),
                (
                    "shape".to_string(),
                    format!(
                        "({}, {})",
                        batch,
                        l.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
                    ),
                ),
                (
                    "alloc_mb".to_string(),
                    format!(
                        "{:.1}",
                        (l.work.act_bytes_per_item * batch as f64 + l.work.weight_bytes) / 1e6
                    ),
                ),
            ];
            if copy_secs > 0.0 {
                tags.push(("weight_copy_ms".to_string(), format!("{:.3}", copy_secs * 1e3)));
            }
            let layer_span_id = tracer.new_trace(); // unique id from the tracer pool
            // Kernel spans (SYSTEM level) nested under the layer span.
            let mut cursor = start;
            if copy_secs > 0.0 && tracer.enabled(TraceLevel::System) {
                tracer.publish(Span {
                    trace_id: *trace_id,
                    span_id: tracer.new_trace(),
                    parent_id: Some(layer_span_id),
                    name: "memcpy_h2d_weights".to_string(),
                    level: TraceLevel::System,
                    start_ns: cursor,
                    end_ns: cursor + (copy_secs * 1e9) as u64,
                    tags: vec![("bytes".to_string(), format!("{}", l.work.weight_bytes as u64))],
                });
            }
            cursor += (copy_secs * 1e9) as u64;
            if tracer.enabled(TraceLevel::System) {
                for k in dominant_kernels(&self.sim, &l.work, &timing, batch) {
                    tracer.publish(Span {
                        trace_id: *trace_id,
                        span_id: tracer.new_trace(),
                        parent_id: Some(layer_span_id),
                        name: k.name,
                        level: TraceLevel::System,
                        start_ns: cursor,
                        end_ns: cursor + (k.seconds * 1e9) as u64,
                        tags: vec![(
                            "alloc_mb".to_string(),
                            format!("{:.1}", k.alloc_bytes / 1e6),
                        )],
                    });
                    cursor += (k.seconds * 1e9) as u64;
                }
            }
            self.clock.advance_secs(layer_total);
            tracer.publish(Span {
                trace_id: *trace_id,
                span_id: layer_span_id,
                parent_id: *parent,
                name: l.name.clone(),
                level: TraceLevel::Framework,
                start_ns: start,
                end_ns: self.clock.now_ns(),
                tags,
            });
            layer_total
        } else {
            let layer_total = copy_secs + timing.total;
            self.clock.advance_secs(layer_total);
            layer_total
        }
    }
}

impl Predictor for SimPredictor {
    fn framework(&self) -> (String, String) {
        (format!("SimFramework-{}", self.sim.profile.gpu_architecture), "1.0.0".to_string())
    }

    fn model_load(&self, model: &str, _batch: usize) -> Result<ModelHandle, PredictError> {
        let zoo_model = crate::zoo::by_name(model)
            .ok_or_else(|| PredictError::Load(format!("unknown zoo model {model:?}")))?;
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        self.models.lock().unwrap().insert(
            id,
            LoadedModel { layers: zoo_model.layers(), cold: true, name: model.to_string() },
        );
        Ok(ModelHandle(id))
    }

    fn predict(
        &self,
        handle: ModelHandle,
        input: &Tensor,
        opts: &PredictOptions,
    ) -> Result<Tensor, PredictError> {
        let (layers, cold, _name) = {
            let mut models = self.models.lock().unwrap();
            let m = models.get_mut(&handle.0).ok_or(PredictError::BadHandle)?;
            let cold = m.cold;
            m.cold = false;
            (m.layers.clone(), cold, m.name.clone())
        };
        let batch = opts.batch_size.max(input.batch());
        if self.eager_copy && cold {
            // Eager frameworks (Caffe2/MXNet/TF/TensorRT per §5.2) upload
            // weights asynchronously on a copy stream, overlapping compute:
            // only the portion of the copy exceeding total compute time
            // stalls the pipeline.
            let total_weights: f64 = layers.iter().map(|l| l.work.weight_bytes).sum();
            let copy = self.sim.host_to_device(total_weights);
            let compute: f64 =
                layers.iter().map(|l| self.sim.layer_time(&l.work, batch).total).sum();
            self.clock.advance_secs((copy.seconds - compute).max(0.0));
        }
        for l in &layers {
            // Lazy (Caffe-style) copy: bill each layer's weights on first
            // touch — §5.2's stall-on-fc6 behaviour.
            let copy_secs = if !self.eager_copy && cold && l.work.weight_bytes > 0.0 {
                self.sim.host_to_device(l.work.weight_bytes).seconds
            } else {
                0.0
            };
            self.publish_layer(l, batch, copy_secs);
        }
        // Plausible logits: deterministic pseudo-random from the input hash.
        let seed = input.data.first().map(|v| v.to_bits() as u64).unwrap_or(1) ^ handle.0;
        Ok(Tensor::random(vec![batch, 1000], seed))
    }

    fn model_unload(&self, handle: ModelHandle) -> Result<(), PredictError> {
        self.models
            .lock()
            .unwrap()
            .remove(&handle.0)
            .map(|_| ())
            .ok_or(PredictError::BadHandle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysmodel::{systems, Device};
    use crate::tracing::MemorySink;

    fn predictor(system: &str) -> SimPredictor {
        SimPredictor::new(Simulator::new(systems()[system].clone(), Device::Gpu))
    }

    #[test]
    fn predict_returns_logits_shaped_by_batch() {
        let p = predictor("aws_p3");
        let h = p.model_load("ResNet_v1_50", 8).unwrap();
        let input = Tensor::zeros(vec![8, 224, 224, 3]);
        let out = p
            .predict(h, &input, &PredictOptions { batch_size: 8, ..Default::default() })
            .unwrap();
        assert_eq!(out.shape, vec![8, 1000]);
    }

    #[test]
    fn unknown_model_rejected() {
        let p = predictor("aws_p3");
        assert!(p.model_load("NotAModel", 1).is_err());
    }

    #[test]
    fn simulated_time_advances_with_work() {
        let p = predictor("aws_p3");
        let h = p.model_load("ResNet_v1_50", 1).unwrap();
        let t0 = p.clock().now_ns();
        p.predict(h, &Tensor::zeros(vec![1, 224, 224, 3]), &PredictOptions::default())
            .unwrap();
        let warm_start = p.clock().now_ns();
        assert!(warm_start > t0, "cold predict advanced the clock");
        p.predict(h, &Tensor::zeros(vec![1, 224, 224, 3]), &PredictOptions::default())
            .unwrap();
        let warm = p.clock().now_ns() - warm_start;
        // Warm predict is faster than cold (no weight upload).
        assert!(warm < warm_start - t0);
    }

    #[test]
    fn traced_predict_publishes_layer_and_kernel_spans() {
        let p = predictor("aws_p3");
        let sink = MemorySink::new();
        let tracer = Tracer::new(TraceLevel::Full, p.clock(), sink.clone());
        p.attach_tracer(tracer.clone(), 99, None);
        let h = p.model_load("BVLC_AlexNet", 64).unwrap();
        p.predict(
            h,
            &Tensor::zeros(vec![1, 224, 224, 3]),
            &PredictOptions { batch_size: 64, ..Default::default() },
        )
        .unwrap();
        let spans = sink.drain();
        let layers: Vec<_> = spans.iter().filter(|s| s.level == TraceLevel::Framework).collect();
        let kernels: Vec<_> = spans.iter().filter(|s| s.level == TraceLevel::System).collect();
        assert!(layers.len() > 10, "layers {}", layers.len());
        assert!(kernels.len() >= layers.len(), "kernels {}", kernels.len());
        // Every kernel is parented to a layer span.
        for k in &kernels {
            assert!(layers.iter().any(|l| Some(l.span_id) == k.parent_id));
        }
        // fc6 exists and carries layer metadata tags.
        let fc6 = layers.iter().find(|l| l.name == "fc6").expect("fc6 span");
        assert_eq!(fc6.tag("kind"), Some("Dense"));
        assert!(fc6.tag("alloc_mb").is_some());
    }

    #[test]
    fn lazy_copy_makes_fc6_dominate_coldstart() {
        // The Fig-8 experiment mechanism: with lazy (Caffe-style) copies,
        // fc6's cold time is dominated by its weight upload.
        let mut p = predictor("aws_p3");
        p.eager_copy = false;
        let sink = MemorySink::new();
        let tracer = Tracer::new(TraceLevel::Full, p.clock(), sink.clone());
        p.attach_tracer(tracer, 1, None);
        let h = p.model_load("BVLC_AlexNet", 64).unwrap();
        p.predict(
            h,
            &Tensor::zeros(vec![1, 224, 224, 3]),
            &PredictOptions { batch_size: 64, ..Default::default() },
        )
        .unwrap();
        let spans = sink.drain();
        let longest_layer = spans
            .iter()
            .filter(|s| s.level == TraceLevel::Framework)
            .max_by_key(|s| s.duration_ns())
            .unwrap();
        assert_eq!(longest_layer.name, "fc6", "fc6 must be the longest layer cold");
        assert!(longest_layer.tag("weight_copy_ms").is_some());
    }

    #[test]
    fn p8_coldstart_beats_p3_fig8() {
        // Paper Fig 8: IBM P8 (NVLink) beats AWS P3 (PCIe) on cold-start
        // AlexNet despite the slower GPU, because fc6 is copy-bound.
        let mut secs = Vec::new();
        for sys in ["aws_p3", "ibm_p8"] {
            let mut p = predictor(sys);
            p.eager_copy = false;
            let h = p.model_load("BVLC_AlexNet", 64).unwrap();
            let t0 = p.clock().now_ns();
            p.predict(
                h,
                &Tensor::zeros(vec![1, 224, 224, 3]),
                &PredictOptions { batch_size: 64, ..Default::default() },
            )
            .unwrap();
            secs.push((p.clock().now_ns() - t0) as f64 / 1e9);
        }
        assert!(secs[1] < secs[0], "P8 {} must beat P3 {}", secs[1], secs[0]);
    }
}
