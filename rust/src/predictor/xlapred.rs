//! The XLA/PJRT framework predictor: executes real AOT artifacts.

use super::{InputMode, ModelHandle, PredictError, PredictOptions, Predictor};
use crate::preprocess::Tensor;
use crate::runtime::{artifact_path, Runtime};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A [`Predictor`] backed by the PJRT runtime. `model_load` resolves a
/// model-family artifact for the requested batch size and compiles it;
/// `predict` executes with zero Python involvement.
pub struct XlaPredictor {
    runtime: Arc<Runtime>,
    handles: Mutex<HashMap<u64, PathBuf>>,
    next: AtomicU64,
}

impl XlaPredictor {
    pub fn new(runtime: Arc<Runtime>) -> XlaPredictor {
        XlaPredictor { runtime, handles: Mutex::new(HashMap::new()), next: AtomicU64::new(1) }
    }

    /// Load a model by explicit artifact path (tests / custom models).
    pub fn load_path(&self, path: PathBuf) -> Result<ModelHandle, PredictError> {
        self.runtime
            .load(&path)
            .map_err(|e| PredictError::Load(e.to_string()))?;
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        self.handles.lock().unwrap().insert(id, path);
        Ok(ModelHandle(id))
    }

    fn path_of(&self, handle: ModelHandle) -> Result<PathBuf, PredictError> {
        self.handles
            .lock()
            .unwrap()
            .get(&handle.0)
            .cloned()
            .ok_or(PredictError::BadHandle)
    }
}

impl Predictor for XlaPredictor {
    fn framework(&self) -> (String, String) {
        ("XLA-PJRT".to_string(), "0.5.1".to_string())
    }

    fn model_load(&self, model: &str, batch: usize) -> Result<ModelHandle, PredictError> {
        // `model` is an artifact family name (e.g. `tiny_resnet`); pick the
        // artifact compiled for this batch size.
        let path = artifact_path(model, batch);
        if !path.exists() {
            return Err(PredictError::Load(format!(
                "artifact {} not found (run `make artifacts`)",
                path.display()
            )));
        }
        self.load_path(path)
    }

    fn predict(
        &self,
        handle: ModelHandle,
        input: &Tensor,
        opts: &PredictOptions,
    ) -> Result<Tensor, PredictError> {
        let path = self.path_of(handle)?;
        let marshalled = if opts.input_mode == InputMode::Direct {
            // Avoid even the clone on the direct path.
            None
        } else {
            Some(opts.input_mode.marshal(input))
        };
        let input = marshalled.as_ref().unwrap_or(input);
        self.runtime
            .run(&path, input)
            .map_err(|e| PredictError::Inference(e.to_string()))
    }

    fn model_unload(&self, handle: ModelHandle) -> Result<(), PredictError> {
        let path = self
            .handles
            .lock()
            .unwrap()
            .remove(&handle.0)
            .ok_or(PredictError::BadHandle)?;
        self.runtime.unload(&path);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_artifact() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mlms_xp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("identityish.hlo.txt");
        // f32[1,4] -> (f32[1,4]) : x * 2
        std::fs::write(
            &path,
            r#"
HloModule jit_double, entry_computation_layout={(f32[1,4]{1,0})->(f32[1,4]{1,0})}

ENTRY main.6 {
  Arg_0.1 = f32[1,4]{1,0} parameter(0)
  constant.2 = f32[] constant(2)
  broadcast.3 = f32[1,4]{1,0} broadcast(constant.2), dimensions={}
  multiply.4 = f32[1,4]{1,0} multiply(Arg_0.1, broadcast.3)
  ROOT tuple.5 = (f32[1,4]{1,0}) tuple(multiply.4)
}
"#,
        )
        .unwrap();
        path
    }

    #[test]
    fn load_unload_lifecycle() {
        // Handle management works against the stub runtime; only the
        // execute step reports the missing PJRT binding.
        let rt = Runtime::cpu().unwrap();
        let p = XlaPredictor::new(rt);
        let h = p.load_path(smoke_artifact()).unwrap();
        let input = Tensor::new(vec![1, 4], vec![1., 2., 3., 4.]);
        let err = p.predict(h, &input, &PredictOptions::default()).unwrap_err();
        assert!(
            matches!(err, PredictError::Inference(ref m) if m.contains("PJRT")),
            "{err}"
        );
        p.model_unload(h).unwrap();
        assert!(matches!(
            p.predict(h, &input, &PredictOptions::default()),
            Err(PredictError::BadHandle)
        ));
    }

    #[test]
    fn marshalling_applies_before_dispatch() {
        // All marshalling modes reach the runtime boundary identically.
        let rt = Runtime::cpu().unwrap();
        let p = XlaPredictor::new(rt);
        let h = p.load_path(smoke_artifact()).unwrap();
        let input = Tensor::new(vec![1, 4], vec![0.5, -1.0, 2.5, 0.0]);
        for mode in [InputMode::Direct, InputMode::NumpyLike, InputMode::Boxed] {
            let opts = PredictOptions { batch_size: 1, input_mode: mode };
            assert!(matches!(
                p.predict(h, &input, &opts),
                Err(PredictError::Inference(_))
            ));
        }
    }

    #[test]
    fn missing_family_reports_make_artifacts() {
        let rt = Runtime::cpu().unwrap();
        let p = XlaPredictor::new(rt);
        let err = p.model_load("no_such_family", 1).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
