//! Cross-request dynamic batching + load-balanced multi-agent dispatch.
//!
//! The original dispatch path resolves one agent and ships one scenario —
//! correct, but it leaves throughput on the table for server-style
//! workloads (`Poisson`, `FixedQps`, `Burst`, `Diurnal`, `TraceReplay`):
//! requests arriving close together can share one predictor call, and a
//! job's batches can spread over every agent that resolved. This module is
//! that subsystem, in two deterministic halves:
//!
//! 1. **Planning** ([`plan_batches`]): fold a generated request schedule
//!    into batches, flushing on `max_batch_size` *or* `max_wait_ms` —
//!    whichever comes first. Planning is a pure function of
//!    `(workload, config)`, so server and agent agree on the exact batch
//!    boundaries the same way they agree on the workload itself
//!    (regenerated from `(scenario, seed)`).
//! 2. **Dispatch** ([`Dispatcher`]): spread planned batches across a pool
//!    of [`BatchExecutor`]s with a least-outstanding-requests policy.
//!    Executor liveness comes from the registry's TTL heartbeats at session
//!    setup and from observed failures at run time: an executor that fails
//!    a batch is marked dead and the batch is requeued to the survivors
//!    **exactly once** — a second failure aborts the dispatch with a typed
//!    error rather than looping.
//!
//! Per-request identity rides in [`Envelope::seq`] end to end: outputs are
//! returned sorted by `seq` and are element-wise identical to per-request
//! execution (batching must never change results — only their latency).
//! Batch occupancy and per-request queue delay are surfaced as
//! [`crate::metrics::BatchingSeries`] so the analysis workflow can report
//! them next to the paper's latency/throughput metrics.

use crate::metrics::BatchingSeries;
use crate::pipeline::Envelope;
use crate::scenario::{Request, Workload};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

pub mod admission;
pub use admission::{
    AdmissionConfig, AdmissionController, Priority, Rejection, ShedCause, TenantPolicy,
};

/// Batching policy: flush on size or deadline, whichever first.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Maximum requests coalesced into one predictor call.
    pub max_batch_size: usize,
    /// Maximum time a request may wait in an open batch, milliseconds.
    pub max_wait_ms: f64,
    /// Fairness-aware dispatch: pick the next batch from the tenant with
    /// the fewest items served so far instead of strict FIFO, so one
    /// tenant's burst cannot starve another tenant's latency SLO.
    pub fair: bool,
    /// Per-batch RPC deadline for *remote* executors, milliseconds: a
    /// remote agent that hasn't answered a `PredictBatch` within it is
    /// treated as dead (connection broken, batch requeued to a survivor).
    /// Carried in every `PredictBatch` frame. An execution-robustness knob,
    /// not an experiment coordinate — deliberately **excluded** from
    /// [`BatcherConfig::fingerprint_json`] so changing it never invalidates
    /// memoized sweep cells.
    pub remote_deadline_ms: Option<f64>,
}

/// Default remote per-batch deadline (generous: real batches finish in
/// milliseconds; only a partitioned or wedged agent ever hits it).
pub const DEFAULT_REMOTE_DEADLINE_MS: f64 = 30_000.0;

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch_size: 8,
            max_wait_ms: 5.0,
            fair: false,
            remote_deadline_ms: Some(DEFAULT_REMOTE_DEADLINE_MS),
        }
    }
}

impl BatcherConfig {
    pub fn new(max_batch_size: usize, max_wait_ms: f64) -> Self {
        BatcherConfig { max_batch_size, max_wait_ms, ..BatcherConfig::default() }
    }

    /// Degenerate config: every request is its own batch (the per-request
    /// dispatch baseline the `fig_batching` bench compares against).
    pub fn per_request() -> Self {
        BatcherConfig { max_batch_size: 1, max_wait_ms: 0.0, ..BatcherConfig::default() }
    }

    pub fn with_fairness(mut self) -> Self {
        self.fair = true;
        self
    }

    /// Override the remote per-batch deadline (`None` waits forever).
    pub fn with_remote_deadline_ms(mut self, ms: Option<f64>) -> Self {
        self.remote_deadline_ms = ms;
        self
    }

    /// The dispatch policy this config implies.
    pub fn policy(&self) -> DispatchPolicy {
        if self.fair {
            DispatchPolicy::FairByTenant
        } else {
            DispatchPolicy::Fifo
        }
    }

    /// Canonical JSON fingerprint of the dispatch configuration. Folded
    /// into the [`crate::evaldb::EvalSpec`] digest so evaluations under
    /// different batching configs never memoize into each other.
    pub fn fingerprint_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("fair", Json::Bool(self.fair)),
            ("max_batch_size", Json::num(self.max_batch_size as f64)),
            ("max_wait_ms", Json::num(self.max_wait_ms)),
        ])
    }
}

/// One planned batch: coalesced request envelopes plus the timing facts the
/// metrics layer needs.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Position in the planned batch stream (formed-time order).
    pub index: u64,
    /// Arrival of the first request in the batch (seconds from t0).
    pub opened_at_secs: f64,
    /// When the batch closed: last arrival for size-triggered flushes,
    /// `opened_at + max_wait` for deadline-triggered ones.
    pub formed_at_secs: f64,
    /// The coalesced requests; `seq` carries each request's identity.
    pub envelopes: Vec<Envelope>,
    /// Arrival offset of each envelope, parallel to `envelopes`.
    pub arrivals: Vec<f64>,
    /// Tenant the batch belongs to. Planning never coalesces across
    /// tenants, so a batch is single-tenant by construction.
    pub tenant: u32,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.envelopes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.envelopes.is_empty()
    }

    /// Per-request batching delay: time spent waiting for the batch to
    /// close after the request arrived.
    pub fn queue_delays_secs(&self) -> Vec<f64> {
        self.arrivals
            .iter()
            .map(|a| (self.formed_at_secs - a).max(0.0))
            .collect()
    }
}

/// Coalesce a workload's request schedule into batches. `make` builds the
/// envelope for each request (payload + `seq = request.id`); planning never
/// reorders requests within a tenant, so arrivals within a batch stay
/// non-decreasing. Multi-tenant workloads ([`crate::scenario::Scenario::Mix`])
/// are planned per tenant — batches never mix tenants, which is what lets
/// the dispatcher schedule fairly between them — then merged in formed-time
/// order and reindexed. Single-tenant workloads plan exactly as before.
pub fn plan_batches(
    workload: &Workload,
    cfg: &BatcherConfig,
    mut make: impl FnMut(&Request) -> Envelope,
) -> Vec<Batch> {
    fn close(
        batches: &mut Vec<Batch>,
        cur: &mut Vec<Envelope>,
        arrivals: &mut Vec<f64>,
        opened_at: f64,
        formed_at: f64,
        tenant: u32,
    ) {
        if cur.is_empty() {
            return;
        }
        batches.push(Batch {
            index: batches.len() as u64,
            opened_at_secs: opened_at,
            formed_at_secs: formed_at,
            envelopes: std::mem::take(cur),
            arrivals: std::mem::take(arrivals),
            tenant,
        });
    }

    let max_batch = cfg.max_batch_size.max(1);
    let max_wait = (cfg.max_wait_ms / 1e3).max(0.0);
    let mut tenant_ids: Vec<u32> = workload.requests.iter().map(|r| r.tenant).collect();
    tenant_ids.sort_unstable();
    tenant_ids.dedup();
    let mut batches = Vec::new();
    for tenant in tenant_ids {
        let mut cur: Vec<Envelope> = Vec::new();
        let mut arrivals: Vec<f64> = Vec::new();
        let mut opened_at = 0.0;
        for r in workload.requests.iter().filter(|r| r.tenant == tenant) {
            // Deadline flush: this request arrived after the open batch's
            // wait window expired, so that batch left at `opened_at +
            // max_wait`.
            if !cur.is_empty() && r.at_secs > opened_at + max_wait {
                close(&mut batches, &mut cur, &mut arrivals, opened_at, opened_at + max_wait, tenant);
            }
            if cur.is_empty() {
                opened_at = r.at_secs;
            }
            cur.push(make(r));
            arrivals.push(r.at_secs);
            // Size flush: the batch is full the moment the last slot fills.
            // Guarded like the end-of-stream flush below: a degenerate plan
            // shape (empty arrivals) falls back to the open time instead of
            // panicking.
            if cur.len() >= max_batch {
                let formed = arrivals.last().copied().unwrap_or(opened_at);
                close(&mut batches, &mut cur, &mut arrivals, opened_at, formed, tenant);
            }
        }
        // Stream end: the workload is complete, so the trailing partial
        // batch flushes immediately at its last arrival — waiting out the
        // deadline would add delay no further request can fill.
        let formed = arrivals.last().copied().unwrap_or(opened_at);
        close(&mut batches, &mut cur, &mut arrivals, opened_at, formed, tenant);
    }
    // Merge tenant streams into one formed-time-ordered plan. The sort is
    // stable, so equal formed times keep tenant order and within-tenant
    // order — the plan stays a pure deterministic function of its inputs.
    // `total_cmp` (not `partial_cmp().unwrap()`): a NaN formed time — e.g.
    // from a corrupt trace replay — sorts last instead of panicking the
    // whole plan.
    batches.sort_by(|a, b| a.formed_at_secs.total_cmp(&b.formed_at_secs));
    for (i, b) in batches.iter_mut().enumerate() {
        b.index = i as u64;
    }
    batches
}

/// Occupancy + queue-delay series for a planned batch stream.
pub fn batching_series(batches: &[Batch], cfg: &BatcherConfig) -> BatchingSeries {
    BatchingSeries {
        capacity: cfg.max_batch_size.max(1),
        occupancy: batches.iter().map(|b| b.len() as f64).collect(),
        queue_delay_s: batches.iter().flat_map(|b| b.queue_delays_secs()).collect(),
    }
}

/// What one executed batch produced.
#[derive(Debug)]
pub struct BatchResult {
    /// One output envelope per input envelope, same `seq`s (any order).
    pub outputs: Vec<Envelope>,
    /// Time the executor spent on the batch, seconds. Simulator-backed
    /// executors report simulated time (§4.4.4), real ones wall-clock.
    pub latency_s: f64,
}

/// Something that can execute a coalesced batch — an in-process agent
/// session, a remote-agent proxy, or a test double.
pub trait BatchExecutor: Send + Sync {
    /// Stable identity used in dispatch accounting (usually the agent id).
    fn id(&self) -> String;

    /// Execute one batch. `Err` marks this executor dead for the rest of
    /// the dispatch; its in-flight batch is requeued to survivors.
    fn execute(&self, batch: &Batch) -> Result<BatchResult, String>;
}

/// How the dispatcher (and the virtual-time queueing replay) picks the next
/// queued batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchPolicy {
    /// Strict formed-time order.
    #[default]
    Fifo,
    /// Deficit fairness: among formed batches, serve the tenant with the
    /// fewest items dispatched so far (ties go to formed-time order). One
    /// tenant's burst then interleaves with — instead of blocking — the
    /// other tenants' traffic.
    FairByTenant,
}

/// Observer hooked into a running dispatch: called after every successfully
/// executed batch. Returning `false` aborts the dispatch — remaining queued
/// batches are dropped and the outcome comes back with `aborted = true`.
/// The SLO probe runner uses this to cut a hopeless probe short instead of
/// running it to completion.
pub trait DispatchWatch: Send + Sync {
    fn on_batch(&self, row: &BatchLogRow) -> bool;
}

/// Least-outstanding-requests pick: among alive executors with spare
/// batch slots, the one with the fewest in-flight requests (ties go to the
/// lowest index, keeping the choice deterministic).
pub fn least_outstanding(
    alive: &[bool],
    outstanding_items: &[usize],
    in_flight_batches: &[usize],
    max_in_flight: usize,
) -> Option<usize> {
    (0..alive.len())
        .filter(|&i| alive[i] && in_flight_batches[i] < max_in_flight)
        .min_by_key(|&i| (outstanding_items[i], i))
}

/// What broke: an agent, or the dispatch machinery itself. The distinction
/// matters to callers — agent failures are retried/failed-over by design,
/// while `Poisoned`/`QueueInvariant` mean the dispatcher's own state was
/// compromised and the run's accounting cannot be trusted.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchError {
    /// An executor failed, returned malformed output, or none survived.
    #[default]
    Agent,
    /// A dispatch lock was poisoned (a thread panicked while holding it) or
    /// a worker thread died. State was recovered via `into_inner` so the
    /// remaining workers shut down cleanly instead of deadlocking on the
    /// condvar, but the outcome is discarded.
    Poisoned,
    /// The shared queue violated an internal invariant (the policy picked a
    /// position that wasn't there). Surfaced as a typed error instead of an
    /// `expect` panic inside the worker loop.
    QueueInvariant,
}

/// Typed dispatch failure.
#[derive(Debug)]
pub struct DispatchError {
    /// Agent the failure is attributed to (`-` when no agent applies).
    pub agent: String,
    pub msg: String,
    /// Failure class — see [`BatchError`].
    pub kind: BatchError,
}

impl DispatchError {
    fn agent_failure(agent: impl Into<String>, msg: impl Into<String>) -> DispatchError {
        DispatchError { agent: agent.into(), msg: msg.into(), kind: BatchError::Agent }
    }

    fn internal(kind: BatchError, msg: impl Into<String>) -> DispatchError {
        DispatchError { agent: "-".into(), msg: msg.into(), kind }
    }
}

impl std::fmt::Display for DispatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "dispatch via agent {}: {}", self.agent, self.msg)
    }
}

impl std::error::Error for DispatchError {}

/// Accounting row for one executed batch.
#[derive(Debug, Clone)]
pub struct BatchLogRow {
    pub index: u64,
    pub occupancy: usize,
    pub latency_s: f64,
    pub agent: String,
}

/// The dispatch result: outputs restored to request order plus the
/// per-agent accounting the analysis layer reports.
#[derive(Debug, Default)]
pub struct DispatchOutcome {
    /// One envelope per request, sorted by `seq`.
    pub outputs: Vec<Envelope>,
    /// Per executed batch: where it ran and how long it took.
    pub batch_log: Vec<BatchLogRow>,
    /// Requests served per agent.
    pub per_agent_items: BTreeMap<String, usize>,
    /// Busy time per agent, seconds — the makespan input for multi-agent
    /// throughput (`items / max busy`).
    pub per_agent_busy_s: BTreeMap<String, f64>,
    /// Batches requeued after an executor death (each at most once).
    pub requeued_batches: usize,
    /// The failover record behind `requeued_batches`: `(batch index, id of
    /// the executor that failed it)` per requeue, in failure order. The
    /// server republishes these as `failover` spans in the serving trace.
    pub requeue_log: Vec<(u64, String)>,
    /// True when a [`DispatchWatch`] aborted the run early; `outputs` then
    /// covers only the batches that completed before the abort.
    pub aborted: bool,
}

impl DispatchOutcome {
    /// Makespan across the pool: the busiest agent's total busy time.
    pub fn makespan_s(&self) -> f64 {
        self.per_agent_busy_s.values().copied().fold(0.0, f64::max)
    }
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

struct QueuedBatch {
    batch: Batch,
    retried: bool,
}

struct DispatchState {
    queue: VecDeque<QueuedBatch>,
    outstanding_items: Vec<usize>,
    in_flight_batches: Vec<usize>,
    alive: Vec<bool>,
    /// Batches currently executing (any executor).
    busy: usize,
    outputs: Vec<Envelope>,
    log: Vec<BatchLogRow>,
    per_agent_items: BTreeMap<String, usize>,
    per_agent_busy_s: BTreeMap<String, f64>,
    /// Items handed out per tenant — the deficit counter behind
    /// [`DispatchPolicy::FairByTenant`].
    tenant_started: BTreeMap<u32, usize>,
    requeued: usize,
    requeue_log: Vec<(u64, String)>,
    fatal: Option<DispatchError>,
    aborted: bool,
}

/// Queue position the policy would serve next (`None` on an empty queue).
fn pick_queued(
    queue: &VecDeque<QueuedBatch>,
    tenant_started: &BTreeMap<u32, usize>,
    policy: DispatchPolicy,
) -> Option<usize> {
    match policy {
        DispatchPolicy::Fifo => {
            if queue.is_empty() {
                None
            } else {
                Some(0)
            }
        }
        DispatchPolicy::FairByTenant => (0..queue.len()).min_by_key(|&i| {
            let tenant = queue[i].batch.tenant;
            (tenant_started.get(&tenant).copied().unwrap_or(0), i)
        }),
    }
}

struct SharedDispatch {
    state: Mutex<DispatchState>,
    cv: Condvar,
}

/// Poison-safe lock: a poisoned mutex (some thread panicked while holding
/// it) still yields its guard via `into_inner`. The dispatch state is then
/// crash evidence rather than trusted accounting, so the second element
/// tells the caller to record a [`BatchError::Poisoned`] fatal — but every
/// worker can still wake up, observe it, and exit instead of propagating
/// the panic into its own `lock().unwrap()`.
fn lock_state(shared: &SharedDispatch) -> (std::sync::MutexGuard<'_, DispatchState>, bool) {
    match shared.state.lock() {
        Ok(g) => (g, false),
        Err(p) => (p.into_inner(), true),
    }
}

/// Record a machinery failure (first one wins) and wake everyone so the
/// remaining workers see it and drain out.
fn fail_dispatch(shared: &SharedDispatch, st: &mut DispatchState, kind: BatchError, msg: String) {
    if st.fatal.is_none() {
        st.fatal = Some(DispatchError::internal(kind, msg));
    }
    shared.cv.notify_all();
}

/// The load-balancing dispatcher: one worker per executor pulls batches off
/// a shared queue under the [`least_outstanding`] policy, choosing which
/// queued batch to serve with a [`DispatchPolicy`].
pub struct Dispatcher {
    executors: Vec<Arc<dyn BatchExecutor>>,
    max_in_flight: usize,
    policy: DispatchPolicy,
    gauges: Option<Arc<crate::dash::FleetGauges>>,
}

impl Dispatcher {
    pub fn new(executors: Vec<Arc<dyn BatchExecutor>>) -> Dispatcher {
        Dispatcher { executors, max_in_flight: 1, policy: DispatchPolicy::Fifo, gauges: None }
    }

    /// Mirror per-agent outstanding/in-flight counts into shared dashboard
    /// gauges ([`crate::dash::FleetGauges`]) as batches start and finish.
    pub fn with_gauges(mut self, gauges: Arc<crate::dash::FleetGauges>) -> Dispatcher {
        self.gauges = Some(gauges);
        self
    }

    /// Allow up to `n` concurrent batches per executor (default 1, which
    /// serializes each executor and keeps simulated-clock latency
    /// measurements clean).
    pub fn with_max_in_flight(mut self, n: usize) -> Dispatcher {
        self.max_in_flight = n.max(1);
        self
    }

    /// Choose the queue-service policy (default FIFO).
    pub fn with_policy(mut self, policy: DispatchPolicy) -> Dispatcher {
        self.policy = policy;
        self
    }

    pub fn agent_ids(&self) -> Vec<String> {
        self.executors.iter().map(|e| e.id()).collect()
    }

    /// Run every batch to completion across the pool.
    pub fn dispatch(&self, batches: Vec<Batch>) -> Result<DispatchOutcome, DispatchError> {
        self.dispatch_watched(batches, None)
    }

    /// As [`Dispatcher::dispatch`], with an optional [`DispatchWatch`] that
    /// can abort the run after any completed batch.
    pub fn dispatch_watched(
        &self,
        batches: Vec<Batch>,
        watch: Option<Arc<dyn DispatchWatch>>,
    ) -> Result<DispatchOutcome, DispatchError> {
        if self.executors.is_empty() {
            return Err(DispatchError::agent_failure("-", "no executors in the dispatch pool"));
        }
        let expected: usize = batches.iter().map(Batch::len).sum();
        if expected == 0 {
            return Ok(DispatchOutcome::default());
        }
        let n = self.executors.len();
        let shared = Arc::new(SharedDispatch {
            state: Mutex::new(DispatchState {
                queue: batches.into_iter().map(|b| QueuedBatch { batch: b, retried: false }).collect(),
                outstanding_items: vec![0; n],
                in_flight_batches: vec![0; n],
                alive: vec![true; n],
                busy: 0,
                outputs: Vec::with_capacity(expected),
                log: Vec::new(),
                per_agent_items: BTreeMap::new(),
                per_agent_busy_s: BTreeMap::new(),
                tenant_started: BTreeMap::new(),
                requeued: 0,
                requeue_log: Vec::new(),
                fatal: None,
                aborted: false,
            }),
            cv: Condvar::new(),
        });

        let workers: Vec<_> = (0..n)
            .map(|_| {
                let shared = shared.clone();
                let executors = self.executors.clone();
                let max_in_flight = self.max_in_flight;
                let policy = self.policy;
                let watch = watch.clone();
                let gauges = self.gauges.clone();
                std::thread::spawn(move || loop {
                    let (qb, idx) = {
                        let (mut st, poisoned) = lock_state(&shared);
                        if poisoned {
                            fail_dispatch(
                                &shared,
                                &mut st,
                                BatchError::Poisoned,
                                "dispatch state lock poisoned".into(),
                            );
                            return;
                        }
                        loop {
                            if st.fatal.is_some() || st.aborted {
                                shared.cv.notify_all();
                                return;
                            }
                            if st.queue.is_empty() {
                                if st.busy == 0 {
                                    shared.cv.notify_all();
                                    return;
                                }
                                st = match shared.cv.wait(st) {
                                    Ok(g) => g,
                                    Err(p) => {
                                        let mut st = p.into_inner();
                                        fail_dispatch(
                                            &shared,
                                            &mut st,
                                            BatchError::Poisoned,
                                            "dispatch condvar wait found a poisoned lock".into(),
                                        );
                                        return;
                                    }
                                };
                                continue;
                            }
                            if !st.alive.iter().any(|a| *a) {
                                st.fatal = Some(DispatchError::agent_failure(
                                    "-",
                                    "no surviving agents for queued batches",
                                ));
                                shared.cv.notify_all();
                                return;
                            }
                            if let Some(i) = least_outstanding(
                                &st.alive,
                                &st.outstanding_items,
                                &st.in_flight_batches,
                                max_in_flight,
                            ) {
                                // The queue was non-empty above, so the
                                // policy must name a position; if it ever
                                // doesn't, surface a typed invariant error
                                // instead of `expect`-panicking while the
                                // other workers sleep on the condvar.
                                let picked = pick_queued(&st.queue, &st.tenant_started, policy)
                                    .and_then(|pos| st.queue.remove(pos));
                                let Some(qb) = picked else {
                                    fail_dispatch(
                                        &shared,
                                        &mut st,
                                        BatchError::QueueInvariant,
                                        format!(
                                            "policy {policy:?} picked no batch from a queue of {}",
                                            st.queue.len()
                                        ),
                                    );
                                    return;
                                };
                                st.outstanding_items[i] += qb.batch.len();
                                st.in_flight_batches[i] += 1;
                                st.busy += 1;
                                // Deficit charge exactly once per batch: a
                                // requeued batch was already charged on its
                                // first dequeue — recharging would penalize
                                // the tenant that suffered the agent death.
                                if !qb.retried {
                                    *st.tenant_started.entry(qb.batch.tenant).or_insert(0) +=
                                        qb.batch.len();
                                }
                                break (qb, i);
                            }
                            // Every live executor is at capacity.
                            st = match shared.cv.wait(st) {
                                Ok(g) => g,
                                Err(p) => {
                                    let mut st = p.into_inner();
                                    fail_dispatch(
                                        &shared,
                                        &mut st,
                                        BatchError::Poisoned,
                                        "dispatch condvar wait found a poisoned lock".into(),
                                    );
                                    return;
                                }
                            };
                        }
                    };
                    if let Some(g) = &gauges {
                        g.batch_started(&executors[idx].id(), qb.batch.len());
                    }
                    // A panic inside an executor must behave like an agent
                    // death (mark dead + requeue), not leave the busy
                    // counters stuck and hang every other worker in wait().
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        executors[idx].execute(&qb.batch)
                    }))
                    .unwrap_or_else(|p| Err(format!("executor panicked: {}", panic_text(&p))));
                    let agent = executors[idx].id();
                    if let Some(g) = &gauges {
                        g.batch_finished(&agent, qb.batch.len());
                        if matches!(&result, Ok(r) if r.outputs.len() == qb.batch.len()) {
                            g.batch_completed(qb.batch.len());
                        }
                    }
                    let (mut st, poisoned) = lock_state(&shared);
                    if poisoned {
                        fail_dispatch(
                            &shared,
                            &mut st,
                            BatchError::Poisoned,
                            "dispatch state lock poisoned".into(),
                        );
                        return;
                    }
                    st.outstanding_items[idx] -= qb.batch.len();
                    st.in_flight_batches[idx] -= 1;
                    st.busy -= 1;
                    match result {
                        Ok(r) if r.outputs.len() == qb.batch.len() => {
                            *st.per_agent_items.entry(agent.clone()).or_insert(0) +=
                                r.outputs.len();
                            *st.per_agent_busy_s.entry(agent.clone()).or_insert(0.0) +=
                                r.latency_s;
                            let row = BatchLogRow {
                                index: qb.batch.index,
                                occupancy: r.outputs.len(),
                                latency_s: r.latency_s,
                                agent,
                            };
                            // The watch runs under the state lock, so a
                            // watch that panics would poison it and strand
                            // every worker sleeping on the condvar. Catch
                            // the panic, convert it to a typed fatal, and
                            // let the notify below wake the pool.
                            if let Some(w) = &watch {
                                let verdict = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| w.on_batch(&row)),
                                );
                                match verdict {
                                    Ok(true) => {}
                                    Ok(false) => st.aborted = true,
                                    Err(p) => {
                                        if st.fatal.is_none() {
                                            st.fatal = Some(DispatchError::internal(
                                                BatchError::Poisoned,
                                                format!(
                                                    "dispatch watch panicked: {}",
                                                    panic_text(&p)
                                                ),
                                            ));
                                        }
                                    }
                                }
                            }
                            st.log.push(row);
                            st.outputs.extend(r.outputs);
                        }
                        Ok(r) => {
                            st.fatal = Some(DispatchError::agent_failure(
                                agent,
                                format!(
                                    "batch {} returned {} outputs for {} requests",
                                    qb.batch.index,
                                    r.outputs.len(),
                                    qb.batch.len()
                                ),
                            ));
                        }
                        Err(msg) => {
                            st.alive[idx] = false;
                            if qb.retried {
                                st.fatal = Some(DispatchError::agent_failure(
                                    agent,
                                    format!(
                                        "batch {} failed after one requeue: {msg}",
                                        qb.batch.index
                                    ),
                                ));
                            } else {
                                st.requeued += 1;
                                st.requeue_log.push((qb.batch.index, agent));
                                st.queue.push_back(QueuedBatch { batch: qb.batch, retried: true });
                            }
                        }
                    }
                    drop(st);
                    shared.cv.notify_all();
                })
            })
            .collect();
        // A worker that died from an uncaught panic (outside the executor
        // `catch_unwind`) is machinery failure, not agent failure: note it
        // and keep joining the rest instead of re-panicking here.
        let mut dead_workers = 0usize;
        for w in workers {
            if w.join().is_err() {
                dead_workers += 1;
            }
        }

        let (mut st, poisoned) = lock_state(&shared);
        if let Some(e) = st.fatal.take() {
            return Err(e);
        }
        if poisoned || dead_workers > 0 {
            return Err(DispatchError::internal(
                BatchError::Poisoned,
                format!(
                    "dispatch machinery compromised: {dead_workers} dead workers, lock poisoned: {poisoned}"
                ),
            ));
        }
        let mut outputs = std::mem::take(&mut st.outputs);
        outputs.sort_by_key(|e| e.seq);
        if !st.aborted && outputs.len() != expected {
            return Err(DispatchError::internal(
                BatchError::QueueInvariant,
                format!("lost requests: {} of {expected} completed", outputs.len()),
            ));
        }
        Ok(DispatchOutcome {
            outputs,
            batch_log: std::mem::take(&mut st.log),
            per_agent_items: std::mem::take(&mut st.per_agent_items),
            per_agent_busy_s: std::mem::take(&mut st.per_agent_busy_s),
            requeued_batches: st.requeued,
            requeue_log: std::mem::take(&mut st.requeue_log),
            aborted: st.aborted,
        })
    }
}

/// One request completed by the [`QueueSim`] replay.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub seq: u64,
    pub tenant: u32,
    /// Queueing-aware end-to-end latency: batching delay + wait for a free
    /// agent + the batch's own service time.
    pub latency_s: f64,
}

struct SimBatch {
    formed_at: f64,
    tenant: u32,
    /// `(seq, arrival)` per coalesced request.
    items: Vec<(u64, f64)>,
}

/// One batch as the virtual-time replay scheduled it — the queueing facts
/// ([`crate::server::Server::evaluate_batched`] turns these into
/// `queue_wait`/`batch_service` spans so bottleneck attribution covers the
/// serving stack).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduledBatch {
    /// Plan index of the batch.
    pub index: u64,
    /// When the batch became available (its formed time), virtual seconds.
    pub formed_at: f64,
    /// When a server started it (`≥ formed_at`; the gap is queueing).
    pub start: f64,
    pub completion: f64,
    /// Virtual server slot the replay ran it on (deterministic — not the
    /// OS thread the real dispatcher happened to use).
    pub server: usize,
}

/// Deterministic virtual-time queueing replay of a batch plan over `n`
/// servers.
///
/// The real [`Dispatcher`] runs on OS threads, so *when* a batch actually
/// executed depends on thread scheduling — fine for outputs, useless for
/// latency accounting. `QueueSim` recomputes the schedule analytically:
/// batches become available at their formed time, each starts on the
/// earliest-free server (never before it formed), and completes after its
/// observed service time. Per-request latency is `completion - arrival`,
/// which — unlike the naive `queue delay + service` sum — includes the time
/// spent waiting for a free agent. That makes latency grow with offered
/// load, which is exactly what the SLO search ([`crate::slo`]) probes for.
///
/// Service times are fed in as the real dispatch observes them
/// ([`QueueSim::offer`]); the replay advances as far as its
/// [`DispatchPolicy`] order allows and returns newly completed requests.
/// The resulting schedule is a pure function of `(plan, services, servers,
/// policy)` — independent of the order services are offered in.
pub struct QueueSim {
    meta: Vec<SimBatch>,
    service: Vec<Option<f64>>,
    started: Vec<bool>,
    n_started: usize,
    /// Free-at virtual times, one per server.
    servers: Vec<f64>,
    /// Parallel to `servers`: retired servers keep their slot (and their
    /// in-progress work's free-at time) but take no new batches. This is
    /// what lets the autoscaler ([`crate::autoscale`]) grow and shrink the
    /// virtual fleet mid-replay without renumbering the schedule log.
    active: Vec<bool>,
    policy: DispatchPolicy,
    tenant_started: BTreeMap<u32, usize>,
    /// Start/completion facts, in schedule order.
    schedule: Vec<ScheduledBatch>,
    /// Batches dropped by admission control before they ever started.
    shed_count: usize,
}

impl QueueSim {
    /// Build a replay for a batch plan (`plan_batches` output: indices are
    /// positions, formed times non-decreasing) on `servers` agents.
    pub fn new(batches: &[Batch], servers: usize, policy: DispatchPolicy) -> QueueSim {
        let meta: Vec<SimBatch> = batches
            .iter()
            .map(|b| SimBatch {
                formed_at: b.formed_at_secs,
                tenant: b.tenant,
                items: b
                    .envelopes
                    .iter()
                    .zip(&b.arrivals)
                    .map(|(e, a)| (e.seq, *a))
                    .collect(),
            })
            .collect();
        QueueSim {
            service: vec![None; meta.len()],
            started: vec![false; meta.len()],
            n_started: 0,
            servers: vec![0.0; servers.max(1)],
            active: vec![true; servers.max(1)],
            policy,
            tenant_started: BTreeMap::new(),
            schedule: Vec::with_capacity(meta.len()),
            meta,
            shed_count: 0,
        }
    }

    /// Servers currently accepting new batches.
    pub fn active_servers(&self) -> usize {
        self.active.iter().filter(|a| **a).count()
    }

    /// Batches dropped via [`QueueSim::shed`] so far.
    pub fn shed_batches(&self) -> usize {
        self.shed_count
    }

    /// Earliest virtual time an active server frees up — the queueing
    /// component of a new batch's predicted start.
    pub fn min_active_free_at(&self) -> f64 {
        self.servers
            .iter()
            .zip(&self.active)
            .filter(|(_, a)| **a)
            .map(|(t, _)| *t)
            .fold(f64::INFINITY, f64::min)
    }

    /// Predicted start time for an unstarted batch offered next: it can't
    /// start before it formed nor before an active server frees up. `None`
    /// for unknown or already-started indices.
    pub fn predicted_start(&self, index: u64) -> Option<f64> {
        let i = index as usize;
        if i >= self.meta.len() || self.started[i] {
            return None;
        }
        Some(self.meta[i].formed_at.max(self.min_active_free_at()))
    }

    /// Grow the virtual fleet by one server that becomes usable at
    /// `free_at` (spawn/model-load delay modeled as initial busy time).
    pub fn add_server(&mut self, free_at: f64) {
        self.servers.push(free_at.max(0.0));
        self.active.push(true);
    }

    /// Retire one server: the active server with the latest free-at time
    /// stops taking new batches (its in-progress batch still completes).
    /// Refuses to retire the last active server — a fleet of zero would
    /// stall the replay forever.
    pub fn retire_server(&mut self) -> bool {
        if self.active_servers() <= 1 {
            return false;
        }
        let victim = self
            .servers
            .iter()
            .enumerate()
            .filter(|(i, _)| self.active[*i])
            .max_by(|a, b| a.1.total_cmp(b.1).then(a.0.cmp(&b.0)))
            .map(|(i, _)| i);
        match victim {
            Some(i) => {
                self.active[i] = false;
                true
            }
            None => false,
        }
    }

    /// Drop batch `index` before it starts (admission control / deadline
    /// shedding): it is marked handled without ever occupying a server, its
    /// requests never complete, and the replay advances past it. Returns
    /// requests that became schedulable as a result. No-op for unknown or
    /// already-started indices.
    pub fn shed(&mut self, index: u64) -> Vec<CompletedRequest> {
        let i = index as usize;
        if i >= self.meta.len() || self.started[i] {
            return Vec::new();
        }
        self.started[i] = true;
        self.n_started += 1;
        self.shed_count += 1;
        // A shed batch reports no service time; clear any stale offer so
        // `drain` never schedules it.
        self.service[i] = None;
        self.drain()
    }

    /// All planned batches have been scheduled.
    pub fn is_complete(&self) -> bool {
        self.n_started == self.meta.len()
    }

    /// The batches scheduled so far (all of them once [`QueueSim::is_complete`]),
    /// in the order the replay started them.
    pub fn schedule_log(&self) -> &[ScheduledBatch] {
        &self.schedule
    }

    /// Feed the observed service time for batch `index` and advance the
    /// replay as far as possible, returning requests that just completed.
    pub fn offer(&mut self, index: u64, service_s: f64) -> Vec<CompletedRequest> {
        if let Some(slot) = self.service.get_mut(index as usize) {
            *slot = Some(service_s.max(0.0));
        }
        self.drain()
    }

    fn drain(&mut self) -> Vec<CompletedRequest> {
        let mut done = Vec::new();
        loop {
            let Some(next) = self.pick_next() else { break };
            // The policy's next batch hasn't reported its service time yet:
            // stall (later `offer`s resume from here). Scheduling order
            // never depends on which services are known, so stalling keeps
            // the replay deterministic.
            let Some(service) = self.service[next] else { break };
            // `total_cmp` keeps the earliest-free pick panic-free when an
            // executor reported a NaN service time (the NaN server orders
            // last, so it is simply never picked again).
            let Some((si, free_at)) = self
                .servers
                .iter()
                .enumerate()
                .filter(|(i, _)| self.active[*i])
                .min_by(|a, b| a.1.total_cmp(b.1).then(a.0.cmp(&b.0)))
                .map(|(i, t)| (i, *t))
            else {
                break;
            };
            let start = free_at.max(self.meta[next].formed_at);
            let completion = start + service;
            self.servers[si] = completion;
            self.started[next] = true;
            self.n_started += 1;
            self.schedule.push(ScheduledBatch {
                index: next as u64,
                formed_at: self.meta[next].formed_at,
                start,
                completion,
                server: si,
            });
            *self.tenant_started.entry(self.meta[next].tenant).or_insert(0) +=
                self.meta[next].items.len();
            for (seq, arrival) in &self.meta[next].items {
                done.push(CompletedRequest {
                    seq: *seq,
                    tenant: self.meta[next].tenant,
                    latency_s: (completion - arrival).max(0.0),
                });
            }
        }
        done
    }

    /// The batch the policy would start next. Candidates are unstarted
    /// batches already formed by the earliest server-free time; if none has
    /// formed yet the server idles until the earliest-formed one.
    fn pick_next(&self) -> Option<usize> {
        let free_at = self.min_active_free_at();
        let mut first_unstarted = None;
        let mut best: Option<usize> = None;
        for i in 0..self.meta.len() {
            if self.started[i] {
                continue;
            }
            if first_unstarted.is_none() {
                first_unstarted = Some(i);
            }
            if self.meta[i].formed_at <= free_at {
                match self.policy {
                    // Plan indices are formed-time-ordered, so the first
                    // arrived unstarted batch is the FIFO pick.
                    DispatchPolicy::Fifo => {
                        best = Some(i);
                        break;
                    }
                    DispatchPolicy::FairByTenant => {
                        let credit = |j: usize| {
                            self.tenant_started
                                .get(&self.meta[j].tenant)
                                .copied()
                                .unwrap_or(0)
                        };
                        best = match best {
                            Some(b) if credit(b) <= credit(i) => Some(b),
                            _ => Some(i),
                        };
                    }
                }
            } else if self.policy == DispatchPolicy::Fifo {
                // Formed-time-ordered: nothing later has arrived either.
                break;
            }
        }
        best.or(first_unstarted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::Payload;
    use crate::scenario::Scenario;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn byte_envelope(r: &Request) -> Envelope {
        Envelope {
            seq: r.id,
            trace_id: 0,
            parent_span: None,
            payload: Payload::Bytes(vec![r.id as u8]),
        }
    }

    /// Deterministic per-item transform + fixed per-item cost.
    struct EchoExec {
        name: String,
        calls: AtomicUsize,
    }

    impl EchoExec {
        fn new(name: &str) -> Arc<EchoExec> {
            Arc::new(EchoExec { name: name.into(), calls: AtomicUsize::new(0) })
        }
    }

    impl BatchExecutor for EchoExec {
        fn id(&self) -> String {
            self.name.clone()
        }

        fn execute(&self, batch: &Batch) -> Result<BatchResult, String> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            let outputs = batch
                .envelopes
                .iter()
                .map(|e| Envelope {
                    payload: match &e.payload {
                        Payload::Bytes(b) => Payload::Bytes(vec![b[0].wrapping_add(1)]),
                        other => other.clone(),
                    },
                    ..e.clone()
                })
                .collect();
            Ok(BatchResult { outputs, latency_s: 1e-4 * batch.len() as f64 })
        }
    }

    /// Dies on every call — the injected agent failure.
    struct DeadExec;

    impl BatchExecutor for DeadExec {
        fn id(&self) -> String {
            "dead".into()
        }

        fn execute(&self, _batch: &Batch) -> Result<BatchResult, String> {
            Err("CUDA_ERROR_OUT_OF_MEMORY (injected)".into())
        }
    }

    #[test]
    fn size_triggered_batches_fill_to_capacity() {
        let w = Workload::generate(&Scenario::Online { count: 20 }, 1);
        let cfg = BatcherConfig::new(8, 5.0);
        let batches = plan_batches(&w, &cfg, byte_envelope);
        let occ: Vec<usize> = batches.iter().map(Batch::len).collect();
        assert_eq!(occ, vec![8, 8, 4]);
        assert!(batches.iter().all(|b| b.formed_at_secs == 0.0));
        assert!(batches
            .iter()
            .all(|b| b.queue_delays_secs().iter().all(|d| *d == 0.0)));
    }

    #[test]
    fn deadline_bounds_queue_delay() {
        let w = Workload::generate(&Scenario::Poisson { rate: 400.0, count: 300 }, 7);
        let cfg = BatcherConfig::new(16, 10.0);
        let batches = plan_batches(&w, &cfg, byte_envelope);
        let total: usize = batches.iter().map(Batch::len).sum();
        assert_eq!(total, 300, "no request lost or duplicated in planning");
        for b in &batches {
            assert!(b.len() <= 16);
            for d in b.queue_delays_secs() {
                assert!((0.0..=0.010 + 1e-9).contains(&d), "delay {d}");
            }
        }
        // High offered load at a 10ms window → real coalescing happens.
        let mean_occ = total as f64 / batches.len() as f64;
        assert!(mean_occ > 2.0, "mean occupancy {mean_occ}");
        // Planning is deterministic (server and agent agree on boundaries).
        let again = plan_batches(&w, &cfg, byte_envelope);
        assert_eq!(batches.len(), again.len());
        for (a, b) in batches.iter().zip(&again) {
            assert_eq!(a.formed_at_secs, b.formed_at_secs);
            assert_eq!(a.len(), b.len());
        }
    }

    #[test]
    fn per_request_config_never_coalesces() {
        let w = Workload::generate(&Scenario::Poisson { rate: 10_000.0, count: 64 }, 3);
        let batches = plan_batches(&w, &BatcherConfig::per_request(), byte_envelope);
        assert_eq!(batches.len(), 64);
        assert!(batches.iter().all(|b| b.len() == 1));
    }

    #[test]
    fn least_outstanding_policy() {
        // Fewest outstanding wins; ties go to the lowest index.
        assert_eq!(
            least_outstanding(&[true, true, true], &[4, 2, 2], &[0, 0, 0], 1),
            Some(1)
        );
        // Dead executors are skipped even at zero load.
        assert_eq!(
            least_outstanding(&[false, true], &[0, 9], &[0, 0], 1),
            Some(1)
        );
        // At capacity → not eligible.
        assert_eq!(least_outstanding(&[true, true], &[0, 5], &[1, 0], 1), Some(1));
        // Nobody eligible.
        assert_eq!(least_outstanding(&[true], &[0], &[1], 1), None);
        assert_eq!(least_outstanding(&[false], &[0], &[0], 1), None);
    }

    #[test]
    fn dispatch_preserves_identity_and_order() {
        let w = Workload::generate(&Scenario::Poisson { rate: 500.0, count: 120 }, 9);
        let cfg = BatcherConfig::new(8, 8.0);
        let batches = plan_batches(&w, &cfg, byte_envelope);
        let pool: Vec<Arc<dyn BatchExecutor>> =
            vec![EchoExec::new("a"), EchoExec::new("b"), EchoExec::new("c")];
        let outcome = Dispatcher::new(pool).dispatch(batches).unwrap();
        assert_eq!(outcome.outputs.len(), 120);
        for (i, env) in outcome.outputs.iter().enumerate() {
            assert_eq!(env.seq, i as u64, "outputs restored to request order");
            match &env.payload {
                Payload::Bytes(b) => assert_eq!(b[0], (i as u8).wrapping_add(1)),
                other => panic!("unexpected payload {other:?}"),
            }
        }
        assert_eq!(outcome.requeued_batches, 0);
        let served: usize = outcome.per_agent_items.values().sum();
        assert_eq!(served, 120);
        assert!(outcome.makespan_s() > 0.0);
    }

    #[test]
    fn dead_executor_requeues_exactly_once() {
        let w = Workload::generate(&Scenario::Online { count: 48 }, 1);
        let cfg = BatcherConfig::new(8, 0.0);
        let batches = plan_batches(&w, &cfg, byte_envelope);
        assert_eq!(batches.len(), 6);
        let pool: Vec<Arc<dyn BatchExecutor>> =
            vec![Arc::new(DeadExec), EchoExec::new("s1"), EchoExec::new("s2")];
        let outcome = Dispatcher::new(pool).dispatch(batches).unwrap();
        // Every request completed exactly once despite the mid-run death.
        assert_eq!(outcome.outputs.len(), 48);
        let seqs: std::collections::HashSet<u64> =
            outcome.outputs.iter().map(|e| e.seq).collect();
        assert_eq!(seqs.len(), 48, "no duplicates from the requeue");
        // The dead agent appears in no accounting; its one batch requeued.
        assert!(!outcome.per_agent_items.contains_key("dead"));
        assert_eq!(outcome.requeued_batches, 1);
        assert!(outcome.batch_log.iter().all(|r| r.agent != "dead"));
    }

    /// Panics in an executor must not hang the dispatch: they convert to
    /// the dead-executor path (requeue once, survivors finish the queue).
    struct PanicExec;

    impl BatchExecutor for PanicExec {
        fn id(&self) -> String {
            "panicky".into()
        }

        fn execute(&self, _batch: &Batch) -> Result<BatchResult, String> {
            panic!("index out of bounds (injected)");
        }
    }

    #[test]
    fn panicking_executor_is_treated_as_dead_not_a_hang() {
        let w = Workload::generate(&Scenario::Online { count: 32 }, 1);
        let cfg = BatcherConfig::new(8, 0.0);
        let batches = plan_batches(&w, &cfg, byte_envelope);
        let pool: Vec<Arc<dyn BatchExecutor>> =
            vec![Arc::new(PanicExec), EchoExec::new("survivor")];
        let outcome = Dispatcher::new(pool).dispatch(batches).unwrap();
        assert_eq!(outcome.outputs.len(), 32);
        assert_eq!(outcome.requeued_batches, 1);
        assert!(!outcome.per_agent_items.contains_key("panicky"));
    }

    #[test]
    fn all_executors_dead_is_a_typed_error() {
        let w = Workload::generate(&Scenario::Online { count: 8 }, 1);
        let batches = plan_batches(&w, &BatcherConfig::default(), byte_envelope);
        let pool: Vec<Arc<dyn BatchExecutor>> = vec![Arc::new(DeadExec)];
        let err = Dispatcher::new(pool).dispatch(batches).unwrap_err();
        assert!(err.msg.contains("injected") || err.msg.contains("surviving"), "{err}");
    }

    #[test]
    fn empty_pool_rejected() {
        let w = Workload::generate(&Scenario::Online { count: 2 }, 1);
        let batches = plan_batches(&w, &BatcherConfig::default(), byte_envelope);
        let err = Dispatcher::new(Vec::new()).dispatch(batches).unwrap_err();
        assert!(err.msg.contains("no executors"));
    }

    #[test]
    fn plan_never_mixes_tenants() {
        let s = Scenario::Mix {
            tenants: vec![
                ("a".into(), Scenario::FixedQps { qps: 1000.0, count: 20 }),
                ("b".into(), Scenario::FixedQps { qps: 1000.0, count: 20 }),
            ],
        };
        let w = Workload::generate(&s, 3);
        let cfg = BatcherConfig::new(8, 5.0);
        let batches = plan_batches(&w, &cfg, byte_envelope);
        let total: usize = batches.iter().map(Batch::len).sum();
        assert_eq!(total, 40);
        // Indices are sequential and formed times non-decreasing.
        for (i, b) in batches.iter().enumerate() {
            assert_eq!(b.index, i as u64);
        }
        for pair in batches.windows(2) {
            assert!(pair[1].formed_at_secs >= pair[0].formed_at_secs);
        }
        // Every batch is single-tenant: its envelopes' seqs map back to
        // requests of exactly one tenant.
        let tenant_of: std::collections::HashMap<u64, u32> =
            w.requests.iter().map(|r| (r.id, r.tenant)).collect();
        for b in &batches {
            let tenants: std::collections::HashSet<u32> =
                b.envelopes.iter().map(|e| tenant_of[&e.seq]).collect();
            assert_eq!(tenants.len(), 1);
            assert!(tenants.contains(&b.tenant));
        }
        assert!(batches.iter().any(|b| b.tenant == 0));
        assert!(batches.iter().any(|b| b.tenant == 1));
    }

    fn mk_batch(index: u64, formed_at: f64, tenant: u32, seqs: &[u64]) -> Batch {
        Batch {
            index,
            opened_at_secs: formed_at,
            formed_at_secs: formed_at,
            envelopes: seqs
                .iter()
                .map(|s| Envelope {
                    seq: *s,
                    trace_id: 0,
                    parent_span: None,
                    payload: Payload::Bytes(vec![*s as u8]),
                })
                .collect(),
            arrivals: vec![formed_at; seqs.len()],
            tenant,
        }
    }

    #[test]
    fn queue_sim_models_server_contention() {
        // 3 single-request batches formed at t=0, one server, 1s service
        // each: completions at 1, 2, 3 → latencies 1, 2, 3.
        let batches =
            vec![mk_batch(0, 0.0, 0, &[0]), mk_batch(1, 0.0, 0, &[1]), mk_batch(2, 0.0, 0, &[2])];
        let mut sim = QueueSim::new(&batches, 1, DispatchPolicy::Fifo);
        // Offer out of order: the replay stalls until batch 0 reports.
        assert!(sim.offer(2, 1.0).is_empty());
        assert!(sim.offer(1, 1.0).is_empty());
        let done = sim.offer(0, 1.0);
        assert!(sim.is_complete());
        // The schedule log records the queueing facts: starts back-to-back
        // on the single server, completion = start + service.
        let sched = sim.schedule_log();
        assert_eq!(sched.len(), 3);
        for (i, s) in sched.iter().enumerate() {
            assert_eq!(s.index, i as u64);
            assert_eq!(s.server, 0);
            assert!((s.start - i as f64).abs() < 1e-9, "{s:?}");
            assert!((s.completion - s.start - 1.0).abs() < 1e-9);
            assert!(s.start >= s.formed_at);
        }
        let mut lat: Vec<(u64, f64)> = done.iter().map(|c| (c.seq, c.latency_s)).collect();
        lat.sort_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(lat.len(), 3);
        assert!((lat[0].1 - 1.0).abs() < 1e-9);
        assert!((lat[1].1 - 2.0).abs() < 1e-9);
        assert!((lat[2].1 - 3.0).abs() < 1e-9);
        // Two servers halve the backlog: latencies 1, 1, 2.
        let mut sim2 = QueueSim::new(&batches, 2, DispatchPolicy::Fifo);
        let mut done2 = Vec::new();
        for i in 0..3 {
            done2.extend(sim2.offer(i, 1.0));
        }
        let mut lat2: Vec<f64> = done2.iter().map(|c| c.latency_s).collect();
        lat2.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(lat2, vec![1.0, 1.0, 2.0]);
    }

    #[test]
    fn fair_policy_interleaves_a_burst() {
        // Tenant 1 bursts 4 batches at t=0; tenant 0 has one batch formed
        // at t=0 too (last in plan order). One server, 1s service.
        let batches = vec![
            mk_batch(0, 0.0, 1, &[0]),
            mk_batch(1, 0.0, 1, &[1]),
            mk_batch(2, 0.0, 1, &[2]),
            mk_batch(3, 0.0, 1, &[3]),
            mk_batch(4, 0.0, 0, &[4]),
        ];
        let run = |policy: DispatchPolicy| {
            let mut sim = QueueSim::new(&batches, 1, policy);
            let mut done = Vec::new();
            for i in 0..5 {
                done.extend(sim.offer(i, 1.0));
            }
            done.iter().find(|c| c.seq == 4).unwrap().latency_s
        };
        // FIFO: the steady tenant waits behind the whole burst.
        assert!((run(DispatchPolicy::Fifo) - 5.0).abs() < 1e-9);
        // Fair: after one burst batch, tenant 0 (credit 0) goes next.
        assert!((run(DispatchPolicy::FairByTenant) - 2.0).abs() < 1e-9);
    }

    /// Aborts the dispatch on the first completed batch.
    struct AbortImmediately;

    impl DispatchWatch for AbortImmediately {
        fn on_batch(&self, _row: &BatchLogRow) -> bool {
            false
        }
    }

    #[test]
    fn watch_abort_stops_dispatch_early() {
        let w = Workload::generate(&Scenario::Online { count: 64 }, 1);
        let cfg = BatcherConfig::new(8, 0.0);
        let batches = plan_batches(&w, &cfg, byte_envelope);
        assert_eq!(batches.len(), 8);
        let pool: Vec<Arc<dyn BatchExecutor>> = vec![EchoExec::new("only")];
        let watch: Arc<dyn DispatchWatch> = Arc::new(AbortImmediately);
        let outcome =
            Dispatcher::new(pool).dispatch_watched(batches, Some(watch)).unwrap();
        assert!(outcome.aborted);
        // The aborting batch's outputs are kept; queued ones never ran.
        assert!(outcome.outputs.len() < 64, "{} outputs", outcome.outputs.len());
        assert!(!outcome.outputs.is_empty());
    }

    #[test]
    fn series_summarizes_occupancy_and_delay() {
        let w = Workload::generate(&Scenario::Online { count: 20 }, 1);
        let cfg = BatcherConfig::new(8, 5.0);
        let batches = plan_batches(&w, &cfg, byte_envelope);
        let series = batching_series(&batches, &cfg);
        assert_eq!(series.batches(), 3);
        assert_eq!(series.queue_delay_s.len(), 20);
        assert!((series.mean_occupancy() - 20.0 / 3.0).abs() < 1e-9);
        assert!(series.fill_ratio() > 0.8);
    }
}
